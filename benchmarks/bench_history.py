"""Benchmark-trajectory recorder + regression gate over the smoke suite.

The paper's claims are measurements; a growing reproduction needs its
measurements to only move FORWARD. This tool runs the serving and
runtime smoke suites, folds their headline metrics (plus the live
sampler's steady-state rates) into schema-versioned JSON baselines at
the repo root — ``BENCH_serve.json`` / ``BENCH_runtime.json`` — and
compares fresh runs against them, failing CI when a *gated* metric
regresses beyond its tolerance.

Two metric classes per baseline:

  * gated         — deterministic quantities (decode-step ratios,
                    equal-memory occupancy ratios, zipf cache hit rate,
                    dispatch compile counts): seed-fixed, scheduler-
                    determined numbers a code change can silently
                    regress. ``tolerance`` is the allowed relative slack
                    in the bad ``direction``.
  * informational — wall-clock quantities (tokens/sec, tracer overhead,
                    sampler rates): recorded so the trajectory is
                    visible in git history, never gated (``tolerance``
                    is null — CI machines are not comparable clocks).

Baselines RATCHET: ``--write`` keeps the better of {old, new} per gated
metric (the recorded trajectory never loosens by accident); an
intentional trade-off is recorded with ``--write --reset``, which
replaces the file wholesale.

    PYTHONPATH=src python -m benchmarks.bench_history \
        --smoke --check [--write [--reset]] [--suite serve|runtime|all]
        [--trace /tmp/serve_trace.json] [--out DIR]

Exit status: 1 when ``--check`` finds a regression (or a baseline is
missing), else 0.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional

from repro.obs import Sampler, set_sampler

SCHEMA_VERSION = 1

#: default baseline location: the repo root (committed next to the code
#: whose trajectory they record)
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# row parsing (the benchmarks.common.emit contract: "name,us,derived")
# ---------------------------------------------------------------------------

def parse_rows(rows: List[str]) -> Dict[str, Dict[str, Any]]:
    """``name -> {"us": float, <derived k=v pairs...>}``. The derived
    field is a comma-joined ``k=v`` list for every row this tool reads;
    non-numeric values survive as strings, bare (non k=v) derived
    fields land under ``"derived"``."""
    out: Dict[str, Dict[str, Any]] = {}
    for row in rows:
        parts = str(row).split(",")
        if len(parts) < 2:
            continue
        d: Dict[str, Any] = {"us": float(parts[1])}
        for part in parts[2:]:
            if "=" in part:
                k, v = part.split("=", 1)
                try:
                    d[k] = float(v)
                except ValueError:
                    d[k] = v
            elif part:
                d["derived"] = part
        out[parts[0]] = d
    return out


def _metric(value, direction: str, tolerance: Optional[float]):
    return {"value": round(float(value), 6), "direction": direction,
            "tolerance": tolerance}


# ---------------------------------------------------------------------------
# suites
# ---------------------------------------------------------------------------

def _steady_rates(smp: Sampler, keys) -> Dict[str, Any]:
    """Informational sampler-derived steady-state rates (per second,
    warmup sample skipped)."""
    out = {}
    for key in keys:
        r = smp.steady_rate(key)
        if r is not None:
            out[f"rate.{key}_per_s"] = _metric(r, "higher", None)
    return out


def run_serve(smoke: bool, trace: Optional[str]) -> Dict[str, Any]:
    """fig_serve with every arm on (paged + windowed + swap +
    speculative + the mesh-sharded arms at 4 shards + the closed-loop
    trace arms when ``trace`` is set) under a wall-clock sampler;
    returns the baseline document."""
    from benchmarks import fig_serve

    smp = Sampler(wall_clock=True, min_interval_s=0.05, capacity=4096)
    prev = set_sampler(smp)
    try:
        rows = fig_serve.run(smoke=smoke, paged=True, preempt="swap",
                             trace=trace, spec=True, mesh=4)
    finally:
        set_sampler(prev)
    idx = parse_rows(rows)
    m: Dict[str, Any] = {}
    # gated: deterministic scheduling/occupancy quantities (seed-fixed
    # workloads, greedy decode — a shift means the scheduler changed)
    cv = idx["fig_serve.continuous_vs_static"]
    m["step_ratio"] = _metric(cv["step_ratio"], "higher", 0.02)
    m["zipf_hit_rate"] = _metric(idx["fig_serve.zipf_cache"]["hit_rate"],
                                 "higher", 0.0)
    m["paged_occupancy_ratio"] = _metric(
        idx["fig_serve.paged_vs_contiguous"]["occupancy_ratio"],
        "higher", 0.02)
    m["windowed_occupancy_ratio"] = _metric(
        idx["fig_serve.windowed_paged_vs_contiguous"]["occupancy_ratio"],
        "higher", 0.02)
    m["shared_prefix_occupancy_ratio"] = _metric(
        idx["fig_serve.shared_prefix"]["occupancy_ratio"],
        "higher", 0.02)
    pp = idx["fig_serve.preempt_swap_vs_recompute"]
    m["overload_swap_occupancy"] = _metric(pp["occupancy_swap"],
                                           "higher", 0.02)
    m["overload_recompute_occupancy"] = _metric(pp["occupancy_recompute"],
                                                "higher", 0.02)
    # sharded slot pool: useful concurrency at mesh=4 vs mesh=1 (equal
    # per-device cache memory) and the work-stealing win under skewed
    # arrivals — both seed-fixed greedy quantities
    m["mesh_occupancy_ratio"] = _metric(
        idx["fig_serve.mesh_sharded_vs_single"]["mesh_occupancy_ratio"],
        "higher", 0.02)
    m["work_stealing_occupancy_ratio"] = _metric(
        idx["fig_serve.work_stealing"]["occupancy_ratio"],
        "higher", 0.02)
    # speculative decoding: useful tokens per fused decode step on the
    # draft-friendly arm and its acceptance rate are seed-fixed, greedy
    # quantities (the in-benchmark assert already requires streams
    # bit-identical to the speculate=0 oracle)
    sp = idx["fig_serve.spec.draft_friendly"]
    m["spec_step_ratio"] = _metric(sp["step_ratio"], "higher", 0.02)
    m["spec_accept_rate"] = _metric(sp["accept_rate"], "higher", 0.02)
    # informational: wall-clock (machine-dependent) quantities
    m["continuous_vs_static_speedup"] = _metric(cv["speedup"],
                                                "higher", None)
    for policy in ("static", "continuous"):
        m[f"{policy}_tok_per_s"] = _metric(
            idx[f"fig_serve.{policy}.tok_per_s"]["tok_per_s"],
            "higher", None)
        m[f"{policy}_ttft_p95_s"] = _metric(
            idx[f"fig_serve.{policy}.ttft"]["p95_s"], "lower", None)
    m["spec_tok_per_s_speedup"] = _metric(sp["speedup"], "higher", None)
    m["spec_adversarial_accept_rate"] = _metric(
        idx["fig_serve.spec.adversarial"]["accept_rate"], "higher", None)
    if trace:
        m["trace_overhead_pct"] = _metric(
            idx["fig_serve.trace_overhead"]["overhead_pct"], "lower", None)
        cl = idx["fig_serve.closed_loop"]
        m["closed_loop_fired"] = _metric(cl["fired"], "higher", None)
        m["closed_loop_engaged"] = _metric(cl["engaged"], "higher", None)
    m.update(_steady_rates(smp, ("serve.generated_tokens",
                                 "serve.decode_steps",
                                 "serve.prefill_tokens")))
    return {"schema_version": SCHEMA_VERSION, "suite": "serve",
            "smoke": bool(smoke), "metrics": m}


def run_runtime(smoke: bool) -> Dict[str, Any]:
    """fig_runtime under a wall-clock sampler; returns the baseline
    document."""
    from benchmarks import fig_runtime
    from repro.runtime.dispatch import BUCKET_STATS

    smp = Sampler(wall_clock=True, min_interval_s=0.05, capacity=4096)
    prev = set_sampler(smp)
    try:
        rows = fig_runtime.run(smoke=smoke)
    finally:
        set_sampler(prev)
    idx = parse_rows(rows)
    m: Dict[str, Any] = {}
    # gated: the dispatch layer's compile behavior is shape-deterministic
    # (fixed seeds + fixed batch ladder -> a fixed set of bucket
    # programs); more misses means bucketing regressed
    cache = idx["fig_runtime.dispatch.cache"]
    m["dispatch_cache_misses"] = _metric(cache["misses"], "lower", 0.0)
    m["dispatch_buckets"] = _metric(len(BUCKET_STATS.buckets), "lower", 0.0)
    # informational: wall-clock speedups and rates
    for name, d in idx.items():
        if "speedup_vs_per_request" in d:
            arm = name.split(".", 1)[1].replace(".", "_")
            m[f"{arm}_speedup"] = _metric(d["speedup_vs_per_request"],
                                          "higher", None)
    m["dispatch_cache_hits"] = _metric(cache["hits"], "higher", None)
    m.update(_steady_rates(smp, ("runtime.dispatch.cache_hits",
                                 "runtime.service.submits")))
    return {"schema_version": SCHEMA_VERSION, "suite": "runtime",
            "smoke": bool(smoke), "metrics": m}


# ---------------------------------------------------------------------------
# comparison + ratcheted write
# ---------------------------------------------------------------------------

def compare(baseline: Dict[str, Any],
            current: Dict[str, Any]) -> List[str]:
    """Regressions of ``current`` vs ``baseline``, as human-readable
    strings (empty = pass). Only gated metrics (tolerance != null)
    gate; a gated baseline metric missing from the current run is
    itself a regression (a silently dropped measurement must not pass).
    """
    problems: List[str] = []
    if baseline.get("schema_version") != SCHEMA_VERSION:
        problems.append(
            f"baseline schema_version {baseline.get('schema_version')} "
            f"!= {SCHEMA_VERSION} (regenerate with --write --reset)")
        return problems
    cur = current.get("metrics", {})
    for name, spec in baseline.get("metrics", {}).items():
        tol = spec.get("tolerance")
        if tol is None:
            continue
        got = cur.get(name)
        if got is None:
            problems.append(f"{name}: gated metric missing from this run")
            continue
        base_v, cur_v = float(spec["value"]), float(got["value"])
        if spec["direction"] == "higher":
            floor = base_v * (1.0 - tol)
            if cur_v < floor:
                problems.append(
                    f"{name}: {cur_v:.4f} < {floor:.4f} "
                    f"(baseline {base_v:.4f}, tolerance {tol})")
        else:
            ceil = base_v * (1.0 + tol)
            if cur_v > ceil:
                problems.append(
                    f"{name}: {cur_v:.4f} > {ceil:.4f} "
                    f"(baseline {base_v:.4f}, tolerance {tol})")
    return problems


def ratchet(old: Dict[str, Any], new: Dict[str, Any]) -> Dict[str, Any]:
    """Merge a fresh run into an existing baseline: gated metrics keep
    the BETTER of {old, new} (the trajectory only tightens),
    informational metrics always take the fresh measurement, and
    metrics new to this run are added."""
    merged = dict(new)
    out = dict(new.get("metrics", {}))
    for name, spec in old.get("metrics", {}).items():
        tol = spec.get("tolerance")
        got = out.get(name)
        if got is None:
            out[name] = spec        # keep retired-but-gated history
            continue
        if tol is None or got.get("tolerance") is None:
            continue
        better = max if spec["direction"] == "higher" else min
        if better(spec["value"], got["value"]) == spec["value"]:
            out[name] = dict(got, value=spec["value"])
    merged["metrics"] = out
    return merged


def baseline_path(suite: str, out_dir: str) -> str:
    return os.path.join(out_dir, f"BENCH_{suite}.json")


def _dump(doc: Dict[str, Any], path: str):
    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="run the smoke benchmark suites and gate/record "
                    "their metric trajectory")
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes (CI cadence; baselines are "
                         "recorded at smoke scale)")
    ap.add_argument("--check", action="store_true",
                    help="compare against the committed baselines; "
                         "exit 1 on any gated regression")
    ap.add_argument("--write", action="store_true",
                    help="update the baselines (ratcheted: gated "
                         "metrics keep the better of old/new)")
    ap.add_argument("--reset", action="store_true",
                    help="with --write: replace baselines wholesale "
                         "(record an intentional trade-off)")
    ap.add_argument("--suite", choices=["serve", "runtime", "all"],
                    default="all")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="forward to fig_serve: run the closed-loop "
                         "trace arms and export the Chrome trace here")
    ap.add_argument("--out", default=REPO_ROOT,
                    help="baseline directory (default: repo root)")
    args = ap.parse_args(argv)
    if args.reset and not args.write:
        ap.error("--reset requires --write")
    if not (args.check or args.write):
        ap.error("nothing to do: pass --check and/or --write")

    suites = ("serve", "runtime") if args.suite == "all" else (args.suite,)
    failures: List[str] = []
    for suite in suites:
        print(f"# bench_history: running {suite} suite "
              f"({'smoke' if args.smoke else 'full'})")
        if suite == "serve":
            doc = run_serve(args.smoke, args.trace)
        else:
            doc = run_runtime(args.smoke)
        path = baseline_path(suite, args.out)
        old: Optional[Dict[str, Any]] = None
        if os.path.exists(path):
            with open(path) as f:
                old = json.load(f)
        if args.check:
            if old is None:
                failures.append(f"{suite}: no baseline at {path} "
                                f"(generate with --write)")
            else:
                problems = compare(old, doc)
                for p in problems:
                    print(f"# bench_history: REGRESSION [{suite}] {p}")
                failures.extend(f"{suite}: {p}" for p in problems)
                if not problems:
                    print(f"# bench_history: {suite} within baseline "
                          f"({sum(1 for s in old['metrics'].values() if s['tolerance'] is not None)} gated metrics)")
        if args.write:
            doc = doc if (old is None or args.reset) else ratchet(old, doc)
            _dump(doc, path)
            print(f"# bench_history: wrote {path} "
                  f"({len(doc['metrics'])} metrics)")
    if failures:
        print(f"# bench_history: {len(failures)} regression(s)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
