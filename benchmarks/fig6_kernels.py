"""Fig. 6 reproduction: the five kernels x {4, 8, 16, 32} workers.

Paper result: RADIX 1.58x / SEED 1.32x (peak at 16 workers, small-input
bound), CHAIN 3.35x / SW 3.43x (32 workers), DTW 7.64x (32 workers).

Per kernel and worker count we report the measured wall-clock of the
Squire-partitioned implementation (CPU proxy) and the depth-model speedup
(`derived` column = model speedup vs the 1-worker sequential depth) —
the hardware-independent reproduction of the figure's scaling shape.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import align as align_lib
from repro.core import chain as chain_lib
from repro.core import dtw as dtw_lib
from repro.core import seeding
from repro.core import sort as sort_lib
from repro.data import genomics

WORKERS = (4, 8, 16, 32)


def bench_radix(rows):
    n = 50_000
    keys = jax.random.randint(jax.random.PRNGKey(0), (n,), 0, 2**31 - 1,
                              dtype=jnp.int32).astype(jnp.uint32)
    f1 = jax.jit(lambda k: sort_lib.radix_sort(k, num_chunks=1,
                                               min_parallel=0)[0])
    base_us = common.time_fn(f1, keys)
    rows.append(common.emit("fig6.radix.w1", base_us, 1.0))
    for w in WORKERS:
        fw = jax.jit(lambda k, w=w: sort_lib.radix_sort(
            k, num_chunks=w, min_parallel=0)[0])
        us = common.time_fn(fw, keys)
        ds, dq = common.depth_radix(n, w)
        rows.append(common.emit(f"fig6.radix.w{w}", us, round(ds / dq, 2)))


def bench_seed(rows):
    ref = genomics.make_reference(50_000, seed=0)
    idx = seeding.build_index(ref, 15, 10)
    read = jnp.asarray(ref[5_000:10_000].astype(np.int32))
    f1 = jax.jit(lambda r: seeding.seed(idx, r, 15, 10,
                                        num_sort_chunks=1)[1])
    base_us = common.time_fn(f1, read)
    rows.append(common.emit("fig6.seed.w1", base_us, 1.0))
    n_anchors = int(f1(read).shape[0])
    for w in WORKERS:
        fw = jax.jit(lambda r, w=w: seeding.seed(idx, r, 15, 10,
                                                 num_sort_chunks=w)[1])
        us = common.time_fn(fw, read)
        ds, dq = common.depth_seed(n_anchors, w)
        rows.append(common.emit(f"fig6.seed.w{w}", us, round(ds / dq, 2)))


def bench_chain(rows):
    q, r = genomics.anchor_set(8192, seed=1)
    qd, rd = jnp.asarray(q), jnp.asarray(r)
    T = 64
    f1 = jax.jit(lambda a, b: chain_lib.chain_anchors(a, b, T=T,
                                                      mode="sequential")[0])
    base_us = common.time_fn(f1, qd, rd)
    rows.append(common.emit("fig6.chain.w1", base_us, 1.0))
    for w in WORKERS:
        # W workers ~ block size N/W in the blocked-transfer formulation
        block = max(len(q) // (len(q) // max(T // w, 1)), 8) \
            if False else max(T // w * 4, 8)
        fw = jax.jit(lambda a, b, bl=block: chain_lib.chain_anchors(
            a, b, T=T, mode="blocked", block=bl)[0])
        us = common.time_fn(fw, qd, rd)
        ds, dq = common.depth_chain(len(q), T, w)
        rows.append(common.emit(f"fig6.chain.w{w}", us, round(ds / dq, 2)))


def bench_sw(rows):
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.integers(0, 4, 512).astype(np.int32))
    b = jnp.asarray(rng.integers(0, 4, 512).astype(np.int32))
    f1 = jax.jit(lambda x, y: align_lib.sw_ref(x, y))
    base_us = common.time_fn(f1, a, b)
    rows.append(common.emit("fig6.sw.w1", base_us, 1.0))
    for w in WORKERS:
        tile = max(512 // w, 16)
        fn = jax.jit(lambda t, l, c, x, y: align_lib._sw_tile_fn(
            align_lib.SWParams(), t, l, c, x, y))

        def fw(x, y, tl=tile):
            return align_lib.sw_tiled(x, y, tile_r=tl, tile_c=tl,
                                      tile_fn=fn)[1]
        us = common.time_fn(fw, a, b)
        ds, dq = common.depth_dtw(512, 512, w)
        rows.append(common.emit(f"fig6.sw.w{w}", us, round(ds / dq, 2)))


def bench_dtw(rows):
    rng = np.random.default_rng(3)
    s = jnp.asarray(rng.normal(size=384).astype(np.float32))
    r = jnp.asarray(rng.normal(size=384).astype(np.float32))
    f1 = jax.jit(lambda x, y: dtw_lib.dtw_ref(x, y)[-1, -1])
    base_us = common.time_fn(f1, s, r)
    rows.append(common.emit("fig6.dtw.w1", base_us, 1.0))
    from repro.core.wavefront import dp_tile_diagonal
    from repro.core.dtw import _cell
    tile_fn = jax.jit(lambda t, l, c, x, y: dp_tile_diagonal(
        _cell, t, l, c, x, y))
    for w in WORKERS:
        tl = max(384 // w, 16)

        def fw(x, y, tl=tl):
            return dtw_lib.dtw_tiled(x, y, tile_r=tl, tile_c=tl,
                                     tile_fn=tile_fn)[1]
        us = common.time_fn(fw, s, r)
        ds, dq = common.depth_dtw(384, 384, w)
        rows.append(common.emit(f"fig6.dtw.w{w}", us, round(ds / dq, 2)))


def run(rows=None):
    rows = rows if rows is not None else []
    print("# fig6: kernel scaling (derived = depth-model speedup vs w1)")
    bench_radix(rows)
    bench_seed(rows)
    bench_chain(rows)
    bench_sw(rows)
    bench_dtw(rows)
    return rows


if __name__ == "__main__":
    run()
