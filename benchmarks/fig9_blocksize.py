"""Fig. 9 analogue: the cache-size design-space exploration.

The paper sweeps worker L1 I/D cache sizes and picks 1 KB / 8 KB from the
MPKI knee. The TPU analogue (DESIGN.md §2) is the Pallas BlockSpec tile
size: the tile determines the VMEM working set exactly like the D-cache
determined the worker's locality. We sweep the DTW/SW tile and the
ssm_scan chunk, reporting the VMEM bytes each claims (`derived`) and the
interpret-mode wall-clock — the knee (VMEM large enough to amortize the
boundary traffic, small enough to fit) mirrors the paper's 8 KB choice.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import dtw as dtw_lib
from repro.core.wavefront import dp_tile_diagonal
from repro.core.dtw import _cell

TILES = (16, 32, 64, 128)
CHUNKS = (16, 32, 64)
CHAIN_BLOCKS = (8, 16, 32)
SORT_CHUNKS = (1, 2, 4)
BUCKETS = (256, 1024)       # anchor/sort shape buckets swept per-bucket


def vmem_dtw_tile(t: int) -> int:
    """fp32 bytes a (t x t) tile's working set claims in VMEM:
    tile + two diagonal buffers + boundaries + row/col inputs."""
    return 4 * (t * t + 2 * t + 2 * t + t + 1)


def vmem_ssm_chunk(c: int, d: int = 64) -> int:
    """4 (C, d) blocks + (d, d) state, fp32."""
    return 4 * (4 * c * d + d * d)


def bench_dtw_tiles(rows):
    rng = np.random.default_rng(0)
    n = 256
    s = jnp.asarray(rng.normal(size=n).astype(np.float32))
    r = jnp.asarray(rng.normal(size=n).astype(np.float32))
    tile_fn = jax.jit(lambda t, l, c, x, y: dp_tile_diagonal(
        _cell, t, l, c, x, y))
    for t in TILES:
        def fw(x, y, t=t):
            return dtw_lib.dtw_tiled(x, y, tile_r=t, tile_c=t,
                                     tile_fn=tile_fn)[1]
        us = common.time_fn(fw, s, r)
        rows.append(common.emit(f"fig9.dtw.tile{t}", us,
                                f"vmem_bytes={vmem_dtw_tile(t)}"))


def bench_ssm_chunks(rows):
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    b, t, d = 4, 512, 64
    r = jax.random.normal(ks[0], (b, t, d))
    w = jax.nn.sigmoid(jax.random.normal(ks[1], (b, t, d)) + 2)
    k = jax.random.normal(ks[2], (b, t, d))
    v = jax.random.normal(ks[3], (b, t, d))
    for c in CHUNKS:
        us = common.time_fn(ops.ssm_scan, r, w, k, v, None, c)
        rows.append(common.emit(f"fig9.ssm.chunk{c}", us,
                                f"vmem_bytes={vmem_ssm_chunk(c, d)}"))


def bench_chain_blocks(rows):
    """Chain DP block size, swept PER ANCHOR BUCKET: the best block moves
    with the bucket (short chains want small blocks), so rows carry the
    ``@b<bucket>`` suffix and land on per-bucket autotune keys."""
    from repro.apps import read_mapper as rm
    from repro.runtime.dispatch import Dispatcher
    rng = np.random.default_rng(2)
    d = Dispatcher()
    for nb in BUCKETS:
        r = np.sort(rng.integers(0, 50 * nb, (8, nb))).astype(np.int32)
        q = np.sort(rng.integers(0, 4 * nb, (8, nb))).astype(np.int32)
        vp = np.ones((8, nb), bool)
        for blk in CHAIN_BLOCKS:
            fn = rm._chain_fn(64, "blocked", blk)
            us = common.time_fn(lambda: d.run(fn, (q, r, vp)))
            rows.append(common.emit(
                f"fig9.chain.block{blk}@b{nb}", us,
                f"depth={common.depth_chain(nb, 64, blk)[1]}"))


def bench_sort_chunks(rows):
    """Radix-sort chunk count per sort bucket (Alg. 1 worker count)."""
    from repro.core import sort as rsort
    from repro.runtime.dispatch import Dispatcher
    rng = np.random.default_rng(3)
    d = Dispatcher()
    for nb in BUCKETS:
        keys = rng.integers(0, 2**32, (8, nb), dtype=np.uint32)
        vals = np.tile(np.arange(nb, dtype=np.int32), (8, 1))
        for c in SORT_CHUNKS:
            def fn(k, v, c=c):
                return rsort.radix_sort(k, v, num_chunks=c, min_parallel=0)
            us = common.time_fn(lambda: d.run(fn, (keys, vals)))
            rows.append(common.emit(
                f"fig9.sort.chunks{c}@b{nb}", us,
                f"depth={common.depth_radix(nb, max(c, 1))[1]}"))


def run(rows=None):
    rows = rows if rows is not None else []
    print("# fig9: BlockSpec/VMEM design-space sweep (cache-size analogue)")
    bench_dtw_tiles(rows)
    bench_ssm_chunks(rows)
    bench_chain_blocks(rows)
    bench_sort_chunks(rows)
    # seed the runtime autotuner: the sweep's fastest tile/chunk become the
    # serving defaults (ServiceConfig.tuned() reads them back).
    try:
        from repro.runtime.autotune import seed_from_fig9
        best = seed_from_fig9(rows)
        if best:
            print(f"# fig9: autotune cache seeded: {best}")
    except OSError as e:                      # read-only cache dir etc.
        print(f"# fig9: autotune cache not written ({e})")
    return rows


if __name__ == "__main__":
    run()
