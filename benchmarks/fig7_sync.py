"""Fig. 7 reproduction: the synchronization module's value.

Paper: DTW with the hardware sync module vs pthread mutexes — up to 1.69x
at 16 workers. The JAX analogue (DESIGN.md §2): the "software mutex"
baseline is the fully sequential recurrence (no fine-grain parallelism
inside the dependency chain); the "sync module" version is the chunked
boundary-handoff form whose carries are structural. We report both for
the 1-D engine (where the associative form also exists) and the 2-D DTW.

derived column = depth-model speedup of the sync-module form.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import dtw as dtw_lib
from repro.core.scan1d import affine_scan
from repro.core.semiring import MAXPLUS

WORKERS = (2, 4, 8, 16)


def bench_scan1d(rows):
    t = 65536
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (t,))
    b = jax.random.normal(jax.random.PRNGKey(1), (t,))
    x0 = jnp.zeros(())

    f_seq = jax.jit(lambda a, b: affine_scan(a, b, x0, MAXPLUS,
                                             mode="sequential"))
    us = common.time_fn(f_seq, a, b)
    rows.append(common.emit("fig7.scan1d.sequential", us, 1.0))

    for w in WORKERS:
        f_chk = jax.jit(lambda a, b, w=w: affine_scan(
            a, b, x0, MAXPLUS, mode="chunked", num_chunks=w))
        us = common.time_fn(f_chk, a, b)
        # chunked depth: t/w local + w boundary
        model = t / (t / w + w)
        rows.append(common.emit(f"fig7.scan1d.chunked.w{w}", us,
                                round(model, 2)))

    f_ass = jax.jit(lambda a, b: affine_scan(a, b, x0, MAXPLUS,
                                             mode="associative"))
    us = common.time_fn(f_ass, a, b)
    model = t / np.log2(t)
    rows.append(common.emit("fig7.scan1d.associative", us, round(model, 2)))


def bench_dtw_sync(rows):
    rng = np.random.default_rng(2)
    n = 256
    s = jnp.asarray(rng.normal(size=n).astype(np.float32))
    r = jnp.asarray(rng.normal(size=n).astype(np.float32))

    f_seq = jax.jit(lambda x, y: dtw_lib.dtw_ref(x, y)[-1, -1])
    us = common.time_fn(f_seq, s, r)
    rows.append(common.emit("fig7.dtw.mutex_baseline", us, 1.0))

    from repro.core.wavefront import dp_tile_diagonal
    from repro.core.dtw import _cell
    tile_fn = jax.jit(lambda t, l, c, x, y: dp_tile_diagonal(
        _cell, t, l, c, x, y))
    for w in WORKERS:
        tl = max(n // w, 16)

        def fw(x, y, tl=tl):
            return dtw_lib.dtw_tiled(x, y, tile_r=tl, tile_c=tl,
                                     tile_fn=tile_fn)[1]
        us = common.time_fn(fw, s, r)
        ds, dq = common.depth_dtw(n, n, w)
        rows.append(common.emit(f"fig7.dtw.sync_module.w{w}", us,
                                round(ds / dq, 2)))


def run(rows=None):
    rows = rows if rows is not None else []
    print("# fig7: sync module vs software-mutex baseline")
    bench_scan1d(rows)
    bench_dtw_sync(rows)
    return rows


if __name__ == "__main__":
    run()
