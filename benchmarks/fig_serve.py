"""Serving throughput/latency: continuous vs static batching (beyond-paper,
the ROADMAP serving-integration item at traffic scale).

Decode is the request-scale dependency-bound recurrence; the paper's
argument is that the right scheduling granularity keeps the worker pool
saturated. Here the pool is the scheduler's B cache slots, and the two
policies differ ONLY in admission (same kernels, same chunked prefill):

  * static     — admit B requests, run until the LAST retires (the pool
                 drains as stragglers finish), then admit the next B.
  * continuous — retire-and-admit per decode step: every tick a free
                 slot is refilled from the FCFS queue.

Under mixed output lengths the static pool idles on the straggler tail;
rows report useful generated tokens/sec and the measured speedup
(`derived`) — the ISSUE acceptance gate checks >= 2x at batch >= 8 —
plus p50/p95 request latency for each policy.

A second phase replays a zipfian repeat mix through the scheduler's
memoizing request cache and reports the hit rate (> 0 gates) and the
cached-traffic throughput.

    PYTHONPATH=src python benchmarks/fig_serve.py [--smoke]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from benchmarks import common
from repro import configs
from repro.models import transformer as T
from repro.serve import Scheduler, SchedulerConfig


def _workload(rng, n_requests: int, vocab: int, max_prompt: int,
              tail_new: int):
    """Mixed prompt lengths, heavy-tailed (Pareto) output budgets — the
    production shape: most completions are short, a few stragglers run
    long. A static batch runs every member to its slowest straggler."""
    prompts, mnts = [], []
    for _ in range(n_requests):
        ln = int(rng.integers(max(4, max_prompt // 4), max_prompt + 1))
        prompts.append(rng.integers(0, vocab, ln).astype(np.int32))
        mnts.append(min(2 + int(rng.pareto(1.1) * 4), tail_new))
    return prompts, mnts


def _run_policy(cfg, params, sc: SchedulerConfig, prompts, mnts):
    """Serve the workload; returns (wall_s, useful_tokens, latencies)."""
    sched = Scheduler(cfg, params, sc)
    t0 = time.time()
    for p, m in zip(prompts, mnts):
        sched.submit([p], max_new_tokens=m)
    done = sched.drain()
    wall = time.time() - t0
    toks = sum(len(c.tokens) for c in done)
    lats = np.asarray([c.latency for c in done])
    return wall, toks, lats, sched


def bench_policies(rows, cfg, params, sc_kw, prompts, mnts):
    out = {}
    work = {}
    for policy in ("static", "continuous"):
        sc = SchedulerConfig(admit=policy, cache_requests=False, **sc_kw)
        # warm run over the FULL workload: greedy scheduling is
        # deterministic, so the timed runs replay exactly the warmed
        # bucket shapes and the comparison is pure scheduling. Median of
        # 3 timed runs — the smoke workload is small enough for a single
        # wall-clock sample to be noise-dominated.
        _run_policy(cfg, params, sc, prompts, mnts)
        runs = [_run_policy(cfg, params, sc, prompts, mnts)
                for _ in range(3)]
        wall, toks, lats, sched = sorted(runs, key=lambda r: r[0])[1]
        out[policy] = toks / wall
        # decode steps are the serial recurrence and deterministic under
        # greedy scheduling — the smoke gate asserts on their ratio, not
        # wall-clock (prefill token totals are identical across policies)
        work[policy] = sched.counters["decode_steps"]
        rows.append(common.emit(
            f"fig_serve.{policy}.tok_per_s", wall * 1e6 / max(toks, 1),
            f"tok_per_s={toks / wall:.1f},steps="
            f"{sched.counters['decode_steps']}"))
        rows.append(common.emit(
            f"fig_serve.{policy}.latency", float(np.median(lats)) * 1e6,
            f"p50_s={np.percentile(lats, 50):.2f},"
            f"p95_s={np.percentile(lats, 95):.2f}"))
    speedup = out["continuous"] / out["static"]
    step_ratio = work["static"] / work["continuous"]
    rows.append(common.emit(
        "fig_serve.continuous_vs_static", 0.0,
        f"speedup={speedup:.2f},step_ratio={step_ratio:.2f}"))
    return speedup, step_ratio


def bench_zipf_cache(rows, cfg, params, sc_kw, rng, n_requests: int,
                     vocab: int, max_prompt: int):
    """Zipfian repeat mix: a few hot prompts dominate; the request cache
    should convert repeats into zero-step completions."""
    distinct = max(4, n_requests // 4)
    pool = [rng.integers(0, vocab, int(rng.integers(4, max_prompt))
                         ).astype(np.int32) for _ in range(distinct)]
    ranks = np.arange(1, distinct + 1, dtype=np.float64)
    probs = (1.0 / ranks) / (1.0 / ranks).sum()          # zipf alpha=1
    picks = rng.choice(distinct, size=n_requests, p=probs)
    sc = SchedulerConfig(admit="continuous", cache_requests=True, **sc_kw)
    sched = Scheduler(cfg, params, sc)
    t0 = time.time()
    for i in picks:
        sched.submit([pool[i]], max_new_tokens=8)
    sched.drain()
    wall = time.time() - t0
    hr = sched.request_cache.hit_rate
    rows.append(common.emit(
        "fig_serve.zipf_cache", wall * 1e6 / n_requests,
        f"hit_rate={hr:.2f},hits={sched.request_cache.hits},"
        f"misses={sched.request_cache.misses}"))
    return hr


def run(rows=None, smoke: bool = False):
    rows = rows if rows is not None else []
    print("# fig_serve: continuous vs static batching on the slot pool")
    arch = "rwkv6-1.6b"                 # O(1)-state decode: cache-cheap
    cfg = configs.reduced_config(arch)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    if smoke:
        n_req, max_prompt, tail_new, slots = 16, 12, 48, 4
    else:
        n_req, max_prompt, tail_new, slots = 64, 12, 96, 8
    sc_kw = dict(num_slots=slots, max_len=max_prompt + tail_new + 8,
                 prefill_chunk=8)

    prompts, mnts = _workload(rng, n_req, cfg.vocab, max_prompt, tail_new)
    speedup, step_ratio = bench_policies(rows, cfg, params, sc_kw, prompts,
                                         mnts)
    hr = bench_zipf_cache(rows, cfg, params, sc_kw, rng, n_req, cfg.vocab,
                          max_prompt)
    print(f"# fig_serve: continuous/static speedup {speedup:.2f}x "
          f"(gate >= 2x), step ratio {step_ratio:.2f}x, "
          f"zipf cache hit rate {hr:.2f} (gate > 0)")
    if smoke:
        # wall-clock is noise-dominated at smoke scale; gate on the
        # deterministic decode-step ratio instead
        assert step_ratio > 1.3, \
            f"continuous needed too many steps ({step_ratio:.2f}x)"
    else:
        # the ISSUE acceptance gate: >= 2x at batch >= 8. The decode-
        # step ratio is deterministic; the wall floor is kept loose
        # (1.5x) so machine noise cannot flake a genuinely-2x result.
        assert step_ratio >= 2.0, \
            f"decode-step ratio regressed ({step_ratio:.2f}x < 2x)"
        assert speedup > 1.5, \
            f"tokens/sec speedup regressed ({speedup:.2f}x)"
    assert hr > 0.0, "request cache never hit under zipf mix"
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + assertions (CI)")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
