"""Serving throughput/latency: continuous vs static batching (beyond-paper,
the ROADMAP serving-integration item at traffic scale).

Decode is the request-scale dependency-bound recurrence; the paper's
argument is that the right scheduling granularity keeps the worker pool
saturated. Here the pool is the scheduler's B cache slots, and the two
policies differ ONLY in admission (same kernels, same chunked prefill):

  * static     — admit B requests, run until the LAST retires (the pool
                 drains as stragglers finish), then admit the next B.
  * continuous — retire-and-admit per decode step: every tick a free
                 slot is refilled from the FCFS queue.

Under mixed output lengths the static pool idles on the straggler tail;
rows report useful generated tokens/sec and the measured speedup
(`derived`) — the ISSUE acceptance gate checks >= 2x at batch >= 8 —
plus p50/p95 request latency for each policy.

A second phase replays a zipfian repeat mix through the scheduler's
memoizing request cache and reports the hit rate (> 0 gates) and the
cached-traffic throughput.

``--paged`` adds the equal-cache-memory occupancy comparisons between
the contiguous and paged slot allocators: the global-attention model
(gemma-2b reduced) and the WINDOWED model (gemma3 reduced, sliding
window 16 paged at block granularity through ring-mode page-table
groups — the window >> block_size configuration). ``--preempt swap``
additionally compares the preemption policies under the overload mix —
recompute's wasted decode steps vs swap's bytes moved through the host
SwapStore, plus the reserved-admission (zero-preemption QoS) arm.

Latency is reported per phase (the PR-6 observability surface):
``Completion.queue_wait`` (submit -> first admission), ``ttft`` (submit
-> first generated token) and ``itl`` (mean inter-token latency over the
decode phase) get their own p50/p95 rows per policy — continuous batching
trades a little ITL (shared pool) for much better queue-wait/TTFT.

``--trace out.json`` runs the observability arms: the <= 3% tokens/sec
overhead gate with the FULL passive stack on (tracer + live sampler +
SLO monitors, interleaved off/on), then a closed-loop forced-overload
serve (paged+swap, half the blocks) where a queue-wait SLO fires, a
BackpressureController caps admissions, and the alert clears on drain
— all exported as a schema-validated Chrome trace-event JSON (load it
at https://ui.perfetto.dev: per-slot tracks + scheduler/dispatcher/
slo/control tracks + 'C' metric counter tracks) with the sampler ring
beside it as ``out.json.samples.jsonl``. The control invariant is
asserted: the closed-loop greedy token streams are bit-identical to an
uncontrolled twin run.

``--mesh N`` adds the sharded-slot-pool arms (the device-mesh sharding
tentpole): mesh=N vs mesh=1 useful-work occupancy at equal PER-DEVICE
cache memory (same blocks per shard; gate >= 2x at N >= 4), and
work-stealing vs static placement under skewed arrivals (round-robin
parks all the long requests on one shard; the blocked queue heads must
migrate to the idle shard and beat the static arm). Runs through a
real shard_map mesh when >= N devices exist (the CI lane forces 8 host
devices), the vmap path otherwise — the gated quantities are identical.

    PYTHONPATH=src python benchmarks/fig_serve.py \
        [--smoke] [--paged] [--preempt swap] [--trace out.json] [--mesh 4]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from benchmarks import common
from repro import configs
from repro.models import transformer as T
from repro.obs import (BackpressureController, Rule, Sampler, SLOManager,
                       Tracer, set_sampler, set_tracer,
                       validate_chrome_trace)
from repro.serve import Scheduler, SchedulerConfig


def _workload(rng, n_requests: int, vocab: int, max_prompt: int,
              tail_new: int):
    """Mixed prompt lengths, heavy-tailed (Pareto) output budgets — the
    production shape: most completions are short, a few stragglers run
    long. A static batch runs every member to its slowest straggler."""
    prompts, mnts = [], []
    for _ in range(n_requests):
        ln = int(rng.integers(max(4, max_prompt // 4), max_prompt + 1))
        prompts.append(rng.integers(0, vocab, ln).astype(np.int32))
        mnts.append(min(2 + int(rng.pareto(1.1) * 4), tail_new))
    return prompts, mnts


def _run_policy(cfg, params, sc: SchedulerConfig, prompts, mnts):
    """Serve the workload; returns (wall_s, useful_tokens, completions,
    scheduler) — per-phase latencies come off the Completions."""
    sched = Scheduler(cfg, params, sc)
    t0 = time.perf_counter()        # monotonic, like Completion stamps
    for p, m in zip(prompts, mnts):
        sched.submit([p], max_new_tokens=m)
    done = sched.drain()
    wall = time.perf_counter() - t0
    toks = sum(len(c.tokens) for c in done)
    return wall, toks, done, sched


def bench_policies(rows, cfg, params, sc_kw, prompts, mnts):
    out = {}
    work = {}
    for policy in ("static", "continuous"):
        sc = SchedulerConfig(admit=policy, cache_requests=False, **sc_kw)
        # warm run over the FULL workload: greedy scheduling is
        # deterministic, so the timed runs replay exactly the warmed
        # bucket shapes and the comparison is pure scheduling. Median of
        # 3 timed runs — the smoke workload is small enough for a single
        # wall-clock sample to be noise-dominated.
        _run_policy(cfg, params, sc, prompts, mnts)
        runs = [_run_policy(cfg, params, sc, prompts, mnts)
                for _ in range(3)]
        wall, toks, done, sched = sorted(runs, key=lambda r: r[0])[1]
        out[policy] = toks / wall
        # decode steps are the serial recurrence and deterministic under
        # greedy scheduling — the smoke gate asserts on their ratio, not
        # wall-clock (prefill token totals are identical across policies)
        work[policy] = sched.counters["decode_steps"]
        rows.append(common.emit(
            f"fig_serve.{policy}.tok_per_s", wall * 1e6 / max(toks, 1),
            f"tok_per_s={toks / wall:.1f},steps="
            f"{sched.counters['decode_steps']}"))
        lats = np.asarray([c.latency for c in done])
        rows.append(common.emit(
            f"fig_serve.{policy}.latency", float(np.median(lats)) * 1e6,
            f"p50_s={np.percentile(lats, 50):.2f},"
            f"p95_s={np.percentile(lats, 95):.2f}"))
        # per-phase latency arms (Completion timelines): where a
        # request's wall time went, not just how much there was
        for arm, xs in (("ttft", [c.ttft for c in done]),
                        ("queue_wait", [c.queue_wait for c in done]),
                        ("itl", [c.itl for c in done])):
            xs = np.asarray(xs)
            rows.append(common.emit(
                f"fig_serve.{policy}.{arm}",
                float(np.median(xs)) * 1e6,
                f"p50_s={np.percentile(xs, 50):.3f},"
                f"p95_s={np.percentile(xs, 95):.3f}"))
    speedup = out["continuous"] / out["static"]
    step_ratio = work["static"] / work["continuous"]
    rows.append(common.emit(
        "fig_serve.continuous_vs_static", 0.0,
        f"speedup={speedup:.2f},step_ratio={step_ratio:.2f}"))
    return speedup, step_ratio


def bench_zipf_cache(rows, cfg, params, sc_kw, rng, n_requests: int,
                     vocab: int, max_prompt: int):
    """Zipfian repeat mix: a few hot prompts dominate; the request cache
    should convert repeats into zero-step completions."""
    distinct = max(4, n_requests // 4)
    pool = [rng.integers(0, vocab, int(rng.integers(4, max_prompt))
                         ).astype(np.int32) for _ in range(distinct)]
    ranks = np.arange(1, distinct + 1, dtype=np.float64)
    probs = (1.0 / ranks) / (1.0 / ranks).sum()          # zipf alpha=1
    picks = rng.choice(distinct, size=n_requests, p=probs)
    sc = SchedulerConfig(admit="continuous", cache_requests=True, **sc_kw)
    sched = Scheduler(cfg, params, sc)
    t0 = time.perf_counter()
    for i in picks:
        sched.submit([pool[i]], max_new_tokens=8)
    sched.drain()
    wall = time.perf_counter() - t0
    hr = sched.request_cache.hit_rate
    rows.append(common.emit(
        "fig_serve.zipf_cache", wall * 1e6 / n_requests,
        f"hit_rate={hr:.2f},hits={sched.request_cache.hits},"
        f"misses={sched.request_cache.misses}"))
    return hr


def _occupancy_arm(rows, cfg, params, prompts, mnts, arm, sc_kw, ch,
                   mesh=None):
    """Serve the workload through one allocator/policy arm; returns the
    USEFUL-work occupancy (a request's surviving run holds a slot for
    decode-ramp + generated ticks — recomputed from the completions so
    preemption thrash, i.e. discarded ticks, cannot inflate the
    concurrency) plus the policy's waste counters."""
    sched = Scheduler(cfg, params, SchedulerConfig(**sc_kw), mesh=mesh)
    for p, m in zip(prompts, mnts):
        sched.submit([p], max_new_tokens=m)
    done = sched.drain()
    st = sched.stats()
    useful_ticks = sum(
        (c.prompt_len - 1) - ((c.prompt_len - 1) // ch) * ch
        + len(c.tokens) for c in done)
    occ = useful_ticks / max(st["decode_steps"], 1)
    # the policy trade-off: recompute pays in redone decode steps,
    # swap pays in bytes over the host link
    waste = (st.get("recomputed_decode_steps", 0),
             st.get("swap_bytes_out", 0))
    rows.append(common.emit(
        f"fig_serve.occupancy.{arm}", occ * 1e6,
        f"useful_live={occ:.2f},"
        f"raw_live={st['mean_occupancy']:.2f},"
        f"capacity={sched.slots.position_capacity},"
        f"preempted={st.get('preempted', 0)},"
        f"recomputed_decode_steps={waste[0]},"
        f"swap_bytes={waste[1]}"))
    return occ, waste, sched


def bench_paged_occupancy(rows, smoke: bool, preempt: str = "recompute"):
    """Equal-cache-memory occupancy: paged vs contiguous allocator under
    the Pareto mixed-length mix (the ISSUE gate: >= 1.5x admitted
    concurrency). Both schedulers get the SAME byte budget of
    global-attention KV positions; the contiguous one can only carve it
    into worst-case max_len slots, the paged one into blocks it maps as
    requests actually grow — short requests stop stranding pool memory,
    so more of them are live per decode tick. Runs on an attention model
    (gemma) — paging targets KV; O(1)-state archs have nothing to page.

    With ``preempt='swap'`` the preemption policies are also compared on
    an OVERLOAD pool (half the equal-memory blocks, so growth genuinely
    hits preempt-on-OOB): recompute's wasted decode steps vs the swap
    policy's bytes moved through the host SwapStore, plus reserved
    admission (the zero-preemption QoS trade-off, reported not gated).
    Gate: swap useful-work occupancy >= recompute's — buying back the
    wasted steps with a block copy must not cost concurrency."""
    cfg = configs.reduced_config("gemma-2b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    # own rng: the phase's workload must not depend on how many draws
    # earlier phases consumed (the comparison is seed-deterministic)
    rng = np.random.default_rng(0)
    n_req, max_prompt, tail_new = (12, 12, 40) if smoke else (48, 12, 80)
    block = 8
    ch = 8
    max_len = max_prompt + tail_new + 8
    contig_slots = 2 if smoke else 4
    budget = contig_slots * max_len             # cache positions (== bytes)
    prompts, mnts = _workload(rng, n_req, cfg.vocab, max_prompt, tail_new)
    base_kw = dict(num_slots=contig_slots, max_len=max_len,
                   prefill_chunk=ch, cache_requests=False)
    # same memory, more slots: width is cheap (dead rows compute junk),
    # positions are the scarce resource being paged. The -1 keeps the
    # TRASH sentinel block inside the byte budget: physical rows =
    # (num_blocks + 1) * block <= budget.
    paged_kw = dict(base_kw, num_slots=4 * contig_slots, allocator="paged",
                    block_size=block, num_blocks=budget // block - 1)
    occ, _, _ = _occupancy_arm(rows, cfg, params, prompts, mnts,
                               "contiguous", base_kw, ch)
    occ_p, _, sched = _occupancy_arm(rows, cfg, params, prompts, mnts,
                                     "paged", paged_kw, ch)
    assert (sched.slots.position_capacity + block) <= budget  # incl. trash
    ratio = occ_p / occ
    rows.append(common.emit("fig_serve.paged_vs_contiguous", 0.0,
                            f"occupancy_ratio={ratio:.2f}"))
    if preempt == "swap":
        bench_preempt_policies(rows, cfg, params, prompts, mnts,
                               paged_kw, ch)
    return ratio


def bench_windowed_ring_paging(rows, smoke: bool):
    """Window-ring paging (the PR-5 tentpole): equal cache memory on a
    WINDOWED model (gemma3 reduced — sliding window 16 + global layers),
    with ``window >> block_size`` so a ring spans many blocks. The dense
    layout reserves the full window-row ring per slot even though the
    Pareto-short majority never fills it; paging the rings through a
    ring-mode page-table group hands those stranded rows to more
    concurrent requests. Both arms get the same TOTAL attention-position
    budget (slots.total_rows: global KV + rings, paged incl. each
    group's trash sentinel block); the gate is admitted (useful-work)
    concurrency at that equal memory."""
    cfg = configs.reduced_config("gemma3-12b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_req, max_prompt, tail_new = (12, 6, 40) if smoke else (48, 6, 80)
    block = 2                               # window 16 >> block 2
    ch = 8
    max_len = max_prompt + tail_new + 8
    contig_slots = 2 if smoke else 4
    window = cfg.pattern[0].window
    budget = contig_slots * (window + max_len)      # dense attn rows
    prompts, mnts = _workload(rng, n_req, cfg.vocab, max_prompt, tail_new)
    base_kw = dict(num_slots=contig_slots, max_len=max_len,
                   prefill_chunk=ch, cache_requests=False)
    # same memory, 4x the slots: split the row budget between the global
    # and ring pools in the dense layout's proportion, minus each
    # group's trash sentinel ((nb+1) * block physical rows per group).
    # A measured sweep of the split (1/16..window/(window+max_len) of
    # the budget to the ring pool) picks proportional: starving the
    # rings preempts 3x more often for less concurrency. Preempt=swap
    # composes the PR-4 win: the under-provisioned pools preempt
    # repeatedly, and the evicted ring+KV blocks resume instead of
    # recomputing (recompute measures ~7% lower here).
    nb_total = budget // block - 2                  # 2 trash sentinels
    nb_ring = max(nb_total * window // (window + max_len), 1)
    paged_kw = dict(base_kw, num_slots=4 * contig_slots, allocator="paged",
                    block_size=block, num_blocks=nb_total - nb_ring,
                    num_window_blocks=nb_ring, preempt="swap")
    occ, _, csched = _occupancy_arm(rows, cfg, params, prompts, mnts,
                                    "windowed_contiguous", base_kw, ch)
    occ_p, _, sched = _occupancy_arm(rows, cfg, params, prompts, mnts,
                                     "windowed_paged", paged_kw, ch)
    assert sched.slots.total_rows <= budget == csched.slots.total_rows, \
        (sched.slots.total_rows, budget)            # equal-memory, really
    st = sched.stats()
    assert st["page_groups"] == 2 and f"ring{window}_blocks_total" in st
    ratio = occ_p / occ
    rows.append(common.emit(
        "fig_serve.windowed_paged_vs_contiguous", 0.0,
        f"occupancy_ratio={ratio:.2f},"
        f"ring_blocks={st[f'ring{window}_blocks_total']},"
        f"preempted={st.get('preempted', 0)}"))
    print(f"# fig_serve: window-ring paging {ratio:.2f}x useful "
          f"concurrency at equal cache memory ({budget} attn rows, "
          f"window {window}, block {block})")
    return ratio


def bench_shared_prefix(rows, smoke: bool):
    """Copy-on-write prefix sharing (this PR's tentpole): the SAME paged
    pool serves prompts that share one system-prompt prefix, with
    ``prefix_sharing`` off and on. Off, every request maps its own copy
    of the prefix blocks; on, the prefix index maps them read-shared and
    only the unique tail (suffix + decode growth) is private — so at
    equal cache memory more requests are live per decode tick. The token
    streams are bit-identical either way (the scheduler differential and
    smoke_opt pin that); this arm measures what the sharing BUYS.
    Gate: >= 1.5x admitted (useful-work) concurrency."""
    cfg = configs.reduced_config("gemma-2b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_req = 12 if smoke else 48
    block = ch = 8
    prefix_len = 24             # 3 blocks, chunk-aligned (lcm(ch, block))
    tail_new = 16
    max_len = prefix_len + 8 + tail_new + 8
    prefix = rng.integers(0, cfg.vocab, prefix_len).astype(np.int32)
    prompts, mnts = [], []
    for _ in range(n_req):
        sfx = rng.integers(0, cfg.vocab,
                           int(rng.integers(1, 8))).astype(np.int32)
        prompts.append(np.concatenate([prefix, sfx]))
        mnts.append(min(2 + int(rng.pareto(1.1) * 3), tail_new))
    # one pool for both arms (equal cache memory by construction): big
    # enough for ~2 unshared requests, so the unshared arm queues while
    # the shared arm's marginal per-request footprint (~footprint - 3
    # prefix blocks) admits more of the same traffic
    kw = dict(num_slots=8, max_len=max_len, prefill_chunk=ch,
              cache_requests=False, allocator="paged", block_size=block,
              num_blocks=12)
    occ, _, _ = _occupancy_arm(rows, cfg, params, prompts, mnts,
                               "prefix_unshared", kw, ch)
    occ_s, _, sched = _occupancy_arm(rows, cfg, params, prompts, mnts,
                                     "prefix_shared",
                                     dict(kw, prefix_sharing=True), ch)
    assert sched.counters["prefix_shared_tokens"] > 0, \
        "prefix sharing never engaged (comparison is vacuous)"
    st = sched.stats()
    ratio = occ_s / occ
    rows.append(common.emit(
        "fig_serve.shared_prefix", 0.0,
        f"occupancy_ratio={ratio:.2f},"
        f"shared_tokens={sched.counters['prefix_shared_tokens']},"
        f"hit_chunks={st['prefix_hit_chunks']},"
        f"cow_copies={st['cow_copies']}"))
    print(f"# fig_serve: shared-prefix occupancy {ratio:.2f}x at equal "
          f"cache memory ({sched.counters['prefix_shared_tokens']} prompt "
          f"tokens admitted pre-written, gate >= 1.5x)")
    return ratio


def bench_mesh_sharding(rows, smoke: bool, mesh_n: int):
    """Sharded slot pool (this PR's tentpole) vs a single pool at equal
    PER-DEVICE cache memory. ``num_blocks``/``num_slots`` are per-SHARD
    quantities in the sharded scheduler, so the mesh arm gets the same
    block pool per device as the mesh=1 arm and simply has ``mesh_n``
    of them — one fused decode/chunk program per tick spans all shards,
    so admitted (useful-work) concurrency per decode step should scale
    with the shard count. When the process actually has >= mesh_n
    devices (the CI forced-8-device lane) the sharded arm runs through
    a real shard_map mesh; otherwise it runs the vmap path — the
    occupancy quantities are identical either way (seed-fixed greedy
    scheduling). Gate (applied by the caller): >= 2x at mesh 4.

    The mix is moderate-UNIFORM lengths, not the Pareto tail: with a
    heavy tail the sharded arm hits the longest request's critical
    path (it admits everything instantly and finishes in exactly that
    many ticks), which caps the measurable ratio regardless of shard
    count. Straggler behavior is the continuous-batching arm's story;
    this arm measures concurrency scaling, so total work must exceed
    critical-path x slots."""
    cfg = configs.reduced_config("gemma-2b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    # enough requests to keep mesh_n shards' worth of slots fed — with
    # too little traffic the sharded arm is load-starved, not measured
    n_req, max_prompt = (32, 12) if smoke else (96, 12)
    block = ch = 8
    max_len = max_prompt + 32 + 8
    slots_per_shard = 4
    # per-shard provision: ~2 worst-case requests' KV — enough that a
    # shard serves, scarce enough that growth pressure (swap preempts)
    # is part of what both arms absorb
    nb_per_shard = 14
    mnts = [int(rng.integers(16, 33)) for _ in range(n_req)]
    prompts = [rng.integers(0, cfg.vocab,
                            int(rng.integers(6, max_prompt + 1))
                            ).astype(np.int32) for _ in range(n_req)]
    base_kw = dict(max_len=max_len, prefill_chunk=ch, cache_requests=False,
                   allocator="paged", block_size=block,
                   num_blocks=nb_per_shard, preempt="swap")
    mesh = None
    if jax.device_count() >= mesh_n:
        from repro.launch import mesh as mesh_lib
        mesh = mesh_lib.make_worker_mesh(mesh_n, axis="slots")
    occ1, _, s1 = _occupancy_arm(
        rows, cfg, params, prompts, mnts, "mesh1",
        dict(base_kw, num_slots=slots_per_shard, mesh_shards=1), ch)
    occn, _, sn = _occupancy_arm(
        rows, cfg, params, prompts, mnts, f"mesh{mesh_n}",
        dict(base_kw, num_slots=slots_per_shard * mesh_n,
             mesh_shards=mesh_n), ch, mesh=mesh)
    # equal per-device memory, really: the sharded pool's total capacity
    # is exactly mesh_n single-shard pools
    assert sn.slots.position_capacity == mesh_n * s1.slots.position_capacity
    ratio = occn / occ1
    rows.append(common.emit(
        "fig_serve.mesh_sharded_vs_single", 0.0,
        f"mesh_occupancy_ratio={ratio:.2f},mesh={mesh_n},"
        f"real_mesh={int(mesh is not None)},"
        f"steals={sn.counters['steals']}"))
    print(f"# fig_serve: mesh={mesh_n} sharded pool {ratio:.2f}x useful "
          f"concurrency vs mesh=1 at equal per-device cache memory "
          f"({nb_per_shard} blocks/shard, "
          f"{'shard_map' if mesh is not None else 'vmap'} path)")
    return ratio


def bench_work_stealing(rows, smoke: bool):
    """Work-stealing rebalance vs static placement under SKEWED
    arrivals: round-robin placement on a 2-shard pool with strictly
    alternating long/short requests parks every long request on shard 0
    and every short one on shard 1. Shard 1 drains its shorts and
    idles; without stealing, shard 0's queue heads block behind its two
    busy slots while shard 1's slots sit free (head-of-line blocking).
    With stealing, each blocked head migrates to the idle shard and the
    drain finishes in fewer fused ticks. Useful ticks are identical
    across the arms (greedy + seed-fixed), so the occupancy ratio IS
    the saved decode steps. Gate: the steal arm really steals and beats
    static placement."""
    cfg = configs.reduced_config("gemma-2b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_req = 8 if smoke else 16
    block = ch = 8
    long_mnt, short_mnt = 32, 2
    max_len = 8 + long_mnt + 8
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32)
               for _ in range(n_req)]
    mnts = [long_mnt if i % 2 == 0 else short_mnt for i in range(n_req)]
    kw = dict(num_slots=4, max_len=max_len, prefill_chunk=ch,
              cache_requests=False, allocator="paged", block_size=block,
              num_blocks=12, mesh_shards=2, placement="round_robin")
    occ_steal, _, ss = _occupancy_arm(rows, cfg, params, prompts, mnts,
                                      "steal", dict(kw, steal=True), ch)
    occ_static, _, st = _occupancy_arm(rows, cfg, params, prompts, mnts,
                                       "no_steal", dict(kw, steal=False),
                                       ch)
    assert st.counters["steals"] == 0
    assert ss.counters["steals"] >= 1, \
        "skewed arrivals never triggered a steal (arm is vacuous)"
    ratio = occ_steal / occ_static
    rows.append(common.emit(
        "fig_serve.work_stealing", 0.0,
        f"occupancy_ratio={ratio:.2f},steals={ss.counters['steals']},"
        f"occ_steal={occ_steal:.2f},occ_static={occ_static:.2f}"))
    print(f"# fig_serve: work stealing {ratio:.2f}x useful concurrency "
          f"vs static round-robin under skewed arrivals "
          f"({ss.counters['steals']} heads stolen)")
    assert occ_steal > occ_static, \
        f"stealing did not beat static placement " \
        f"({occ_steal:.2f} <= {occ_static:.2f})"
    return ratio


def bench_mesh_arms(rows, smoke: bool, mesh_n: int):
    """The sharded-serving arms + their gates (the ISSUE acceptance:
    >= 2x admitted concurrency at mesh 4, equal per-device memory; the
    stealing arm must beat static placement under skew)."""
    ratio = bench_mesh_sharding(rows, smoke, mesh_n)
    floor = 2.0 if mesh_n >= 4 else 1.2
    assert ratio >= floor, \
        f"mesh={mesh_n} occupancy gain regressed " \
        f"({ratio:.2f}x < {floor}x)"
    bench_work_stealing(rows, smoke)
    return ratio


def bench_preempt_policies(rows, cfg, params, prompts, mnts, paged_kw, ch):
    """Preemption-policy comparison on an overloaded block pool (half
    the equal-memory provision — growth OOBs repeatedly): what does a
    preemption COST? recompute redoes the victim's decode steps, swap
    moves its block bytes host-side and resumes, reserved admission
    books the whole budget up front and never preempts."""
    over_kw = dict(paged_kw, num_blocks=paged_kw["num_blocks"] // 2)
    res = {}
    for arm, extra in (("recompute", {}),
                       ("swap", {"preempt": "swap"}),
                       ("reserved", {"admission": "reserved"})):
        res[arm] = _occupancy_arm(rows, cfg, params, prompts, mnts,
                                  f"overload_{arm}", dict(over_kw, **extra),
                                  ch)
    occ = {arm: r[0] for arm, r in res.items()}
    wasted_steps = res["recompute"][1][0]
    swap_bytes = res["swap"][1][1]
    rows.append(common.emit(
        "fig_serve.preempt_swap_vs_recompute", 0.0,
        f"occupancy_swap={occ['swap']:.2f},"
        f"occupancy_recompute={occ['recompute']:.2f},"
        f"wasted_decode_steps={wasted_steps},"
        f"swap_bytes={swap_bytes},"
        f"occupancy_reserved={occ['reserved']:.2f}"))
    print(f"# fig_serve: preempt policies on the overload pool — "
          f"recompute {occ['recompute']:.2f} useful-live "
          f"(wasted {wasted_steps} decode steps), "
          f"swap {occ['swap']:.2f} ({swap_bytes} bytes swapped, "
          f"0 recomputed), reserved {occ['reserved']:.2f} "
          f"({res['reserved'][2].counters['preempted']} preemptions)")
    # the comparison must not be vacuous: overload really preempts, and
    # the swap arm really resumes instead of recomputing
    assert res["recompute"][2].counters["preempted"] >= 1, \
        "overload pool never preempted (comparison is vacuous)"
    assert res["swap"][2].counters["recomputed_decode_steps"] == 0
    assert res["reserved"][2].counters["preempted"] == 0
    # the preserved-work gate: buying back wasted decode steps with a
    # block copy must not cost useful-work occupancy
    assert occ["swap"] >= occ["recompute"], \
        f"swap occupancy {occ['swap']:.2f} < recompute " \
        f"{occ['recompute']:.2f}"
    return occ


def _spec_serve(cfg, params, prompts, mnts, kw, draft_fn=None, **extra):
    """One speculative-arm serve (median wall of 3 timed runs after a
    warm run — greedy + seed-fixed, so streams/steps replay exactly);
    returns (tok_per_s, streams, scheduler)."""
    sc = SchedulerConfig(**dict(kw, **extra))

    def once():
        sched = Scheduler(cfg, params, sc, draft_fn=draft_fn)
        t0 = time.perf_counter()
        for p, m in zip(prompts, mnts):
            sched.submit([p], max_new_tokens=m)
        done = sched.drain()
        wall = time.perf_counter() - t0
        toks = sum(len(c.tokens) for c in done)
        return toks / wall, {c.rid: c.tokens.tolist() for c in done}, sched

    once()                                              # warm compiles
    runs = sorted(once() for _ in range(3))
    return runs[1]


def bench_speculative(rows, smoke: bool):
    """Self-speculative decoding (this PR's tentpole): the verify-accept
    tick drafts k tokens per slot, teacher-forces them through ONE fused
    chunk call, commits the agreeing prefix and rolls the rejected cache
    writes back in-program — so useful (emitted) tokens per decode step
    rises with draft quality while the streams stay bit-identical to the
    speculate=0 oracle.

    Two traffic arms on the paged+swap pool, k=4:

      * draft-friendly — a recorded-continuation draft source through the
        pluggable ``draft_fn`` hook (the draft-model seam): emulates
        grounded traffic where drafts are usually right (extraction /
        summarization-style prompt-lookup hits, or a strong draft
        model). Acceptance ~0.9; gate >= 1.3x useful tokens per decode
        step (measured ~4.6x at smoke scale).
      * adversarial — the built-in trailing-2-gram prompt-lookup
        self-draft on uniform-random prompts: drafts are usually wrong,
        acceptance is near zero, and the arm pins the overhead + the
        correctness story (streams still bit-identical, zero recomputed
        decode steps — no KV was ever silently recomputed to paper over
        a bad rollback).

    The deterministic gate is the decode-step ratio (useful tokens per
    fused step); wall tokens/sec rides along informationally and is
    additionally gated loosely at full (non-smoke) scale."""
    cfg = configs.reduced_config("gemma-2b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_req, mnt = (8, 40) if smoke else (24, 64)
    k = 4
    max_len = 16 + mnt + 8
    prompts = [rng.integers(0, cfg.vocab,
                            int(rng.integers(8, 17))).astype(np.int32)
               for _ in range(n_req)]
    mnts = [mnt] * n_req
    kw = dict(num_slots=4, max_len=max_len, prefill_chunk=8,
              cache_requests=False, allocator="paged", block_size=8,
              preempt="swap")
    base_tps, base_streams, base_sched = _spec_serve(cfg, params, prompts,
                                                     mnts, kw)
    base_steps = base_sched.counters["decode_steps"]
    rows.append(common.emit(
        "fig_serve.spec.base", 1e6 / base_tps,
        f"tok_per_s={base_tps:.1f},steps={base_steps}"))

    # recorded-continuation draft: the oracle streams keyed by prompt
    # bytes (a draft model would slot into the same hook)
    oracle = {prompts[rid].tobytes(): np.asarray(toks, np.int32)
              for rid, toks in base_streams.items()}

    def recorded_draft(seq, need):
        for pb, cont in oracle.items():
            p = np.frombuffer(pb, np.int32)
            if len(seq) >= len(p) and seq[:len(p)].tobytes() == pb:
                done = len(seq) - len(p)
                return cont[done:done + need]
        return []                       # unknown prompt: lookup pads

    out = {}
    for arm, draft_fn in (("draft_friendly", recorded_draft),
                          ("adversarial", None)):
        tps, streams, sched = _spec_serve(cfg, params, prompts, mnts, kw,
                                          draft_fn=draft_fn, speculate=k)
        assert streams == base_streams, \
            f"spec[{arm}] streams diverged from the speculate=0 oracle"
        assert sched.counters["recomputed_decode_steps"] == 0, \
            f"spec[{arm}] recomputed KV ({sched.counters})"
        drafted = sched.counters["spec.drafted_tokens"]
        accepted = sched.counters["spec.accepted_tokens"]
        accept_rate = accepted / max(drafted, 1)
        step_ratio = base_steps / sched.counters["decode_steps"]
        speedup = tps / base_tps
        out[arm] = (step_ratio, accept_rate, speedup)
        rows.append(common.emit(
            f"fig_serve.spec.{arm}", 1e6 / tps,
            f"step_ratio={step_ratio:.2f},accept_rate={accept_rate:.3f},"
            f"tok_per_s={tps:.1f},speedup={speedup:.2f},"
            f"drafted={drafted},accepted={accepted},"
            f"rollbacks={sched.counters['spec.rollbacks']}"))
    fr, fa, fs = out["draft_friendly"]
    print(f"# fig_serve: speculative k={k} — draft-friendly "
          f"{fr:.2f}x useful tokens/step (accept {fa:.2f}, wall "
          f"{fs:.2f}x, gate >= 1.3x); adversarial "
          f"{out['adversarial'][0]:.2f}x (accept "
          f"{out['adversarial'][1]:.3f}), streams bit-identical")
    assert fr >= 1.3, \
        f"draft-friendly useful tokens/step regressed ({fr:.2f}x < 1.3x)"
    assert fa > 0.0 and out["adversarial"][1] > 0.0, \
        "speculation never accepted a real draft (arm is vacuous)"
    if not smoke:
        # wall-clock floor only at full scale (smoke walls are noise)
        assert fs >= 1.3, \
            f"draft-friendly tokens/sec speedup {fs:.2f}x < 1.3x"
    return out


def _overload_serve(cfg, params, prompts, mnts, sc: SchedulerConfig):
    """One overload serve on a fresh scheduler; returns (scheduler,
    {rid: tokens}) — rids restart at 0 per scheduler, so streams are
    positionally comparable across twin runs."""
    sched = Scheduler(cfg, params, sc)
    for p, m in zip(prompts, mnts):
        sched.submit([p], max_new_tokens=m)
    done = sched.drain()
    return sched, {c.rid: c.tokens.tolist() for c in done}


def bench_trace(rows, cfg, params, sc_kw, prompts, mnts, trace_path):
    """The observability arms: tracing + the closed loop.

    1. Overhead gate: serve the continuous workload with observability
       OFF and ON, strictly interleaved (12 off/on pairs, same warmed
       compile caches), and compare the best observed tokens/sec of
       each arm — tracer + live sampler + SLO monitors together must
       cost <= 3%. Interleaving defeats machine drift (a sequential
       off-then-on measurement charges any mid-benchmark slowdown to
       the instrumentation), and best-of-N is the right timing
       statistic because noise only ever *adds* wall time. Disabled
       tracing/sampling is a single attribute or None check per site
       and is on the tier-1 path, so it is free by construction.
    2. Closed-loop export: a traced paged+swap serve on an overloaded
       block pool (preemptions + swaps really happen) with the full
       loop engaged — sampler ticking off every scheduler step, a
       queue-wait SLO monitor with hysteresis, and a
       BackpressureController capping admissions while the alert
       fires. The run must show fire -> actuate -> clear in the
       registry AND as schema-validated trace events (slo-fire /
       backpressure-on / backpressure-off / slo-clear + 'C' counter
       tracks), and — the control invariant — its greedy token streams
       must be bit-identical to an UNCONTROLLED twin run. Exported as
       Chrome trace-event JSON to ``trace_path`` (Perfetto-loadable)
       plus the sampler ring as ``<trace_path>.samples.jsonl``."""
    sc = SchedulerConfig(admit="continuous", cache_requests=False, **sc_kw)
    _run_policy(cfg, params, sc, prompts, mnts)         # warm compiles

    def toks_per_s():
        wall, toks, _, _ = _run_policy(cfg, params, sc, prompts, mnts)
        return toks / wall

    tr = Tracer(enabled=True, capacity=1 << 20)

    def obs_on():
        """Install tracer + sampler + SLO monitors (the full passive
        observability stack; controllers excluded — they change
        scheduling, which would measure policy, not instrumentation).
        The sampler runs at the live-monitoring cadence (20 Hz wall
        clock — registry snapshots are not free, and SLO hysteresis
        operates on human-scale breaches, not per-decode-tick noise);
        the per-tick cost between samples is one time check."""
        smp = Sampler(tracer=tr, wall_clock=True, min_interval_s=0.05)
        slo = SLOManager([
            Rule("queue_wait", key="serve.queue_head_wait_s", op="<",
                 threshold=0.25),
            Rule("ttft_p95", key="serve.ttft_ms.p95", op="<",
                 threshold=2000.0)], tracer=tr)
        smp.add_listener(slo.on_sample)
        return set_tracer(tr), set_sampler(smp)

    def measure():
        off, on = [], []
        for _ in range(12):             # interleaved off/on pairs
            off.append(toks_per_s())
            prev_tr, prev_smp = obs_on()
            on.append(toks_per_s())
            set_tracer(prev_tr)
            set_sampler(prev_smp)
            tr.clear()
        return max(off), max(on)

    # up to 3 attempts, keep the MINIMUM observed overhead: measured
    # per-serve wall noise on a shared box is far larger than the true
    # instrumentation cost (~1.5%: tracer ~free, 20 Hz sampling ~1%),
    # and noise can only inflate an interleaved best-of-N ratio — a real
    # regression shows up in every attempt, a noise spike cannot
    off, on = measure()
    overhead = max(0.0, 1.0 - on / off)
    for _ in range(2):
        if overhead <= 0.03:
            break
        off2, on2 = measure()
        if max(0.0, 1.0 - on2 / off2) < overhead:
            off, on = off2, on2
            overhead = max(0.0, 1.0 - on / off)
    rows.append(common.emit(
        "fig_serve.trace_overhead", overhead * 1e6,
        f"overhead_pct={overhead * 100:.2f},"
        f"tok_per_s_off={off:.1f},tok_per_s_on={on:.1f}"))
    assert overhead <= 0.03, \
        f"observability overhead {overhead * 100:.2f}% > 3% tokens/sec"

    # closed-loop traced paged + swap serve on an overload pool (the
    # Perfetto artifact CI validates): gemma reduced, half the
    # equal-memory blocks so growth hits preempt-on-OOB and swaps
    # really happen
    gcfg = configs.reduced_config("gemma-2b")
    gparams = T.init_model(jax.random.PRNGKey(0), gcfg)
    rng = np.random.default_rng(0)
    max_prompt, tail_new, block, ch = 12, 40, 8, 8
    max_len = max_prompt + tail_new + 8
    gp, gm = _workload(rng, 12, gcfg.vocab, max_prompt, tail_new)
    osc = SchedulerConfig(
        num_slots=8, max_len=max_len, prefill_chunk=ch,
        cache_requests=False, allocator="paged", block_size=block,
        num_blocks=(2 * max_len // block - 1) // 2, preempt="swap")
    # the control-invariant twin: same workload, same config, NO
    # controllers — the closed-loop run's streams must match these bits
    _, base_streams = _overload_serve(gcfg, gparams, gp, gm, osc)

    tr = Tracer(enabled=True, capacity=1 << 20)
    smp = Sampler(tracer=tr, counter_tracks=(
        ("serve.pending", "value"), ("serve.live", "value"),
        ("serve.generated_tokens", "rate")))
    # overload holds the queue head for many consecutive ticks, so a
    # tiny head-wait threshold fires deterministically; it clears once
    # admission catches up and the queue drains
    slo = SLOManager([Rule("queue_wait", key="serve.queue_head_wait_s",
                           op="<", threshold=1e-4, fire_after=2,
                           clear_after=2)], tracer=tr)
    smp.add_listener(slo.on_sample)
    # the registry namespace is process-global (the overhead arm above
    # also evaluated a queue_wait rule) — assert on deltas, not levels
    fired0 = slo.registry.counter("obs.slo.queue_wait.fired").value
    engaged0 = slo.registry.counter(
        "obs.control.backpressure.engaged").value
    prev_tr = set_tracer(tr)
    prev_smp = set_sampler(smp)
    try:
        sched = Scheduler(gcfg, gparams, osc)
        ctrl = BackpressureController(sched, admit_cap=1, preempt="swap",
                                      tracer=tr)
        slo.subscribe(ctrl)
        for p, m in zip(gp, gm):
            sched.submit([p], max_new_tokens=m)
        done = sched.drain()
    finally:
        set_tracer(prev_tr)
        set_sampler(prev_smp)
    streams = {c.rid: c.tokens.tolist() for c in done}
    assert streams == base_streams, \
        "closed-loop streams diverged from the uncontrolled twin " \
        "(controllers must only change timing/admission)"
    # the loop really closed: fired >= once, actuated, and recovered
    mon = slo.monitors["queue_wait"]
    fired = slo.registry.counter("obs.slo.queue_wait.fired").value - fired0
    engaged = slo.registry.counter(
        "obs.control.backpressure.engaged").value - engaged0
    assert fired >= 1, "SLO never fired under forced overload"
    assert engaged >= 1, "backpressure never actuated"
    assert not mon.firing and not ctrl.engaged, \
        "alert/controller still engaged after the queue drained"
    assert sched.admit_cap is None, "admit_cap not restored on clear"

    data = tr.chrome_trace()
    problems = validate_chrome_trace(data)
    assert not problems, f"exported trace invalid: {problems[:3]}"
    names = {e["name"] for e in data["traceEvents"]}
    want = {"submit", "admit", "prefill", "decode", "decode-tick",
            "retire", "slo-fire", "slo-clear", "backpressure-on",
            "backpressure-off"}
    assert want <= names, f"trace missing events: {want - names}"
    assert any(e["ph"] == "C" for e in data["traceEvents"]), \
        "sampler counter tracks missing from the trace"
    assert sched.counters["swapped_out"] >= 1 and "swap-out" in names, \
        "overload trace never swapped (artifact would not show swap)"
    slot_tracks = {e["args"]["name"] for e in data["traceEvents"]
                   if e["ph"] == "M" and e["name"] == "thread_name"
                   and e["args"]["name"].startswith("slot")}
    assert len(slot_tracks) >= 2, f"per-slot tracks missing: {slot_tracks}"
    tr.export_chrome(trace_path)
    smp.export_jsonl(f"{trace_path}.samples.jsonl")
    rows.append(common.emit(
        "fig_serve.trace_export", float(len(data["traceEvents"])),
        f"path={trace_path},events={len(data['traceEvents'])},"
        f"slot_tracks={len(slot_tracks)},"
        f"swaps={sched.counters['swapped_out']}"))
    rows.append(common.emit(
        "fig_serve.closed_loop", 0.0,
        f"fired={fired},engaged={engaged},"
        f"samples={smp.sample_count},streams_identical=1"))
    print(f"# fig_serve: observability overhead {overhead * 100:.2f}% "
          f"(gate <= 3%); closed loop fired/actuated/recovered; "
          f"{len(data['traceEvents'])} trace events "
          f"-> {trace_path} (load in https://ui.perfetto.dev)")
    return overhead


def run(rows=None, smoke: bool = False, paged: bool = False,
        preempt: str = "recompute", trace: str = None,
        shared_prefix: bool = False, spec: bool = False, mesh: int = 0):
    rows = rows if rows is not None else []
    if shared_prefix and not paged:
        # standalone smoke of just the CoW prefix-sharing arm
        sratio = bench_shared_prefix(rows, smoke)
        assert sratio >= 1.5, \
            f"shared-prefix occupancy gain regressed ({sratio:.2f}x < 1.5x)"
        return rows
    if spec and not paged:
        # standalone smoke of just the speculative-decoding arms
        bench_speculative(rows, smoke)
        return rows
    if mesh and not paged:
        # standalone sharded-serving arms (the CI forced-8-device lane)
        bench_mesh_arms(rows, smoke, mesh)
        return rows
    print("# fig_serve: continuous vs static batching on the slot pool")
    arch = "rwkv6-1.6b"                 # O(1)-state decode: cache-cheap
    cfg = configs.reduced_config(arch)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    if smoke:
        n_req, max_prompt, tail_new, slots = 16, 12, 48, 4
    else:
        n_req, max_prompt, tail_new, slots = 64, 12, 96, 8
    sc_kw = dict(num_slots=slots, max_len=max_prompt + tail_new + 8,
                 prefill_chunk=8)

    prompts, mnts = _workload(rng, n_req, cfg.vocab, max_prompt, tail_new)
    speedup, step_ratio = bench_policies(rows, cfg, params, sc_kw, prompts,
                                         mnts)
    hr = bench_zipf_cache(rows, cfg, params, sc_kw, rng, n_req, cfg.vocab,
                          max_prompt)
    print(f"# fig_serve: continuous/static speedup {speedup:.2f}x "
          f"(gate >= 2x), step ratio {step_ratio:.2f}x, "
          f"zipf cache hit rate {hr:.2f} (gate > 0)")
    if paged:
        ratio = bench_paged_occupancy(rows, smoke, preempt=preempt)
        print(f"# fig_serve: paged/contiguous occupancy {ratio:.2f}x "
              f"at equal cache memory (gate >= 1.5x)")
        assert ratio >= 1.5, \
            f"paged occupancy gain regressed ({ratio:.2f}x < 1.5x)"
        # measured: 1.77x at smoke scale, 1.29x at full scale (the win
        # scales with the windows' share of cache memory; here the
        # Pareto tail's global KV dominates) — gate below both
        wratio = bench_windowed_ring_paging(rows, smoke)
        assert wratio >= 1.25, \
            f"window-ring paging gain regressed ({wratio:.2f}x < 1.25x)"
        sratio = bench_shared_prefix(rows, smoke)
        assert sratio >= 1.5, \
            f"shared-prefix occupancy gain regressed ({sratio:.2f}x < 1.5x)"
    if spec:
        bench_speculative(rows, smoke)
    if mesh:
        bench_mesh_arms(rows, smoke, mesh)
    if trace:
        bench_trace(rows, cfg, params, sc_kw, prompts, mnts, trace)
    if smoke:
        # wall-clock is noise-dominated at smoke scale; gate on the
        # deterministic decode-step ratio instead
        assert step_ratio > 1.3, \
            f"continuous needed too many steps ({step_ratio:.2f}x)"
    else:
        # the ISSUE acceptance gate: >= 2x at batch >= 8. The decode-
        # step ratio is deterministic; the wall floor is kept loose
        # (1.5x) so machine noise cannot flake a genuinely-2x result.
        assert step_ratio >= 2.0, \
            f"decode-step ratio regressed ({step_ratio:.2f}x < 2x)"
        assert speedup > 1.5, \
            f"tokens/sec speedup regressed ({speedup:.2f}x)"
    assert hr > 0.0, "request cache never hit under zipf mix"
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + assertions (CI)")
    ap.add_argument("--paged", action="store_true",
                    help="also run the paged-vs-contiguous equal-memory "
                         "occupancy comparison (gate >= 1.5x)")
    ap.add_argument("--preempt", choices=["recompute", "swap"],
                    default="recompute",
                    help="with --paged: 'swap' adds the swap-out and "
                         "reserved-admission arms (wasted decode steps "
                         "vs swap bytes; gate: swap occupancy >= "
                         "recompute's)")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="export a Chrome trace-event JSON from a traced "
                         "paged+swap serve (Perfetto-loadable), validate "
                         "it, and gate tracer overhead at <= 3% tok/s")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="run only the copy-on-write prefix-sharing "
                         "occupancy arm (gate >= 1.5x admitted "
                         "concurrency at equal cache memory; included "
                         "in --paged automatically)")
    ap.add_argument("--spec", action="store_true",
                    help="run the speculative-decoding arms (draft-"
                         "friendly recorded-draft + adversarial lookup "
                         "self-draft; gate >= 1.3x useful tokens/step "
                         "and acceptance > 0, streams bit-identical to "
                         "speculate=0). Without --paged, runs ONLY them")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="run the sharded-slot-pool arms at N shards: "
                         "mesh=N vs mesh=1 occupancy at equal per-device "
                         "cache memory (gate >= 2x at N >= 4) plus the "
                         "work-stealing-vs-static arm under skewed "
                         "arrivals. Uses a real shard_map mesh when the "
                         "process has >= N devices, the vmap path "
                         "otherwise. Without --paged, runs ONLY them")
    args = ap.parse_args(argv)
    run(smoke=args.smoke, paged=args.paged, preempt=args.preempt,
        trace=args.trace, shared_prefix=args.shared_prefix,
        spec=args.spec, mesh=args.mesh)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
