"""Runtime throughput suite: requests/sec vs batch size, per-request
dispatch vs the batched KernelService (beyond-paper, the ROADMAP's
traffic-scale story).

The paper measures per-kernel speedup for one caller; serving millions of
users means the dispatch layer itself must amortize: one compiled program
per shape bucket, one launch per bucket batch instead of per request.
Rows report the batched wall-clock per request (``us_per_call``) and, as
``derived``, the measured speedup over dispatching the same (warm,
compiled) requests one at a time — the quantity the ISSUE acceptance
gate checks (>= 2x at batch >= 32).

Both paths produce bit-identical results (asserted here), so the
comparison is pure dispatch-efficiency.

A final table reports the dispatcher's own observability (PR 6): the
compile-cache hit/miss counts and the compile-vs-execute wall-time
split, overall (``runtime.dispatch.*`` registry metrics) and per bucket
(``runtime.dispatch.bucket.*``) — where the amortization argument is
measured rather than asserted.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.obs import REGISTRY
from repro.runtime import KernelService, Request, ServiceConfig
from repro.runtime.dispatch import BUCKET_STATS

BATCHES = (1, 8, 32, 128)


def _chain_request(rng, n: int) -> Request:
    r = np.sort(rng.integers(0, 5000, n)).astype(np.int32)
    q = np.sort(rng.integers(0, 400, n)).astype(np.int32)
    return Request("chain", {"q": q, "r": r})


def _dtw_request(rng, n: int, m: int) -> Request:
    return Request("dtw", {"s": rng.normal(size=n).astype(np.float32),
                           "r": rng.normal(size=m).astype(np.float32)})


def _throughput(svc: KernelService, reqs, repeats: int = 3):
    """(batched_us_per_req, per_request_us_per_req); both warm."""
    batched = svc.submit(reqs)                      # warm the bucket compiles
    singles = [svc.submit([r])[0] for r in reqs]    # warm the B=1 compiles
    for a, b in zip(batched, singles):              # dispatch must be exact
        for k in a:
            assert np.array_equal(a[k], b[k]), f"batched != single on {k}"

    def med(fn):
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[len(ts) // 2] * 1e6 / len(reqs)

    us_b = med(lambda: svc.submit(reqs))
    us_s = med(lambda: [svc.submit([r]) for r in reqs])
    return us_b, us_s


def bench_kernel(rows, name: str, make_request, svc: KernelService,
                 batches=BATCHES):
    rng = np.random.default_rng(0)
    for bsz in batches:
        reqs = [make_request(rng) for _ in range(bsz)]
        us_b, us_s = _throughput(svc, reqs)
        rows.append(common.emit(
            f"fig_runtime.{name}.batch{bsz}", us_b,
            f"speedup_vs_per_request={us_s / us_b:.2f}"))


def report_dispatch(rows):
    """Dispatcher observability rows: overall compile/execute split plus
    the per-bucket table (hits amortize the bucket's one compile)."""
    snap = REGISTRY.snapshot()
    hits = snap.get("runtime.dispatch.cache_hits", 0)
    misses = snap.get("runtime.dispatch.cache_misses", 0)
    rows.append(common.emit(
        "fig_runtime.dispatch.cache",
        snap.get("runtime.dispatch.execute_ms.p50", 0.0) * 1e3,
        f"hits={hits},misses={misses},"
        f"compile_ms={snap.get('runtime.dispatch.compile_ms.sum', 0.0)},"
        f"execute_ms={snap.get('runtime.dispatch.execute_ms.sum', 0.0)}"))
    for key, b in sorted(BUCKET_STATS.buckets.items()):
        rows.append(common.emit(
            f"fig_runtime.dispatch.bucket.{key}",
            b["execute_ms"] * 1e3 / max(b["hits"], 1),
            f"hits={b['hits']},misses={b['misses']},"
            f"compile_ms={b['compile_ms']:.1f},"
            f"execute_ms={b['execute_ms']:.1f}"))


def run(rows=None, smoke: bool = False):
    rows = rows if rows is not None else []
    print("# fig_runtime: batched KernelService vs per-request dispatch")
    svc = KernelService(ServiceConfig(dtw_tile=16, seq_bucket=64))
    BUCKET_STATS.clear()        # per-run table, not process history
    batches = BATCHES[:3] if smoke else BATCHES     # smoke: skip b128
    bench_kernel(rows, "chain",
                 lambda r: _chain_request(r, int(r.integers(64, 256))), svc,
                 batches)
    bench_kernel(rows, "dtw",
                 lambda r: _dtw_request(r, int(r.integers(24, 64)),
                                        int(r.integers(24, 64))), svc,
                 batches)
    report_dispatch(rows)
    return rows


if __name__ == "__main__":
    import sys
    run(smoke="--smoke" in sys.argv)
