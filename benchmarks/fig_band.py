"""§V-B band-truncation claim: T = 5000 -> 64 with negligible mispredictions.

Paper: misprediction rate < 9e-6 at T=64 (and the chain stage output is
unchanged for minimap2 purposes). We sweep T over {16, 32, 64, 128, 256}
against a T=2000 oracle on synthetic anchor sets with realistic collinear
structure, reporting the f-score disagreement rate as ``derived``.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import chain as chain_lib
from repro.data import genomics

T_SWEEP = (16, 32, 64, 128, 256)
ORACLE_T = 2000
N_ANCHORS = 4000
N_SETS = 3


def run(rows=None):
    rows = rows if rows is not None else []
    print("# fig_band: T truncation vs T=2000 oracle "
          "(derived = misprediction rate)")
    import time
    for T in T_SWEEP:
        mis, total = 0, 0
        us = 0.0
        for s in range(N_SETS):
            q, r = genomics.anchor_set(N_ANCHORS, seed=s)
            t0 = time.perf_counter()
            f_t, _ = chain_lib.chain_ref_unbanded(q, r, T=T)
            us += (time.perf_counter() - t0) * 1e6
            f_o, _ = chain_lib.chain_ref_unbanded(q, r, T=ORACLE_T)
            mis += int(np.sum(np.abs(f_t - f_o) > 1e-6))
            total += len(q)
        rate = mis / total
        rows.append(common.emit(f"fig_band.T{T}", us / N_SETS,
                                f"mispred={rate:.2e}"))
    return rows


if __name__ == "__main__":
    run()
