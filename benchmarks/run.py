"""Benchmark driver — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig6,fig_band]

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks.common for
the two-column semantics: measured CPU wall-clock + the hardware-
independent depth-model / claim-specific derived quantity).

The dry-run / roofline numbers (EXPERIMENTS.md §Dry-run/§Roofline) come
from ``python -m repro.launch.dryrun``, not from this driver — they need
the 512-device XLA flag that must not leak into benchmark processes.
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig6,fig7,fig8,fig9,fig_band,"
                         "fig_runtime,fig_serve")
    args = ap.parse_args(argv)

    from benchmarks import (fig6_kernels, fig7_sync, fig8_end2end,
                            fig9_blocksize, fig_band, fig_runtime,
                            fig_serve)
    suites = {
        "fig6": fig6_kernels.run,
        "fig7": fig7_sync.run,
        "fig8": fig8_end2end.run,
        "fig9": fig9_blocksize.run,
        "fig_band": fig_band.run,
        "fig_runtime": fig_runtime.run,
        # full sweep includes the paged-allocator occupancy comparison
        # (CI smoke reaches it via `fig_serve --smoke --paged`)
        "fig_serve": lambda rows: fig_serve.run(rows, paged=True),
    }
    want = args.only.split(",") if args.only else list(suites)

    rows = []
    t0 = time.time()
    print("name,us_per_call,derived")
    for name in want:
        if name not in suites:
            print(f"unknown suite {name!r}; have {sorted(suites)}",
                  file=sys.stderr)
            return 2
        suites[name](rows)
    print(f"# total: {len(rows)} rows in {time.time()-t0:.0f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
