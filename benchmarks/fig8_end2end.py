"""Fig. 8 reproduction: the end-to-end read mapper over the paper's five
input profiles (Table IV statistics, scaled for CPU).

Paper: end-to-end speedups 2.27-3.66x; PBHF (high-accuracy) inputs gain
most because their work shifts from align to seed/chain where chunk
parallelism bites. We report, per profile: baseline and squire wall-clock
(CPU proxy), the accuracy (must be equal — the transformation is exact),
and as ``derived`` the per-read depth-model speedup composed across the
three stages weighted by their measured work split.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.apps.read_mapper import MapperConfig, ReadMapper, mapping_accuracy
from repro.data import genomics

PROFILE_SCALE = 0.25     # lengths vs Table IV/10 (CPU wall-clock budget)
N_READS = 3
REF_LEN = 20_000
W = 16                   # paper's balanced design point


def _scaled(profile):
    return genomics.ReadProfile(
        profile.name, max(300, int(profile.mean_len * PROFILE_SCALE)),
        max(60, int(profile.std_len * PROFILE_SCALE)), profile.accuracy)


def _model_speedup(res, n_anchors_mean, read_len, w=W):
    """Compose per-stage depth models with the align/seed split the paper
    describes (align work ~ read_len^2; seed/chain ~ anchors)."""
    ds_sw, dq_sw = common.depth_dtw(read_len, int(read_len * 1.2), w)
    ds_ch, dq_ch = common.depth_chain(max(n_anchors_mean, 1), 64, w)
    ds_so, dq_so = common.depth_radix(max(n_anchors_mean, 1) * 8, w)
    work_sw = ds_sw
    work_ch = ds_ch
    work_so = ds_so
    seq = work_sw + work_ch + work_so
    par = dq_sw + dq_ch + dq_so
    return seq / par


def run(rows=None):
    rows = rows if rows is not None else []
    print("# fig8: end-to-end read mapper per input profile")
    ref = genomics.make_reference(REF_LEN, seed=0)
    for profile in genomics.PROFILES:
        prof = _scaled(profile)
        pairs = genomics.sample_reads(ref, prof, N_READS, seed=1)
        reads = [r for r, _ in pairs]
        truths = [t for _, t in pairs]

        stats = {}
        for mode in ("baseline", "squire"):
            mapper = ReadMapper(ref, MapperConfig(mode=mode, num_workers=W))
            mapper.map_read(reads[0])                 # warm compile caches
            t0 = time.time()
            res = mapper.map_reads(reads)
            dt = (time.time() - t0) * 1e6 / len(reads)
            stats[mode] = (dt, res)

        acc_b = mapping_accuracy(stats["baseline"][1], truths)
        acc_s = mapping_accuracy(stats["squire"][1], truths)
        assert acc_b == acc_s, "exactness violated"
        n_anchor = int(np.mean([r.n_anchors for r in stats["squire"][1]]))
        model = _model_speedup(stats["squire"][1], n_anchor, prof.mean_len)
        rows.append(common.emit(
            f"fig8.{profile.name}.baseline", stats["baseline"][0],
            f"acc={acc_b:.2f}"))
        rows.append(common.emit(
            f"fig8.{profile.name}.squire", stats["squire"][0],
            f"model_speedup={model:.2f}"))
    return rows


if __name__ == "__main__":
    run()
