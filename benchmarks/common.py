"""Shared benchmark utilities: timing, CSV emission, and the critical-path
depth model.

The paper's Figures 6-8 are gem5 cycle measurements of parallel hardware;
this container is one CPU core, so wall-clock cannot show MIMD speedups.
Each benchmark therefore reports two quantities per configuration:

  * ``us_per_call`` — measured wall-clock (the honest CPU proxy), and
  * ``derived``     — the *critical-path depth model*: the length of the
    serial dependency chain under the paper's work partitioning, in cell-
    updates. The depth ratio sequential/parallel is the hardware-
    independent reproduction of the paper's speedup curves (it is what a
    machine with W independent workers is limited by).

Every row prints as ``name,us_per_call,derived`` (the run.py contract).
"""

from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 1, repeats: int = 3,
            **kw) -> float:
    """Median wall-clock microseconds of fn(*args); blocks on the result."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived) -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line)
    return line


# --------------------------------------------------------------------------
# critical-path depth models (cell-updates on the serial chain)
# --------------------------------------------------------------------------

def depth_dtw(n: int, m: int, workers: int) -> tuple[int, int]:
    """(sequential, squire) depth for an n x m DTW/SW matrix with column-
    blocks of m/workers (paper Fig. 5): worker x starts row i one block
    after worker x-1 -> pipeline depth (n + W - 1) * ceil(m/W)."""
    seq = n * m
    blk = -(-m // workers)
    sq = (n + workers - 1) * blk
    return seq, sq


def depth_chain(n: int, band: int, workers: int) -> tuple[int, int]:
    """Chain: fission makes the (N x T) score pass parallel (depth
    N*T/W amortized to T/W per anchor); the serial consume chain is N
    steps whose inner max is a W-way parallel reduction."""
    seq = n * band                       # scalar inner loop, one worker
    per_step = max(band // workers, 1)
    sq = n * per_step + workers          # + boundary handoff
    return seq, sq


def depth_radix(n: int, workers: int, passes: int = 4) -> tuple[int, int]:
    """Radix: chunk sorts are independent (depth passes * n/W); the merge
    tree adds log2(W) passes over n elements (parallel pairwise merges)."""
    import math
    seq = passes * n
    chunk = passes * (-(-n // workers))
    merge = int(math.log2(max(workers, 2))) * n // workers
    return seq, chunk + merge


def depth_seed(n_anchors: int, workers: int) -> tuple[int, int]:
    """Seeding is dominated by the anchor sort (paper §VI-B)."""
    return depth_radix(n_anchors, workers)
