"""Property tests for the semiring algebra (hypothesis).

The engine's exactness rests on two algebraic facts: affine maps over a
semiring compose associatively, and composition distributes the way
affine_compose claims. These are the invariants that let Squire's ordered
counters dissolve into chunked/associative scans — so they get property
tests, not just examples.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.semiring import MAXPLUS, MINPLUS, REAL, SEMIRINGS

finite = st.floats(min_value=-100, max_value=100, allow_nan=False,
                   width=32)


def _vec(draw, n):
    return jnp.asarray(draw(st.lists(finite, min_size=n, max_size=n)),
                       jnp.float32)


@st.composite
def affine_triples(draw):
    n = draw(st.integers(1, 8))
    return tuple(_vec(draw, n) for _ in range(7))  # a1,b1,a2,b2,a3,b3,x


@given(affine_triples(), st.sampled_from(sorted(SEMIRINGS)))
@settings(max_examples=100, deadline=None)
def test_affine_compose_is_apply_twice(tr, srname):
    sr = SEMIRINGS[srname]
    a1, b1, a2, b2, _, _, x = tr
    ca, cb = sr.affine_compose(a1, b1, a2, b2)
    lhs = sr.affine_apply(ca, cb, x)
    rhs = sr.affine_apply(a2, b2, sr.affine_apply(a1, b1, x))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-4)


@given(affine_triples(), st.sampled_from(sorted(SEMIRINGS)))
@settings(max_examples=100, deadline=None)
def test_affine_compose_associative(tr, srname):
    sr = SEMIRINGS[srname]
    a1, b1, a2, b2, a3, b3, x = tr
    l_a, l_b = sr.affine_compose(*sr.affine_compose(a1, b1, a2, b2), a3, b3)
    r_a, r_b = sr.affine_compose(a1, b1, *sr.affine_compose(a2, b2, a3, b3))
    np.testing.assert_allclose(sr.affine_apply(l_a, l_b, x),
                               sr.affine_apply(r_a, r_b, x),
                               rtol=1e-4, atol=1e-3)


def test_tropical_matmul_matches_dense_def():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(5, 7)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(7, 3)), jnp.float32)
    got = MAXPLUS.matmul(a, b)
    want = np.max(np.asarray(a)[:, :, None] + np.asarray(b)[None, :, :],
                  axis=1)
    np.testing.assert_allclose(got, want, atol=1e-6)
    got_min = MINPLUS.matmul(a, b)
    want_min = np.min(np.asarray(a)[:, :, None] + np.asarray(b)[None, :, :],
                      axis=1)
    np.testing.assert_allclose(got_min, want_min, atol=1e-6)


def test_real_semiring_is_plain_linear_algebra():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)
    np.testing.assert_allclose(REAL.matmul(a, b), np.asarray(a) @
                               np.asarray(b), rtol=1e-5, atol=1e-5)


def test_identity_elements():
    for sr in SEMIRINGS.values():
        x = jnp.asarray([1.5, -2.0, 3.0], jnp.float32)
        one = jnp.full_like(x, sr.one)
        np.testing.assert_allclose(sr.mul(one, x), x)
        if np.isfinite(sr.zero):
            zero = jnp.full_like(x, sr.zero)
            np.testing.assert_allclose(sr.add(zero, x), x)
