"""repro.runtime: bucketing round-trips, dispatcher equivalence (vmap and
shard_map), mixed-kernel KernelService.submit bit-identical to direct
kernel calls, the batched mapper vs per-read ReadMapper, the pipelined
executor, and the autotune cache."""

import json
from functools import partial

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.apps.read_mapper import MapperConfig, ReadMapper
from repro.core import align as align_lib
from repro.core import chain as chain_lib
from repro.core import dtw as dtw_lib
from repro.core import sort as rsort
from repro.core.scan1d import affine_scan
from repro.data import genomics
from repro.runtime import (Autotuner, BucketSpec, KernelService, Request,
                           ServiceConfig, bucketing, pad_stack, run_pipelined,
                           unpad, valid_mask)
from repro.runtime.autotune import seed_from_fig9
from repro.runtime.dispatch import Dispatcher, make_worker_mesh

CFG = ServiceConfig(seq_bucket=32, sw_tile=8, dtw_tile=8, anchor_bucket=64,
                    sort_bucket=64, scan_bucket=16)


# --------------------------------------------------------------------------
# bucketing
# --------------------------------------------------------------------------

def test_bucket_specs():
    lin = BucketSpec(64)
    assert [lin.padded(n) for n in (1, 64, 65, 130)] == [64, 64, 128, 192]
    p2 = BucketSpec(64, mode="pow2")
    assert [p2.padded(n) for n in (1, 64, 65, 130)] == [64, 64, 128, 256]


def test_pad_mask_unpad_roundtrip(rng):
    arrs = [rng.normal(size=n).astype(np.float32) for n in (3, 17, 32, 1)]
    lengths = bucketing.lengths_of(arrs)
    stacked = pad_stack(arrs, 32, fill=-1.0)
    assert stacked.shape == (4, 32)
    mask = valid_mask(lengths, 32)
    assert np.all(stacked[~mask] == -1.0)       # padding is all sentinel
    back = unpad(stacked, lengths)
    for a, b in zip(arrs, back):
        np.testing.assert_array_equal(a, b)     # pad -> unpad is identity


def test_group_by_bucket():
    groups = bucketing.group_by_bucket([3, 70, 64, 130, 5], BucketSpec(64))
    assert groups == {64: [0, 2, 4], 128: [1], 192: [3]}


# --------------------------------------------------------------------------
# dispatcher
# --------------------------------------------------------------------------

def _affine(x, y):
    return x * 2.0 + y, jnp.sum(x)


@pytest.mark.parametrize("use_mesh", [False, True])
def test_dispatcher_matches_direct_loop(use_mesh):
    d = Dispatcher(mesh=make_worker_mesh() if use_mesh else None)
    x = np.arange(15, dtype=np.float32).reshape(5, 3)
    y = np.float32(1.0)
    out, s = d.run(_affine, (x, y), in_axes=(0, None))
    direct = [jax.jit(_affine)(x[i], y) for i in range(5)]
    np.testing.assert_array_equal(np.asarray(out),
                                  np.stack([np.asarray(o) for o, _ in direct]))
    np.testing.assert_array_equal(np.asarray(s),
                                  np.stack([np.asarray(v) for _, v in direct]))


def test_dispatcher_odd_batch_through_mesh():
    # on this 1-device container the worker count is 1, so any batch size
    # divides; the test still pins the shard_map path's shape contract
    # (production meshes only change num_workers, not the semantics).
    d = Dispatcher(mesh=make_worker_mesh())
    x = np.arange(7, dtype=np.float32)[:, None]
    out, _ = d.run(_affine, (x, np.float32(0.0)), in_axes=(0, None))
    assert np.asarray(out).shape == (7, 1)


# --------------------------------------------------------------------------
# pipeline
# --------------------------------------------------------------------------

def test_run_pipelined_preserves_order_and_results():
    fn = jax.jit(lambda x: x * x)
    items = [np.float32(i) for i in range(9)]
    got = list(run_pipelined(items, fn, depth=3))
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray([i * i for i in range(9)],
                                             np.float32))


def test_run_pipelined_propagates_producer_errors():
    def items():
        yield 1.0
        raise RuntimeError("producer boom")
    with pytest.raises(RuntimeError, match="producer boom"):
        list(run_pipelined(items(), lambda x: x))


# --------------------------------------------------------------------------
# KernelService == direct kernel calls (bit-identical, shape sweep)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def svc():
    return KernelService(CFG)


def test_service_chain_matches_direct(svc, rng):
    direct_fn = jax.jit(partial(chain_lib.chain_anchors, T=CFG.chain_T,
                                mode=CFG.chain_mode, block=CFG.chain_block))
    reqs, want = [], []
    for n in (5, 17, 63, 130):
        r = np.sort(rng.integers(0, 5000, n)).astype(np.int32)
        q = np.sort(rng.integers(0, 400, n)).astype(np.int32)
        reqs.append(Request("chain", {"q": q, "r": r}))
        want.append(direct_fn(jnp.asarray(q), jnp.asarray(r)))
    for got, (f, pred) in zip(svc.submit(reqs), want):
        np.testing.assert_array_equal(got["f"], np.asarray(f))
        np.testing.assert_array_equal(got["pred"], np.asarray(pred))


def test_service_sw_matches_direct(svc, rng):
    reqs, want = [], []
    for la, lb in ((7, 12), (31, 17), (40, 64), (100, 80)):
        a = rng.integers(0, 4, la).astype(np.int32)
        b = rng.integers(0, 4, lb).astype(np.int32)
        reqs.append(Request("sw", {"a": a, "b": b}))
        mat, score = align_lib.sw_tiled(
            jnp.asarray(a), jnp.asarray(b), CFG.sw_params,
            tile_r=CFG.sw_tile, tile_c=CFG.sw_tile)
        ei, ej = align_lib.sw_end_position(mat)
        want.append((float(score), (int(ei), int(ej))))
    for got, (score, end) in zip(svc.submit(reqs), want):
        assert float(got["score"]) == score
        assert tuple(int(x) for x in got["end"]) == end


def test_service_dtw_matches_direct(svc, rng):
    reqs, want = [], []
    for ls, lr in ((5, 9), (16, 16), (33, 40)):
        s = rng.normal(size=ls).astype(np.float32)
        r = rng.normal(size=lr).astype(np.float32)
        reqs.append(Request("dtw", {"s": s, "r": r}))
        want.append(float(dtw_lib.dtw_tiled(
            jnp.asarray(s), jnp.asarray(r),
            tile_r=CFG.dtw_tile, tile_c=CFG.dtw_tile)[1]))
    for got, dist in zip(svc.submit(reqs), want):
        assert float(got["distance"]) == dist


def test_service_sort_matches_direct(svc, rng):
    reqs, want = [], []
    for n in (3, 50, 130):
        keys = rng.integers(0, 2**32, n, dtype=np.uint32)
        reqs.append(Request("sort", {"keys": keys}))
        want.append(rsort.radix_sort(jnp.asarray(keys),
                                     num_chunks=CFG.sort_chunks,
                                     min_parallel=0))
    for got, (sk, sv) in zip(svc.submit(reqs), want):
        np.testing.assert_array_equal(got["keys"], np.asarray(sk))
        np.testing.assert_array_equal(got["vals"], np.asarray(sv))


def test_service_scan1d_matches_direct(svc, rng):
    direct_fn = jax.jit(affine_scan)
    reqs, want = [], []
    for t in (4, 20, 33):
        a = rng.normal(size=t).astype(np.float32)
        b = rng.normal(size=t).astype(np.float32)
        x0 = np.float32(rng.normal())
        reqs.append(Request("scan1d", {"a": a, "b": b, "x0": x0}))
        want.append(np.asarray(direct_fn(jnp.asarray(a), jnp.asarray(b),
                                         jnp.asarray(x0))))
    for got, xs in zip(svc.submit(reqs), want):
        np.testing.assert_array_equal(got["xs"], xs)


def test_service_mixed_submit_preserves_order(svc, rng):
    reqs = [
        Request("dtw", {"s": rng.normal(size=6).astype(np.float32),
                        "r": rng.normal(size=8).astype(np.float32)}),
        Request("sort", {"keys": rng.integers(0, 99, 7, dtype=np.uint32)}),
        Request("scan1d", {"a": np.ones(5, np.float32),
                           "b": np.zeros(5, np.float32),
                           "x0": np.float32(3.0)}),
        Request("dtw", {"s": rng.normal(size=12).astype(np.float32),
                        "r": rng.normal(size=5).astype(np.float32)}),
    ]
    out = svc.submit(reqs)
    assert "distance" in out[0] and "distance" in out[3]
    assert "keys" in out[1] and "xs" in out[2]
    np.testing.assert_array_equal(out[2]["xs"], np.full(5, 3.0, np.float32))
    with pytest.raises(KeyError):
        svc.submit([Request("nope", {})])


def test_service_seed_needs_reference(svc):
    with pytest.raises(ValueError, match="reference"):
        svc.submit([Request("seed", {"read": np.zeros(64, np.int8)})])


def test_service_dedups_identical_payloads_without_aliasing(svc, rng):
    """A bulk submit repeating one payload pays for ONE dispatch — and
    the duplicates must come back as fresh arrays, not views of the
    original's buffer (the RequestCache aliasing bug, one layer down:
    one caller's in-place edit must never corrupt a sibling's result)."""
    keys = rng.integers(0, 2**32, 17, dtype=np.uint32)
    other = rng.integers(0, 2**32, 9, dtype=np.uint32)
    before = svc.deduped_requests
    out = svc.submit([Request("sort", {"keys": keys}),
                      Request("sort", {"keys": other}),
                      Request("sort", {"keys": keys.copy()}),
                      Request("sort", {"keys": keys.copy()})])
    assert svc.deduped_requests == before + 2     # 3 identical -> 1 dispatch
    assert svc.metrics()["deduped_requests"] == svc.deduped_requests
    want = np.sort(keys)
    for i in (0, 2, 3):
        np.testing.assert_array_equal(out[i]["keys"], want)
    # duplicates own their buffers: scribbling on one leaves the rest
    out[2]["keys"][:] = 0
    np.testing.assert_array_equal(out[0]["keys"], want)
    np.testing.assert_array_equal(out[3]["keys"], want)
    # same little-endian bytes under different dtypes/shapes is NOT a
    # duplicate (the key carries bytes+dtype+shape, like RequestCache.key)
    from repro.runtime.service import _payload_key
    a32 = np.asarray([1, 0], np.uint32)
    b64 = np.asarray([1], np.uint64)
    assert a32.tobytes() == b64.tobytes()
    assert _payload_key({"keys": a32}) != _payload_key({"keys": b64})
    assert _payload_key({"keys": a32}) == _payload_key({"keys": a32.copy()})


# --------------------------------------------------------------------------
# adversarial shapes: the bucketing edge cases submit() must not bend on
# --------------------------------------------------------------------------

def test_service_empty_submit(svc):
    assert svc.submit([]) == []


def test_service_singleton_batch_matches_direct(svc, rng):
    """A lone request (batch of one, nothing to amortize padding against)
    must still be bit-identical to the direct jitted kernel."""
    s = rng.normal(size=CFG.seq_bucket).astype(np.float32)
    r = rng.normal(size=5).astype(np.float32)
    got = svc.submit([Request("dtw", {"s": s, "r": r})])[0]
    want = float(dtw_lib.dtw_tiled(jnp.asarray(s), jnp.asarray(r),
                                   tile_r=CFG.dtw_tile,
                                   tile_c=CFG.dtw_tile)[1])
    assert float(got["distance"]) == want


def test_service_exact_bucket_boundary_lengths(svc, rng):
    """Lengths at bucket-1 / bucket / bucket+1: the off-by-one edges of
    BucketSpec.padded (bucket is padding-free, bucket+1 spills into the
    next bucket) stay bit-identical to direct calls."""
    lens = (CFG.sort_bucket - 1, CFG.sort_bucket, CFG.sort_bucket + 1, 1)
    reqs, want = [], []
    for n in lens:
        keys = rng.integers(0, 2**32, n, dtype=np.uint32)
        reqs.append(Request("sort", {"keys": keys}))
        want.append(rsort.radix_sort(jnp.asarray(keys),
                                     num_chunks=CFG.sort_chunks,
                                     min_parallel=0))
    for got, (sk, sv) in zip(svc.submit(reqs), want):
        np.testing.assert_array_equal(got["keys"], np.asarray(sk))
        np.testing.assert_array_equal(got["vals"], np.asarray(sv))


def test_service_one_bucket_vs_distinct_buckets(svc, rng):
    """All requests sharing ONE bucket vs every request in its own
    bucket: grouping must be invisible in the results (each compared to
    the direct jitted kernel)."""
    direct_fn = jax.jit(affine_scan)
    same_bucket = (9, 11, 14)        # all pad to scan_bucket=16
    distinct = (5, 20, 40)           # pad to 16, 32, 48
    spec = BucketSpec(CFG.scan_bucket)
    assert len(bucketing.group_by_bucket(list(same_bucket), spec)) == 1
    assert len(bucketing.group_by_bucket(list(distinct), spec)) == 3
    for lens in (same_bucket, distinct):
        reqs, want = [], []
        for t in lens:
            a = rng.normal(size=t).astype(np.float32)
            b = rng.normal(size=t).astype(np.float32)
            x0 = np.float32(rng.normal())
            reqs.append(Request("scan1d", {"a": a, "b": b, "x0": x0}))
            want.append(np.asarray(direct_fn(jnp.asarray(a),
                                             jnp.asarray(b),
                                             jnp.asarray(x0))))
        for got, xs in zip(svc.submit(reqs), want):
            np.testing.assert_array_equal(got["xs"], xs)


# --------------------------------------------------------------------------
# end-to-end mapper: batched service == per-read ReadMapper (bit-identical)
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_service_mapper_matches_readmapper():
    ref = genomics.make_reference(12_000, seed=0)
    prof = genomics.ReadProfile("TEST", 400, 80, 0.93)
    reads = [r for r, _ in genomics.sample_reads(ref, prof, 3, seed=1)]
    junk = np.random.default_rng(9).integers(0, 4, 300).astype(np.int8)
    reads += [junk, np.zeros(10, np.int8)]   # gating paths: unmapped, short

    mcfg = MapperConfig(mode="squire")
    direct = ReadMapper(ref, mcfg).map_reads(reads)
    svc = KernelService(ServiceConfig(mapper=mcfg), reference=ref)
    got = svc.submit([Request("map", {"read": r}) for r in reads])
    seeds = svc.submit([Request("seed", {"read": reads[0]})])

    for a, b in zip(direct, got):
        assert a.pos == b.pos
        assert a.sw_score == b.sw_score          # bit-identical, not close
        assert a.chain_score == b.chain_score
        assert a.n_anchors == b.n_anchors
        assert a.align_cells == b.align_cells
    assert len(seeds[0]["q"]) == direct[0].n_anchors


# --------------------------------------------------------------------------
# autotune
# --------------------------------------------------------------------------

def test_autotune_cache_roundtrip(tmp_path):
    path = str(tmp_path / "cache.json")
    t = Autotuner(path)
    assert t.get("dtw.tile") is None
    t.put("dtw.tile", 32, us=12.5)
    assert Autotuner(path).get("dtw.tile") == 32   # fresh instance reloads
    data = json.loads((tmp_path / "cache.json").read_text())
    assert data["dtw.tile"]["value"] == 32


def test_autotune_tune_picks_fastest(tmp_path):
    t = Autotuner(str(tmp_path / "cache.json"))
    calls = []

    def make_thunk(cand):
        def thunk():
            calls.append(cand)
            if cand == "slow":
                sum(range(200_000))
            return jnp.zeros(())
        return thunk

    best = t.tune("toy.knob", {"slow": "slow", "fast": "fast"}, make_thunk)
    assert best == "fast"
    calls.clear()
    assert t.tune("toy.knob", {"slow": "slow"}, make_thunk) == "fast"
    assert calls == []                              # cached: not re-measured


def test_autotune_tune_skips_failing_candidates(tmp_path):
    """One bad candidate (e.g. a block size incompatible with the bucket
    shape) must not abort the sweep: it is skipped, recorded in the
    cache entry, and tune raises only when EVERY candidate fails."""
    path = str(tmp_path / "cache.json")
    t = Autotuner(path)

    def make_thunk(cand):
        def thunk():
            if cand == "bad":
                raise ValueError("block size incompatible with bucket")
            if cand == "slow":
                sum(range(200_000))
            return jnp.zeros(())
        return thunk

    best = t.tune("toy.knob", {"bad": "bad", "slow": "slow",
                               "fast": "fast"}, make_thunk)
    assert best == "fast"
    entry = json.loads((tmp_path / "cache.json").read_text())["toy.knob"]
    assert "bad" in entry["failed"]                 # failure is recorded
    assert "incompatible" in entry["failed"]["bad"]
    with pytest.raises(RuntimeError, match="every candidate failed"):
        t.tune("doomed.knob", {"bad": "bad"}, make_thunk)
    assert t.get("doomed.knob") is None             # nothing persisted


def test_autotune_save_tmp_is_per_pid_and_merges(tmp_path, monkeypatch):
    """save() renames a per-pid tmp file AND merges the on-disk entries
    first: two processes that loaded the cache before either wrote must
    not lose each other's keys to a whole-file last-rename-wins race."""
    import os

    path = str(tmp_path / "cache.json")
    seen = []
    real_replace = os.replace

    def spy(src, dst):
        seen.append(src)
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", spy)
    a = Autotuner(path)
    b = Autotuner(path)            # "process B": loaded before A wrote
    a.put("a.knob", 1)
    assert seen and seen[0] == f"{path}.{os.getpid()}.tmp"
    b.put("b.knob", 2)             # B's save must not discard A's entry
    fresh = Autotuner(path)
    assert fresh.get("a.knob") == 1 and fresh.get("b.knob") == 2


def test_autotune_seed_from_fig9(tmp_path):
    path = str(tmp_path / "cache.json")
    rows = ["fig9.dtw.tile16,90.0,vmem_bytes=1",
            "fig9.dtw.tile32,40.0,vmem_bytes=2",
            "fig9.dtw.tile64,70.0,vmem_bytes=3",
            "fig9.ssm.chunk64,10.0,vmem_bytes=4",
            "not_a_fig9_row,1.0,x"]
    best = seed_from_fig9(rows, path=path)
    assert best == {"dtw.tile": 32, "ssm.chunk": 64}
    tuned = CFG.tuned(Autotuner(path))
    assert tuned.dtw_tile == 32 and tuned.sw_tile == 32
    assert tuned.scan_bucket == 64


def test_autotune_bucketed_keys(tmp_path):
    """Per-bucket knobs resolve ahead of per-kernel, then default."""
    path = str(tmp_path / "cache.json")
    t = Autotuner(path)
    t.put("chain.block", 16)
    t.put("chain.block@b256", 8)
    assert t.get_bucketed("chain.block", 256, 32) == 8    # bucketed wins
    assert t.get_bucketed("chain.block", 1024, 32) == 16  # kernel fallback
    assert t.get_bucketed("sort.chunks", 256, 4) == 4     # default


def test_autotune_seed_from_fig9_bucketed(tmp_path):
    """fig9's chain-block / sort-chunk sweeps carry @b<bucket> suffixes
    and land on per-bucket keys (fastest per bucket wins)."""
    path = str(tmp_path / "cache.json")
    rows = ["fig9.chain.block8@b256,50.0,depth=1",
            "fig9.chain.block16@b256,20.0,depth=2",
            "fig9.chain.block16@b1024,90.0,depth=3",
            "fig9.chain.block32@b1024,30.0,depth=4",
            "fig9.sort.chunks2@b256,15.0,depth=5",
            "fig9.dtw.tile32,40.0,vmem_bytes=2"]
    best = seed_from_fig9(rows, path=path)
    assert best == {"chain.block@b256": 16, "chain.block@b1024": 32,
                    "sort.chunks@b256": 2, "dtw.tile": 32}
    t = Autotuner(path)
    assert t.get_bucketed("chain.block", 256, 64) == 16
    assert t.get_bucketed("chain.block", 1024, 64) == 32
    assert t.get_bucketed("chain.block", 512, 64) == 64   # unswept bucket


def test_service_uses_bucketed_chain_block(tmp_path):
    """ChainAdapter consults the per-bucket tuned block in blocked mode
    (the schedule that consumes it); results stay bit-identical to the
    default knob — block size is perf-only."""
    import dataclasses
    path = str(tmp_path / "cache.json")
    t = Autotuner(path)
    t.put("chain.block@b64", 8)
    cfg = dataclasses.replace(CFG, chain_mode="blocked", chain_block=16)
    rng = np.random.default_rng(0)
    q = np.sort(rng.integers(0, 400, 40)).astype(np.int32)
    r = np.sort(rng.integers(0, 5000, 40)).astype(np.int32)
    req = [Request("chain", {"q": q, "r": r})]
    # untuned cache (empty file path) vs per-bucket tuned block
    tuned = KernelService(cfg, tuner=t).submit(req)[0]
    default = KernelService(
        cfg, tuner=Autotuner(str(tmp_path / "empty.json"))).submit(req)[0]
    np.testing.assert_array_equal(tuned["f"], default["f"])
    np.testing.assert_array_equal(tuned["pred"], default["pred"])
