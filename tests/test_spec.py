"""Per-chunk logits end-to-end: the chunk/decode logits seam, top-k /
top-p sampling filters, self-speculative decoding (verify-accept +
rollback bit-identical to the oracle), the prompt-scoring API, and the
sampling-policy / mode-aware request-cache keys."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as T
from repro.serve import (RequestCache, Scheduler, SchedulerConfig,
                         SlotManager, engine)


@pytest.fixture(scope="module")
def gemma():
    cfg = configs.reduced_config("gemma-2b")
    return cfg, T.init_model(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def gemma3():
    """Windowed model: sliding-window (16) rings + global layers."""
    cfg = configs.reduced_config("gemma3-12b")
    return cfg, T.init_model(jax.random.PRNGKey(0), cfg)


def _prompts(rng, vocab, lens):
    return [rng.integers(0, vocab, n).astype(np.int32) for n in lens]


# --------------------------------------------------------------------------
# the per-chunk-logits seam: chunk logits == stepwise decode, bitwise,
# at EVERY position (dense / paged / windowed-paged)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("model,paged", [
    ("gemma", False), ("gemma", True), ("gemma3", True)])
def test_chunk_logits_bitwise_match_stepwise_decode(request, model, paged):
    """The tentpole contract: run_chunk surfaces (B, C, V) logits that
    are BITWISE identical to feeding the same tokens one at a time
    through the fused decode step — at every position, not just the
    last. Speculative verification and prompt scoring both stand on
    this identity."""
    cfg, params = request.getfixturevalue(model)
    L, ch, cache = 24, 8, 32
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, L).astype(np.int32)
    kw = (dict(paged=True, block_size=4, num_blocks=32)
          if paged else {})

    sm_c = SlotManager(cfg, num_slots=2, cache_slots=cache, **kw)
    sc = sm_c.alloc(owner=0, prompt_len=L)
    chunk_logits = []
    for c0 in range(0, L, ch):
        sm_c.ensure(sc, c0 + ch - 1)
        lg = sm_c.run_chunk(params, [sc], toks[None, c0:c0 + ch],
                            np.asarray([c0], np.int32))
        chunk_logits.append(np.asarray(lg[0], np.float32))
    chunk_logits = np.concatenate(chunk_logits, axis=0)     # (L, V)

    sm_d = SlotManager(cfg, num_slots=2, cache_slots=cache, **kw)
    sd = sm_d.alloc(owner=0, prompt_len=L)
    b = sm_d.num_slots
    key = jax.random.PRNGKey(0)
    for i in range(L):
        sm_d.ensure(sd, i)
        tok = np.zeros((b, 1), np.int32)
        tok[sd, 0] = toks[i]
        _, lg = sm_d.run_decode(params, jnp.asarray(tok),
                                jnp.full((b,), i, jnp.int32),
                                jnp.zeros((b,), jnp.float32), key)
        np.testing.assert_array_equal(
            chunk_logits[i], np.asarray(lg[sd, 0], np.float32),
            err_msg=f"position {i}: chunk logits != stepwise decode")


# --------------------------------------------------------------------------
# sample_token: top-k / top-p filters
# --------------------------------------------------------------------------

def test_filter_disabled_is_bitwise_identity():
    lg = jax.random.normal(jax.random.PRNGKey(1), (3, 17), jnp.float32)
    out = engine._filter_topk_topp(lg, jnp.zeros((3,), jnp.int32),
                                   jnp.ones((3,), jnp.float32))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(lg))


def test_sample_token_top_k_one_is_greedy():
    """top_k=1 must reproduce greedy exactly on both the scalar and the
    per-slot-vector paths, at any temperature."""
    lg = jax.random.normal(jax.random.PRNGKey(2), (4, 1, 9), jnp.float32)
    greedy = engine.sample_token(lg)
    for i in range(20):
        key = jax.random.PRNGKey(100 + i)
        scalar = engine.sample_token(lg, key, temperature=3.0, top_k=1)
        vector = engine.sample_token(lg, key,
                                     jnp.full((4,), 3.0, jnp.float32),
                                     jnp.ones((4,), jnp.int32),
                                     jnp.ones((4,), jnp.float32))
        assert scalar.tolist() == greedy.tolist()
        assert vector.tolist() == greedy.tolist()


def test_sample_token_top_k_mass_stays_in_set():
    """With top_k=2 every sample lands in the top-2 set; with a tiny
    top_p only the argmax survives; and each filter actually reaches
    every allowed token under a hot temperature."""
    lg = jnp.asarray([[[0.0, 4.0, 1.0, 3.5, -2.0]]] * 2)    # top-2 = {1, 3}
    seen = set()
    for i in range(60):
        t = engine.sample_token(lg, jax.random.PRNGKey(i),
                                temperature=5.0, top_k=2)
        seen.update(int(x) for x in t)
    assert seen == {1, 3}
    for i in range(20):
        t = engine.sample_token(lg, jax.random.PRNGKey(i),
                                temperature=5.0, top_p=1e-6)
        assert set(t.tolist()) == {1}           # nucleus always has argmax


def test_sample_token_greedy_rows_exact_argmax_under_filters():
    """Per-slot vectors: a greedy row (temp 0) must be EXACTLY argmax of
    the raw logits even when its filter entries are active — the
    differential harness's bit-identity depends on it."""
    lg = jax.random.normal(jax.random.PRNGKey(3), (6, 1, 31), jnp.float32)
    greedy = engine.sample_token(lg)
    temps = jnp.asarray([0.0, 2.0, 0.0, 1.0, 0.0, 0.5], jnp.float32)
    ks = jnp.asarray([3, 3, 0, 5, 1, 0], jnp.int32)
    ps = jnp.asarray([0.5, 0.9, 0.2, 1.0, 1.0, 0.7], jnp.float32)
    for i in range(10):
        got = engine.sample_token(lg, jax.random.PRNGKey(i), temps, ks, ps)
        for row in (0, 2, 4):
            assert int(got[row]) == int(greedy[row])


def test_sampling_policy_validation():
    with pytest.raises(ValueError, match="temperature"):
        engine.SamplingPolicy(temperature=-0.1)
    with pytest.raises(ValueError, match="top_k"):
        engine.SamplingPolicy(top_k=-1)
    with pytest.raises(ValueError, match="top_p"):
        engine.SamplingPolicy(top_p=0.0)
    with pytest.raises(ValueError, match="top_p"):
        engine.SamplingPolicy(top_p=1.5)
    assert engine.SamplingPolicy().greedy
    assert not engine.SamplingPolicy(temperature=0.7).greedy


# --------------------------------------------------------------------------
# RequestCache: mode + sampling policy are part of the key
# --------------------------------------------------------------------------

def test_request_cache_mode_and_policy_in_key():
    """Regression: the memo key used to ignore the request mode and the
    sampling policy — a score() and a generate() of one prompt (or two
    different top-k configs) would alias and serve each other's
    artifacts."""
    p = np.asarray([3, 1, 4, 1, 5], np.int32)
    kg = RequestCache.key(p, 4, None, mode="generate",
                          policy=engine.SamplingPolicy().fingerprint())
    ks = RequestCache.key(p, 4, None, mode="score",
                          policy=engine.SamplingPolicy().fingerprint())
    assert kg != ks
    k1 = RequestCache.key(p, 4, None, policy=(0.0, 0, 1.0))
    k2 = RequestCache.key(p, 4, None, policy=(0.0, 5, 1.0))
    k3 = RequestCache.key(p, 4, None, policy=(0.0, 0, 0.9))
    assert len({k1, k2, k3}) == 3
    rc = RequestCache(maxsize=4)
    rc.put(kg, np.asarray([7, 8], np.int32), "length")
    rc.put(ks, np.asarray([], np.int32), "score",
           np.asarray([-1.5, -2.0], np.float32))
    toks, reason, lps = rc.get(kg)
    assert toks.tolist() == [7, 8] and reason == "length" and lps is None
    toks, reason, lps = rc.get(ks)
    assert reason == "score" and lps.tolist() == [-1.5, -2.0]
    assert not lps.flags.writeable


def test_score_and_generate_do_not_alias_end_to_end(gemma):
    """A cached generate() of a prompt must not satisfy a score() of the
    same prompt (and vice versa): each mode produces its own artifact."""
    cfg, params = gemma
    sched = Scheduler(cfg, params, SchedulerConfig(
        num_slots=2, max_len=32, prefill_chunk=8))
    rng = np.random.default_rng(4)
    (p,) = _prompts(rng, cfg.vocab, [9])
    (rg,) = sched.submit([p], max_new_tokens=3)
    sched.drain()
    (rs,) = sched.score([p])
    sched.drain()
    gen, sc = sched.results[rg], sched.results[rs]
    assert gen.reason in ("length", "eos") and gen.logprobs is None
    assert sc.reason == "score" and len(sc.tokens) == 0
    assert sc.logprobs is not None and len(sc.logprobs) == len(p) - 1
    # repeat score IS served from the memo, with the logprobs intact
    (rs2,) = sched.score([p])
    sched.drain()
    again = sched.results[rs2]
    assert again.reason == "cached"
    np.testing.assert_array_equal(again.logprobs, sc.logprobs)


# --------------------------------------------------------------------------
# speculative decoding: bit-identical to the oracle, counters flow
# --------------------------------------------------------------------------

def _serve(cfg, params, prompts, mnts, **kw):
    sc = SchedulerConfig(num_slots=2, max_len=64, prefill_chunk=8,
                         eos_token=7, cache_requests=False, **kw)
    sched = Scheduler(cfg, params, sc)
    rids = [sched.submit([p], max_new_tokens=m)[0]
            for p, m in zip(prompts, mnts)]
    sched.drain()
    return [sched.results[r] for r in rids], sched


@pytest.mark.parametrize("model,arm,kw", [
    ("gemma", "contiguous", {}),
    ("gemma", "paged", dict(allocator="paged", block_size=8)),
    ("gemma", "paged-swap", dict(allocator="paged", block_size=8,
                                 num_blocks=14, preempt="swap")),
    ("gemma3", "windowed", dict(allocator="paged", block_size=4)),
])
@pytest.mark.parametrize("k", [1, 3])
def test_speculative_streams_bit_identical(request, model, arm, kw, k):
    """speculate=k greedy streams must be BITWISE identical to the
    speculate=0 oracle — tokens and finish reasons — on every slot
    backing, while real drafts actually flow (Completion.drafted > 0
    for decode-phase requests)."""
    cfg, params = request.getfixturevalue(model)
    rng = np.random.default_rng(5)
    prompts = _prompts(rng, cfg.vocab, [5, 12, 9, 20, 7])
    mnts = [8, 5, 10, 6, 9]
    base, _ = _serve(cfg, params, prompts, mnts, **kw)
    spec, sched = _serve(cfg, params, prompts, mnts, speculate=k, **kw)
    for b, s in zip(base, spec):
        assert s.tokens.tolist() == b.tokens.tolist(), \
            f"{arm} k={k}: stream diverged"
        assert s.reason == b.reason
    assert sched.counters["spec.drafted_tokens"] > 0
    assert sum(c.drafted for c in spec) == \
        sched.counters["spec.drafted_tokens"]
    assert sum(c.accepted for c in spec) == \
        sched.counters["spec.accepted_tokens"]
    if "swap" in arm:
        assert sched.counters["recomputed_decode_steps"] == 0


def test_speculative_prefix_sharing_bit_identical(gemma):
    """Speculation composed with CoW prefix sharing: rejected-draft
    rollback must never scribble on shared prefix blocks."""
    cfg, params = gemma
    rng = np.random.default_rng(6)
    prefix = rng.integers(0, cfg.vocab, 24).astype(np.int32)
    prompts = [np.concatenate([prefix, s]) for s in
               _prompts(rng, cfg.vocab, [3, 6, 1, 5])]
    mnts = [5, 4, 6, 5]
    kw = dict(allocator="paged", block_size=8, prefix_sharing=True)
    base, _ = _serve(cfg, params, prompts, mnts, **kw)
    spec, sched = _serve(cfg, params, prompts, mnts, speculate=2, **kw)
    for b, s in zip(base, spec):
        assert s.tokens.tolist() == b.tokens.tolist()
    assert sched.counters["prefix_shared_tokens"] > 0
    assert sched.counters["spec.drafted_tokens"] > 0


def test_speculative_sampled_rows_still_one_token_per_tick(gemma):
    """Sampled (temperature > 0) rows never accept drafts — they emit
    exactly one distribution-correct token per tick and their spec
    counters stay untouched."""
    cfg, params = gemma
    rng = np.random.default_rng(7)
    prompts = _prompts(rng, cfg.vocab, [6, 11])
    spec, sched = _serve(cfg, params, prompts, [6, 6], speculate=3,
                         temperature=0.8)
    for c in spec:
        assert c.drafted == 0 and c.accepted == 0
        assert len(c.tokens) >= 1
    assert sched.counters["spec.drafted_tokens"] == 0


def test_speculate_validation(gemma, gemma3):
    """speculate needs an attention-only pattern (SSM chunk scans cannot
    roll back) and a verify span that fits the smallest attention view."""
    cfg_r = configs.reduced_config("rwkv6-1.6b")
    params_r = T.init_model(jax.random.PRNGKey(0), cfg_r)
    with pytest.raises(ValueError, match="attention-only"):
        Scheduler(cfg_r, params_r, SchedulerConfig(speculate=2))
    cfg3, params3 = gemma3
    window = min(s.window for s in cfg3.pattern if s.window)
    with pytest.raises(ValueError, match="attention view"):
        Scheduler(cfg3, params3, SchedulerConfig(
            num_slots=2, max_len=64, speculate=window))
    cfg, params = gemma
    with pytest.raises(ValueError, match="speculate"):
        Scheduler(cfg, params, SchedulerConfig(speculate=-1))


# --------------------------------------------------------------------------
# score(): per-token prompt logprobs
# --------------------------------------------------------------------------

def _reference_logprobs(cfg, params, prompt):
    """log p(prompt[i] | prompt[:i]) via a single-row chunk replay."""
    caches = T.init_caches(cfg, batch=1, slots=len(prompt) + 4,
                           per_slot_pos=True)
    lg, _ = engine.jit_chunk_step(cfg)(
        params, caches, jnp.asarray(prompt[None, :-1]),
        jnp.zeros((1,), jnp.int32))
    lp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
    return np.asarray([float(lp[0, i, prompt[i + 1]])
                       for i in range(len(prompt) - 1)], np.float32)


@pytest.mark.parametrize("kw", [
    {}, dict(allocator="paged", block_size=8),
], ids=["contiguous", "paged"])
def test_score_matches_reference(gemma, kw):
    cfg, params = gemma
    rng = np.random.default_rng(8)
    prompts = _prompts(rng, cfg.vocab, [2, 9, 17, 30])
    sched = Scheduler(cfg, params, SchedulerConfig(
        num_slots=2, max_len=64, prefill_chunk=8, cache_requests=False,
        **kw))
    rids = sched.score(prompts)
    sched.drain()
    for r, p in zip(rids, prompts):
        c = sched.results[r]
        assert c.reason == "score" and len(c.tokens) == 0
        ref = _reference_logprobs(cfg, params, p)
        assert c.logprobs.shape == ref.shape
        np.testing.assert_allclose(c.logprobs, ref, rtol=1e-5, atol=1e-5)


def test_score_speculative_matches_plain(gemma):
    """score() under speculate=k collects the same logprobs (verify-path
    log-softmax vs host log-softmax may differ in the last ulp)."""
    cfg, params = gemma
    rng = np.random.default_rng(9)
    prompts = _prompts(rng, cfg.vocab, [4, 13, 21])
    lps = {}
    for k in (0, 3):
        sched = Scheduler(cfg, params, SchedulerConfig(
            num_slots=2, max_len=64, prefill_chunk=8,
            cache_requests=False, speculate=k))
        rids = sched.score(prompts)
        sched.drain()
        lps[k] = [sched.results[r].logprobs for r in rids]
    for a, b in zip(lps[0], lps[3]):
        assert a.shape == b.shape
        np.testing.assert_allclose(a, b, rtol=0, atol=1e-5)


def test_score_validation(gemma):
    cfg, params = gemma
    sched = Scheduler(cfg, params, SchedulerConfig(
        num_slots=1, max_len=16, prefill_chunk=8))
    with pytest.raises(ValueError, match=r"must be in \[2,"):
        sched.score([np.asarray([3], np.int32)])
    with pytest.raises(ValueError, match=r"must be in \[2,"):
        sched.score([np.arange(17, dtype=np.int32)])


def test_service_score_adapter(gemma):
    """The KernelService front door routes 'score' traffic to the
    attached scheduler and returns per-request logprobs."""
    from repro.runtime.service import KernelService, Request

    cfg, params = gemma
    sched = Scheduler(cfg, params, SchedulerConfig(
        num_slots=2, max_len=32, prefill_chunk=8))
    svc = KernelService(lm=sched)
    assert "score" in svc.kernels
    rng = np.random.default_rng(10)
    prompts = _prompts(rng, cfg.vocab, [5, 11])
    got = svc.submit([Request("score", {"prompt": p}) for p in prompts])
    for res, p in zip(got, prompts):
        assert res["reason"] == "score"
        ref = _reference_logprobs(cfg, params, p)
        np.testing.assert_allclose(res["logprobs"], ref, rtol=1e-5,
                                   atol=1e-5)
