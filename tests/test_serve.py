"""Serve engine: greedy decode consistency, sampling, ring-buffer caches,
and O(1)-state long-context decode for SSM archs."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import attention as attn_lib
from repro.models import transformer as T
from repro.serve import engine


def test_sample_token_greedy_and_temperature():
    logits = jnp.asarray([[[0.0, 5.0, 1.0]]])
    assert int(engine.sample_token(logits)[0]) == 1
    key = jax.random.PRNGKey(0)
    toks = [int(engine.sample_token(logits, jax.random.fold_in(key, i),
                                    temperature=2.0)[0]) for i in range(50)]
    assert len(set(toks)) > 1, "temperature sampling should vary"


@pytest.mark.parametrize("arch", ["gemma-2b", "rwkv6-1.6b"])
def test_prefill_decode_pipeline(arch):
    cfg = configs.reduced_config(arch)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    b, s, gen = 2, 12, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    prefill = jax.jit(engine.make_prefill_step(cfg, cache_slots=s + gen))
    decode = jax.jit(engine.make_decode_step(cfg))
    logits, caches = prefill(params, {"tokens": toks})
    assert logits.shape == (b, 1, cfg.vocab)
    tok = engine.sample_token(logits)
    for i in range(gen):
        tok, logits, caches = decode(params, caches, {"tokens": tok[:, None]},
                                     jnp.asarray(s + i, jnp.int32))
        assert tok.shape == (b,)
        assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_ring_buffer_cache_wraps_correctly():
    """Writing past the window must overwrite the oldest slot and attention
    must honor absolute positions (order-invariant online softmax)."""
    cache = attn_lib.make_cache(batch=1, slots=4, kv_heads=1, head_dim=8)
    for pos in range(6):
        k = jnp.full((1, 1, 1, 8), float(pos))
        cache = attn_lib.cache_update(cache, k, k, jnp.asarray(pos))
    pos_np = np.asarray(cache.pos)
    assert sorted(pos_np.tolist()) == [2, 3, 4, 5]
    # slot of pos p is p % 4
    for slot, p in enumerate(pos_np):
        assert p % 4 == slot
        np.testing.assert_allclose(np.asarray(cache.k)[0, slot, 0, 0],
                                   float(p))


def test_sliding_window_attention_matches_truncated_context():
    """A windowed layer attending over a ring buffer == full attention over
    only the last `window` tokens."""
    cfg = attn_lib.AttnConfig(d_model=32, num_heads=2, num_kv_heads=2,
                              head_dim=16, window=4)
    params = attn_lib.init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 10, 32))
    positions = jnp.arange(10)[None]
    out_full, _ = attn_lib.attention(params, cfg, x, positions)
    # last token output must equal attention over tokens 6..9 only
    cfg_nw = cfg._replace(window=0)
    out_trunc, _ = attn_lib.attention(params, cfg_nw, x[:, 6:],
                                      positions[:, 6:])
    np.testing.assert_allclose(np.asarray(out_full[0, -1], np.float32),
                               np.asarray(out_trunc[0, -1], np.float32),
                               rtol=2e-2, atol=2e-2)


def test_blockwise_attention_block_size_invariance():
    """Online softmax must be exact for any KV block size."""
    key = jax.random.PRNGKey(2)
    b, s, h, hd = 2, 33, 4, 16
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    outs = [attn_lib.blockwise_attention(q, k, v, pos, pos, kv_block=bs)
            for bs in (8, 16, 512)]
    for o in outs[1:]:
        # scores use bf16 MXU inputs (fp32 accum): per-pair scores are
        # identical for any blocking, so invariance holds to fp32 exactness
        np.testing.assert_allclose(np.asarray(outs[0], np.float32),
                                   np.asarray(o, np.float32),
                                   rtol=1e-4, atol=1e-4)


def test_banded_equals_blockwise_sliding_window():
    """Block-banded local attention (§Perf gemma3) is exact vs the full
    blockwise path for any window/GQA/odd-length combination."""
    for (b, s, h, kvh, hd, win) in [(2, 48, 4, 2, 16, 8),
                                    (1, 64, 4, 1, 32, 16),
                                    (1, 33, 2, 2, 8, 12)]:
        ks = jax.random.split(jax.random.PRNGKey(s + win), 3)
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, kvh, hd))
        v = jax.random.normal(ks[2], (b, s, kvh, hd))
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        want = attn_lib.blockwise_attention(q, k, v, pos, pos, window=win)
        got = attn_lib.banded_attention(q, k, v, pos, win)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=2e-2, atol=2e-2)


def test_mqa_gqa_head_mapping():
    """GQA with kv=1 (MQA, gemma-2b style) must broadcast the single KV head
    across all query heads."""
    cfg = attn_lib.AttnConfig(d_model=32, num_heads=4, num_kv_heads=1,
                              head_dim=8)
    params = attn_lib.init_attention(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 6, 32))
    out, _ = attn_lib.attention(params, cfg, x, jnp.arange(6)[None])
    assert out.shape == (1, 6, 32)
    assert np.isfinite(np.asarray(out, np.float32)).all()


def test_ssm_decode_indifferent_to_slots():
    """SSM decode carries O(1) state: caches built with different `slots`
    are identical (no KV dependence)."""
    cfg = configs.reduced_config("rwkv6-1.6b")
    c1 = T.init_caches(cfg, batch=1, slots=16)
    c2 = T.init_caches(cfg, batch=1, slots=4096)
    s1 = jax.tree_util.tree_structure(c1)
    s2 = jax.tree_util.tree_structure(c2)
    assert s1 == s2
    for a, b in zip(jax.tree_util.tree_leaves(c1),
                    jax.tree_util.tree_leaves(c2)):
        assert a.shape == b.shape
