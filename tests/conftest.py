"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the 1 real CPU
device; only launch/dryrun.py forces 512 host devices (per the brief)."""

import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
