"""Launcher integration: dry-run machinery on a smoke mesh (subprocess —
device count locks at first jax init) and the CLI entry points."""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")
ENV = {**os.environ, "PYTHONPATH": SRC}


def _run(code: str, timeout=540):
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=ENV, timeout=timeout)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


@pytest.mark.slow
def test_dryrun_lower_compile_smoke_mesh():
    """Lower + compile a reduced train cell and a decode cell on a forced
    8-device mesh; assert roofline terms derive from the HLO."""
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, math
        import jax
        from repro import configs
        from repro.configs.base import ShapeConfig
        from repro.launch import mesh as mesh_lib, roofline, specs
        from repro.launch.dryrun import build_cell
        from repro.sharding import configure

        mesh = mesh_lib.make_smoke_mesh()
        configure(mesh)
        cfg = configs.reduced_config("gemma-2b")
        shape = ShapeConfig("smoke_train", "train", seq_len=64,
                            global_batch=8)
        jfn, args, tokens, kind = build_cell(cfg, shape, mesh)
        with mesh:
            lowered = jfn.lower(*args)
            compiled = lowered.compile()
        hlo = compiled.as_text()
        summary = roofline.summarize(hlo, 1_000_000, tokens, "train")
        assert summary["hlo_flops_per_device"] > 0
        assert summary["dominant"] in ("compute", "memory", "collective")

        # decode cell too (cache machinery under shardings)
        shape_d = ShapeConfig("smoke_decode", "decode", seq_len=128,
                              global_batch=8)
        jfn2, args2, _, _ = build_cell(cfg, shape_d, mesh)
        with mesh:
            jfn2.lower(*args2).compile()
        configure(None)
        print("SMOKE_OK")
    """))
    assert "SMOKE_OK" in out


@pytest.mark.slow
def test_train_cli(tmp_path):
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "gemma-2b",
         "--reduced", "--steps", "4", "--batch", "2", "--seq", "16",
         "--log-every", "2", "--ckpt-dir", str(tmp_path)],
        capture_output=True, text=True, env=ENV, timeout=540)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "[train] done: 4 steps" in res.stdout
    assert any(d.name.startswith("step_") for d in tmp_path.iterdir())


@pytest.mark.slow
def test_serve_cli():
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "rwkv6-1.6b",
         "--reduced", "--batch", "2", "--prompt-len", "8", "--gen", "4"],
        capture_output=True, text=True, env=ENV, timeout=540)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "[serve] decode:" in res.stdout


_DRYRUN_ARTIFACTS = sorted(
    (Path(__file__).resolve().parents[1] / "experiments" / "dryrun")
    .glob("*__single.json"))


@pytest.mark.skipif(
    not _DRYRUN_ARTIFACTS,
    reason="experiments/dryrun artifacts not generated — run "
           "`python -m repro.launch.dryrun --all --mesh single` "
           "(hours of 512-device compiles; see ROADMAP)")
def test_report_tables_render():
    from repro.launch import report
    t = report.roofline_table("single")
    assert t.count("\n") > 30            # 33 OK rows + header
    assert "dominant" in t.splitlines()[0]
