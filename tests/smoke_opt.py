"""`python -O` smoke for the serve path — NOT a pytest module.

Under ``python -O`` every ``assert`` statement is stripped (including
pytest's, whose assertion rewriting is disabled there), so the regular
test suite cannot catch a serve-path bug that only manifests with
optimization on. This script re-runs the scheduler differential with
EXPLICIT raises: the paged allocator (both preemption policies, plus
reserved admission) must emit greedy token streams bit-identical to the
contiguous baseline, with the swap policy recomputing zero decode steps.

The regression this pins: ``_prefill_chunks`` used to call the
side-effecting ``slots.ensure(...)`` inside an assert — under -O the
call vanished and the paged prefill path silently skipped block mapping.
Submit-time feasibility must likewise reject bad input via ValueError,
not a strippable assert.

    PYTHONPATH=src python -O tests/smoke_opt.py
"""

import sys

import numpy as np

import jax


def check(cond, msg):
    """An assert that survives python -O."""
    if not cond:
        raise SystemExit(f"[smoke_opt] FAIL: {msg}")


def run_trace(cfg, params, prompts, mnts, **sc_kw):
    from repro.serve import Scheduler, SchedulerConfig

    sc = SchedulerConfig(num_slots=3, max_len=48, prefill_chunk=8,
                         eos_token=5, cache_requests=False, **sc_kw)
    sched = Scheduler(cfg, params, sc)
    submitted, steps, done = 0, 0, []
    while submitted < len(prompts) or sched.pending or sched.live:
        if submitted < len(prompts) and steps % 2 == 0:
            sched.submit([prompts[submitted]],
                         max_new_tokens=mnts[submitted])
            submitted += 1
        done += sched.step()
        steps += 1
    done += sched.drain()
    check(len({c.rid for c in done}) == len(prompts),
          "completions missing or duplicated across step/drain")
    return {c.rid: c for c in done}, sched


def main():
    check(not __debug__, "run me with python -O (asserts must be stripped)")
    from repro import configs
    from repro.models import transformer as T

    cfg = configs.reduced_config("gemma-2b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    lens = [3, 17, 9, 24, 5, 12]
    mnts = [6, 4, 8, 5, 7, 3]
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in lens]

    base, _ = run_trace(cfg, params, prompts, mnts)
    arms = [("paged/recompute", dict(preempt="recompute")),
            ("paged/swap", dict(preempt="swap")),
            ("paged/reserved", dict(admission="reserved"))]
    for name, kw in arms:
        got, sched = run_trace(cfg, params, prompts, mnts,
                               allocator="paged", block_size=8,
                               num_blocks=6, **kw)
        for rid in base:
            check(got[rid].tokens.tolist() == base[rid].tokens.tolist(),
                  f"{name}: rid {rid} token stream diverged from "
                  f"contiguous (stripped-assert side effect?)")
            check(got[rid].reason == base[rid].reason,
                  f"{name}: rid {rid} finish reason diverged")
        c = sched.counters
        if name == "paged/swap":
            check(c["recomputed_decode_steps"] == 0,
                  f"swap policy recomputed {c['recomputed_decode_steps']} "
                  "decode steps")
            check(c["swapped_out"] >= 1 and
                  c["swapped_in"] == c["swapped_out"],
                  "swap path never exercised")
        if name == "paged/reserved":
            check(c["preempted"] == 0, "reserved admission preempted")
        check(sched.stats()["blocks_used"] == 0,
              f"{name}: retire leaked blocks")
        print(f"[smoke_opt] {name}: OK ({c['preempted']} preemptions, "
              f"{c['recomputed_decode_steps']} recomputed decode steps)")

    # user-input feasibility must be ValueError, not a stripped assert
    from repro.serve import Scheduler, SchedulerConfig
    sched = Scheduler(cfg, params, SchedulerConfig(
        num_slots=1, max_len=16, prefill_chunk=8))
    for bad in (dict(max_new_tokens=0),
                dict(max_new_tokens=15)):
        try:
            sched.submit([np.arange(4, dtype=np.int32)], **bad)
        except ValueError:
            pass
        else:
            raise SystemExit(f"[smoke_opt] FAIL: submit({bad}) accepted "
                             "under -O (feasibility check stripped)")
    print("[smoke_opt] all serve-path checks green under python -O")
    return 0


if __name__ == "__main__":
    sys.exit(main())
