"""`python -O` smoke for the serve path — NOT a pytest module.

Under ``python -O`` every ``assert`` statement is stripped (including
pytest's, whose assertion rewriting is disabled there), so the regular
test suite cannot catch a serve-path bug that only manifests with
optimization on. This script re-runs the scheduler differential with
EXPLICIT raises: the paged allocator (both preemption policies, reserved
admission, AND the windowed model whose sliding-window rings page
through ring-mode page-table groups) must emit greedy token streams
bit-identical to the contiguous baseline, with the swap policy
recomputing zero decode steps and a swap-budget rejection degrading to
recompute per victim.

It also drives the allocator's state guards directly: BlockPool double
free, PageTable ensure/swap_in misuse, check_invariants and the
SwapStore byte budget must all raise ValueError/RuntimeError — under -O
a bare ``assert`` guard would vanish and let pool corruption proceed.

The regression this pins: ``_prefill_chunks`` used to call the
side-effecting ``slots.ensure(...)`` inside an assert — under -O the
call vanished and the paged prefill path silently skipped block mapping.
Submit-time feasibility must likewise reject bad input via ValueError,
not a strippable assert.

    PYTHONPATH=src python -O tests/smoke_opt.py
"""

import sys

import numpy as np

import jax


def check(cond, msg):
    """An assert that survives python -O."""
    if not cond:
        raise SystemExit(f"[smoke_opt] FAIL: {msg}")


def run_trace(cfg, params, prompts, mnts, **sc_kw):
    from repro.serve import Scheduler, SchedulerConfig

    sc = SchedulerConfig(**{**dict(num_slots=3, max_len=48,
                                   prefill_chunk=8, eos_token=5,
                                   cache_requests=False), **sc_kw})
    sched = Scheduler(cfg, params, sc)
    submitted, steps, done = 0, 0, []
    while submitted < len(prompts) or sched.pending or sched.live:
        if submitted < len(prompts) and steps % 2 == 0:
            sched.submit([prompts[submitted]],
                         max_new_tokens=mnts[submitted])
            submitted += 1
        done += sched.step()
        steps += 1
    done += sched.drain()
    check(len({c.rid for c in done}) == len(prompts),
          "completions missing or duplicated across step/drain")
    return {c.rid: c for c in done}, sched


def check_allocator_guards():
    """The paged allocator's state guards must be explicit raises, not
    ``assert`` — under -O a stripped guard lets pool/table corruption
    proceed silently. Every violation here must raise the documented
    ValueError/RuntimeError even with asserts gone."""
    from repro.serve.paging import BlockPool, PageTable, SwapEntry, SwapStore

    def expect(exc, fn, msg):
        try:
            fn()
        except exc:
            return
        raise SystemExit(f"[smoke_opt] FAIL: {msg} did not raise "
                         f"{exc.__name__} under -O")

    bp = BlockPool(4, block_size=4)
    a = bp.alloc()
    bp.free(a)
    expect(ValueError, lambda: bp.free(a), "double free")
    expect(ValueError, lambda: BlockPool(0, 4), "bad pool sizing")
    # out-of-range ids must be rejected up front: free(-1) used to reach
    # numpy fancy indexing and silently free the LAST block in the pool
    held = bp.alloc()
    for bad in (-1, -4, bp.num_blocks, 99):
        expect(ValueError, lambda b=bad: bp.free(b), f"free({bad})")
        expect(ValueError, lambda b=bad: bp.ref(b), f"ref({bad})")
        expect(ValueError, lambda b=bad: bp.refcount(b), f"refcount({bad})")
    check(bp.used_count == 1 and bp.refcount(held) == 1,
          "rejected out-of-range free still mutated the pool")
    # refcounted sharing: free() only releases at refcount zero, and
    # cow_block refuses to copy a block nobody shares
    bp.ref(held)
    check(not bp.free(held) and bp.used_count == 1,
          "free() released a block with refcount > 1")
    check(bp.free(held) and bp.used_count == 0,
          "free() at refcount 1 did not release")
    pt = PageTable(bp, num_slots=2, slot_positions=16)
    expect(ValueError, lambda: pt.ensure(0, 16), "ensure out of range")
    pt.ensure(0, 3)
    expect(RuntimeError, lambda: pt.swap_in(0, 1), "swap_in non-empty slot")
    expect(ValueError, lambda: pt.swap_in(1, 99), "swap_in oversize")
    pt.table[0, 1] = pt.table[0, 0]             # corrupt: double mapping
    expect(RuntimeError, pt.check_invariants, "check_invariants")
    # copy-on-write misuse is loud too: cow of an unmapped logical block
    # and cow of a private (unshared) block are both caller bugs
    bp2 = BlockPool(4, block_size=4)
    pt2 = PageTable(bp2, num_slots=2, slot_positions=16)
    pt2.ensure(0, 3)
    expect(RuntimeError, lambda: pt2.cow_block(0, 2), "cow of unmapped")
    expect(RuntimeError, lambda: pt2.cow_block(0, 0), "cow of private")
    expect(RuntimeError,
           lambda: pt2.map_shared(0, [int(pt2.table[0, 0])]),
           "map_shared over an occupied slot")
    ring = PageTable(BlockPool(4, 4), num_slots=1, slot_positions=10,
                     ring=True)
    ok, new = ring.ensure(0, 10_000)            # ring clamps, no raise
    check(ok and len(new) == 3, "ring ensure did not clamp to the ring")
    ok, new = ring.ensure(0, 10_001)
    check(ok and new == [], "saturated ring kept allocating")
    store = SwapStore(max_bytes=8)
    big = SwapEntry(blocks={4: 1}, paged={},
                    dense={"x": np.zeros((4,), np.float32)})   # 16 B
    expect(RuntimeError, lambda: store.put(1, big), "swap budget overflow")
    check(store.rejected == 1, "rejected put was not counted")
    print("[smoke_opt] allocator guards: OK (raises survive -O)")


def main():
    check(not __debug__, "run me with python -O (asserts must be stripped)")
    from repro import configs
    from repro.models import transformer as T

    check_allocator_guards()

    cfg = configs.reduced_config("gemma-2b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    cfg_w = configs.reduced_config("gemma3-12b")    # sliding-window model
    params_w = T.init_model(jax.random.PRNGKey(0), cfg_w)
    rng = np.random.default_rng(7)
    lens = [3, 17, 9, 24, 5, 12]
    mnts = [6, 4, 8, 5, 7, 3]
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in lens]

    base, _ = run_trace(cfg, params, prompts, mnts)
    base_w, _ = run_trace(cfg_w, params_w, prompts, mnts)
    pool = dict(allocator="paged", block_size=8, num_blocks=6)
    # windowed pool: under-provisioned global AND window-ring groups, so
    # ring paging, ring growth-OOB and ring swap all really run
    pool_w = dict(allocator="paged", block_size=2, num_blocks=16,
                  num_window_blocks=9)
    arms = [
        ("paged/recompute", cfg, params, base, dict(pool)),
        ("paged/swap", cfg, params, base, dict(pool, preempt="swap")),
        ("paged/reserved", cfg, params, base,
         dict(pool, admission="reserved")),
        ("paged-window/recompute", cfg_w, params_w, base_w, dict(pool_w)),
        ("paged-window/swap", cfg_w, params_w, base_w,
         dict(pool_w, preempt="swap")),
        # swap with a 1-byte budget must degrade to recompute per victim
        # (loud rejection), still bit-identical
        ("paged-window/swap-budget", cfg_w, params_w, base_w,
         dict(pool_w, preempt="swap", swap_bytes_budget=1)),
    ]
    for name, c_, p_, b_, kw in arms:
        got, sched = run_trace(c_, p_, prompts, mnts, **kw)
        for rid in b_:
            check(got[rid].tokens.tolist() == b_[rid].tokens.tolist(),
                  f"{name}: rid {rid} token stream diverged from "
                  f"contiguous (stripped-assert side effect?)")
            check(got[rid].reason == b_[rid].reason,
                  f"{name}: rid {rid} finish reason diverged")
        c = sched.counters
        if name.endswith("/swap"):
            check(c["recomputed_decode_steps"] == 0,
                  f"swap policy recomputed {c['recomputed_decode_steps']} "
                  "decode steps")
            check(c["swapped_out"] >= 1 and
                  c["swapped_in"] == c["swapped_out"],
                  "swap path never exercised")
        if name == "paged/reserved":
            check(c["preempted"] == 0, "reserved admission preempted")
        if name == "paged-window/swap-budget":
            check(sched.stats()["swap_rejected"] >= 1
                  and c["swapped_out"] == 0,
                  "swap budget never rejected")
            check(c["preempted"] >= 1 and
                  c["recomputed_decode_steps"] >= 1,
                  "rejected swap did not fall back to recompute")
        if name.startswith("paged-window"):
            check(c["preempted"] >= 1,
                  f"{name}: windowed pool never preempted (vacuous)")
            check(sched.stats()["ring16_blocks_used"] == 0,
                  f"{name}: retire leaked ring blocks")
        check(sched.stats()["blocks_used"] == 0,
              f"{name}: retire leaked blocks")
        print(f"[smoke_opt] {name}: OK ({c['preempted']} preemptions, "
              f"{c['recomputed_decode_steps']} recomputed decode steps)")

    # sharded-pool differential: the mesh-sharded slot pool (per-shard
    # block pools + swap stores, mesh-aware admission, work-stealing
    # rebalance) must emit the same greedy streams — the shard routing,
    # steal migration and per-shard preemption guards are explicit
    # raises that a stripped assert must never replace. n=1 runs the
    # delegate path; n=2 exercises real shard-local pools + swap.
    shard_arms = [
        ("sharded-n1/swap",
         dict(pool, preempt="swap", mesh_shards=1, num_slots=4)),
        ("sharded-n2/swap",
         dict(pool, preempt="swap", mesh_shards=2, num_slots=4,
              num_blocks=4)),
    ]
    for name, kw in shard_arms:
        got, sched = run_trace(cfg, params, prompts, mnts, **kw)
        for rid in base:
            check(got[rid].tokens.tolist() == base[rid].tokens.tolist(),
                  f"{name}: rid {rid} stream diverged on the sharded pool")
            check(got[rid].reason == base[rid].reason,
                  f"{name}: rid {rid} finish reason diverged")
        check(sched.counters["recomputed_decode_steps"] == 0,
              f"{name}: sharded swap recomputed decode steps")
        check(sched.stats()["blocks_used"] == 0,
              f"{name}: retire leaked blocks on a shard")
        print(f"[smoke_opt] {name}: OK "
              f"({sched.counters['preempted']} preemptions, "
              f"{sched.counters['steals']} steals)")

    # shared-prefix differential: prefix_sharing=True must be bit-
    # identical to sharing OFF on prompts with a common system prefix —
    # under BOTH preemption policies — while actually sharing (the
    # admission fast-path, CoW guards and index refcounts are all
    # explicit raises; a stripped assert here would corrupt shared KV)
    sp_prompts = [np.concatenate(
        [prompts[3][:24], rng.integers(0, cfg.vocab, n).astype(np.int32)])
        for n in (3, 6, 1, 5, 2)]
    sp_mnts = [4, 6, 3, 5, 4]
    for name, kw in [("shared-prefix/recompute", dict(pool)),
                     ("shared-prefix/swap", dict(pool, preempt="swap"))]:
        off, _ = run_trace(cfg, params, sp_prompts, sp_mnts, **kw)
        on, sched = run_trace(cfg, params, sp_prompts, sp_mnts,
                              prefix_sharing=True, **kw)
        for rid in off:
            check(on[rid].tokens.tolist() == off[rid].tokens.tolist(),
                  f"{name}: rid {rid} diverged with sharing on")
            check(on[rid].reason == off[rid].reason,
                  f"{name}: rid {rid} finish reason diverged")
        check(sched.counters["prefix_shared_tokens"] > 0,
              f"{name}: sharing never engaged (vacuous differential)")
        sched.slots.flush_prefix()
        check(sched.stats()["blocks_used"] == 0,
              f"{name}: prefix index leaked blocks after flush")
        print(f"[smoke_opt] {name}: OK "
              f"({sched.counters['prefix_shared_tokens']} shared tokens)")

    # speculative differential: speculate=k greedy streams must be bit-
    # identical to the k=0 baseline across the contiguous, paged+swap,
    # windowed-ring and shared-prefix pools — the verify-accept rollback
    # and the host commit logic are the serve path's newest stateful
    # code, and a stripped assert there would silently commit rejected
    # KV. Real drafts must flow (else the differential is vacuous) and
    # the swap arms must still recompute nothing.
    spec_arms = [
        ("spec/contiguous-k2", cfg, params, base, prompts, mnts,
         dict(speculate=2)),
        ("spec/paged-swap-k3", cfg, params, base, prompts, mnts,
         dict(pool, preempt="swap", speculate=3)),
        ("spec/windowed-swap-k2", cfg_w, params_w, base_w, prompts, mnts,
         dict(pool_w, preempt="swap", speculate=2)),
    ]
    for name, c_, p_, b_, ps_, ms_, kw in spec_arms:
        got, sched = run_trace(c_, p_, ps_, ms_, **kw)
        for rid in b_:
            check(got[rid].tokens.tolist() == b_[rid].tokens.tolist(),
                  f"{name}: rid {rid} stream diverged from speculate=0")
            check(got[rid].reason == b_[rid].reason,
                  f"{name}: rid {rid} finish reason diverged")
        c = sched.counters
        check(c["spec.drafted_tokens"] > 0,
              f"{name}: no real drafts flowed (vacuous differential)")
        if "swap" in name:
            check(c["recomputed_decode_steps"] == 0,
                  f"{name}: speculation recomputed decode steps")
        if "paged" in name or "windowed" in name:
            check(sched.stats()["blocks_used"] == 0,
                  f"{name}: retire leaked blocks")
        print(f"[smoke_opt] {name}: OK ({c['spec.accepted_tokens']}/"
              f"{c['spec.drafted_tokens']} drafts accepted, "
              f"{c['spec.rollbacks']} rollbacks)")
    sp_off, _ = run_trace(cfg, params, sp_prompts, sp_mnts,
                          **dict(pool, preempt="swap"))
    sp_on, sched = run_trace(cfg, params, sp_prompts, sp_mnts,
                             prefix_sharing=True, speculate=2,
                             **dict(pool, preempt="swap"))
    for rid in sp_off:
        check(sp_on[rid].tokens.tolist() == sp_off[rid].tokens.tolist(),
              f"spec/shared-prefix: rid {rid} diverged")
    check(sched.counters["prefix_shared_tokens"] > 0
          and sched.counters["spec.drafted_tokens"] > 0,
          "spec/shared-prefix: sharing or speculation never engaged")
    print(f"[smoke_opt] spec/shared-prefix-k2: OK "
          f"({sched.counters['prefix_shared_tokens']} shared tokens)")

    # user-input feasibility must be ValueError, not a stripped assert
    from repro.serve import Scheduler, SchedulerConfig
    sched = Scheduler(cfg, params, SchedulerConfig(
        num_slots=1, max_len=16, prefill_chunk=8))
    for bad in (dict(max_new_tokens=0),
                dict(max_new_tokens=15)):
        try:
            sched.submit([np.arange(4, dtype=np.int32)], **bad)
        except ValueError:
            pass
        else:
            raise SystemExit(f"[smoke_opt] FAIL: submit({bad}) accepted "
                             "under -O (feasibility check stripped)")
    # paged feasibility (every page-table group) must reject too
    paged = Scheduler(cfg_w, params_w, SchedulerConfig(
        num_slots=1, max_len=64, prefill_chunk=8, allocator="paged",
        block_size=8, num_blocks=2))
    try:
        paged.submit([np.arange(20, dtype=np.int32)], max_new_tokens=8)
    except ValueError:
        pass
    else:
        raise SystemExit("[smoke_opt] FAIL: infeasible paged submit "
                         "accepted under -O")
    print("[smoke_opt] all serve-path checks green under python -O")
    return 0


if __name__ == "__main__":
    sys.exit(main())
