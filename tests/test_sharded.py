"""Sharded serving slot pool: mesh_shards=1 bit-identity to the
unsharded scheduler, n-shard token identity to the unsharded oracle
(through forced swap, CoW prefix sharing, windowed rings and
speculate=k), mesh-aware placement + work-stealing rebalance, the
serve.shard observability surface, jit-cache keying across mesh sizes,
and the shard-pool invariants (property-tested when hypothesis is
installed, seeded-random always). The real shard_map lanes run on a
forced 8-device mesh in a subprocess (XLA device count is fixed at jax
import, so the in-process tests cover the delegate and vmap paths)."""

import dataclasses
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax

from repro import configs
from repro.launch import mesh as mesh_lib
from repro.models import transformer as T
from repro.obs import schema
from repro.serve import Scheduler, SchedulerConfig, engine

SRC = str(Path(__file__).resolve().parents[1] / "src")
ENV = {**os.environ, "PYTHONPATH": SRC,
       "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}


@pytest.fixture(scope="module")
def gemma():
    cfg = configs.reduced_config("gemma-2b")
    return cfg, T.init_model(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def gemma3():
    """Windowed model: sliding-window rings + global KV — two
    page-table groups per shard."""
    cfg = configs.reduced_config("gemma3-12b")
    return cfg, T.init_model(jax.random.PRNGKey(0), cfg)


_LENS = [3, 17, 9, 24, 5, 12]
_MNTS = [6, 4, 8, 5, 7, 3]


def _prompts(cfg, lens, seed=1, prefix=0):
    rng = np.random.default_rng(seed)
    out = [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in lens]
    if prefix:
        shared = rng.integers(0, cfg.vocab, prefix).astype(np.int32)
        out = [np.concatenate([shared, p]) for p in out]
    return out


def _run(cfg, params, sc, prompts, mnts, mesh=None):
    """Staggered submit/step then drain; returns ([(tokens, reason)],
    scheduler) in submission order."""
    s = Scheduler(cfg, params, sc, mesh=mesh)
    rids = []
    for p, m in zip(prompts, mnts):
        rids += s.submit([p], max_new_tokens=m)
        s.step()
    s.drain()
    return [(list(map(int, s.results[r].tokens)), s.results[r].reason)
            for r in rids], s


_BASE = SchedulerConfig(num_slots=4, max_len=64, prefill_chunk=8,
                        allocator="paged", block_size=8, num_blocks=24,
                        eos_token=5, cache_requests=False)


# --------------------------------------------------------------------------
# mesh_shards=1: bit-identical control flow AND streams vs unsharded
# --------------------------------------------------------------------------

def test_mesh1_bit_identical_to_unsharded(gemma):
    """The n=1 sharded pool runs the SAME compiled programs (the
    delegate path), the same admission order, the same slot choices —
    token streams, finish reasons and the scheduler's control-flow
    counters must all be identical to the unsharded scheduler."""
    cfg, params = gemma
    prompts = _prompts(cfg, _LENS)
    a, sa = _run(cfg, params, _BASE, prompts, _MNTS)
    b, sb = _run(cfg, params,
                 dataclasses.replace(_BASE, mesh_shards=1), prompts, _MNTS)
    assert a == b
    for k in ("admitted", "preempted", "chunk_steps", "decode_steps",
              "prefill_tokens", "generated_tokens"):
        assert sa.counters[k] == sb.counters[k], k


def test_mesh1_bit_identical_with_sampling(gemma):
    """Sampled (temperature>0) streams consume the PRNG identically on
    the n=1 path (keys reshape through the delegate unchanged)."""
    cfg, params = gemma
    sc = dataclasses.replace(_BASE, temperature=0.8, top_k=8, seed=3)
    prompts = _prompts(cfg, _LENS[:4])
    a, _ = _run(cfg, params, sc, prompts, _MNTS[:4])
    b, _ = _run(cfg, params, dataclasses.replace(sc, mesh_shards=1),
                prompts, _MNTS[:4])
    assert a == b


# --------------------------------------------------------------------------
# n-shard pools (vmap path): greedy token identity vs the unsharded
# oracle — equal TOTAL resources, per-shard splits
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 4])
def test_sharded_tokens_match_unsharded_oracle(gemma, n):
    cfg, params = gemma
    prompts = _prompts(cfg, _LENS)
    a, _ = _run(cfg, params, _BASE, prompts, _MNTS)
    sc = dataclasses.replace(_BASE, mesh_shards=n, num_blocks=24 // n)
    b, sb = _run(cfg, params, sc, prompts, _MNTS)
    assert a == b
    assert sb.slots.num_shards == n


def test_sharded_forced_swap_matches_oracle(gemma):
    """Per-shard pools small enough that decode growth preempts; under
    preempt='swap' the resumed streams must still match the oracle
    (swap is shard-local: blocks and bytes never cross shards except
    via explicit steal migration)."""
    cfg, params = gemma
    sc = dataclasses.replace(_BASE, num_blocks=10, preempt="swap")
    prompts = _prompts(cfg, _LENS)
    mnts = [20, 16, 20, 12, 18, 14]     # long tails force decode growth
    a, sa = _run(cfg, params, sc, prompts, mnts)
    b, sb = _run(cfg, params,
                 dataclasses.replace(sc, mesh_shards=2, num_blocks=5),
                 prompts, mnts)
    assert sa.counters["swapped_out"] > 0      # both arms actually swap
    assert sb.counters["swapped_out"] > 0
    assert [t for t, _ in a] == [t for t, _ in b]


def test_sharded_prefix_sharing_matches_oracle(gemma):
    """CoW prefix sharing stays shard-local: sharers hit the index when
    placed on the shard holding the prefix (pinned here — least-blocks
    placement deliberately spreads load instead), and streams still
    match the unshared oracle bit-for-bit."""
    cfg, params = gemma
    # shared 16-token prefix = 2 chunks = 2 blocks (align lcm(8,8)=8)
    prompts = _prompts(cfg, [5, 7, 9, 6], prefix=16)
    mnts = [4, 4, 4, 4]
    a, _ = _run(cfg, params, _BASE, prompts, mnts)
    sc = dataclasses.replace(_BASE, prefix_sharing=True, mesh_shards=2,
                             num_blocks=12)
    s = Scheduler(cfg, params, sc)
    s.placement_fn = lambda sched, st: 0    # co-locate with the prefix
    rids = []
    for p, m in zip(prompts, mnts):
        rids += s.submit([p], max_new_tokens=m)
        s.step()
    s.drain()
    b = [(list(map(int, s.results[r].tokens)), s.results[r].reason)
         for r in rids]
    assert s.counters["prefix_shared_tokens"] > 0
    assert s.stats()["shared_blocks"] >= 0      # aggregated across shards
    assert a == b


def test_sharded_windowed_rings_match_oracle(gemma3):
    """Two page-table groups per shard (ring + global KV): the sharded
    pool must reproduce the windowed oracle streams."""
    cfg, params = gemma3
    sc = dataclasses.replace(_BASE, block_size=4, num_blocks=48)
    prompts = _prompts(cfg, _LENS)
    a, _ = _run(cfg, params, sc, prompts, _MNTS)
    b, _ = _run(cfg, params,
                dataclasses.replace(sc, mesh_shards=2, num_blocks=24),
                prompts, _MNTS)
    assert a == b


def test_sharded_speculative_matches_oracle(gemma):
    """speculate=k verify-accept ticks run per shard on shard-local
    rows; greedy streams must equal both the unsharded speculative run
    and (by its own test) the non-speculative oracle."""
    cfg, params = gemma
    sc = dataclasses.replace(_BASE, speculate=2)
    prompts = _prompts(cfg, _LENS[:4])
    a, _ = _run(cfg, params, sc, prompts, _MNTS[:4])
    b, _ = _run(cfg, params,
                dataclasses.replace(sc, mesh_shards=2, num_blocks=12),
                prompts, _MNTS[:4])
    assert a == b


def test_sharded_score_rows_match_oracle(gemma):
    """Prompt scoring rides the chunk path: per-token logprobs from a
    sharded pool must equal the unsharded ones bitwise (chunk logits
    come back in input order regardless of shard assignment)."""
    cfg, params = gemma
    prompts = _prompts(cfg, [19, 25, 10])

    def score(sc):
        s = Scheduler(cfg, params, sc)
        rids = s.score(prompts)
        s.drain()
        return [np.asarray(s.results[r].logprobs) for r in rids]

    a = score(_BASE)
    b = score(dataclasses.replace(_BASE, mesh_shards=2, num_blocks=12))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


# --------------------------------------------------------------------------
# placement + work-stealing
# --------------------------------------------------------------------------

def test_placement_round_robin_and_pluggable(gemma):
    cfg, params = gemma
    sc = dataclasses.replace(_BASE, mesh_shards=2, num_blocks=12,
                             placement="round_robin")
    s = Scheduler(cfg, params, sc)
    prompts = _prompts(cfg, [4, 4, 4, 4])
    for p in prompts:
        s.submit([p], max_new_tokens=2)
    assert [len(q) for q in s._queues] == [2, 2]
    s.drain()
    # pluggable: pin everything to shard 1
    s2 = Scheduler(cfg, params, sc)
    s2.placement_fn = lambda sched, st: 1
    for p in prompts:
        s2.submit([p], max_new_tokens=2)
    assert [len(q) for q in s2._queues] == [0, 4]
    s2.drain()
    assert s2._shard_placed == [0, 4]


def test_steal_rebalance_beats_head_of_line(gemma):
    """A head queued behind a full shard migrates to the idle shard and
    admits immediately instead of waiting for the full shard to drain."""
    cfg, params = gemma
    sc = dataclasses.replace(_BASE, num_slots=2, mesh_shards=2,
                             num_blocks=12, max_new_tokens=24)
    s = Scheduler(cfg, params, sc)
    s.placement_fn = lambda sched, st: 0        # skewed arrivals
    prompts = _prompts(cfg, [8, 8])
    s.submit([prompts[0]], max_new_tokens=24)
    s.step()                                    # occupies shard 0's slot
    s.submit([prompts[1]], max_new_tokens=24)
    s.step()
    # shard 0 is full (1 slot) -> the second head was stolen to shard 1
    assert s.counters["steals"] == 1
    assert s.live == 2                          # both decoding at once
    s.drain()
    # no-steal control: the same skew head-of-line blocks
    s3 = Scheduler(cfg, params, dataclasses.replace(sc, steal=False))
    s3.placement_fn = lambda sched, st: 0
    s3.submit([prompts[0]], max_new_tokens=24)
    s3.step()
    s3.submit([prompts[1]], max_new_tokens=24)
    s3.step()
    assert s3.counters["steals"] == 0 and s3.live == 1
    s3.drain()


def test_steal_swapped_preserves_prefill_progress(gemma):
    """A swap-preempted request stolen to another shard moves its host
    SwapEntry (budget-checked) and resumes at its saved position: the
    final stream matches the oracle and the migration counters fire."""
    cfg, params = gemma
    prompts = _prompts(cfg, [20, 20, 8])
    mnts = [12, 12, 6]
    oracle, _ = _run(cfg, params,
                     dataclasses.replace(_BASE, num_slots=2, num_blocks=8,
                                         preempt="swap"),
                     prompts, mnts)
    sc = dataclasses.replace(_BASE, num_slots=4, mesh_shards=2,
                             num_blocks=4, preempt="swap")
    s = Scheduler(cfg, params, sc, )
    s.placement_fn = lambda sched, st: 0        # all arrive on shard 0
    rids = []
    for p, m in zip(prompts, mnts):
        rids += s.submit([p], max_new_tokens=m)
        s.step()
    s.drain()
    got = [(list(map(int, s.results[r].tokens)), s.results[r].reason)
           for r in rids]
    # the skewed run forced swap preemption and cross-shard migration
    st = s.stats()
    if s.counters["steals"] and st["swap_migrated_in"]:
        assert st["swap_migrated_in"] == st["swap_migrated_out"]
    assert [t for t, _ in got] == [t for t, _ in oracle]


# --------------------------------------------------------------------------
# observability: serve.shard gauges + stats schema
# --------------------------------------------------------------------------

def test_shard_metrics_schema(gemma):
    cfg, params = gemma
    sc = dataclasses.replace(_BASE, mesh_shards=2, num_blocks=12)
    _, s = _run(cfg, params, sc, _prompts(cfg, _LENS[:3]), _MNTS[:3])
    assert schema.validate_shard_metrics(
        s._shard_obs.metrics(), 2) == []
    assert schema.validate_stats(s.stats(), schema.SCHEDULER_STATS) == []
    assert schema.validate_stats(s.stats(), schema.PAGED_STATS) == []
    placed = sum(s._shard_obs.metrics()[f"shard{i}.placed"]
                 for i in range(2))
    assert placed == 3


# --------------------------------------------------------------------------
# mesh constructors + jit-cache keys (satellites 1 + 2)
# --------------------------------------------------------------------------

def test_make_worker_mesh_oversubscription_message():
    n = len(jax.devices()) + 1
    with pytest.raises(ValueError) as ei:
        mesh_lib.make_worker_mesh(n)
    msg = str(ei.value)
    assert f"requested {n} workers" in msg
    assert f"--xla_force_host_platform_device_count={n}" in msg
    with pytest.raises(ValueError):
        mesh_lib.make_worker_mesh(0)


def test_sharded_step_cache_keys_fold_shard_count(gemma):
    """Installing two shard counts back-to-back must give two distinct
    compiled programs (the cache key folds num_shards + mesh), and
    re-requesting the first must return the SAME object (cache hit)."""
    cfg, _ = gemma
    f1 = engine.jit_sharded_decode_step(cfg, 1, 8)
    f2 = engine.jit_sharded_decode_step(cfg, 2, 8)
    assert f1 is not f2
    assert engine.jit_sharded_decode_step(cfg, 1, 8) is f1
    mesh = mesh_lib.make_worker_mesh(1, axis="slots")
    f3 = engine.jit_sharded_decode_step(cfg, 1, 8, mesh=mesh, axis="slots")
    assert f3 is not f1


def test_dispatch_jit_cache_folds_mesh():
    from repro.runtime.dispatch import _jit_batched

    def fn(x):
        return x * 2

    mesh = mesh_lib.make_worker_mesh(1)
    a = _jit_batched(fn, (0,), None, None)
    b = _jit_batched(fn, (0,), mesh, mesh.axis_names[0])
    assert a is not b
    x = np.arange(4, dtype=np.float32)
    np.testing.assert_array_equal(np.asarray(a(x)), np.asarray(b(x)))


# --------------------------------------------------------------------------
# shard-pool invariants (hypothesis when available, seeded always)
# --------------------------------------------------------------------------

def _check_shard_invariants(s):
    """(1) every live request maps to exactly ONE shard (slot shard ==
    recorded home; queued requests sit in their home queue; swapped
    entries parked on exactly one store). (2) per-shard block
    accounting: free + mapped(+index-held) == total in every group —
    blocks never cross shards."""
    sm = s.slots
    b = sm.backing
    owners = {}
    for slot, st in s._by_slot.items():
        assert sm.shard_of_slot(slot) == st.shard
        owners[st.rid] = owners.get(st.rid, 0) + 1
    for i, q in enumerate(s._queues):
        for st in q:
            assert st.shard == i
            owners[st.rid] = owners.get(st.rid, 0) + 1
            if sm.is_swapped(st.rid):
                held = [j for j, sh in enumerate(b.shards)
                        if st.rid in sh.swaps]
                assert held == [i]      # parked exactly on the home shard
    assert all(v == 1 for v in owners.values()), owners
    for i, sh in enumerate(b.shards):
        holds = sh.prefix_holds()
        for vl, g in sh.groups.items():
            g.pt.check_invariants(holds[vl])
            free = g.pool.num_blocks - g.pool.used_count
            assert free == sum(1 for a in g.pool.allocated if not a)
        assert sm.shard_free_blocks(i) == sum(
            g.pool.num_blocks - g.pool.used_count
            for g in sh.groups.values())


def _random_serving_trace(gemma, seed, n_shards, preempt, steal=True):
    cfg, params = gemma
    rng = np.random.default_rng(seed)
    sc = dataclasses.replace(
        _BASE, num_slots=4, mesh_shards=n_shards,
        num_blocks=int(rng.integers(4, 9)), preempt=preempt,
        placement=str(rng.choice(["least_blocks", "round_robin"])),
        steal=steal, prefix_sharing=bool(rng.integers(0, 2)))
    s = Scheduler(cfg, params, sc)
    for _ in range(int(rng.integers(4, 10))):
        k = int(rng.integers(1, 3))
        lens = rng.integers(2, 28, size=k)
        prompts = [rng.integers(0, cfg.vocab, l).astype(np.int32)
                   for l in lens]
        s.submit(prompts, max_new_tokens=int(rng.integers(1, 10)))
        for _ in range(int(rng.integers(0, 3))):
            s.step()
            _check_shard_invariants(s)
    s.drain()
    _check_shard_invariants(s)
    assert s.pending == 0 and s.live == 0
    # pool fully drained back: every block free on every shard
    for i in range(s.slots.num_shards):
        free = s.slots.shard_free_blocks(i)
        total = sum(g.pool.num_blocks
                    for g in s.slots.backing.shards[i].groups.values())
        held = sum(int(h.sum()) for h in
                   s.slots.backing.shards[i].prefix_holds().values())
        assert free + held == total


@pytest.mark.parametrize("preempt", ["recompute", "swap"])
def test_property_shard_invariants_seeded(gemma, preempt):
    for seed in (0, 1):
        _random_serving_trace(gemma, seed, 2, preempt)


def test_property_shard_invariants_hypothesis(gemma):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as hst

    @settings(max_examples=10, deadline=None)
    @given(hst.integers(0, 2**16), hst.sampled_from([2, 4]),
           hst.sampled_from(["recompute", "swap"]))
    def prop(seed, n, preempt):
        _random_serving_trace(gemma, seed, n, preempt)

    prop()


# --------------------------------------------------------------------------
# real shard_map lanes: forced 8-device mesh in a subprocess (two mesh
# sizes back-to-back also regression-tests the jit-cache keying on live
# meshes)
# --------------------------------------------------------------------------

_SHARD_MAP_CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    import jax
    from repro import configs
    from repro.launch import mesh as mesh_lib
    from repro.models import transformer as T
    from repro.serve import Scheduler, SchedulerConfig

    cfg = configs.reduced_config("gemma-2b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    lens, mnts = [3, 17, 9, 12], [5, 4, 6, 3]
    prompts = [rng.integers(0, cfg.vocab, l).astype(np.int32)
               for l in lens]

    base = SchedulerConfig(num_slots=4, max_len=64, prefill_chunk=8,
                           allocator="paged", block_size=8,
                           num_blocks=16, eos_token=5,
                           cache_requests=False)

    def run(sc, mesh=None):
        s = Scheduler(cfg, params, sc, mesh=mesh)
        rids = []
        for p, m in zip(prompts, mnts):
            rids += s.submit([p], max_new_tokens=m)
            s.step()
        s.drain()
        return [list(map(int, s.results[r].tokens)) for r in rids]

    oracle = run(base)
    for n in (2, 4):            # two mesh sizes back-to-back
        mesh = mesh_lib.make_worker_mesh(n, axis="slots")
        got = run(dataclasses.replace(base, mesh_shards=n,
                                      num_blocks=16 // n), mesh=mesh)
        assert got == oracle, (n, got, oracle)
    print("SHARD_MAP_OK", len(jax.devices()))
""")


def test_shard_map_differential_forced_8_devices():
    res = subprocess.run([sys.executable, "-c", _SHARD_MAP_CODE],
                         capture_output=True, text=True, env=ENV,
                         timeout=1200)
    assert res.returncode == 0, res.stderr[-4000:]
    assert "SHARD_MAP_OK 8" in res.stdout
