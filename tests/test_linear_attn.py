"""core.linear_attn: chunked WKV/Mamba scans vs sequential oracles —
the paper's chunk decomposition at LM scale must be exact — plus decode-
step consistency (prefill state == running decode state)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import linear_attn as la


def _wkv_oracle(r, w, k, v, s0):
    """Sequential readout: y_t = r_t @ S_{t-1}... matches wkv_chunked's
    contract (query BEFORE update, no bonus)."""
    b, t, dk = r.shape
    dv = v.shape[-1]
    s = np.array(s0, np.float64) if s0 is not None else \
        np.zeros((b, dk, dv))
    y = np.zeros((b, t, dv))
    for i in range(t):
        for bb in range(b):
            y[bb, i] = r[bb, i] @ s[bb]
            s[bb] = w[bb, i][:, None] * s[bb] + np.outer(k[bb, i], v[bb, i])
    return y, s


@pytest.mark.parametrize("t,chunk", [(16, 4), (33, 8), (64, 64), (100, 32)])
def test_wkv_chunked_exact(t, chunk):
    rng = np.random.default_rng(t)
    b, dk, dv = 2, 8, 12
    r = rng.normal(size=(b, t, dk)).astype(np.float32)
    w = rng.uniform(0.6, 1.0, (b, t, dk)).astype(np.float32)
    k = rng.normal(size=(b, t, dk)).astype(np.float32)
    v = rng.normal(size=(b, t, dv)).astype(np.float32)
    s0 = rng.normal(size=(b, dk, dv)).astype(np.float32)

    y, s_fin = la.wkv_chunked(jnp.asarray(r), jnp.asarray(w), jnp.asarray(k),
                              jnp.asarray(v), None, jnp.asarray(s0),
                              chunk=chunk)
    y_ref, s_ref = _wkv_oracle(r, w, k, v, s0)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s_fin), s_ref, rtol=1e-3,
                               atol=1e-3)


def test_wkv_decode_matches_chunked_tail():
    """Running T decode steps == one chunked call (state handoff exact)."""
    rng = np.random.default_rng(0)
    b, t, dk, dv = 1, 12, 4, 4
    r = rng.normal(size=(b, t, dk)).astype(np.float32)
    w = rng.uniform(0.5, 1.0, (b, t, dk)).astype(np.float32)
    k = rng.normal(size=(b, t, dk)).astype(np.float32)
    v = rng.normal(size=(b, t, dv)).astype(np.float32)

    y_chunk, s_chunk = la.wkv_chunked(
        jnp.asarray(r), jnp.asarray(w), jnp.asarray(k), jnp.asarray(v),
        None, None, chunk=4)

    s = jnp.zeros((b, dk, dv))
    ys = []
    for i in range(t):
        y, s = la.wkv_decode_step(jnp.asarray(r[:, i]), jnp.asarray(w[:, i]),
                                  jnp.asarray(k[:, i]), jnp.asarray(v[:, i]),
                                  None, s)
        ys.append(y)
    y_dec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_chunk),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_chunk),
                               rtol=1e-4, atol=1e-4)


def test_chunk_size_invariance():
    rng = np.random.default_rng(1)
    b, t, d = 2, 96, 8
    r = rng.normal(size=(b, t, d)).astype(np.float32)
    w = rng.uniform(0.7, 1.0, (b, t, d)).astype(np.float32)
    k = rng.normal(size=(b, t, d)).astype(np.float32)
    v = rng.normal(size=(b, t, d)).astype(np.float32)
    outs = [la.wkv_chunked(jnp.asarray(r), jnp.asarray(w), jnp.asarray(k),
                           jnp.asarray(v), None, None, chunk=c)[0]
            for c in (8, 24, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-3, atol=1e-3)


def test_mamba_chunked_matches_sequential():
    rng = np.random.default_rng(2)
    b, t, d_inner, d_state = 1, 32, 6, 4
    x = rng.normal(size=(b, t, d_inner)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, (b, t, d_inner)).astype(np.float32)
    B = rng.normal(size=(b, t, d_state)).astype(np.float32)
    Cm = rng.normal(size=(b, t, d_state)).astype(np.float32)
    A = -rng.uniform(0.5, 1.5, (d_inner, d_state)).astype(np.float32)

    y_c, s_c = la.mamba_chunked(jnp.asarray(x), jnp.asarray(dt),
                                jnp.asarray(A), jnp.asarray(B),
                                jnp.asarray(Cm),
                                jnp.zeros((d_inner,), jnp.float32), chunk=8)

    # sequential oracle
    s = np.zeros((b, d_inner, d_state))
    y_ref = np.zeros((b, t, d_inner))
    for i in range(t):
        for bb in range(b):
            da = np.exp(dt[bb, i][:, None] * A)            # (d_inner, d_state)
            db = dt[bb, i][:, None] * B[bb, i][None, :]
            s[bb] = da * s[bb] + db * x[bb, i][:, None]
            y_ref[bb, i] = s[bb] @ Cm[bb, i]
    np.testing.assert_allclose(np.asarray(y_c), y_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s_c), s, rtol=1e-3, atol=1e-3)


def test_decay_clamp_contract():
    """Log-decay clamp: w below e^-1 is clamped, not NaN/overflowed."""
    b, t, d = 1, 8, 4
    r = jnp.ones((b, t, d))
    w = jnp.full((b, t, d), 1e-6)       # extreme decay
    k = jnp.ones((b, t, d))
    v = jnp.ones((b, t, d))
    y, s = la.wkv_chunked(r, w, k, v, None, None, chunk=4)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(s)).all()
