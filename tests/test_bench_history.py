"""Benchmark-trajectory gate (PR 7): row parsing, the comparator's
gated/informational split (a degraded gated metric, a silently dropped
gated metric and a schema bump must all FAIL the check), and the
ratcheted-write merge semantics. Pure functions — no benchmarks run."""

import json

import pytest

from benchmarks.bench_history import (SCHEMA_VERSION, _dump, _metric,
                                      baseline_path, compare, parse_rows,
                                      ratchet)


def _doc(metrics, schema=SCHEMA_VERSION):
    return {"schema_version": schema, "suite": "serve", "smoke": True,
            "metrics": metrics}


# --------------------------------------------------------------------------
# row parsing (the benchmarks.common.emit contract)
# --------------------------------------------------------------------------

def test_parse_rows():
    idx = parse_rows([
        "fig.a,12.5,speedup=2.0,steps=42",
        "fig.b,3.0,label=paged,note",
        "not-a-row",
    ])
    assert idx["fig.a"] == {"us": 12.5, "speedup": 2.0, "steps": 42.0}
    assert idx["fig.b"] == {"us": 3.0, "label": "paged", "derived": "note"}
    assert "not-a-row" not in idx


# --------------------------------------------------------------------------
# comparator: only gated metrics gate, in their bad direction
# --------------------------------------------------------------------------

def test_compare_passes_on_equal_and_improved():
    base = _doc({"ratio": _metric(2.0, "higher", 0.02),
                 "misses": _metric(3.0, "lower", 0.0),
                 "tok_per_s": _metric(1000.0, "higher", None)})
    assert compare(base, base) == []
    better = _doc({"ratio": _metric(2.5, "higher", 0.02),
                   "misses": _metric(2.0, "lower", 0.0),
                   "tok_per_s": _metric(5.0, "higher", None)})
    assert compare(base, better) == []      # informational never gates


def test_compare_fails_on_degraded_gated_metric():
    base = _doc({"ratio": _metric(2.0, "higher", 0.02)})
    ok = _doc({"ratio": _metric(1.97, "higher", 0.02)})
    assert compare(base, ok) == []          # inside tolerance
    bad = _doc({"ratio": _metric(1.9, "higher", 0.02)})
    problems = compare(base, bad)
    assert len(problems) == 1 and "ratio" in problems[0]
    # 'lower' direction: exceeding the ceiling fails
    base = _doc({"misses": _metric(3.0, "lower", 0.0)})
    assert compare(base, _doc({"misses": _metric(4.0, "lower", 0.0)}))
    assert compare(base, _doc({"misses": _metric(3.0, "lower", 0.0)})) == []


def test_compare_fails_on_missing_gated_metric_and_schema_bump():
    base = _doc({"ratio": _metric(2.0, "higher", 0.02),
                 "tok_per_s": _metric(1000.0, "higher", None)})
    # dropped gated measurement must not silently pass; dropped
    # informational one is fine
    problems = compare(base, _doc({}))
    assert len(problems) == 1 and "ratio" in problems[0]
    stale = _doc({"ratio": _metric(2.0, "higher", 0.02)},
                 schema=SCHEMA_VERSION + 1)
    problems = compare(stale, _doc({"ratio": _metric(2.0, "higher", 0.02)}))
    assert len(problems) == 1 and "schema_version" in problems[0]


# --------------------------------------------------------------------------
# ratchet: gated keeps the better value, informational takes the fresh
# --------------------------------------------------------------------------

def test_ratchet_semantics():
    old = _doc({"ratio": _metric(2.5, "higher", 0.02),
                "misses": _metric(2.0, "lower", 0.0),
                "tok_per_s": _metric(1000.0, "higher", None),
                "retired": _metric(7.0, "higher", 0.0)})
    new = _doc({"ratio": _metric(2.1, "higher", 0.02),    # worse
                "misses": _metric(3.0, "lower", 0.0),     # worse
                "tok_per_s": _metric(1200.0, "higher", None),
                "fresh": _metric(1.0, "higher", 0.02)})
    m = ratchet(old, new)["metrics"]
    assert m["ratio"]["value"] == 2.5       # gated never loosens
    assert m["misses"]["value"] == 2.0
    assert m["tok_per_s"]["value"] == 1200.0    # informational refreshes
    assert m["fresh"]["value"] == 1.0           # new metrics added
    assert m["retired"]["value"] == 7.0         # gated history retained
    # an improved fresh value wins the ratchet
    new2 = _doc({"ratio": _metric(3.0, "higher", 0.02)})
    assert ratchet(old, new2)["metrics"]["ratio"]["value"] == 3.0


def test_dump_and_baseline_path(tmp_path):
    path = baseline_path("serve", str(tmp_path))
    assert path.endswith("BENCH_serve.json")
    doc = _doc({"ratio": _metric(2.0, "higher", 0.02)})
    _dump(doc, path)
    assert json.load(open(path)) == doc
    assert [p.name for p in tmp_path.iterdir()] == ["BENCH_serve.json"]


def test_committed_baselines_are_valid():
    """The files CI gates against must exist at the repo root, carry the
    current schema, and have at least one gated metric each (a baseline
    with no gated metrics gates nothing)."""
    from benchmarks.bench_history import REPO_ROOT

    for suite in ("serve", "runtime"):
        with open(baseline_path(suite, REPO_ROOT)) as f:
            doc = json.load(f)
        assert doc["schema_version"] == SCHEMA_VERSION
        gated = [n for n, s in doc["metrics"].items()
                 if s["tolerance"] is not None]
        assert gated, f"{suite}: no gated metrics in committed baseline"
        for spec in doc["metrics"].values():
            assert spec["direction"] in ("higher", "lower")
