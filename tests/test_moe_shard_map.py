"""shard_map all-to-all MoE dispatch (§Perf MoE iteration 1): exactness vs
the GSPMD path, decode/long-context shapes, and gradient flow — on a
forced 8-device mesh in a subprocess."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")
ENV = {**os.environ, "PYTHONPATH": SRC}

_CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.launch import mesh as mesh_lib
    from repro.sharding import configure
    from repro.models import moe as M

    mesh = mesh_lib.make_smoke_mesh()          # (data=2, model=4)
    configure(mesh)
    cfg = M.MoEConfig(d_model=16, d_ff=32, num_experts=8,
                      experts_per_token=2, capacity_factor=8.0)
    params = M.init_moe(jax.random.PRNGKey(0), cfg)

    checks = []

    # 1. exactness vs the GSPMD oracle in the drop-free regime
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
    with mesh:
        y_sm, aux = jax.jit(lambda p, x: M.moe(p, cfg, x))(params, x)
    configure(None)
    y_gs, _ = M._moe_gspmd(params, cfg, x)
    checks.append(("exact", np.allclose(np.asarray(y_sm),
                                        np.asarray(y_gs), atol=2e-2)))
    configure(mesh)

    # 2. decode shapes: seq=1 and batch=1 (non-divisible dims replicate)
    for shp in ((4, 1, 16), (1, 1, 16)):
        xd = jax.random.normal(jax.random.PRNGKey(2), shp)
        with mesh:
            yd, _ = jax.jit(lambda p, x: M.moe(p, cfg, x))(params, xd)
        checks.append((f"decode{shp}", bool(np.isfinite(
            np.asarray(yd, np.float32)).all())))

    # 3. gradients flow through the all_to_all exchange
    def loss(p):
        y, aux = M.moe(p, cfg, x)
        return jnp.sum(jnp.square(y.astype(jnp.float32))) + aux
    with mesh:
        g = jax.jit(jax.grad(loss))(params)
    checks.append(("router_grad",
                   float(jnp.linalg.norm(g["router"])) > 0))
    checks.append(("expert_grad",
                   float(jnp.linalg.norm(g["expert_gate"])) > 0))

    # 4. non-divisible experts fall back to the GSPMD path
    cfg_odd = M.MoEConfig(d_model=16, d_ff=32, num_experts=6,
                          experts_per_token=2, capacity_factor=8.0)
    p_odd = M.init_moe(jax.random.PRNGKey(3), cfg_odd)
    with mesh:
        y_odd, _ = jax.jit(lambda p, x: M.moe(p, cfg_odd, x))(p_odd, x)
    checks.append(("fallback", bool(np.isfinite(
        np.asarray(y_odd, np.float32)).all())))

    configure(None)
    for name, ok in checks:
        print(f"CHECK {name} {'PASS' if ok else 'FAIL'}")
""")


@pytest.fixture(scope="module")
def output():
    res = subprocess.run([sys.executable, "-c", _CODE],
                         capture_output=True, text=True, env=ENV,
                         timeout=540)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


@pytest.mark.parametrize("name", ["exact", "decode(4, 1, 16)",
                                  "decode(1, 1, 16)", "router_grad",
                                  "expert_grad", "fallback"])
def test_shard_map_moe(output, name):
    assert f"CHECK {name} PASS" in output, output
