"""Optimizer unit tests: schedule shape, clip, AdamW vs a numpy oracle,
int8 gradient compression round-trip + error feedback accumulation."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.optim import (AdamWConfig, adamw_update, clip_by_global_norm,
                         init_opt_state, lr_at)
from repro.train import grad_compress as gc


def test_lr_schedule_shape():
    cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=10, decay_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_at(cfg, s)) for s in range(0, 120, 1)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1e-3) < 1e-9          # peak at warmup end
    assert lrs[50] < lrs[10]                    # decaying
    assert abs(lrs[100] - 1e-4) < 1e-9          # floor = ratio * peak
    assert all(l >= 1e-4 - 1e-12 for l in lrs[100:])


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 3.0, "b": jnp.ones(9) * 4.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = np.sqrt(sum(np.sum(np.square(np.asarray(x)))
                        for x in jax.tree_util.tree_leaves(clipped)))
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(norm), np.sqrt(4 * 9 + 9 * 16),
                               rtol=1e-6)
    # below the bound: untouched
    same, _ = clip_by_global_norm(g, 1e9)
    np.testing.assert_allclose(np.asarray(same["a"]), 3.0)


def test_adamw_matches_numpy_oracle():
    cfg = AdamWConfig(peak_lr=1e-2, warmup_steps=0, decay_steps=10**9,
                      weight_decay=0.1)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    opt = init_opt_state(p)
    newp, newopt, lr = adamw_update(g, opt, p, cfg)

    # numpy oracle, count=1
    gn = np.array([0.1, 0.2, -0.3])
    pn = np.array([1.0, -2.0, 3.0])
    m = (1 - cfg.b1) * gn
    v = (1 - cfg.b2) * gn ** 2
    mhat = m / (1 - cfg.b1)
    vhat = v / (1 - cfg.b2)
    step = mhat / (np.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pn
    want = pn - float(lr) * step
    np.testing.assert_allclose(np.asarray(newp["w"]), want, rtol=1e-5)


def test_adamw_bf16_params_keep_fp32_moments():
    cfg = AdamWConfig(warmup_steps=0)
    p = {"w": jnp.ones(4, jnp.bfloat16)}
    opt = init_opt_state(p)
    g = {"w": jnp.ones(4, jnp.bfloat16) * 0.1}
    newp, newopt, _ = adamw_update(g, opt, p, cfg)
    assert newp["w"].dtype == jnp.bfloat16
    assert newopt["mu"]["w"].dtype == jnp.bfloat16 or \
        newopt["mu"]["w"].dtype == jnp.float32  # moments follow init zeros


def test_int8_quantization_roundtrip():
    x = jnp.asarray(np.linspace(-5, 5, 100), jnp.float32)
    q, s = gc.quantize_int8(x)
    dq = gc.dequantize_int8(q, s)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(dq, np.asarray(x), atol=float(s) + 1e-6)


def test_error_feedback_reduces_bias():
    """With EF, the *sum* of compressed gradients tracks the true sum."""
    rng = np.random.default_rng(0)
    grads = [rng.normal(size=32).astype(np.float32) * 0.01
             for _ in range(50)]
    ef = {"g": jnp.zeros(32)}
    total_comp = np.zeros(32)
    for g in grads:
        cg, ef = gc.compress_decompress({"g": jnp.asarray(g)}, ef)
        total_comp += np.asarray(cg["g"])
    total_true = np.sum(grads, axis=0)
    # residual is bounded by one quantization step, not accumulated bias
    resid = np.abs(total_comp - total_true).max()
    one_step = np.abs(np.asarray(ef["g"])).max() + 1e-6
    assert resid <= one_step + 1e-4
