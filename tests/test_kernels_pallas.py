"""Pallas kernel sweeps: every kernel x shapes x dtypes vs the ref.py
pure-jnp oracle, in interpret mode (the brief's per-kernel contract)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import chain as C
from repro.core import dtw as D
from repro.core import align as A
from repro.kernels import ops, ref
from repro.kernels.chain_scan import chain_scan_pallas
from repro.kernels.dtw_wavefront import dp_tile_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas


# --------------------------------------------------------------------------
# ssm_scan (chunked WKV)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,dk,dv,chunk", [
    (1, 32, 16, 16, 8),
    (2, 64, 32, 16, 16),
    (3, 96, 64, 64, 32),
    (2, 128, 8, 24, 64),
])
def test_ssm_scan_shapes(b, t, dk, dv, chunk):
    ks = jax.random.split(jax.random.PRNGKey(t), 5)
    r = jax.random.normal(ks[0], (b, t, dk))
    w = jax.nn.sigmoid(jax.random.normal(ks[1], (b, t, dk)) + 2.0)
    k = jax.random.normal(ks[2], (b, t, dk))
    v = jax.random.normal(ks[3], (b, t, dv))
    u = 0.1 * jax.random.normal(ks[4], (dk,))
    got = ssm_scan_pallas(r, w, k, v, u, chunk=chunk)
    want = ref.ssm_scan_ref(r, w, k, v, u)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    b, t, d = 2, 64, 32
    r = jax.random.normal(ks[0], (b, t, d), dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[1], (b, t, d), dtype) + 2)
    k = jax.random.normal(ks[2], (b, t, d), dtype)
    v = jax.random.normal(ks[3], (b, t, d), dtype)
    u = jnp.zeros((d,), dtype)
    got = ops.ssm_scan(r, w, k, v, u, chunk=16)
    want = ref.ssm_scan_ref(r, w, k, v, u)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_ssm_scan_t_padding():
    """ops wrapper pads T to the chunk size; result must be unaffected."""
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    b, t, d = 1, 50, 16       # t=50 not a multiple of 16
    r = jax.random.normal(ks[0], (b, t, d))
    w = jax.nn.sigmoid(jax.random.normal(ks[1], (b, t, d)))
    k = jax.random.normal(ks[2], (b, t, d))
    v = jax.random.normal(ks[3], (b, t, d))
    got = ops.ssm_scan(r, w, k, v, chunk=16)
    want = ref.ssm_scan_ref(r, w, k, v, jnp.zeros((d,)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# chain_scan
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,t,block", [
    (256, 128, 256),
    (512, 128, 256),
    (1024, 128, 512),
])
def test_chain_scan_vs_core(n, t, block):
    rng = np.random.default_rng(n)
    scores = rng.normal(size=(n, t)).astype(np.float32)
    scores[rng.random((n, t)) < 0.5] = -1e18
    # ban forward references (j >= i): mask t >= i
    for i in range(min(n, t)):
        scores[i, i:] = -1e18
    w = np.full((n,), 15.0, np.float32)
    f_pal, off_pal = chain_scan_pallas(jnp.asarray(scores), jnp.asarray(w),
                                       block=block)
    f_ref, off_ref = C.chain_sequential(jnp.asarray(scores), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(f_pal), np.asarray(f_ref),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(off_pal), np.asarray(off_ref))


def test_chain_scan_ops_band_padding():
    """ops.chain_scan pads T<128 bands to 128 lanes; exactness preserved."""
    q, r = np.arange(300) * 10, np.arange(300) * 10
    f_core, p_core = C.chain_anchors(jnp.asarray(q), jnp.asarray(r), T=64,
                                     mode="sequential")
    f_pal, p_pal = ops.chain_anchors(jnp.asarray(q), jnp.asarray(r), T=64)
    np.testing.assert_allclose(np.asarray(f_pal), np.asarray(f_core),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(p_pal), np.asarray(p_core))


# --------------------------------------------------------------------------
# dp tile (DTW / SW)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("tr,tc", [(8, 8), (16, 16), (32, 16), (16, 32)])
def test_dp_tile_dtw_vs_jnp_tile(tr, tc):
    ks = jax.random.split(jax.random.PRNGKey(tr * 100 + tc), 5)
    top = jax.random.normal(ks[0], (tc,))
    left = jax.random.normal(ks[1], (tr,))
    corner = jax.random.normal(ks[2], ())
    a = jax.random.normal(ks[3], (tr,))
    b = jax.random.normal(ks[4], (tc,))
    tile, bot, right, c_out = ops.dp_tile(top, left, corner, a, b,
                                          kind="dtw")
    from repro.core.wavefront import dp_tile_diagonal
    from repro.core.dtw import _cell
    want, wb, wr, wc = dp_tile_diagonal(_cell, top, left, corner, a, b)
    np.testing.assert_allclose(tile, want, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(bot, wb, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(right, wr, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("n,m", [(32, 32), (64, 96)])
def test_dtw_tiled_pallas_end_to_end(n, m):
    ks = jax.random.split(jax.random.PRNGKey(n), 2)
    s = jax.random.normal(ks[0], (n,))
    r = jax.random.normal(ks[1], (m,))
    want = D.dtw_ref(s, r)
    mat, dist = ops.dtw_tiled(s, r, tile_r=32, tile_c=32)
    np.testing.assert_allclose(mat, want, rtol=1e-5, atol=1e-4)


def test_sw_tiled_pallas_end_to_end():
    rng = np.random.default_rng(7)
    a = rng.integers(0, 4, 48).astype(np.int32)
    b = rng.integers(0, 4, 64).astype(np.int32)
    want = A.sw_ref(jnp.asarray(a), jnp.asarray(b))
    mat, best = ops.sw_tiled(jnp.asarray(a), jnp.asarray(b),
                             tile_r=16, tile_c=16)
    np.testing.assert_allclose(mat, want, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(best, np.asarray(want).max(), atol=1e-4)


# --------------------------------------------------------------------------
# radix rank kernel
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shift", [0, 8, 16, 24])
def test_radix_rank_vs_oracle(shift):
    from repro.kernels.radix_rank import radix_rank_pallas
    rng = np.random.default_rng(shift)
    keys = rng.integers(0, 2**32, (3, 512), dtype=np.uint32)
    ranks, hists = radix_rank_pallas(jnp.asarray(keys), shift=shift,
                                     block=256)
    for c in range(3):
        bucket = (keys[c] >> shift) & 255
        want = np.zeros(512, np.int32)
        cnt: dict = {}
        for i, bkt in enumerate(bucket):
            want[i] = cnt.get(bkt, 0)
            cnt[bkt] = cnt.get(bkt, 0) + 1
        np.testing.assert_array_equal(np.asarray(ranks)[c], want)
        np.testing.assert_array_equal(np.asarray(hists)[c],
                                      np.bincount(bucket, minlength=256))


def test_radix_sort_chunks_full_pipeline():
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 2**32, (4, 512), dtype=np.uint32)
    sk, sv = ops.radix_sort_chunks(jnp.asarray(keys), block=256)
    sk = np.asarray(sk)
    for c in range(4):
        np.testing.assert_array_equal(sk[c], np.sort(keys[c]))
    # values permuted consistently (stable)
    sv = np.asarray(sv)
    for c in range(4):
        np.testing.assert_array_equal(keys[c][sv[c]], sk[c])


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

def _naive_attn(q, k, v, window=0):
    b, h, sq, hd = q.shape
    grp = h // k.shape[1]
    kf = jnp.repeat(k, grp, axis=1)
    vf = jnp.repeat(v, grp, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) * hd ** -0.5
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(k.shape[2])[None, :]
    ok = kp <= qp
    if window:
        ok &= (qp - kp) < window
    p = jax.nn.softmax(jnp.where(ok, s, -1e30), axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf.astype(jnp.float32))


@pytest.mark.parametrize("b,h,kvh,sq,hd,win,bq,bk", [
    (2, 4, 4, 128, 64, 0, 64, 64),       # MHA
    (1, 8, 2, 256, 32, 0, 128, 128),     # GQA 4:1
    (1, 4, 1, 256, 64, 0, 64, 128),      # MQA
    (1, 4, 2, 256, 64, 96, 64, 64),      # sliding window (gemma3 local)
])
def test_flash_attention_sweep(b, h, kvh, sq, hd, win, bq, bk):
    from repro.kernels.flash_attention import flash_attention_pallas
    ks = jax.random.split(jax.random.PRNGKey(sq + win), 3)
    q = jax.random.normal(ks[0], (b, h, sq, hd))
    k = jax.random.normal(ks[1], (b, kvh, sq, hd))
    v = jax.random.normal(ks[2], (b, kvh, sq, hd))
    out = flash_attention_pallas(q, k, v, window=win, bq=bq, bk=bk)
    want = _naive_attn(q, k, v, win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    from repro.kernels.flash_attention import flash_attention_pallas
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64), dtype)
    k = jax.random.normal(ks[1], (1, 2, 128, 64), dtype)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), dtype)
    out = flash_attention_pallas(q, k, v)
    assert out.dtype == dtype
    want = _naive_attn(q, k, v)
    tol = 2e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), rtol=tol, atol=tol)


def test_sw_tile_scoring_params():
    rng = np.random.default_rng(8)
    a = rng.integers(0, 4, 16).astype(np.int32)
    b = rng.integers(0, 4, 16).astype(np.int32)
    p = A.SWParams(match=3.0, mismatch=-2.0, gap=1.5)
    want = A.sw_ref(jnp.asarray(a), jnp.asarray(b), p)
    fn = ops.make_sw_tile_fn(p.match, p.mismatch, p.gap)
    mat, best = A.sw_tiled(jnp.asarray(a), jnp.asarray(b), p,
                           tile_r=8, tile_c=8, tile_fn=fn)
    np.testing.assert_allclose(mat, want, rtol=1e-5, atol=1e-4)
