"""Sharding rule table + mesh tests on forced host devices.

Runs in a subprocess (XLA device count locks at first jax init), asserting:
rule resolution, divisibility fallbacks, param spec positional rules, and a
real sharded train step on a smoke mesh with checkpoint->remesh restore
(the elastic path with actual device movement).
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.sharding import partition as P


# --------------------------------------------------------------------------
# pure rule-table tests (no mesh needed)
# --------------------------------------------------------------------------

def test_rules_drop_without_mesh():
    P.configure(None)
    assert P.resolve_axes((8, 16), ("batch", "seq")) == \
        __import__("jax").sharding.PartitionSpec(None, None)


def test_rules_overridden_context():
    P.configure(None)
    base = P.current_rules()
    with P.rules_overridden({"seq": None}):
        assert P.current_rules()["seq"] is None
    assert P.current_rules() == base


# --------------------------------------------------------------------------
# subprocess: real 8-device mesh
# --------------------------------------------------------------------------

_SUB = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro import configs
    from repro.launch import mesh as mesh_lib
    from repro.sharding import (configure, make_param_shardings,
                                named_sharding, resolve_axes)
    from repro.optim import AdamWConfig
    from repro.train import (Checkpointer, init_train_state,
                             make_train_step, state_shardings,
                             batch_shardings)
    import tempfile

    out = {}
    mesh = mesh_lib.make_smoke_mesh()            # (data=2, model=4)
    configure(mesh)

    # 1. divisibility fallback: dim not divisible by axis -> replicated
    spec = resolve_axes((6, 16), ("batch", "seq"))   # batch 6 % 2 == 0
    out["spec_ok"] = str(spec)
    spec2 = resolve_axes((5, 16), ("batch", "seq"))  # 5 % 2 -> drop
    out["spec_fallback"] = str(spec2)

    # 2. sharded end-to-end train step + elastic re-mesh restore
    cfg = configs.reduced_config("deepseek-7b")
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    shapes = jax.eval_shape(lambda: state)
    st_sh = state_shardings(shapes, mesh)
    step = jax.jit(make_train_step(cfg, AdamWConfig(warmup_steps=1)),
                   in_shardings=(st_sh, None), out_shardings=(st_sh, None),
                   donate_argnums=(0,))
    batch = {"tokens": jnp.zeros((4, 32), jnp.int32),
             "labels": jnp.zeros((4, 32), jnp.int32)}
    with mesh:
        state = jax.device_put(state, st_sh)
        state, m = step(state, batch)
        state, m = step(state, batch)
    out["loss"] = float(m["loss"])
    out["sharded"] = str(
        jax.tree_util.tree_leaves(state.params)[1].sharding)

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(2, state, extra={"next_step": 2})

        # elastic: restore onto a *different* mesh shape (4, 2)
        mesh2 = jax.make_mesh((4, 2), ("data", "model"))
        configure(mesh2)
        st_sh2 = state_shardings(shapes, mesh2)
        state2, extra = ck.restore(shapes, shardings=st_sh2)
        step2 = jax.jit(make_train_step(cfg, AdamWConfig(warmup_steps=1)),
                        in_shardings=(st_sh2, None),
                        out_shardings=(st_sh2, None), donate_argnums=(0,))
        with mesh2:
            state2, m2 = step2(state2, batch)
    out["loss_after_remesh"] = float(m2["loss"])
    out["resumed_step"] = int(extra["next_step"])
    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def subproc_result():
    res = subprocess.run(
        [sys.executable, "-c", _SUB], capture_output=True, text=True,
        env={**__import__("os").environ,
             "PYTHONPATH": str(Path(__file__).resolve().parents[1] / "src")},
        timeout=540)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, res.stdout
    return json.loads(line[-1][len("RESULT "):])


def test_divisibility_fallback(subproc_result):
    assert "'data'" in subproc_result["spec_ok"].replace('"', "'")
    # batch=5 not divisible by data=2 -> replicated
    assert subproc_result["spec_fallback"].count("data") == 0


def test_sharded_train_step_runs(subproc_result):
    import math
    assert math.isfinite(subproc_result["loss"])


def test_params_actually_sharded(subproc_result):
    assert "NamedSharding" in subproc_result["sharded"]


def test_elastic_remesh_restore(subproc_result):
    import math
    assert subproc_result["resumed_step"] == 2
    assert math.isfinite(subproc_result["loss_after_remesh"])
