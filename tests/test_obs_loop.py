"""Closed-loop observability (PR 7): SLO rule/monitor hysteresis (unit +
hypothesis property vs an independent reference model), SLOManager
transition events/metrics/subscriber callbacks, BackpressureController
save/restore semantics, the forced-overload control-invariant
differential (backpressure-on greedy streams bit-identical to the
uncontrolled twin), Autotuner.retune online re-sweep semantics, and the
AutotuneController cooldown/apply-on-improvement behavior."""

import numpy as np
import pytest

import jax

from repro import configs
from repro.models import transformer as T
from repro.obs import (REGISTRY, AutotuneController, BackpressureController,
                       Monitor, Registry, Rule, Sampler, SLOManager, Tracer,
                       build_serve_loop, dispatch_imbalance_rule,
                       set_sampler, set_tracer)
from repro.runtime.autotune import Autotuner
from repro.serve import Scheduler, SchedulerConfig


# --------------------------------------------------------------------------
# rule validation + extraction
# --------------------------------------------------------------------------

def test_rule_validation():
    with pytest.raises(ValueError):
        Rule("r", key="k", op="!=")
    with pytest.raises(ValueError):
        Rule("r", key="k", source="median")
    with pytest.raises(ValueError):
        Rule("r", key="k", fire_after=0)
    with pytest.raises(ValueError):
        Rule("r", key="k", clear_after=0)
    with pytest.raises(ValueError):
        Rule("r")                       # needs key or value_fn


def test_rule_sources_and_value_fn():
    values, rates = {"a": 5.0}, {"a": 2.0}
    assert Rule("v", key="a").extract(values, rates) == 5.0
    assert Rule("r", key="a", source="rate").extract(values, rates) == 2.0
    assert Rule("m", key="missing").extract(values, rates) is None
    fn = Rule("f", value_fn=lambda v, r: v["a"] + r["a"])
    assert fn.extract(values, rates) == 7.0


# --------------------------------------------------------------------------
# hysteresis: exact fire/clear semantics
# --------------------------------------------------------------------------

def test_monitor_fires_on_nth_breach_clears_on_mth_ok():
    # SLO holds when value < 0; 1.0 breaches, -1.0 conforms
    m = Monitor(Rule("r", key="k", op="<", threshold=0.0,
                     fire_after=3, clear_after=2))
    assert [m.observe(1.0) for _ in range(2)] == [None, None]
    assert m.observe(1.0) == "fire"         # 3rd consecutive breach
    assert m.firing
    assert m.observe(1.0) is None           # already firing: no re-fire
    assert m.observe(-1.0) is None
    assert m.observe(-1.0) == "clear"       # 2nd consecutive OK
    assert not m.firing


def test_monitor_streak_resets():
    m = Monitor(Rule("r", key="k", op="<", threshold=0.0,
                     fire_after=2, clear_after=2))
    # breach streak broken by a conforming sample: never fires
    assert m.observe(1.0) is None
    assert m.observe(-1.0) is None
    assert m.observe(1.0) is None
    assert m.observe(1.0) == "fire"
    # ok streak broken by a breach: stays firing
    assert m.observe(-1.0) is None
    assert m.observe(1.0) is None
    assert m.observe(-1.0) is None
    assert m.observe(-1.0) == "clear"


def test_monitor_hysteresis_property():
    """Differential vs an independent reference model over random breach
    patterns: transitions strictly alternate fire->clear, fire lands
    exactly on the sample completing the fire_after-th consecutive
    breach while not firing, clear exactly on the clear_after-th
    consecutive OK while firing."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    def reference(seq, fire_after, clear_after):
        firing, breaches, oks, out = False, 0, 0, []
        for breach in seq:
            if breach:
                breaches, oks = breaches + 1, 0
                fire = not firing and breaches == fire_after
                firing = firing or fire
                out.append("fire" if fire else None)
            else:
                oks, breaches = oks + 1, 0
                clear = firing and oks == clear_after
                firing = firing and not clear
                out.append("clear" if clear else None)
        return out

    @settings(max_examples=200, deadline=None)
    @given(seq=st.lists(st.booleans(), max_size=60),
           fire_after=st.integers(1, 4), clear_after=st.integers(1, 4))
    def check(seq, fire_after, clear_after):
        m = Monitor(Rule("r", key="k", op="<", threshold=0.0,
                         fire_after=fire_after, clear_after=clear_after))
        got = [m.observe(1.0 if breach else -1.0) for breach in seq]
        assert got == reference(seq, fire_after, clear_after)
        transitions = [t for t in got if t]
        # strict alternation starting with fire
        assert transitions == (["fire", "clear"]
                               * len(transitions))[:len(transitions)]
        assert m.firing == (transitions[-1:] == ["fire"])

    check()


# --------------------------------------------------------------------------
# SLO manager: events, metrics, subscribers
# --------------------------------------------------------------------------

def test_slo_manager_transitions_metrics_and_subscribers():
    reg = Registry()
    tr = Tracer(enabled=True)
    mgr = SLOManager([Rule("lat", key="ms", op="<", threshold=10.0,
                           fire_after=2, clear_after=1)],
                     registry=reg, tracer=tr)
    calls = []

    class Sub:
        def on_fire(self, rule, value):
            calls.append(("fire", rule.name, value))

        def on_clear(self, rule, value):
            calls.append(("clear", rule.name, value))

    mgr.subscribe(Sub())
    # namespace pre-declared at construction
    assert reg.snapshot()["obs.slo.lat.firing"] == 0

    assert mgr.evaluate({"ms": 50.0}, {}) == []
    assert mgr.evaluate({"ms": 50.0}, {}) == ["lat:fire"]
    assert mgr.evaluate({"ms": 50.0}, {}) == []     # no re-fire
    assert mgr.evaluate({"ms": 1.0}, {}) == ["lat:clear"]
    snap = reg.snapshot()
    assert snap["obs.slo.lat.fired"] == 1
    assert snap["obs.slo.lat.cleared"] == 1
    assert snap["obs.slo.lat.breaches"] == 3
    assert snap["obs.slo.lat.firing"] == 0
    assert calls == [("fire", "lat", 50.0), ("clear", "lat", 1.0)]
    evs = [(e.name, e.track) for e in tr.events]
    assert evs == [("slo-fire", "slo"), ("slo-clear", "slo")]


def test_slo_manager_missing_key_skips_hysteresis():
    reg = Registry()
    mgr = SLOManager([Rule("lat", key="ms", op="<", threshold=10.0,
                           fire_after=2)], registry=reg,
                     tracer=Tracer(enabled=False))
    assert mgr.evaluate({"ms": 50.0}, {}) == []
    # absent key: no state change, the breach streak survives the gap
    assert mgr.evaluate({}, {}) == []
    assert mgr.evaluate({"ms": 50.0}, {}) == ["lat:fire"]


def test_slo_manager_rejects_duplicate_rule_names():
    with pytest.raises(ValueError):
        SLOManager([Rule("r", key="a"), Rule("r", key="b")],
                   registry=Registry(), tracer=Tracer(enabled=False))


# --------------------------------------------------------------------------
# backpressure controller: save/restore semantics
# --------------------------------------------------------------------------

class _FakeSlots:
    def __init__(self, paged):
        self.paged = paged


class _FakeSched:
    """The knob surface BackpressureController actuates on."""

    def __init__(self, paged=True):
        self.admit_cap = None
        self.preempt_override = None
        self.slots = _FakeSlots(paged)
        self._preempt = "recompute"

    @property
    def preempt_policy(self):
        return self.preempt_override or self._preempt


def test_backpressure_saves_and_restores_exactly():
    reg = Registry()
    sched = _FakeSched(paged=True)
    ctrl = BackpressureController(sched, admit_cap=2, preempt="swap",
                                  registry=reg, tracer=Tracer(enabled=False))
    rule = Rule("queue_wait", key="k", op="<", threshold=0.0)
    ctrl.on_fire(rule, 1.0)
    assert ctrl.engaged
    assert sched.admit_cap == 2
    assert sched.preempt_override == "swap"
    ctrl.on_fire(rule, 2.0)                 # idempotent while engaged
    assert sched.admit_cap == 2
    ctrl.on_clear(rule, 0.0)
    assert not ctrl.engaged
    assert sched.admit_cap is None          # exactly what was saved
    assert sched.preempt_override is None
    snap = reg.snapshot()
    assert snap["obs.control.backpressure.engaged"] == 1
    assert snap["obs.control.backpressure.released"] == 1
    assert snap["obs.control.backpressure.active"] == 0


def test_backpressure_ignores_other_rules_and_contiguous_preempt():
    sched = _FakeSched(paged=False)
    ctrl = BackpressureController(sched, registry=Registry(),
                                  tracer=Tracer(enabled=False))
    other = Rule("ttft_p95", key="k", op="<", threshold=0.0)
    ctrl.on_fire(other, 1.0)
    assert not ctrl.engaged and sched.admit_cap is None
    ctrl.on_clear(other, 0.0)               # clear while not engaged: no-op
    mine = Rule("queue_wait", key="k", op="<", threshold=0.0)
    ctrl.on_fire(mine, 1.0)
    assert sched.admit_cap == 1
    assert sched.preempt_override is None   # no swap on contiguous pools


def test_backpressure_rejects_starving_cap():
    with pytest.raises(ValueError):
        BackpressureController(_FakeSched(), admit_cap=0,
                               registry=Registry())


def test_build_serve_loop_wiring():
    sched = _FakeSched()
    smp, slo, ctrls = build_serve_loop(sched, install=False,
                                       queue_wait_s=0.1)
    assert len(ctrls) == 1 and isinstance(ctrls[0], BackpressureController)
    assert slo.monitors["queue_wait"].rule.threshold == 0.1
    # sampler -> manager is wired: a sample with no serve.* keys is a
    # clean no-op through the whole chain
    smp.tick()
    assert slo.firing == {name: False for name in slo.monitors}


# --------------------------------------------------------------------------
# the control invariant: forced-overload differential
# --------------------------------------------------------------------------

def test_forced_overload_backpressure_streams_bit_identical():
    """Greedy token streams with the closed loop engaged (queue-wait SLO
    fires -> admissions capped + swap preempt -> clears on drain) must
    be bit-identical to the uncontrolled twin: controllers change timing
    and admission order pressure only, never outputs."""
    cfg = configs.reduced_config("gemma-2b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    max_prompt, tail_new, block = 12, 32, 8
    max_len = max_prompt + tail_new + 8
    prompts = [rng.integers(0, cfg.vocab, rng.integers(4, max_prompt + 1))
               .astype(np.int32) for _ in range(8)]
    mnts = [int(rng.integers(8, tail_new + 1)) for _ in prompts]
    sc = SchedulerConfig(
        num_slots=6, max_len=max_len, prefill_chunk=8,
        cache_requests=False, allocator="paged", block_size=block,
        num_blocks=(2 * max_len // block - 1) // 2, preempt="swap")

    def serve(controlled):
        sched = Scheduler(cfg, params, sc)
        if controlled:
            smp = Sampler()
            slo = SLOManager(
                [Rule("queue_wait", key="serve.queue_head_wait_s",
                      op="<", threshold=1e-4, fire_after=2,
                      clear_after=2)],
                tracer=Tracer(enabled=False))
            ctrl = BackpressureController(sched, admit_cap=1,
                                          preempt="swap",
                                          tracer=Tracer(enabled=False))
            smp.add_listener(slo.on_sample)
            slo.subscribe(ctrl)
            prev = set_sampler(smp)
        try:
            for p, m in zip(prompts, mnts):
                sched.submit([p], max_new_tokens=m)
            done = sched.drain()
        finally:
            if controlled:
                set_sampler(prev)
        streams = {c.rid: c.tokens.tolist() for c in done}
        return streams, (slo, ctrl, sched) if controlled else None

    fired0 = REGISTRY.counter("obs.slo.queue_wait.fired").value
    base, _ = serve(controlled=False)
    ctl, (slo, ctrl, sched) = serve(controlled=True)
    assert ctl == base, "controller changed the token streams"
    fired = REGISTRY.counter("obs.slo.queue_wait.fired").value - fired0
    assert fired >= 1, "SLO never fired under forced overload"
    assert not slo.monitors["queue_wait"].firing and not ctrl.engaged
    assert sched.admit_cap is None and sched.preempt_override is None


# --------------------------------------------------------------------------
# online autotune: retune semantics + controller
# --------------------------------------------------------------------------

def _fast_thunk(_cand):
    return lambda: 0


def test_retune_applies_only_on_improvement(tmp_path):
    tuner = Autotuner(str(tmp_path / "cache.json"))
    # incumbent is unbeatable (0 us): re-measurement keeps it
    tuner.put("k.knob", 16, us=0.0)
    value, improved = tuner.retune("k.knob", [16, 32], _fast_thunk)
    assert (value, improved) == (16, False)
    # incumbent is terrible: any real measurement wins and persists
    tuner.put("k.knob", 16, us=1e12)
    value, improved = tuner.retune("k.knob", [16, 32], _fast_thunk)
    assert improved and value in (16, 32)
    assert tuner.get("k.knob") == value
    entry = tuner._cache["k.knob"]
    assert entry["us"] < 1e12


def test_retune_all_fail_keeps_incumbent_never_raises(tmp_path):
    tuner = Autotuner(str(tmp_path / "cache.json"))

    def broken(_cand):
        def thunk():
            raise RuntimeError("bad candidate")
        return thunk

    # no incumbent: nothing to keep, still no raise
    assert tuner.retune("k.knob", [1, 2], broken) == (None, False)
    tuner.put("k.knob", 8, us=5.0)
    value, improved = tuner.retune("k.knob", [1, 2], broken)
    assert (value, improved) == (8, False)
    assert "resweep_failed" in tuner._cache["k.knob"]
    assert tuner.get("k.knob") == 8         # incumbent value untouched


def test_autotune_controller_cooldown_and_apply(tmp_path):
    reg = Registry()

    class FakeTuner:
        def __init__(self):
            self.calls = 0
            self.result = (32, True)

        def retune(self, key, candidates, make_thunk):
            self.calls += 1
            return self.result

    tuner = FakeTuner()
    applied = []
    ctrl = AutotuneController(tuner, "k.knob", [16, 32], _fast_thunk,
                              apply=applied.append, cooldown_s=3600.0,
                              registry=reg, tracer=Tracer(enabled=False))
    rule = dispatch_imbalance_rule("run[b32]")
    other = Rule("queue_wait", key="k", op="<", threshold=0.0)
    ctrl.on_fire(other, 1.0)                # wrong rule: ignored
    assert tuner.calls == 0
    ctrl.on_fire(rule, 2.0)
    assert tuner.calls == 1 and applied == [32]
    ctrl.on_fire(rule, 2.0)                 # inside cooldown: skipped
    assert tuner.calls == 1
    ctrl.on_clear(rule, 0.5)                # nothing to undo
    ctrl._last_sweep = None                 # cooldown expired
    tuner.result = (16, False)              # no improvement: not applied
    ctrl.on_fire(rule, 2.0)
    assert tuner.calls == 2 and applied == [32]
    snap = reg.snapshot()
    assert snap["obs.control.autotune.resweeps"] == 2
    assert snap["obs.control.autotune.applied"] == 1


def test_dispatch_imbalance_rule_value_fn():
    rule = dispatch_imbalance_rule("run[b32]", ratio=1.0,
                                   min_execute_ms=1.0)
    c = "runtime.dispatch.bucket.run[b32].compile_ms"
    e = "runtime.dispatch.bucket.run[b32].execute_ms"
    # under min_execute_ms: no signal yet, sample skipped
    assert rule.extract({c: 50.0, e: 0.5}, {}) is None
    assert rule.extract({}, {}) is None
    v = rule.extract({c: 25.0, e: 10.0}, {})
    assert v == pytest.approx(2.5)
    assert not rule.holds(v)                # compile 2.5x execute: breach
    assert rule.holds(rule.extract({c: 5.0, e: 10.0}, {}))
