"""BlockPool / PageTable properties and paged-view bit-identity with the
contiguous slot layout: arbitrary alloc/grow/free sequences never
double-assign a block, freed blocks are reusable, and gathering a cache
through the page table round-trips bit-identically with a directly
maintained contiguous mirror."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import attention
from repro.serve import engine
from repro.serve.paging import BlockPool, PageTable, PrefixIndex, SwapStore


# --------------------------------------------------------------------------
# BlockPool basics
# --------------------------------------------------------------------------

def test_block_pool_alloc_free_lifo():
    bp = BlockPool(3, block_size=4)
    got = {bp.alloc(), bp.alloc(), bp.alloc()}
    assert got == {0, 1, 2}
    assert bp.alloc() is None and bp.free_count == 0 and bp.used_count == 3
    bp.free(1)
    assert bp.alloc() == 1          # LIFO reuse keeps hot blocks hot


def test_block_pool_guards_double_free():
    # explicit ValueError, not assert: the guard must survive python -O
    # (tests/smoke_opt.py replays these under -O)
    bp = BlockPool(2, block_size=4)
    a = bp.alloc()
    bp.free(a)
    with pytest.raises(ValueError, match="not allocated"):
        bp.free(a)
    with pytest.raises(ValueError, match="block_size"):
        BlockPool(0, 4)


def test_page_table_guards_raise_not_assert():
    """Every pool/table state guard raises ValueError/RuntimeError: under
    python -O a bare assert would vanish and let corruption proceed."""
    bp = BlockPool(4, block_size=4)
    pt = PageTable(bp, num_slots=2, slot_positions=16)
    with pytest.raises(ValueError, match="outside slot"):
        pt.ensure(0, 16)                         # non-ring: OOB rejected
    pt.ensure(0, 3)
    with pytest.raises(RuntimeError, match="not empty"):
        pt.swap_in(0, 1)                         # slot still mapped
    with pytest.raises(ValueError, match="swap_in"):
        pt.swap_in(1, 99)                        # more than blocks_per_slot
    # a corrupted (non-prefix) mapping must refuse to swap out
    pt.ensure(1, 3)
    pt.table[1, 0] = pt.trash                    # corrupt: hole at lb 0
    pt.table[1, 2] = 0                           # duplicate-map block 0
    with pytest.raises(RuntimeError, match="not a logical prefix"):
        pt.swap_out(1)
    # block 0 is now mapped twice but holds refcount 1: the refcount/
    # table agreement check (which replaced the old uniqueness check
    # when sharing landed) must catch it
    with pytest.raises(RuntimeError, match="disagree with pool refcounts"):
        pt.check_invariants()


# --------------------------------------------------------------------------
# PageTable mechanics
# --------------------------------------------------------------------------

def test_page_table_ensure_free_remap():
    bp = BlockPool(4, block_size=4)
    pt = PageTable(bp, num_slots=2, slot_positions=14)   # last block partial
    assert pt.blocks_per_slot == 4
    ok, new = pt.ensure(0, 6)                # positions 0..6 -> blocks 0, 1
    assert ok and len(new) == 2 and pt.mapped_blocks(0) == 2
    ok, again = pt.ensure(0, 6)              # idempotent
    assert ok and again == []
    ok, part = pt.ensure(1, 13)              # needs 4, only 2 free: partial
    assert not ok and len(part) == 2 and bp.free_count == 0
    freed = pt.free_slot(0)
    assert sorted(freed) == sorted(new)      # retire returns its blocks
    ok, _ = pt.ensure(1, 13)                 # freed blocks immediately usable
    assert ok and pt.mapped_blocks(1) == 4
    pt.check_invariants()


def test_page_table_rows_layout():
    bs = 4
    bp = BlockPool(4, block_size=bs)
    pt = PageTable(bp, num_slots=2, slot_positions=10)
    pt.ensure(0, 5)                          # blocks 0, 1 of slot 0
    rows = pt.rows([0, 1])
    assert rows.shape == (2, 10)             # view is exactly slot_positions
    for lb in range(2):
        phys = pt.table[0, lb]
        np.testing.assert_array_equal(
            rows[0, lb * bs:(lb + 1) * bs], phys * bs + np.arange(bs))
    trash_floor = bp.num_blocks * bs
    assert (rows[0, 8:] >= trash_floor).all()     # unmapped tail -> trash
    assert (rows[1] >= trash_floor).all()         # whole unmapped slot


def test_blocks_for_clamps_to_slot():
    pt = PageTable(BlockPool(8, 4), num_slots=1, slot_positions=10)
    assert pt.blocks_for(0) == 0
    assert pt.blocks_for(1) == 1
    assert pt.blocks_for(10) == 3
    assert pt.blocks_for(10_000) == pt.blocks_per_slot   # never over-asks


# --------------------------------------------------------------------------
# ring mode: sliding-window rings page like growing slots, then saturate
# --------------------------------------------------------------------------

def test_ring_page_table_ramp_up_then_saturates():
    """Ring mode maps blocks lazily while pos ramps up to the window,
    then the resident ring absorbs every later position: ensure clamps
    (no error past the ring) and allocates nothing new."""
    bp = BlockPool(8, block_size=4)
    pt = PageTable(bp, num_slots=2, slot_positions=10, ring=True)  # window 10
    assert pt.blocks_per_slot == 3
    ok, new = pt.ensure(0, 0)                    # first write: 1 block
    assert ok and len(new) == 1
    ok, new = pt.ensure(0, 6)                    # ramp-up: 1 more
    assert ok and len(new) == 1 and pt.mapped_blocks(0) == 2
    ok, new = pt.ensure(0, 9)                    # ring full
    assert ok and len(new) == 1 and pt.mapped_blocks(0) == 3
    for pos in (10, 25, 10_000):                 # wrap-around: steady state
        ok, new = pt.ensure(0, pos)
        assert ok and new == []
    assert pt.mapped_blocks(0) == 3              # never more than the ring
    with pytest.raises(ValueError, match="outside slot"):
        pt.ensure(0, -1)                         # clamp is one-sided
    pt.check_invariants()


def test_ring_page_table_short_request_maps_partial_ring():
    """The tentpole's win: a request that finishes before filling the
    ring only ever maps ceil((pos+1)/bs) blocks — the dense layout would
    have reserved the full window for it."""
    bp = BlockPool(16, block_size=4)
    pt = PageTable(bp, num_slots=4, slot_positions=16, ring=True)
    ok, _ = pt.ensure(0, 5)                      # short request: 6 positions
    assert ok and pt.mapped_blocks(0) == 2       # not the full 4-block ring
    rows = pt.rows([0])
    assert rows.shape == (1, 16)                 # view is still the ring
    assert (rows[0, 8:] >= bp.num_blocks * 4).all()   # unmapped tail: trash
    freed = pt.free_slot(0)
    assert len(freed) == 2


def test_property_paged_ring_view_matches_dense_ring_mirror():
    """Hypothesis property (the wrap-around acceptance gate): sequential
    per-slot decode writes at ring address pos % V through the paged
    view must equal a directly maintained dense ring mirror BITWISE at
    every step — through ramp-up, saturation, several wrap-arounds, and
    slot retire/reuse."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    P, KV, HD, SLOTS = 1, 1, 2, 2

    @settings(max_examples=25, deadline=None)
    @given(st.data())
    def prop(data):
        BS = data.draw(st.sampled_from([2, 4, 8]))    # incl. window < BS
        V = data.draw(st.sampled_from([3, 4, 6]))     # the ring (window)
        num_blocks = data.draw(st.integers(2, 2 * SLOTS * (-(-V // BS))))
        rng = np.random.default_rng(data.draw(st.integers(0, 2**16)))
        flat = attention.make_paged_cache(num_blocks, BS, KV, HD,
                                          dtype=jnp.float32, periods=P)
        flat = attention.KVCache(                 # scribble: prove masking
            k=flat.k + 7.0, v=flat.v - 3.0, pos=flat.pos + 99)
        live = num_blocks * BS
        bp = BlockPool(num_blocks, BS)
        pt = PageTable(bp, SLOTS, V, ring=True)
        ref_k = np.zeros((P, SLOTS, V, KV, HD), np.float32)
        ref_v = np.zeros_like(ref_k)
        ref_pos = np.full((P, SLOTS, V), -1, np.int32)
        clock = [0] * SLOTS                       # per-slot decode position

        for _ in range(data.draw(st.integers(1, 3 * V + 4))):
            slot = data.draw(st.integers(0, SLOTS - 1))
            if data.draw(st.integers(0, 9)) == 0:  # occasional retire
                freed = pt.free_slot(slot)
                for b in freed:
                    assert not bp.allocated[b]
                ref_k[:, slot] = 0.0
                ref_v[:, slot] = 0.0
                ref_pos[:, slot] = -1
                clock[slot] = 0
            else:                                  # one decode-tick write
                pos = clock[slot]
                ok, new = pt.ensure(slot, pos)
                if not ok:                         # pool OOB: skip tick
                    continue
                if new:
                    flat = _zero_blocks(flat, new, BS)
                r = pos % V                        # ring addressing
                rows = jnp.asarray(pt.rows([slot]))
                view = attention.paged_view(flat, rows, live)
                nk = rng.normal(size=(P, 1, 1, KV, HD)).astype(np.float32)
                nv = rng.normal(size=(P, 1, 1, KV, HD)).astype(np.float32)
                view = attention.KVCache(
                    k=view.k.at[:, :, r:r + 1].set(nk),
                    v=view.v.at[:, :, r:r + 1].set(nv),
                    pos=view.pos.at[:, :, r:r + 1].set(pos))
                flat = attention.paged_writeback(flat, view, rows)
                ref_k[:, slot, r] = nk[:, 0, 0]
                ref_v[:, slot, r] = nv[:, 0, 0]
                ref_pos[:, slot, r] = pos
                clock[slot] = pos + 1
            pt.check_invariants()
            got = attention.paged_view(flat, jnp.asarray(pt.rows()), live)
            np.testing.assert_array_equal(np.asarray(got.k), ref_k)
            np.testing.assert_array_equal(np.asarray(got.v), ref_v)
            np.testing.assert_array_equal(np.asarray(got.pos), ref_pos)

    prop()


# --------------------------------------------------------------------------
# property: arbitrary alloc/grow/free sequences keep the pool sound
# --------------------------------------------------------------------------

def test_property_alloc_grow_free_never_double_assigns():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def prop(data):
        num_blocks = data.draw(st.integers(2, 12))
        bs = data.draw(st.sampled_from([2, 4]))
        num_slots = data.draw(st.integers(1, 4))
        slot_pos = data.draw(st.integers(bs, 4 * bs))
        bp = BlockPool(num_blocks, bs)
        pt = PageTable(bp, num_slots, slot_pos)
        for _ in range(data.draw(st.integers(1, 30))):
            slot = data.draw(st.integers(0, num_slots - 1))
            if data.draw(st.booleans()):
                pt.ensure(slot, data.draw(st.integers(0, slot_pos - 1)))
            else:
                freed = pt.free_slot(slot)
                for b in freed:                 # freed -> immediately free
                    assert not bp.allocated[b]
            pt.check_invariants()               # incl. no double-assignment
            assert bp.free_count + bp.used_count == num_blocks

    prop()


# --------------------------------------------------------------------------
# property: page-table gather round-trips bit-identically with contiguous
# --------------------------------------------------------------------------

def _zero_blocks(flat, blocks, bs):
    """The engine's reset_block_rows contract for freshly-mapped blocks."""
    rows = PageTable.block_rows(blocks, bs)
    return attention.KVCache(k=flat.k.at[:, rows].set(0),
                             v=flat.v.at[:, rows].set(0),
                             pos=flat.pos.at[:, rows].set(-1))


def test_property_paged_view_matches_contiguous_mirror():
    """Random grow/write/free sequences against BOTH layouts: the view
    gathered through the page table must equal the contiguous mirror
    bit-for-bit at every step (unmapped positions read as the zeroed rows
    a contiguous slot would hold)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    P, KV, HD, BS, SLOTS = 1, 1, 2, 4, 2

    @settings(max_examples=25, deadline=None)
    @given(st.data())
    def prop(data):
        num_blocks = data.draw(st.integers(2, 6))
        V = data.draw(st.sampled_from([6, 8, 11]))
        rng = np.random.default_rng(data.draw(st.integers(0, 2**16)))
        flat = attention.make_paged_cache(num_blocks, BS, KV, HD,
                                          dtype=jnp.float32, periods=P)
        # scribble the pool so "reads as zero" is proven by masking +
        # block resets, not by luck of a fresh allocation
        flat = attention.KVCache(
            k=flat.k + 7.0, v=flat.v - 3.0, pos=flat.pos + 99)
        live = num_blocks * BS
        bp = BlockPool(num_blocks, BS)
        pt = PageTable(bp, SLOTS, V)
        ref_k = np.zeros((P, SLOTS, V, KV, HD), np.float32)
        ref_v = np.zeros_like(ref_k)
        ref_pos = np.full((P, SLOTS, V), -1, np.int32)

        for _ in range(data.draw(st.integers(1, 12))):
            slot = data.draw(st.integers(0, SLOTS - 1))
            op = data.draw(st.sampled_from(["grow", "write", "free"]))
            if op == "grow":
                _, new = pt.ensure(slot, data.draw(st.integers(0, V - 1)))
                if new:
                    flat = _zero_blocks(flat, new, BS)
            elif op == "write":
                hi = min(pt.mapped_blocks(slot) * BS, V)
                if hi == 0:
                    continue
                a = data.draw(st.integers(0, hi - 1))
                b = data.draw(st.integers(a + 1, hi))
                rows = jnp.asarray(pt.rows([slot]))
                view = attention.paged_view(flat, rows, live)
                nk = rng.normal(size=(P, 1, b - a, KV, HD)).astype(np.float32)
                nv = rng.normal(size=(P, 1, b - a, KV, HD)).astype(np.float32)
                npos = rng.integers(0, 100, (P, 1, b - a)).astype(np.int32)
                view = attention.KVCache(k=view.k.at[:, :, a:b].set(nk),
                                         v=view.v.at[:, :, a:b].set(nv),
                                         pos=view.pos.at[:, :, a:b].set(npos))
                flat = attention.paged_writeback(flat, view, rows)
                ref_k[:, slot, a:b] = nk[:, 0]
                ref_v[:, slot, a:b] = nv[:, 0]
                ref_pos[:, slot, a:b] = npos[:, 0]
            else:
                pt.free_slot(slot)
                ref_k[:, slot] = 0.0
                ref_v[:, slot] = 0.0
                ref_pos[:, slot] = -1
            pt.check_invariants()
            got = attention.paged_view(flat, jnp.asarray(pt.rows()), live)
            np.testing.assert_array_equal(np.asarray(got.k), ref_k)
            np.testing.assert_array_equal(np.asarray(got.v), ref_v)
            np.testing.assert_array_equal(np.asarray(got.pos), ref_pos)

    prop()


# --------------------------------------------------------------------------
# swap-out / swap-in: preemption must preserve the slot's view bitwise
# --------------------------------------------------------------------------

def test_page_table_swap_out_in_mechanics():
    """swap_out frees exactly the mapped blocks (saved row keeps the
    logical prefix); swap_in is all-or-nothing and re-maps the prefix
    onto fresh physical blocks without double-assigning."""
    bp = BlockPool(4, block_size=4)
    pt = PageTable(bp, num_slots=2, slot_positions=16)
    pt.ensure(0, 9)                              # blocks 0..2 of slot 0
    mapped = [int(b) for b in pt.table[0] if b != pt.trash]
    row, freed = pt.swap_out(0)
    assert freed == mapped and len(freed) == 3
    assert all(not bp.allocated[b] for b in freed)
    assert int(np.sum(row != pt.trash)) == 3     # the saved logical view
    assert (pt.table[0] == pt.trash).all()
    pt.check_invariants()
    # another slot steals blocks: swap_in must be all-or-nothing
    pt.ensure(1, 7)                              # takes 2 of 4
    assert pt.swap_in(0, 3) is None              # only 2 free: nothing maps
    assert pt.mapped_blocks(0) == 0 and bp.free_count == 2
    pt.free_slot(1)
    new = pt.swap_in(0, 3)
    assert new is not None and len(new) == 3
    assert pt.mapped_blocks(0) == 3
    pt.check_invariants()


def test_swap_store_tracks_bytes_and_membership():
    from repro.serve.paging import SwapEntry

    store = SwapStore()
    entry = SwapEntry(blocks={10: 1}, paged={},
                      dense={"x": np.zeros((2, 4), np.float32)})
    n = store.put(7, entry)
    assert n == entry.nbytes == 32
    assert 7 in store and len(store) == 1
    st = store.stats()
    assert st["swapped_held"] == 1 and st["swap_bytes_out"] == 32
    assert st["swap_bytes_held"] == 32 and st["swap_bytes_in"] == 0
    assert st["swap_bytes_budget"] == -1        # unbounded
    with pytest.raises(ValueError, match="already swapped"):
        store.put(7, entry)                      # rid parked twice
    assert store.pop(7) is entry
    assert 7 not in store and store.bytes_in == 32
    assert store.held_bytes == 0


def test_swap_store_byte_budget_rejects_loudly():
    """The store is bounded: an entry that would exceed ``max_bytes``
    raises (the backing pre-checks with can_hold and falls back to
    recompute-preemption), and held bytes drop on pop so the budget
    frees up as requests re-admit."""
    from repro.serve.paging import SwapEntry

    mk = lambda: SwapEntry(blocks={8: 1}, paged={},
                           dense={"x": np.zeros((8,), np.float32)})  # 32 B
    store = SwapStore(max_bytes=48)
    assert store.can_hold(32)
    store.put(1, mk())
    assert not store.can_hold(32)                # 32 + 32 > 48
    with pytest.raises(RuntimeError, match="swap budget"):
        store.put(2, mk())
    assert store.rejected == 1 and 2 not in store
    assert store.stats()["swap_rejected"] == 1
    assert store.stats()["swap_bytes_budget"] == 48
    store.pop(1)                                 # budget frees on re-admit
    assert store.can_hold(32)
    store.put(2, mk())
    assert store.held_bytes == 32


def _gather_blocks_host(flat, blocks, bs):
    """The backing's swap_out device half: engine.gather_block_rows over
    the mapped blocks (pow2-padded with trash), sliced back on host."""
    n = 1
    while n < len(blocks):
        n *= 2
    trash = flat.k.shape[1] // bs - 1
    rows = PageTable.block_rows(list(blocks) + [trash] * (n - len(blocks)),
                                bs)
    got = jax.device_get(engine.gather_block_rows({"p0": flat},
                                                  jnp.asarray(rows)))["p0"]
    keep = len(blocks) * bs
    return attention.KVCache(k=got.k[:, :keep], v=got.v[:, :keep],
                             pos=got.pos[:, :keep])


def _upload_blocks(flat, saved, blocks, bs):
    """The backing's swap_in device half: engine.upload_block_rows into
    the freshly-mapped blocks (trash-padded rows carry zero payloads)."""
    n = 1
    while n < len(blocks):
        n *= 2
    trash = flat.k.shape[1] // bs - 1
    rows = PageTable.block_rows(list(blocks) + [trash] * (n - len(blocks)),
                                bs)
    pad = n * bs - len(blocks) * bs

    def padz(a):
        z = np.zeros((a.shape[0], pad) + a.shape[2:], a.dtype)
        return np.concatenate([np.asarray(a), z], axis=1)

    padded = attention.KVCache(k=padz(saved.k), v=padz(saved.v),
                               pos=padz(saved.pos))
    return engine.upload_block_rows({"p0": flat}, {"p0": padded},
                                    jnp.asarray(rows))["p0"]


def test_swap_roundtrip_restores_view_bitwise():
    """Deterministic swap cycle: write a slot, gather its block bytes,
    swap_out, let another slot claim (and dirty) the freed physical
    blocks, then swap_in + upload — the view must be bit-identical to
    the pre-swap view even though the physical mapping changed."""
    P, KV, HD, BS, V = 1, 1, 2, 4, 10
    num_blocks = 4
    rng = np.random.default_rng(0)
    flat = attention.make_paged_cache(num_blocks, BS, KV, HD,
                                      dtype=jnp.float32, periods=P)
    live = num_blocks * BS
    bp = BlockPool(num_blocks, BS)
    pt = PageTable(bp, 2, V)
    _, new = pt.ensure(0, 9)                     # 3 blocks
    flat = _zero_blocks(flat, new, BS)
    rows0 = jnp.asarray(pt.rows([0]))
    view = attention.paged_view(flat, rows0, live)
    k = rng.normal(size=(P, 1, V, KV, HD)).astype(np.float32)
    v = rng.normal(size=(P, 1, V, KV, HD)).astype(np.float32)
    pos = rng.integers(0, 50, (P, 1, V)).astype(np.int32)
    view = attention.KVCache(k=view.k.at[:].set(k), v=view.v.at[:].set(v),
                             pos=view.pos.at[:].set(pos))
    flat = attention.paged_writeback(flat, view, rows0)
    before = jax.device_get(attention.paged_view(flat, rows0, live))

    mapped = [int(b) for b in pt.table[0] if b != pt.trash]
    saved = _gather_blocks_host(flat, mapped, BS)
    _, freed = pt.swap_out(0)
    assert freed == mapped
    # adversary: slot 1 grabs ALL freed blocks and scribbles over them
    _, stolen = pt.ensure(1, V - 1)
    assert set(freed) <= set(stolen)
    flat = _zero_blocks(flat, stolen, BS)
    rows1 = jnp.asarray(pt.rows([1]))
    dirty = attention.paged_view(flat, rows1, live)
    flat = attention.paged_writeback(
        flat, attention.KVCache(k=dirty.k + 5.0, v=dirty.v - 2.0,
                                pos=dirty.pos + 11), rows1)
    pt.free_slot(1)
    new = pt.swap_in(0, len(mapped))
    assert new is not None
    flat = _upload_blocks(flat, saved, new, BS)
    pt.check_invariants()
    after = jax.device_get(attention.paged_view(
        flat, jnp.asarray(pt.rows([0])), live))
    np.testing.assert_array_equal(after.k, before.k)
    np.testing.assert_array_equal(after.v, before.v)
    np.testing.assert_array_equal(after.pos, before.pos)


def test_property_swap_roundtrip_under_interleaved_churn():
    """Hypothesis property for the swap path: random grow/write/swap
    cycles — swap_out frees exactly the mapped blocks and never leaves a
    double assignment; swap_out -> (other-slot churn) -> swap_in + upload
    round-trips the page-table view bitwise."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    P, KV, HD, BS, SLOTS = 1, 1, 2, 4, 2

    @settings(max_examples=20, deadline=None)
    @given(st.data())
    def prop(data):
        num_blocks = data.draw(st.integers(2, 6))
        V = data.draw(st.sampled_from([6, 8, 11]))
        rng = np.random.default_rng(data.draw(st.integers(0, 2**16)))
        flat = attention.make_paged_cache(num_blocks, BS, KV, HD,
                                          dtype=jnp.float32, periods=P)
        live = num_blocks * BS
        bp = BlockPool(num_blocks, BS)
        pt = PageTable(bp, SLOTS, V)
        for _ in range(data.draw(st.integers(1, 8))):
            slot = data.draw(st.integers(0, SLOTS - 1))
            other = 1 - slot
            # grow + write the slot so there is state worth preserving
            _, new = pt.ensure(slot, data.draw(st.integers(0, V - 1)))
            if new:
                flat = _zero_blocks(flat, new, BS)
            n = pt.mapped_blocks(slot)
            if n == 0:
                continue
            rows = jnp.asarray(pt.rows([slot]))
            view = attention.paged_view(flat, rows, live)
            hi = min(n * BS, V)
            nk = rng.normal(size=(P, 1, hi, KV, HD)).astype(np.float32)
            npos = rng.integers(0, 99, (P, 1, hi)).astype(np.int32)
            view = attention.KVCache(k=view.k.at[:, :, :hi].set(nk),
                                     v=view.v.at[:, :, :hi].set(-nk),
                                     pos=view.pos.at[:, :, :hi].set(npos))
            flat = attention.paged_writeback(flat, view, rows)
            before = jax.device_get(attention.paged_view(flat, rows, live))
            # swap out: frees exactly the mapped blocks, invariants hold
            mapped = [int(b) for b in pt.table[slot] if b != pt.trash]
            saved = _gather_blocks_host(flat, mapped, BS)
            _, freed = pt.swap_out(slot)
            assert freed == mapped
            assert all(not bp.allocated[b] for b in freed)
            pt.check_invariants()
            # churn: the other slot may claim freed blocks, dirty them,
            # and give some back
            if data.draw(st.booleans()):
                _, stolen = pt.ensure(other,
                                      data.draw(st.integers(0, V - 1)))
                if stolen:
                    flat = _zero_blocks(flat, stolen, BS)
                    orows = jnp.asarray(pt.rows([other]))
                    d = attention.paged_view(flat, orows, live)
                    flat = attention.paged_writeback(
                        flat, attention.KVCache(k=d.k + 1.0, v=d.v - 1.0,
                                                pos=d.pos + 7), orows)
                pt.free_slot(other)
            # swap in (guaranteed to fit: the other slot was freed) and
            # upload: the view must round-trip bitwise
            new = pt.swap_in(slot, n)
            assert new is not None
            flat = _upload_blocks(flat, saved, new, BS)
            pt.check_invariants()
            after = jax.device_get(attention.paged_view(
                flat, jnp.asarray(pt.rows([slot])), live))
            np.testing.assert_array_equal(after.k, before.k)
            np.testing.assert_array_equal(after.v, before.v)
            np.testing.assert_array_equal(after.pos, before.pos)

    prop()


# --------------------------------------------------------------------------
# SlotManager facade over the paged backing (no model step needed)
# --------------------------------------------------------------------------

def test_paged_slot_manager_gather_is_zeroed_after_realloc():
    """alloc -> dirty -> release -> alloc again: the paged gather must
    read the empty-slot encoding, exactly like the contiguous reset."""
    import jax
    from repro import configs
    from repro.models import transformer as T
    from repro.serve import SlotManager

    cfg = configs.reduced_config("gemma-2b")
    sm = SlotManager(cfg, num_slots=2, cache_slots=16, paged=True,
                     block_size=4, num_blocks=5)
    a = sm.alloc(owner=1, prompt_len=9)          # maps 3 blocks
    assert a is not None and sm.stats()["blocks_used"] == 3
    dirty = jax.tree_util.tree_map(lambda l: l + 1, sm.gather([a]))
    sm.scatter(dirty, [a])
    freed = sm.release(a)
    assert len(freed) == 3 and sm.stats()["blocks_used"] == 0
    a2 = sm.alloc(owner=2, prompt_len=9)
    fresh = sm.gather([a2])
    zeros = T.init_caches(cfg, 1, 16, per_slot_pos=True)
    for x, z in zip(jax.tree_util.tree_leaves(fresh),
                    jax.tree_util.tree_leaves(zeros)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(z))


def test_paged_slot_manager_admission_gates_on_blocks():
    from repro import configs
    from repro.serve import SlotManager

    cfg = configs.reduced_config("gemma-2b")
    sm = SlotManager(cfg, num_slots=4, cache_slots=32, paged=True,
                     block_size=8, num_blocks=3)
    assert sm.can_admit(prompt_len=24)           # 3 blocks: fits exactly
    a = sm.alloc(owner=1, prompt_len=9)          # takes 2 blocks
    assert not sm.can_admit(prompt_len=9)        # 1 block left, needs 2
    assert sm.alloc(owner=2, prompt_len=9) is None
    assert sm.can_admit(prompt_len=8)            # 1 block suffices
    assert not sm.ensure(a, 31)                  # growth past pool: OOB
    sm.release(a)
    assert sm.can_admit(prompt_len=24)


def test_windowed_slot_manager_pages_rings_by_group():
    """A windowed model (gemma3: p0 window=16, p1 global) gets TWO
    page-table groups over separate pools; ring demand clamps at the
    full ring, rings stop growing at steady state, and retire frees
    both groups' blocks."""
    from repro import configs
    from repro.serve import SlotManager

    cfg = configs.reduced_config("gemma3-12b")   # window 16 + global
    sm = SlotManager(cfg, num_slots=2, cache_slots=48, paged=True,
                     block_size=4)               # equal-memory pools
    bk = sm.backing
    assert sorted(bk.groups) == [16, 48]
    assert bk.groups[16].ring and not bk.groups[48].ring
    assert bk.groups[16].pool.num_blocks == 2 * 4     # 2 slots * 16/4
    assert bk.groups[48].pool.num_blocks == 2 * 12
    # dense leaves hold neither ring nor global KV anymore
    assert bk.dense["p0"]["attn"] is None
    assert bk.dense["p1"]["attn"] is None

    a = sm.alloc(owner=1, prompt_len=9)          # 9 positions
    assert bk.groups[48].pt.mapped_blocks(a) == 3     # ceil(9/4)
    assert bk.groups[16].pt.mapped_blocks(a) == 3     # ring ramp-up
    sm.ensure(a, 30)                             # decode grows past window
    assert bk.groups[48].pt.mapped_blocks(a) == 8     # ceil(31/4)
    assert bk.groups[16].pt.mapped_blocks(a) == 4     # ring saturated
    sm.ensure(a, 47)
    assert bk.groups[16].pt.mapped_blocks(a) == 4     # still the ring
    st = sm.stats()
    assert st["page_groups"] == 2
    assert st["ring16_blocks_used"] == 4
    freed = sm.release(a)
    assert len(freed) == 12 + 4 and st["blocks_used"] == 16
    assert sm.stats()["blocks_used"] == 0
    # equal-memory axis: paged total_rows (incl. 2 trash sentinels) vs
    # the dense layout's num_slots * (window + cache_slots)
    dense_rows = SlotManager(cfg, num_slots=2, cache_slots=48).total_rows
    assert dense_rows == 2 * (16 + 48)
    assert sm.total_rows == (2 * 4 + 1) * 4 + (2 * 12 + 1) * 4


def test_windowed_slot_manager_window_pool_gates_admission():
    """An under-provisioned RING pool alone blocks admission and growth:
    the second allocator client gates exactly like the first."""
    from repro import configs
    from repro.serve import SlotManager

    cfg = configs.reduced_config("gemma3-12b")
    sm = SlotManager(cfg, num_slots=4, cache_slots=48, paged=True,
                     block_size=4, num_window_blocks=5)
    a = sm.alloc(owner=1, prompt_len=16)         # full ring: 4 of 5
    assert a is not None
    assert not sm.can_admit(prompt_len=8)        # ring needs 2, has 1
    assert sm.can_admit(prompt_len=4)            # 1 ring block suffices
    b = sm.alloc(owner=2, prompt_len=3)
    assert not sm.ensure(b, 7)                   # ring growth OOB
    sm.release(a)
    assert sm.ensure(b, 7)                       # freed ring blocks reused
    # ...and paged_window=False keeps rings dense (the PR-3/4 layout)
    sm_dense = SlotManager(cfg, num_slots=2, cache_slots=48, paged=True,
                           block_size=4, paged_window=False)
    assert sorted(sm_dense.backing.groups) == [48]
    assert sm_dense.backing.dense["p0"]["attn"] is not None
    assert sm_dense.total_rows == 2 * 16 + (2 * 12 + 1) * 4


# --------------------------------------------------------------------------
# refcounts, sharing, copy-on-write (the prefix-sharing tentpole)
# --------------------------------------------------------------------------

def test_block_pool_free_rejects_out_of_range_ids():
    """REGRESSION: free(-1) used to hit numpy negative indexing — it
    silently freed the LAST block and pushed -1 onto the free list, so a
    later alloc() returned -1 and every flat row derived from it aliased
    another slot's KV. Out-of-range ids must raise ValueError (not
    IndexError — the -O guard policy) and leave the pool untouched."""
    bp = BlockPool(4, block_size=4)
    while bp.alloc() is not None:
        pass
    assert bp.free_count == 0
    for bad in (-1, -4, 4, 99):
        with pytest.raises(ValueError, match="outside pool"):
            bp.free(bad)
        with pytest.raises(ValueError, match="outside pool"):
            bp.ref(bad)
        with pytest.raises(ValueError, match="outside pool"):
            bp.refcount(bad)
    # the old corruption: free list stays empty, last block stays owned
    assert bp.free_count == 0 and bp.allocated[3]
    assert bp.alloc() is None                    # and alloc can't return -1


def test_block_pool_refcounts_free_only_at_zero():
    bp = BlockPool(2, block_size=4)
    a = bp.alloc()
    assert bp.refcount(a) == 1 and bp.shared_count == 0
    bp.ref(a)
    assert bp.refcount(a) == 2 and bp.shared_count == 1
    assert bp.free(a) is False                   # one sharer left
    assert bp.allocated[a] and bp.free_count == 1
    assert bp.unref(a) is True                   # last reference: freed
    assert not bp.allocated[a] and bp.free_count == 2
    with pytest.raises(ValueError, match="not allocated"):
        bp.free(a)
    with pytest.raises(ValueError, match="unallocated"):
        bp.ref(a)                                # can't share a freed block


def test_page_table_map_shared_and_cow():
    bp = BlockPool(6, block_size=4)
    pt = PageTable(bp, num_slots=3, slot_positions=16)
    pt.ensure(0, 11)                             # donor: blocks for 3 chunks
    donor = [int(b) for b in pt.table[0, :2]]    # share the first two
    pt.map_shared(1, donor)
    assert pt.is_shared(0, 0) and pt.is_shared(1, 1)
    assert bp.refcount(donor[0]) == 2 and bp.shared_count == 2
    pt.check_invariants()
    with pytest.raises(RuntimeError, match="already mapped"):
        pt.map_shared(1, donor)                  # logical prefix taken
    with pytest.raises(ValueError, match="shared blocks"):
        pt.map_shared(2, [donor[0]] * 5)         # > blocks_per_slot
    # CoW: slot 1 gets a private copy of logical block 1; slot 0 keeps it
    old, new = pt.cow_block(1, 1)
    assert old == donor[1] and new != old
    assert int(pt.table[1, 1]) == new and int(pt.table[0, 1]) == old
    assert bp.refcount(old) == 1 and bp.refcount(new) == 1
    assert not pt.is_shared(1, 1) and not pt.is_shared(0, 1)
    pt.check_invariants()
    with pytest.raises(RuntimeError, match="private block"):
        pt.cow_block(1, 1)                       # already private
    with pytest.raises(RuntimeError, match="unmapped"):
        pt.cow_block(2, 0)
    # releasing the sharer leaves the donor's mapping fully intact
    pt.free_slot(1)
    assert [int(b) for b in pt.table[0, :3] if b != pt.trash] \
        == [int(b) for b in pt.table[0, :3]]
    assert bp.refcount(donor[0]) == 1
    pt.check_invariants()


def test_page_table_cow_exhaustion_leaves_state_unchanged():
    bp = BlockPool(2, block_size=4)
    pt = PageTable(bp, num_slots=2, slot_positions=8)
    pt.ensure(0, 7)                              # pool now empty
    pt.free_slot(0)
    pt.ensure(0, 3)
    shared = int(pt.table[0, 0])
    pt.map_shared(1, [shared])
    bp.alloc()                                   # drain the last free block
    assert pt.cow_block(1, 0) is None            # exhausted: no-op
    assert int(pt.table[1, 0]) == shared and bp.refcount(shared) == 2


def test_page_table_write_blocks_spans():
    bp = BlockPool(8, block_size=4)
    pt = PageTable(bp, num_slots=1, slot_positions=16)
    assert pt.write_blocks(0, 0, 3) == [0]
    assert pt.write_blocks(0, 2, 9) == [0, 1, 2]
    assert pt.write_blocks(0, 15, 15) == [3]
    with pytest.raises(ValueError, match="empty write span"):
        pt.write_blocks(0, 5, 4)
    ring = PageTable(BlockPool(8, 4), num_slots=1, slot_positions=8,
                     ring=True)
    assert ring.write_blocks(0, 9, 10) == [0]    # wraps to positions 1, 2
    assert ring.write_blocks(0, 6, 9) == [0, 1]  # wrap straddles the seam
    assert ring.write_blocks(0, 3, 11) == [0, 1]  # >= ring: everything


def test_swap_out_of_shared_blocks_releases_not_steals():
    """Swap-preempting a sharer must leave the other sharers' mappings
    (and the blocks themselves) intact: swap_out's free only drops the
    victim's reference — the bytes were gathered to host beforehand, a
    copy, never a steal."""
    bp = BlockPool(6, block_size=4)
    pt = PageTable(bp, num_slots=2, slot_positions=16)
    pt.ensure(0, 11)
    donor = [int(b) for b in pt.table[0, :2]]
    pt.map_shared(1, donor)
    row, released = pt.swap_out(1)
    assert released == donor                     # released FROM this slot
    assert all(bp.allocated[b] for b in donor)   # ...but still alive
    assert [int(b) for b in pt.table[0, :2]] == donor
    assert bp.refcount(donor[0]) == 1
    assert int(np.sum(row != pt.trash)) == 2     # resume knows its prefix
    pt.check_invariants()


def test_prefix_index_chained_hash_and_lru():
    rng = np.random.default_rng(3)
    toks = rng.integers(0, 100, 64).astype(np.int32)
    keys = PrefixIndex.chunk_keys(toks, 16, 4)
    assert len(keys) == 4 and len(set(keys)) == 4
    # chained: same chunk-1 tokens after a DIFFERENT chunk 0 must not
    # produce chunk 1's key (KV depends on the whole prefix before it)
    other = toks.copy()
    other[0] += 1
    keys2 = PrefixIndex.chunk_keys(other, 16, 4)
    assert keys2[0] != keys[0] and keys2[1] != keys[1]
    # prefix property: a longer prompt's leading keys match the short one
    assert PrefixIndex.chunk_keys(toks[:32], 16, 4) == keys[:2]
    assert PrefixIndex.chunk_keys(toks[:31], 16, 4) == keys[:1]  # partial
    idx = PrefixIndex(capacity=8)
    assert idx.match(keys) == []                 # empty: no hits
    for i, k in enumerate(keys[:3]):
        assert idx.publish(k, {16: i})
    assert not idx.publish(keys[0], {16: 9})     # first publisher wins
    got = idx.match(keys)                        # longest indexed prefix
    assert [e[16] for e in got] == [0, 1, 2]
    assert [e[16] for e in idx.match(keys2)] == []   # diverged at chunk 0
    st = idx.stats()
    assert st["prefix_entries"] == 3 and st["prefix_published"] == 3
    assert st["prefix_hit_chunks"] == 3 and st["prefix_lookups"] == 3


def test_prefix_index_evict_lru_respects_keep():
    idx = PrefixIndex(capacity=8)
    idx.publish(b"a", {16: 0})
    idx.publish(b"b", {16: 1})
    idx.publish(b"c", {16: 2})
    idx.match([b"a"])                            # refresh: a is now MRU
    assert idx.evict_lru(keep={b"b"}) == {16: 2}     # c was LRU non-kept
    assert idx.evict_lru(keep={b"b", b"a"}) is None  # only kept remain
    assert idx.evict_lru() == {16: 1}
    assert idx.evict_lru() == {16: 0}
    assert idx.evict_lru() is None and len(idx) == 0
    assert idx.stats()["prefix_evicted"] == 3
    with pytest.raises(ValueError, match="capacity"):
        PrefixIndex(capacity=0)


def test_check_invariants_counts_index_holds_as_external_refs():
    bp = BlockPool(4, block_size=4)
    pt = PageTable(bp, num_slots=2, slot_positions=16)
    pt.ensure(0, 3)
    b = int(pt.table[0, 0])
    idx = PrefixIndex()
    bp.ref(b)                                    # the index's hold
    idx.publish(b"k", {16: b})
    with pytest.raises(RuntimeError, match="disagree"):
        pt.check_invariants()                    # unaware of the index
    holds = idx.holds({16: bp.num_blocks})
    pt.check_invariants(external_refs=holds[16])     # aware: consistent
    pt.free_slot(0)                              # donor retires...
    assert bp.allocated[b]                       # ...block outlives it
    pt.check_invariants(external_refs=holds[16])


def test_property_refcounted_pool_never_frees_shared():
    """Hypothesis property: under random alloc/ref/free sequences against
    a shadow refcount model, a block never returns to the free list while
    references remain, the free list never holds duplicates or
    out-of-range ids, and allocated == (refs > 0) throughout."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def prop(data):
        n = data.draw(st.integers(2, 8))
        bp = BlockPool(n, block_size=4)
        shadow = {}                              # block -> refcount
        for _ in range(data.draw(st.integers(1, 40))):
            op = data.draw(st.sampled_from(["alloc", "ref", "free"]))
            if op == "alloc":
                b = bp.alloc()
                if b is None:
                    assert len(shadow) == n
                else:
                    assert b not in shadow
                    shadow[b] = 1
            elif op == "ref" and shadow:
                b = data.draw(st.sampled_from(sorted(shadow)))
                bp.ref(b)
                shadow[b] += 1
            elif op == "free" and shadow:
                b = data.draw(st.sampled_from(sorted(shadow)))
                freed = bp.free(b)
                shadow[b] -= 1
                assert freed == (shadow[b] == 0)
                if freed:
                    del shadow[b]
            for b, r in shadow.items():
                assert bp.refcount(b) == r and bp.allocated[b]
            free = bp._free
            assert len(free) == len(set(free))
            assert all(0 <= b < n for b in free)
            assert set(free) == set(range(n)) - set(shadow)
            assert bp.shared_count == sum(r > 1 for r in shadow.values())

    prop()


def test_property_cow_invisible_to_the_sharing_reader():
    """THE copy-on-write acceptance property: while one slot repeatedly
    writes through (CoW-then-write) blocks it shares with another, the
    reader's gathered view stays bitwise equal to a contiguous mirror
    frozen at share time — no write by any sharer is ever observable
    through another sharer's view."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    P, KV, HD, BS, SLOTS = 1, 1, 2, 4, 2

    @settings(max_examples=20, deadline=None)
    @given(st.data())
    def prop(data):
        V = data.draw(st.sampled_from([8, 12]))
        num_blocks = data.draw(st.integers(2 * (V // BS), 3 * (V // BS)))
        rng = np.random.default_rng(data.draw(st.integers(0, 2**16)))
        flat = attention.make_paged_cache(num_blocks, BS, KV, HD,
                                          dtype=jnp.float32, periods=P)
        live = num_blocks * BS
        bp = BlockPool(num_blocks, BS)
        pt = PageTable(bp, SLOTS, V)
        # donor slot 0 writes its whole view, then shares a prefix
        _, new = pt.ensure(0, V - 1)
        flat = _zero_blocks(flat, new, BS)
        rows0 = jnp.asarray(pt.rows([0]))
        view = attention.paged_view(flat, rows0, live)
        k0 = rng.normal(size=(P, 1, V, KV, HD)).astype(np.float32)
        p0 = rng.integers(0, 50, (P, 1, V)).astype(np.int32)
        view = attention.KVCache(k=view.k.at[:].set(k0),
                                 v=view.v.at[:].set(-k0),
                                 pos=view.pos.at[:].set(p0))
        flat = attention.paged_writeback(flat, view, rows0)
        donor_before = jax.device_get(attention.paged_view(flat, rows0,
                                                           live))
        n_share = data.draw(st.integers(1, V // BS))
        pt.map_shared(1, [int(b) for b in pt.table[0, :n_share]])
        # slot 1 now writes arbitrary positions; any write landing in a
        # shared block is preceded by CoW + device block copy — exactly
        # the backing's ensure() protocol
        for _ in range(data.draw(st.integers(1, 6))):
            lo = data.draw(st.integers(0, V - 1))
            hi = data.draw(st.integers(lo, V - 1))
            pt.ensure(1, hi)
            for lb in pt.write_blocks(1, lo, hi):
                if pt.is_shared(1, lb):
                    old, newb = pt.cow_block(1, lb)
                    src = PageTable.block_rows([old], BS)
                    dst = PageTable.block_rows([newb], BS)
                    flat = engine.copy_block_rows(
                        {"p0": flat}, jnp.asarray(src),
                        jnp.asarray(dst))["p0"]
            rows1 = jnp.asarray(pt.rows([1]))
            v1 = attention.paged_view(flat, rows1, live)
            nk = rng.normal(size=(P, 1, hi - lo + 1, KV, HD)) \
                    .astype(np.float32)
            npos = rng.integers(0, 99, (P, 1, hi - lo + 1)).astype(np.int32)
            v1 = attention.KVCache(k=v1.k.at[:, :, lo:hi + 1].set(nk),
                                   v=v1.v.at[:, :, lo:hi + 1].set(-nk),
                                   pos=v1.pos.at[:, :, lo:hi + 1].set(npos))
            flat = attention.paged_writeback(flat, v1, rows1)
            pt.check_invariants()
            got = jax.device_get(attention.paged_view(flat, rows0, live))
            np.testing.assert_array_equal(got.k, donor_before.k)
            np.testing.assert_array_equal(got.v, donor_before.v)
            np.testing.assert_array_equal(got.pos, donor_before.pos)

    prop()


def test_property_shared_swap_out_leaves_sharers_intact():
    """Hypothesis property: swap-preempting a random sharer never
    perturbs the remaining sharers — their mappings, the shared blocks'
    liveness, and the refcount agreement all survive."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.data())
    def prop(data):
        BS = 4
        V = data.draw(st.sampled_from([8, 16]))
        slots = data.draw(st.integers(2, 4))
        bp = BlockPool(slots * (V // BS), BS)
        pt = PageTable(bp, slots, V)
        pt.ensure(0, V - 1)
        n_share = data.draw(st.integers(1, V // BS))
        donor = [int(b) for b in pt.table[0, :n_share]]
        sharers = list(range(1, data.draw(st.integers(2, slots))))
        for s in sharers:
            pt.map_shared(s, donor)
        victim = data.draw(st.sampled_from([0] + sharers))
        _, released = pt.swap_out(victim)
        assert released[:n_share] == donor
        for s in [0] + sharers:
            if s == victim:
                assert pt.mapped_blocks(s) == 0
            else:
                assert [int(b) for b in pt.table[s, :n_share]] == donor
        assert all(bp.allocated[b] for b in donor)
        assert all(bp.refcount(b) == len(sharers) for b in donor)
        pt.check_invariants()
        for s in [0] + sharers:                  # full drain: no leaks
            if s != victim:
                pt.free_slot(s)
        assert bp.used_count == 0
        pt.check_invariants()

    prop()
