"""Training-loop runtime: checkpoint atomicity/resume, failure injection +
elastic restart, straggler watchdog, gradient compression, determinism."""

import json
import shutil
import time
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.lm import DataConfig, TokenStream
from repro.optim import AdamWConfig
from repro.train import (Checkpointer, FailureInjector, LoopConfig,
                         init_train_state, make_train_step, train)


CFG = configs.reduced_config("gemma-2b")
OPT = AdamWConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=100)


def _stream(batch=4, seq=16):
    return TokenStream(DataConfig(vocab=CFG.vocab, batch=batch, seq_len=seq))


# --------------------------------------------------------------------------
# checkpointer
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    ck.save(7, tree, extra={"next_step": 7})
    like = jax.eval_shape(lambda: tree)
    out, extra = ck.restore(like)
    assert extra["next_step"] == 7
    np.testing.assert_array_equal(out["a"], np.arange(10.0))
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomicity_ignores_partial(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = {"x": jnp.zeros(4)}
    ck.save(1, tree)
    # simulate a crash mid-write: a .tmp dir with garbage
    bad = tmp_path / "step_00000002.tmp"
    bad.mkdir()
    (bad / "leaf_00000.npy").write_bytes(b"garbage")
    assert ck.latest_step() == 1
    out, _ = ck.restore(jax.eval_shape(lambda: tree))
    np.testing.assert_array_equal(out["x"], np.zeros(4))


def test_checkpoint_gc_retention(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = {"x": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    steps = sorted(int(d.name[5:]) for d in tmp_path.iterdir()
                   if d.name.startswith("step_"))
    assert steps == [3, 4]


def test_checkpoint_async_overlap(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = {"x": jnp.arange(1000.0)}
    ck.save_async(5, tree)
    ck.wait()
    assert ck.latest_step() == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, {"x": jnp.zeros(4)})
    with pytest.raises(ValueError):
        ck.restore(jax.eval_shape(lambda: {"x": jnp.zeros(5)}))


# --------------------------------------------------------------------------
# loop: resume / failure / elasticity
# --------------------------------------------------------------------------

def test_resume_is_exact(tmp_path):
    """12 straight steps == 6 steps + restart + 6 steps, bitwise on loss."""
    ds = _stream()
    kw = dict(opt_cfg=OPT, seed=0, verbose=False)

    r_straight = train(CFG, ds.batch,
                       LoopConfig(total_steps=12, ckpt_every=100,
                                  log_every=1), **kw)

    d1 = tmp_path / "resume"
    r_first = train(CFG, ds.batch,
                    LoopConfig(total_steps=6, ckpt_every=6, log_every=1),
                    ckpt_dir=str(d1), **kw)
    r_second = train(CFG, ds.batch,
                     LoopConfig(total_steps=12, ckpt_every=6, log_every=1),
                     ckpt_dir=str(d1), **kw)
    straight = [m["loss"] for m in r_straight.metrics_history][6:]
    resumed = [m["loss"] for m in r_second.metrics_history]
    np.testing.assert_allclose(resumed, straight, rtol=1e-5)


def test_failure_injection_recovers(tmp_path):
    ds = _stream()
    res = train(CFG, ds.batch,
                LoopConfig(total_steps=10, ckpt_every=3, log_every=1),
                OPT, ckpt_dir=str(tmp_path), seed=0, verbose=False,
                failure_injector=FailureInjector(fail_at=(5, 8)))
    assert res.restarts == 2
    assert res.final_step == 10
    assert all(np.isfinite(l) for l in res.losses)


def test_failure_without_ckpt_raises():
    ds = _stream()
    with pytest.raises(RuntimeError):
        train(CFG, ds.batch, LoopConfig(total_steps=5), OPT,
              ckpt_dir=None, verbose=False,
              failure_injector=FailureInjector(fail_at=(2,)))


def test_elastic_restart_onto_new_mesh(tmp_path):
    """After a failure the loop re-jits against a new mesh and restores the
    checkpoint onto it (device-count change simulated by mesh=None->None;
    the sharding path is exercised in test_sharding_meshes)."""
    calls = []

    def new_mesh(restart_idx):
        calls.append(restart_idx)
        return None       # single CPU device "survivor" mesh

    ds = _stream()
    res = train(CFG, ds.batch,
                LoopConfig(total_steps=8, ckpt_every=2, log_every=1),
                OPT, ckpt_dir=str(tmp_path), verbose=False,
                failure_injector=FailureInjector(fail_at=(4,)),
                make_mesh_after_failure=new_mesh)
    assert calls == [1]
    assert res.final_step == 8


def test_straggler_watchdog_detects_slow_steps():
    ds = _stream(batch=2, seq=8)
    slow_seen = []
    orig_batch = ds.batch

    def delayed_batch(step):
        if step == 7:
            time.sleep(1.0)           # inject a straggler
        return orig_batch(step)

    res = train(CFG, delayed_batch,
                LoopConfig(total_steps=10, log_every=100,
                           straggler_factor=4.0, straggler_warmup=2),
                OPT, verbose=False,
                on_straggler=lambda s, dt: slow_seen.append(s))
    assert 7 in [e["step"] for e in res.straggler_events] or 7 in slow_seen


def test_gradient_accumulation_matches_single_pass():
    """accum_steps=2 must match the single-pass step up to one bf16 ulp of
    the update (fp reassociation of the grad mean)."""
    import jax
    import jax.numpy as jnp
    from repro.train.step import make_train_step, init_train_state

    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, CFG.vocab),
             "labels": jax.random.randint(key, (4, 32), 0, CFG.vocab)}
    s1 = init_train_state(key, CFG)
    s2 = init_train_state(key, CFG)
    st1, m1 = jax.jit(make_train_step(CFG, OPT))(s1, batch)
    st2, m2 = jax.jit(make_train_step(CFG, OPT, accum_steps=2))(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(st1.params),
                    jax.tree_util.tree_leaves(st2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)


def test_gradient_compression_trains():
    ds = _stream()
    res = train(CFG, ds.batch,
                LoopConfig(total_steps=6, log_every=1), OPT,
                compress=True, verbose=False)
    assert all(np.isfinite(l) for l in res.losses)


def test_determinism_same_seed_same_losses():
    ds = _stream()
    r1 = train(CFG, ds.batch, LoopConfig(total_steps=4, log_every=1),
               OPT, seed=3, verbose=False)
    r2 = train(CFG, ds.batch, LoopConfig(total_steps=4, log_every=1),
               OPT, seed=3, verbose=False)
    np.testing.assert_allclose(r1.losses, r2.losses, rtol=1e-6)


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_data_stateless_and_sharded():
    cfg = DataConfig(vocab=256, batch=8, seq_len=16)
    full = TokenStream(cfg)
    b0 = full.batch(3)
    again = TokenStream(cfg).batch(3)
    np.testing.assert_array_equal(b0["tokens"], again["tokens"])

    sh0 = TokenStream(cfg, shard=(0, 2)).batch(3)
    sh1 = TokenStream(cfg, shard=(1, 2)).batch(3)
    assert sh0["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(sh0["tokens"]),
                              np.asarray(sh1["tokens"]))


def test_data_labels_shifted():
    cfg = DataConfig(vocab=64, batch=2, seq_len=12)
    b = TokenStream(cfg).batch(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_data_has_learnable_structure():
    from repro.data.lm import bigram_entropy_estimate
    cfg = DataConfig(vocab=256, batch=2, seq_len=12)
    h = bigram_entropy_estimate(cfg, n_samples=2000)
    assert h < 0.75 * np.log(256), "stream should be well below uniform"
