"""MoE layer unit tests: routing exactness in the drop-free regime,
capacity behaviour, and gradient flow to experts."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import moe as M


def _cfg(**kw):
    d = dict(d_model=16, d_ff=32, num_experts=4, experts_per_token=2,
             capacity_factor=8.0)
    d.update(kw)
    return M.MoEConfig(**d)


def _dense_moe_oracle(params, cfg, x):
    """Dense (no-capacity) MoE: every token reaches its top-k experts."""
    n, d = x.shape
    logits = x.astype(np.float64) @ np.asarray(params["router"], np.float64)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    k = cfg.experts_per_token
    out = np.zeros((n, d))
    for t in range(n):
        top = np.argsort(-probs[t])[:k]
        p = probs[t][top] / probs[t][top].sum()
        for e, pe in zip(top, p):
            wg = np.asarray(params["expert_gate"][e], np.float64)
            wu = np.asarray(params["expert_up"][e], np.float64)
            wd = np.asarray(params["expert_down"][e], np.float64)
            h = x[t].astype(np.float64)
            g = h @ wg
            silu = g / (1 + np.exp(-g)) if True else g
            y = (silu * (h @ wu)) @ wd
            out[t] += pe * y
    return out


def test_moe_matches_dense_oracle_drop_free():
    cfg = _cfg()
    params = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, cfg.d_model))
    y, aux = M.moe(params, cfg, x)
    want = _dense_moe_oracle(params, cfg, np.asarray(x[0], np.float64))
    np.testing.assert_allclose(np.asarray(y[0], np.float64), want,
                               rtol=2e-2, atol=2e-2)


def test_capacity_drops_tokens_when_tight():
    cfg = _cfg(capacity_factor=0.1)
    params = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    y_tight, _ = M.moe(params, cfg, x)
    y_loose, _ = M.moe(params, _cfg(capacity_factor=8.0), x)
    # tight capacity must change (drop) some token outputs
    assert not np.allclose(np.asarray(y_tight), np.asarray(y_loose),
                           atol=1e-5)
    # dropped tokens produce zeros, not garbage
    assert np.isfinite(np.asarray(y_tight, np.float32)).all()


def test_aux_loss_penalizes_imbalance():
    cfg = _cfg(num_experts=2, experts_per_token=1)
    params = M.init_moe(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, cfg.d_model))
    # force all tokens to expert 0
    params_skew = dict(params)
    router = np.zeros((cfg.d_model, 2), np.float32)
    router[:, 0] = 10.0
    params_skew["router"] = jnp.asarray(router)
    _, aux_skew = M.moe(params_skew, cfg, x)
    _, aux_balanced = M.moe(params, cfg, x)
    assert float(aux_skew) > float(aux_balanced)


def test_experts_receive_gradients():
    cfg = _cfg()
    params = M.init_moe(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 16, cfg.d_model))

    def loss(p):
        y, aux = M.moe(p, cfg, x)
        return jnp.sum(jnp.square(y)) + aux

    grads = jax.grad(loss)(params)
    gnorm = np.asarray(jnp.linalg.norm(grads["expert_gate"]))
    assert gnorm > 0, "expert weights got no gradient"
    rnorm = np.asarray(jnp.linalg.norm(grads["router"]))
    assert rnorm > 0, "router got no gradient"


def test_capacity_rounding():
    cfg = _cfg()
    c = M.capacity(100, cfg)
    assert c % 4 == 0 and c >= 4
