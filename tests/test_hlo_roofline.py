"""Roofline machinery unit tests: collective-byte HLO parsing, term
arithmetic, and the trip-count-aware HLO walk (hlo_analysis)."""

import numpy as np
import pytest

from repro.launch import hlo_analysis, roofline

HLO_COLL = """HloModule m

ENTRY %main (p0: f32[8,128]) -> f32[64,128] {
  %p0 = f32[8,128]{1,0} parameter(0)
  %ag = f32[64,128]{1,0} all-gather(%p0), replica_groups={}
  %ar = f32[64,128]{1,0} all-reduce(%ag), to_apply=%add
  %t = (f32[4,2]{1,0}, f32[4,2]{1,0}) all-reduce-start(%p0), to_apply=%add
  %d = f32[4,2]{1,0} all-reduce-done(%t)
  %rs = bf16[32]{0} reduce-scatter(%p0), dimensions={0}
  ROOT %cp = f32[64,128]{1,0} collective-permute(%ar)
}
"""


def test_collective_bytes_parses_kinds():
    mc = hlo_analysis.analyze(HLO_COLL)
    assert mc.collectives["all-gather"] == 64 * 128 * 4
    # plain all-reduce result + async start payload (max array in tuple)
    assert mc.collectives["all-reduce"] == 64 * 128 * 4 + 4 * 2 * 4
    assert mc.collectives["reduce-scatter"] == 32 * 2
    assert mc.collectives["collective-permute"] == 64 * 128 * 4
    assert mc.collective_bytes == sum(mc.collectives.values())


def test_done_variants_not_double_counted():
    hlo = """HloModule m

ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8]{0} parameter(0)
  %t = (f32[8]{0}, f32[8]{0}) all-reduce-start(%p0), to_apply=%add
  ROOT %d = f32[8]{0} all-reduce-done(%t)
}
"""
    mc = hlo_analysis.analyze(hlo)
    # -done carries no new traffic; -start counts its payload once
    assert mc.collectives["all-reduce"] == 8 * 4


def test_roofline_terms_dominance():
    t = roofline.roofline_terms(197e12, 0.0, 0.0)     # 1s of pure compute
    assert t["dominant"] == "compute"
    np.testing.assert_allclose(t["compute_s"], 1.0)
    t2 = roofline.roofline_terms(0.0, 819e9, 0.0)
    assert t2["dominant"] == "memory"
    t3 = roofline.roofline_terms(0.0, 0.0, 50e9)
    assert t3["dominant"] == "collective"
    assert t3["step_lower_bound_s"] == pytest.approx(1.0)


def test_model_flops():
    assert roofline.model_flops(1000, 10, "train") == 6e4
    assert roofline.model_flops(1000, 10, "inference") == 2e4


WHILE_HLO = """HloModule m

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %a = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %dot = f32[8,8]{1,0} dot(%a, %a), lhs_contracting_dims={1}, \
rhs_contracting_dims={0}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %r = (s32[], f32[8,8]) tuple(%i, %dot)
}

ENTRY %main (init: (s32[], f32[8,8])) -> f32[8,8] {
  %init = (s32[], f32[8,8]) parameter(0)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, \
backend_config={"known_trip_count":{"n":"12"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_while_trip_count_multiplies():
    mc = hlo_analysis.analyze(WHILE_HLO)
    assert any(n == 12 for _, n in mc.while_trips), mc.while_trips
    # the dot inside the while must be counted 12x
    assert mc.flops == pytest.approx(12 * 2 * 8 * 8 * 8)


def test_collectives_inside_while_trip_multiplied():
    hlo = """HloModule m

%body (p: (s32[], f32[16])) -> (s32[], f32[16]) {
  %p = (s32[], f32[16]) parameter(0)
  %x = f32[16]{0} get-tuple-element(%p), index=1
  %ag = f32[16]{0} all-reduce(%x), to_apply=%add
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %r = (s32[], f32[16]) tuple(%i, %ag)
}

ENTRY %main (init: (s32[], f32[16])) -> f32[16] {
  %init = (s32[], f32[16]) parameter(0)
  %w = (s32[], f32[16]) while(%init), condition=%cond, body=%body, \
backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[16]{0} get-tuple-element(%w), index=1
}
"""
    mc = hlo_analysis.analyze(hlo)
    assert mc.collectives["all-reduce"] == 5 * 16 * 4


def test_real_dryrun_artifacts_have_sane_terms():
    """Spot-check the recorded dry-run JSONs: every OK cell's roofline
    terms are positive and the dominant term matches the max."""
    import json
    from pathlib import Path
    d = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    if not d.exists():
        pytest.skip("no dry-run artifacts")
    checked = 0
    for f in sorted(d.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "OK":
            continue
        r = rec["roofline"]
        terms = {k: r[k] for k in ("compute_s", "memory_s", "collective_s")}
        assert all(v >= 0 for v in terms.values()), f.name
        assert r["dominant"] == max(terms, key=terms.get).replace("_s", "")
        assert r["hlo_flops_per_device"] > 0, f.name
        checked += 1
    assert checked >= 30, f"only {checked} OK cells recorded"
