"""End-to-end read mapper (paper §VI-C): baseline == squire exactness,
mapping accuracy on planted reads, and profile behaviour (Fig. 8's
accuracy->align-work relation)."""

import numpy as np
import pytest

from repro.apps.read_mapper import MapperConfig, ReadMapper, mapping_accuracy
from repro.data import genomics


@pytest.fixture(scope="module")
def ref():
    return genomics.make_reference(12_000, seed=0)


@pytest.fixture(scope="module")
def reads(ref):
    prof = genomics.ReadProfile("TEST", 400, 80, 0.93)
    return genomics.sample_reads(ref, prof, 3, seed=1)


@pytest.mark.slow
def test_mapper_finds_planted_reads(ref, reads):
    mapper = ReadMapper(ref, MapperConfig(mode="squire"))
    res = mapper.map_reads([r for r, _ in reads])
    acc = mapping_accuracy(res, [t for _, t in reads])
    assert acc == 1.0, [(r.pos, t) for r, (_, t) in zip(res, reads)]


@pytest.mark.slow
def test_baseline_and_squire_identical(ref, reads):
    """The paper's transformation is exact: both pipelines must agree on
    position and score for every read."""
    rb = ReadMapper(ref, MapperConfig(mode="baseline")).map_reads(
        [r for r, _ in reads])
    rs = ReadMapper(ref, MapperConfig(mode="squire")).map_reads(
        [r for r, _ in reads])
    for a, b in zip(rb, rs):
        assert a.pos == b.pos
        assert a.n_anchors == b.n_anchors
        np.testing.assert_allclose(a.sw_score, b.sw_score, atol=1e-3)
        np.testing.assert_allclose(a.chain_score, b.chain_score, atol=1e-3)


@pytest.mark.slow
def test_high_accuracy_reads_anchor_denser(ref):
    """PBHF-style (99.99%) reads produce more anchors per base than
    ONT-style (85%) reads — the Fig. 8 workload-shift mechanism."""
    mapper = ReadMapper(ref, MapperConfig(mode="squire"))
    hi = genomics.sample_reads(
        ref, genomics.ReadProfile("HI", 400, 1, 0.9999), 2, seed=3)
    lo = genomics.sample_reads(
        ref, genomics.ReadProfile("LO", 400, 1, 0.85), 2, seed=3)
    d_hi = np.mean([mapper.map_read(r).n_anchors / len(r) for r, _ in hi])
    d_lo = np.mean([mapper.map_read(r).n_anchors / len(r) for r, _ in lo])
    assert d_hi > 2 * d_lo


def test_unmappable_read_returns_unmapped(ref):
    rng = np.random.default_rng(9)
    junk = rng.integers(0, 4, 300).astype(np.int8)  # random, not from ref
    mapper = ReadMapper(ref, MapperConfig(mode="squire"))
    res = mapper.map_read(junk)
    assert res.pos == -1 or res.chain_score < 60


def test_short_read_rejected(ref):
    mapper = ReadMapper(ref, MapperConfig())
    res = mapper.map_read(np.zeros(10, np.int8))
    assert res.pos == -1
