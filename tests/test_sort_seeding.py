"""Chunk-parallel radix sort (property: == np.sort, stability) and the
seeding stage (minimizers, index lookup, anchors)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import seeding as S
from repro.core import sort as R


# --------------------------------------------------------------------------
# radix sort
# --------------------------------------------------------------------------

@given(st.lists(st.integers(0, 2**32 - 1), min_size=0, max_size=500),
       st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=40, deadline=None)
def test_radix_sort_matches_npsort(keys, chunks):
    k = jnp.asarray(np.array(keys, np.uint32))
    sk, sv = R.radix_sort(k, num_chunks=chunks, min_parallel=0)
    np.testing.assert_array_equal(np.asarray(sk),
                                  np.sort(np.array(keys, np.uint32)))


def test_radix_sort_is_stable():
    """Equal keys keep input order (required for the seeding pipeline)."""
    keys = np.array([5, 3, 5, 3, 5, 1] * 50, np.uint32)
    vals = np.arange(len(keys), dtype=np.int32)
    sk, sv = R.radix_sort(jnp.asarray(keys), jnp.asarray(vals),
                          num_chunks=4, min_parallel=0)
    sk, sv = np.asarray(sk), np.asarray(sv)
    for key in (1, 3, 5):
        idx = sv[sk == key]
        assert (np.diff(idx) > 0).all(), f"key {key} unstable"


def test_radix_sort_carries_values():
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2**31, 10_000).astype(np.uint32)
    vals = rng.integers(0, 2**31, 10_000).astype(np.int32)
    sk, sv = R.radix_sort(jnp.asarray(keys), jnp.asarray(vals), num_chunks=8)
    order = np.argsort(keys, kind="stable")
    np.testing.assert_array_equal(np.asarray(sk), keys[order])
    np.testing.assert_array_equal(np.asarray(sv), vals[order])


def test_small_input_skips_worker_path():
    """Paper Alg. 1 line 2: arrays below the threshold sort on the host."""
    keys = jnp.asarray(np.array([3, 1, 2], np.uint32))
    sk, _ = R.radix_sort(keys, num_chunks=8, min_parallel=10)
    np.testing.assert_array_equal(np.asarray(sk), [1, 2, 3])


def test_sort_i32_signed():
    rng = np.random.default_rng(1)
    keys = rng.integers(-2**31, 2**31 - 1, 5000).astype(np.int32)
    sk, _ = R.sort_i32(jnp.asarray(keys), num_chunks=4, min_parallel=0)
    np.testing.assert_array_equal(np.asarray(sk), np.sort(keys))


@given(st.integers(2, 9), st.integers(0, 300), st.integers(0, 300))
@settings(max_examples=20, deadline=None)
def test_merge_sorted_property(seed, na, nb):
    rng = np.random.default_rng(seed)
    a = np.sort(rng.integers(0, 1000, na).astype(np.uint32))
    b = np.sort(rng.integers(0, 1000, nb).astype(np.uint32))
    mk, _ = R.merge_sorted(jnp.asarray(a), jnp.zeros(na, jnp.int32),
                           jnp.asarray(b), jnp.zeros(nb, jnp.int32))
    np.testing.assert_array_equal(np.asarray(mk),
                                  np.sort(np.concatenate([a, b])))


# --------------------------------------------------------------------------
# seeding
# --------------------------------------------------------------------------

def test_kmer_codes():
    seq = jnp.asarray([0, 1, 2, 3, 0], jnp.int32)
    codes = S.kmer_codes(seq, 3)
    assert codes.shape == (3,)
    assert int(codes[0]) == 0b000110          # 0,1,2
    assert int(codes[1]) == 0b011011          # 1,2,3
    assert int(codes[2]) == 0b101100          # 2,3,0


def test_minimizers_shift_invariance():
    """A window minimizer set is a subsequence property: shifting the whole
    sequence does not change which relative positions are minimizers."""
    rng = np.random.default_rng(2)
    seq = rng.integers(0, 4, 300).astype(np.int32)
    pos1, h1, keep1 = S.minimizers(jnp.asarray(seq), 15, 10)
    pos2, h2, keep2 = S.minimizers(jnp.asarray(seq), 15, 10)
    np.testing.assert_array_equal(np.asarray(pos1), np.asarray(pos2))


def test_index_lookup_finds_planted_matches():
    rng = np.random.default_rng(3)
    ref = rng.integers(0, 4, 5000).astype(np.int8)
    idx = S.build_index(ref, 15, 10)
    # a read copied verbatim from the reference must anchor to its origin
    start = 1234
    read = ref[start:start + 300].astype(np.int32)
    q, r, valid = S.seed(idx, jnp.asarray(read), 15, 10, max_occ=8)
    q, r, valid = map(np.asarray, (q, r, valid))
    hits = r[valid] - q[valid]
    assert (np.abs(hits - start) <= 2).mean() > 0.8, \
        "anchors do not cluster at the true position"
    # anchors sorted by reference position
    assert (np.diff(r[valid]) >= 0).all()


def test_seed_valid_len_masks_padding():
    rng = np.random.default_rng(4)
    ref = rng.integers(0, 4, 5000).astype(np.int8)
    idx = S.build_index(ref, 15, 10)
    read = ref[100:400].astype(np.int32)
    padded = np.zeros(512, np.int32)
    padded[:300] = read
    q1, r1, v1 = S.seed(idx, jnp.asarray(read), 15, 10)
    q2, r2, v2 = S.seed(idx, jnp.asarray(padded), 15, 10,
                        valid_len=jnp.asarray(300))
    a1 = set(zip(np.asarray(q1)[np.asarray(v1)].tolist(),
                 np.asarray(r1)[np.asarray(v1)].tolist()))
    a2 = set(zip(np.asarray(q2)[np.asarray(v2)].tolist(),
                 np.asarray(r2)[np.asarray(v2)].tolist()))
    assert a1 == a2, "padding changed the anchor set"


def test_hash32_is_permutation_like():
    xs = jnp.arange(10_000, dtype=jnp.uint32)
    hs = np.asarray(S.hash32(xs))
    assert len(np.unique(hs)) == len(hs)      # murmur finalizer is injective
