"""Chain kernel: all execution modes vs the unbanded numpy oracle, the
band-truncation claim machinery, and backtracking invariants."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import chain as C
from repro.data import genomics


def _anchors(n, seed=0, noise=30):
    return genomics.anchor_set(n, seed=seed, noise=noise)


@pytest.mark.parametrize("mode", ["sequential", "fission", "blocked"])
@pytest.mark.parametrize("n,seed", [(100, 0), (333, 1), (1024, 2)])
def test_chain_matches_oracle(mode, n, seed):
    q, r = _anchors(n, seed=seed)
    f_ref, p_ref = C.chain_ref_unbanded(q, r, T=64)
    f, p = C.chain_anchors(jnp.asarray(q), jnp.asarray(r), T=64, mode=mode)
    np.testing.assert_allclose(np.asarray(f), f_ref, rtol=1e-4, atol=1e-3)
    # predecessors may differ only on exact ties; scores must agree
    diff = np.asarray(p) != p_ref
    if diff.any():
        for i in np.where(diff)[0]:
            np.testing.assert_allclose(np.asarray(f)[i], f_ref[i], atol=1e-3)


@pytest.mark.parametrize("block", [4, 16, 64])
def test_blocked_block_sizes(block):
    q, r = _anchors(257, seed=3)
    f_seq, _ = C.chain_anchors(jnp.asarray(q), jnp.asarray(r), T=32,
                               mode="sequential")
    f_blk, _ = C.chain_anchors(jnp.asarray(q), jnp.asarray(r), T=32,
                               mode="blocked", block=block)
    np.testing.assert_allclose(np.asarray(f_blk), np.asarray(f_seq),
                               rtol=1e-4, atol=1e-3)


def test_anchor_validity_mask():
    """Padding anchors (fixed-capacity pipelines) must not affect scores."""
    q, r = _anchors(200, seed=4)
    f_ref, _ = C.chain_anchors(jnp.asarray(q), jnp.asarray(r), T=64)
    pad = 56
    qp = np.concatenate([q, np.zeros(pad, q.dtype)])
    rp = np.concatenate([r, np.full(pad, 2**30, r.dtype)])
    valid = np.concatenate([np.ones(200, bool), np.zeros(pad, bool)])
    f, _ = C.chain_anchors(jnp.asarray(qp), jnp.asarray(rp), T=64,
                           anchor_valid=jnp.asarray(valid))
    np.testing.assert_allclose(np.asarray(f)[:200], np.asarray(f_ref),
                               atol=1e-3)
    assert (np.asarray(f)[200:] < -1e17).all()


def test_band_truncation_t64_misprediction_low():
    """Paper §V-B: T=5000 -> 64 changes <9e-6 of predecessors. On synthetic
    anchors the rate depends on the generator; assert it is *small*."""
    q, r = _anchors(4000, seed=5)
    f64, p64 = C.chain_ref_unbanded(q, r, T=64)
    f5k, p5k = C.chain_ref_unbanded(q, r, T=2000)
    mis = np.mean(np.abs(f64 - f5k) > 1e-6)
    assert mis < 0.01, f"band truncation misprediction {mis:.2%}"


def test_backtrack_chains_are_consistent():
    q, r = _anchors(500, seed=6)
    f, p = C.chain_anchors(jnp.asarray(q), jnp.asarray(r), T=64)
    chains = C.backtrack(np.asarray(f), np.asarray(p), min_score=20.0)
    assert chains, "no chains found on collinear anchors"
    seen = set()
    for score, members in chains:
        assert len(members) >= 2
        assert score >= 20.0
        for m in members:
            assert m not in seen       # node-disjoint
            seen.add(m)
        # members follow predecessor links
        for a, b in zip(members[:-1], members[1:]):
            assert np.asarray(p)[b] == a


def test_chain_scores_masking_rules():
    q = jnp.asarray([0, 10, 20, 10_000], jnp.int32)
    r = jnp.asarray([0, 10, 20, 10_000], jnp.int32)
    s = C.chain_scores(q, r, T=4)
    s = np.asarray(s)
    assert s[1, 0] > -1e17          # 10,10 after 0,0: valid
    assert s[3, 0] < -1e17          # 10k jump exceeds max_dist
    assert (s[0] < -1e17).all()     # no predecessors for anchor 0
