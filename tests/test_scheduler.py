"""Continuous-batching scheduler: token identity vs per-request generate
(staggered arrivals, slot reuse), SlotManager pool mechanics, chunked
prefill exactness, per-slot sampling, the memoizing request cache, and
the KernelService 'generate' front door."""

import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as T
from repro.serve import (RequestCache, Scheduler, SchedulerConfig,
                         SlotManager, engine, generate)


@pytest.fixture(scope="module")
def gemma():
    cfg = configs.reduced_config("gemma-2b")
    return cfg, T.init_model(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def rwkv():
    cfg = configs.reduced_config("rwkv6-1.6b")
    return cfg, T.init_model(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def gemma3():
    """Windowed model: p0 is a sliding-window (16) ring, p1 global —
    the paged backing runs TWO page-table groups (ring + global KV)."""
    cfg = configs.reduced_config("gemma3-12b")
    return cfg, T.init_model(jax.random.PRNGKey(0), cfg)


def _prompts(rng, vocab, lens):
    return [rng.integers(0, vocab, l).astype(np.int32) for l in lens]


# --------------------------------------------------------------------------
# token identity: continuous batching == per-request generate (greedy)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["gemma", "rwkv"])
def test_staggered_arrivals_match_per_request_generate(model, request):
    """Mixed prompt lengths, arrivals mid-stream, N > pool (slot reuse
    after eviction): every emitted stream must equal engine.generate's
    (same chunk policy) under greedy sampling."""
    cfg, params = request.getfixturevalue(model)
    rng = np.random.default_rng(1)
    lens = [3, 11, 20, 33, 9, 5]
    mnts = [4, 7, 3, 6, 9, 5]
    prompts = _prompts(rng, cfg.vocab, lens)
    eos = 7
    sc = SchedulerConfig(num_slots=2, max_len=64, prefill_chunk=8,
                         eos_token=eos)
    sched = Scheduler(cfg, params, sc)

    rid2i = {}
    submitted = 0
    for i in range(3):                        # wave 1
        rid2i[sched.submit([prompts[i]], max_new_tokens=mnts[i])[0]] = i
        submitted += 1
    steps = 0
    done = []
    while sched.pending or sched.live or submitted < len(prompts):
        done += sched.step()                  # each handed out ONCE
        steps += 1
        if steps % 3 == 0 and submitted < len(prompts):   # mid-stream
            rid2i[sched.submit([prompts[submitted]],
                               max_new_tokens=mnts[submitted])[0]] \
                = submitted
            submitted += 1
    done += sched.drain()
    assert len(done) == len(prompts)
    assert len({c.rid for c in done}) == len(prompts)     # no duplicates
    assert sched.counters["completed"] == len(prompts)
    for c in done:
        i = rid2i[c.rid]
        ref, reason = generate(params, cfg, prompts[i], mnts[i],
                               eos_token=eos, prefill_chunk=8)
        assert c.tokens.tolist() == ref.tolist(), \
            f"request {i}: {c.tokens.tolist()} != {ref.tolist()}"
        assert c.reason == reason


@pytest.mark.parametrize("model,allocator,preempt", [
    ("gemma", "contiguous", "recompute"), ("gemma", "paged", "recompute"),
    ("gemma", "paged", "swap"), ("gemma3", "paged", "swap")])
def test_property_random_arrival_patterns(request, model, allocator,
                                          preempt):
    """Property test: random prompt lengths / budgets / arrival patterns
    keep the scheduler token-identical to per-request generate — under
    BOTH slot allocators (paged runs block alloc/grow/free on every
    trace; a sub-equal-memory pool also exercises preempt-on-OOB, under
    both the recompute and the swap-out preemption policies) and for the
    windowed model, whose sliding-window rings page through a ring-mode
    page-table group next to the global-KV one."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    cfg, params = request.getfixturevalue(model)
    oracle = {}

    @settings(max_examples=5, deadline=None)
    @given(st.data())
    def prop(data):
        rng = np.random.default_rng(data.draw(st.integers(0, 2**16)))
        n = data.draw(st.integers(2, 5))
        lens = [data.draw(st.integers(1, 24)) for _ in range(n)]
        mnts = [data.draw(st.integers(1, 6)) for _ in range(n)]
        stagger = data.draw(st.integers(1, 4))
        prompts = _prompts(rng, cfg.vocab, lens)
        sc = SchedulerConfig(num_slots=2, max_len=48, prefill_chunk=8,
                             cache_requests=False, allocator=allocator,
                             block_size=8, preempt=preempt,
                             num_blocks=8 if allocator == "paged" else None)
        sched = Scheduler(cfg, params, sc)
        rid2i = {}
        submitted = 0
        steps = 0
        done = []
        while submitted < n or sched.pending or sched.live:
            if submitted < n and steps % stagger == 0:
                rid2i[sched.submit([prompts[submitted]],
                                   max_new_tokens=mnts[submitted])[0]] \
                    = submitted
                submitted += 1
            done += sched.step()
            steps += 1
        for c in done + sched.drain():
            i = rid2i[c.rid]
            key = (prompts[i].tobytes(), mnts[i])
            if key not in oracle:
                oracle[key] = generate(params, cfg, prompts[i], mnts[i],
                                       prefill_chunk=8)[0].tolist()
            assert c.tokens.tolist() == oracle[key]
        if preempt == "swap":
            assert sched.counters["recomputed_decode_steps"] == 0

    prop()


# --------------------------------------------------------------------------
# paged vs contiguous: the allocators must be observationally identical
# --------------------------------------------------------------------------

def _run_trace(cfg, params, prompts, mnts, eos, **sc_kw):
    """Replay one staggered arrival trace; returns ({idx: Completion},
    scheduler). Submissions interleave with steps so slots are reused;
    completions are collected across step() AND drain() (each handed
    out exactly once)."""
    sc = SchedulerConfig(num_slots=3, max_len=48, prefill_chunk=8,
                         eos_token=eos, cache_requests=False, **sc_kw)
    sched = Scheduler(cfg, params, sc)
    rid2i, submitted, steps, done = {}, 0, 0, []
    while submitted < len(prompts) or sched.pending or sched.live:
        if submitted < len(prompts) and steps % 2 == 0:
            rid2i[sched.submit([prompts[submitted]],
                               max_new_tokens=mnts[submitted])[0]] = submitted
            submitted += 1
        done += sched.step()
        steps += 1
    done += sched.drain()
    assert len({c.rid for c in done}) == len(done)  # delivered once each
    return {rid2i[c.rid]: c for c in done}, sched


_TRACE = dict(lens=[3, 17, 9, 24, 5, 12], mnts=[6, 4, 8, 5, 7, 3], eos=5)


@pytest.mark.parametrize("model,block_size,num_blocks,num_window_blocks,"
                         "preempt", [
    # global-attention model (the PR-3/4 arms)
    ("gemma", 8, None, None, "recompute"),
    ("gemma", 8, 6, None, "recompute"),
    ("gemma", 8, 6, None, "swap"),
    # windowed model, window(16) >> block_size(2): the ring group pages
    # 8 blocks per ring; under-provisioned global AND ring pools both
    # hit growth-OOB (ring contention during ramp-up)
    ("gemma3", 2, None, None, "recompute"),
    ("gemma3", 2, 16, 9, "recompute"),
    ("gemma3", 2, 16, 9, "swap"),
    # windowed model, window(16) < block_size(24): the ring is a single
    # partial block; the global pool under-provisions to 3
    ("gemma3", 24, 3, None, "swap"),
])
def test_paged_matches_contiguous_differential(request, model, block_size,
                                               num_blocks,
                                               num_window_blocks, preempt):
    """Same arrival trace (staggered, mixed-length, slot reuse) through
    both allocators: token-identical greedy streams and identical finish
    reasons — for the global-attention model AND the windowed model
    (whose rings page through ring-mode page-table groups, with
    window >> block_size and window < block_size layouts).
    num_blocks=None is the equal-memory pool (scheduling provably
    identical); smaller pools under-provision so growth hits
    preempt-on-OOB — invisible under greedy for BOTH policies: recompute
    restarts the victim from scratch, swap must resume it at its saved
    position (ring blocks ride the block path, not a dense snapshot)
    with ZERO recomputed decode steps (the preserved-work acceptance
    gate)."""
    cfg, params = request.getfixturevalue(model)
    rng = np.random.default_rng(7)
    prompts = _prompts(rng, cfg.vocab, _TRACE["lens"])
    mnts, eos = _TRACE["mnts"], _TRACE["eos"]
    base, ref_sched = _run_trace(cfg, params, prompts, mnts, eos)
    paged, sched = _run_trace(cfg, params, prompts, mnts, eos,
                              allocator="paged", block_size=block_size,
                              num_blocks=num_blocks,
                              num_window_blocks=num_window_blocks,
                              preempt=preempt)
    assert set(base) == set(paged) == set(range(len(prompts)))
    for i in range(len(prompts)):
        assert paged[i].tokens.tolist() == base[i].tokens.tolist(), \
            f"request {i}: paged {paged[i].tokens.tolist()} != " \
            f"contiguous {base[i].tokens.tolist()}"
        assert paged[i].reason == base[i].reason
    if num_blocks is None:
        assert sched.counters["preempted"] == 0   # equal memory: no OOB
    else:
        assert sched.counters["preempted"] >= 1   # the path really ran
    if preempt == "swap":
        # preemption preserved every decode step already paid for
        assert sched.counters["recomputed_decode_steps"] == 0
        assert sched.counters["swapped_out"] >= 1
        assert sched.counters["swapped_in"] == sched.counters["swapped_out"]
        # byte traffic is tracked by the SwapStore (single source of
        # truth), surfaced through stats()
        assert sched.stats()["swap_bytes_in"] == \
            sched.stats()["swap_bytes_out"] > 0
        # no slot-tick of work is ever redone: total live decode work ==
        # the useful work a never-preempted run does (pool TICKS may
        # still differ — a swapped request waits in the queue — but its
        # paid-for steps all survive; fig_serve gates the occupancy win)
        assert sched.counters["generated_tokens"] == \
            ref_sched.counters["generated_tokens"]
        assert sched.stats()["swapped_held"] == 0  # store fully drained
    elif num_blocks is not None:
        assert sched.counters["recomputed_decode_steps"] >= 1
    assert sched.stats()["blocks_used"] == 0      # retire freed everything


def test_reserved_admission_never_preempts(gemma):
    """admission='reserved' books blocks_for(prompt + max_new) up front:
    the under-provisioned pool that forces preemptions in the optimistic
    differential must complete the same trace with ZERO preemptions (and
    identical greedy streams) — the QoS half of the trade-off."""
    cfg, params = gemma
    rng = np.random.default_rng(7)
    prompts = _prompts(rng, cfg.vocab, _TRACE["lens"])
    mnts, eos = _TRACE["mnts"], _TRACE["eos"]
    base, _ = _run_trace(cfg, params, prompts, mnts, eos)
    got, sched = _run_trace(cfg, params, prompts, mnts, eos,
                            allocator="paged", block_size=8, num_blocks=6,
                            admission="reserved")
    for i in range(len(prompts)):
        assert got[i].tokens.tolist() == base[i].tokens.tolist()
        assert got[i].reason == base[i].reason
    assert sched.counters["preempted"] == 0
    assert sched.counters["recomputed_decode_steps"] == 0
    assert sched.stats()["blocks_used"] == 0


def test_swap_budget_rejection_falls_back_to_recompute(gemma3):
    """A SwapStore byte budget of 1 rejects every eviction: the swap
    policy must degrade to recompute per victim — still token-identical,
    with the rejection count owned by the SwapStore alone (regression:
    a scheduler-side shadow counter was once silently overwritten by
    the store's zero in the merged stats())."""
    cfg, params = gemma3
    rng = np.random.default_rng(7)
    prompts = _prompts(rng, cfg.vocab, _TRACE["lens"])
    mnts, eos = _TRACE["mnts"], _TRACE["eos"]
    base, _ = _run_trace(cfg, params, prompts, mnts, eos)
    got, sched = _run_trace(cfg, params, prompts, mnts, eos,
                            allocator="paged", block_size=2, num_blocks=16,
                            num_window_blocks=9, preempt="swap",
                            swap_bytes_budget=1)
    for i in range(len(prompts)):
        assert got[i].tokens.tolist() == base[i].tokens.tolist()
        assert got[i].reason == base[i].reason
    c = sched.counters
    assert c["swapped_out"] == 0
    assert c["preempted"] >= 1 and c["recomputed_decode_steps"] >= 1
    st = sched.stats()
    assert st["swap_rejected"] >= 1                     # the store's count
    assert st["swap_bytes_held"] == 0 and st["swap_bytes_budget"] == 1


# --------------------------------------------------------------------------
# slot manager
# --------------------------------------------------------------------------

def test_slot_manager_alloc_release_reset(rwkv):
    cfg, _ = rwkv
    sm = SlotManager(cfg, num_slots=3, cache_slots=16)
    a = sm.alloc(owner=10)
    b = sm.alloc(owner=11)
    assert {a, b} == {0, 1} and sm.free_count == 1
    assert sm.valid[a] and sm.owner[b] == 11

    # dirty slot a, release, realloc -> rows must be zeroed again
    dirty = jax.tree_util.tree_map(lambda l: l + 1, sm.gather([a]))
    sm.scatter(dirty, [a])
    sm.release(a)
    assert not sm.valid[a] and sm.free_count == 2
    a2 = sm.alloc(owner=12)
    assert a2 == a                      # LIFO free list reuses the slot
    fresh = sm.gather([a2])
    zeros = T.init_caches(cfg, 1, 16, per_slot_pos=True)
    for x, z in zip(jax.tree_util.tree_leaves(fresh),
                    jax.tree_util.tree_leaves(zeros)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(z))


def test_slot_gather_scatter_roundtrip(gemma):
    cfg, _ = gemma
    sm = SlotManager(cfg, num_slots=4, cache_slots=8)
    ref = jax.tree_util.tree_map(np.asarray, sm.caches)
    marked = jax.tree_util.tree_map(lambda l: l + 2, sm.gather([1, 3]))
    sm.scatter(marked, [1, 3])
    got = jax.tree_util.tree_map(np.asarray, sm.caches)
    for g, r in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_array_equal(g[:, [0, 2]], r[:, [0, 2]])
        np.testing.assert_array_equal(g[:, [1, 3]], r[:, [1, 3]] + 2)


def test_pool_exhaustion_queues_fcfs(gemma):
    cfg, params = gemma
    sc = SchedulerConfig(num_slots=1, max_len=32, prefill_chunk=8,
                         cache_requests=False)
    sched = Scheduler(cfg, params, sc)
    rng = np.random.default_rng(2)
    rids = sched.submit(_prompts(rng, cfg.vocab, [4, 4, 4]),
                        max_new_tokens=2)
    done = sched.step()
    assert sched.live == 1 and sched.pending == 2       # FCFS backlog
    done += sched.drain()
    assert [c.rid for c in done] == sorted(rids)        # completion order


def test_interleaved_step_drain_delivers_each_completion_once(gemma):
    """Regression: drain() used to return sorted(self.results) — every
    completion already handed out by an earlier step() (or a previous
    drain) came back a second time. Each completion must be delivered
    exactly once across an interleaved step/drain/submit sequence, while
    ``results`` keeps archiving until the caller pops."""
    cfg, params = gemma
    sc = SchedulerConfig(num_slots=2, max_len=32, prefill_chunk=8,
                         cache_requests=False)
    sched = Scheduler(cfg, params, sc)
    rng = np.random.default_rng(11)
    delivered = []
    r1 = sched.submit(_prompts(rng, cfg.vocab, [3, 5]), max_new_tokens=2)
    for _ in range(8):                      # enough steps to finish both
        delivered += sched.step()
    assert sorted(c.rid for c in delivered) == sorted(r1)
    assert sched.drain() == []              # nothing new: no re-delivery
    r2 = sched.submit(_prompts(rng, cfg.vocab, [4]), max_new_tokens=2)
    got = sched.drain()                     # only the new completion
    assert [c.rid for c in got] == r2
    assert sched.drain() == []
    # the archive still holds everything until the caller pops (the
    # KernelService front door pops on delivery)
    assert sorted(sched.results) == sorted(r1 + r2)
    for rid in r1 + r2:
        sched.results.pop(rid)
    assert sched.results == {}


def test_submit_validation_raises_value_error(gemma):
    """User-input feasibility is enforced with ValueError (not assert —
    it must survive `python -O`): zero budget, oversize prompt, and a
    paged request that could never fit the whole block pool."""
    cfg, params = gemma
    sched = Scheduler(cfg, params, SchedulerConfig(
        num_slots=1, max_len=16, prefill_chunk=8))
    with pytest.raises(ValueError, match="max_new_tokens"):
        sched.submit([np.arange(4, dtype=np.int32)], max_new_tokens=0)
    with pytest.raises(ValueError, match="exceeds"):
        sched.submit([np.arange(14, dtype=np.int32)], max_new_tokens=4)
    paged = Scheduler(cfg, params, SchedulerConfig(
        num_slots=1, max_len=64, prefill_chunk=8, allocator="paged",
        block_size=8, num_blocks=2))
    with pytest.raises(ValueError, match="blocks > pool"):
        paged.submit([np.arange(20, dtype=np.int32)], max_new_tokens=8)
    with pytest.raises(ValueError, match="SchedulerConfig.preempt"):
        Scheduler(cfg, params, SchedulerConfig(preempt="restart"))


def test_completion_latency_uses_monotonic_clock(gemma):
    """Completion stamps come from time.perf_counter(): latencies are
    non-negative by construction (a wall-clock NTP step cannot skew
    fig_serve's p50/p95) and ordered submit <= finish."""
    cfg, params = gemma
    sched = Scheduler(cfg, params, SchedulerConfig(
        num_slots=1, max_len=32, prefill_chunk=8, cache_requests=False))
    rng = np.random.default_rng(12)
    t0 = time.perf_counter()
    sched.submit(_prompts(rng, cfg.vocab, [4]), max_new_tokens=2)
    done = sched.drain()
    t1 = time.perf_counter()
    (c,) = done
    assert t0 <= c.submit_t <= c.finish_t <= t1
    assert 0.0 <= c.latency <= t1 - t0


# --------------------------------------------------------------------------
# chunked prefill / per-slot steps
# --------------------------------------------------------------------------

def test_chunked_prefill_matches_full_prefill_logits(gemma):
    """Chunk steps over the full prompt == one-shot prefill (tolerance:
    online-softmax accumulation order differs across chunk boundaries)."""
    cfg, params = gemma
    b, s, ch = 2, 24, 8
    toks = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0, cfg.vocab)
    logits_full, caches_full = jax.jit(
        engine.make_prefill_step(cfg, cache_slots=s))(params,
                                                      {"tokens": toks})
    caches = T.init_caches(cfg, b, s, per_slot_pos=True)
    chunk = jax.jit(engine.make_chunk_step(cfg))
    for c0 in range(0, s, ch):
        logits, caches = chunk(params, caches, toks[:, c0:c0 + ch],
                               jnp.full((b,), c0, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits[:, -1], np.float32),
                               np.asarray(logits_full[:, -1], np.float32),
                               rtol=3e-2, atol=3e-2)


def test_per_slot_positions_match_shared_clock(gemma):
    """A per-row position vector with equal entries must reproduce the
    scalar-clock decode step (same tokens, same caches)."""
    cfg, params = gemma
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(6), (b, s), 0, cfg.vocab)
    prefill = jax.jit(engine.make_prefill_step(cfg, cache_slots=s + 4))
    logits, caches = prefill(params, {"tokens": toks})
    tok = engine.sample_token(logits)

    caches2 = T.init_caches(cfg, b, s + 4, per_slot_pos=True)
    chunk = jax.jit(engine.make_chunk_step(cfg))
    _, caches2 = chunk(params, caches2, toks,
                       jnp.zeros((b,), jnp.int32))
    sdec = jax.jit(engine.make_slot_decode_step(cfg))
    decode = jax.jit(engine.make_decode_step(cfg))
    key = jax.random.PRNGKey(0)
    for i in range(3):
        ref_tok, ref_logits, caches = decode(
            params, caches, {"tokens": tok[:, None]},
            jnp.asarray(s + i, jnp.int32))
        got_tok, got_logits, caches2 = sdec(
            params, caches2, tok[:, None],
            jnp.full((b,), s + i, jnp.int32),
            jnp.zeros((b,), jnp.float32), key)
        np.testing.assert_allclose(
            np.asarray(got_logits[:, 0], np.float32),
            np.asarray(ref_logits[:, 0], np.float32),
            rtol=3e-2, atol=3e-2)
        assert got_tok.tolist() == ref_tok.tolist()
        tok = ref_tok


def test_sample_token_per_slot_temperatures():
    """temps vector: greedy rows exactly argmax, hot rows vary."""
    logits = jnp.tile(jnp.asarray([[[0.0, 3.0, 1.0, 2.9]]]), (2, 1, 1))
    temps = jnp.asarray([0.0, 5.0])
    toks = [engine.sample_token(logits, jax.random.PRNGKey(i), temps)
            for i in range(40)]
    greedy = [int(t[0]) for t in toks]
    hot = [int(t[1]) for t in toks]
    assert set(greedy) == {1}
    assert len(set(hot)) > 1


# --------------------------------------------------------------------------
# shared-prefix admission (copy-on-write paged pool)
# --------------------------------------------------------------------------

def _shared_prefix_prompts(cfg, prefix_len, suffix_lens, seed=21):
    """Prompts sharing one system-prompt prefix + unique suffixes."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab, prefix_len).astype(np.int32)
    return [np.concatenate([prefix,
                            rng.integers(0, cfg.vocab, l).astype(np.int32)])
            for l in suffix_lens]


@pytest.mark.parametrize("model,block_size,num_blocks,num_window_blocks,"
                         "preempt,prefix_len,suffix_lens,mnts", [
    # global-attention model, equal-memory pool: sharing engages with no
    # preemption in sight (the common fast path)
    ("gemma", 8, None, None, "recompute", 24, [3, 6, 1, 5, 2],
     [4, 6, 3, 5, 4]),
    # under-provisioned pools: sharing + preempt-recompute and
    # preempt-swap interleave (a swapped-out sharer must resume against
    # blocks it no longer co-owns; evicted index entries must not free
    # blocks still mapped)
    ("gemma", 8, 8, None, "recompute", 24, [3, 6, 1, 5, 2],
     [4, 6, 3, 5, 4]),
    ("gemma", 8, 8, None, "swap", 24, [3, 6, 1, 5, 2], [4, 6, 3, 5, 4]),
    # windowed model: the ring group only shares when the whole request
    # span fits its view (no wrap during a sharer's lifetime), so spans
    # are kept <= window(16); the global-KV group shares alongside
    ("gemma3", 2, None, None, "recompute", 8, [2, 4, 1, 3], [4, 3, 5, 4]),
    ("gemma3", 2, 20, 12, "swap", 8, [2, 4, 1, 3], [4, 3, 5, 4]),
])
def test_shared_prefix_streams_bit_identical(request, model, block_size,
                                             num_blocks, num_window_blocks,
                                             preempt, prefix_len,
                                             suffix_lens, mnts):
    """prefix_sharing=True must be observationally invisible: the same
    staggered trace of prompts sharing a system-prompt prefix produces
    bit-identical greedy streams and finish reasons with sharing on and
    off — while actually sharing (prefix_shared_tokens > 0), including
    through preemption (recompute AND swap) and the windowed model's
    ring + global page-table groups."""
    cfg, params = request.getfixturevalue(model)
    prompts = _shared_prefix_prompts(cfg, prefix_len, suffix_lens)
    eos = _TRACE["eos"]
    kw = dict(allocator="paged", block_size=block_size,
              num_blocks=num_blocks, num_window_blocks=num_window_blocks,
              preempt=preempt)
    base, _ = _run_trace(cfg, params, prompts, mnts, eos, **kw)
    got, sched = _run_trace(cfg, params, prompts, mnts, eos,
                            prefix_sharing=True, **kw)
    assert set(base) == set(got) == set(range(len(prompts)))
    for i in range(len(prompts)):
        assert got[i].tokens.tolist() == base[i].tokens.tolist(), \
            f"request {i}: shared {got[i].tokens.tolist()} != " \
            f"unshared {base[i].tokens.tolist()}"
        assert got[i].reason == base[i].reason
    # sharing really engaged: later arrivals were admitted with their
    # prefix chunks already written
    assert sched.counters["prefix_shared_tokens"] > 0
    st = sched.stats()
    assert st["prefix_hit_chunks"] > 0 and st["prefix_published"] > 0
    if preempt == "swap":
        assert sched.counters["recomputed_decode_steps"] == 0
    # index entries pin their blocks; dropping the index frees them all
    assert st["blocks_used"] > 0            # the index holds blocks
    sched.slots.flush_prefix()
    assert sched.stats()["blocks_used"] == 0
    assert sched.stats()["shared_blocks"] == 0


def test_prefix_sharing_requires_paged_allocator(gemma):
    cfg, params = gemma
    with pytest.raises(ValueError, match="prefix_sharing requires"):
        Scheduler(cfg, params, SchedulerConfig(prefix_sharing=True))


def test_prefix_sharing_counters_zero_when_off(gemma):
    """The sharing keys are pre-declared (schema regression): a plain
    paged run reports them all as exact zeros."""
    cfg, params = gemma
    rng = np.random.default_rng(5)
    sched = Scheduler(cfg, params, SchedulerConfig(
        num_slots=2, max_len=32, prefill_chunk=8, cache_requests=False,
        allocator="paged", block_size=8))
    sched.submit(_prompts(rng, cfg.vocab, [6, 6]), max_new_tokens=2)
    sched.drain()
    st = sched.stats()
    assert sched.counters["prefix_shared_tokens"] == 0
    for k in ("shared_blocks", "cow_copies", "prefix_shared_chunks",
              "prefix_entries", "prefix_lookups", "prefix_hit_chunks",
              "prefix_published", "prefix_evicted"):
        assert st[k] == 0, k


# --------------------------------------------------------------------------
# submit atomicity (batch validation)
# --------------------------------------------------------------------------

def test_submit_batch_is_atomic(gemma):
    """Regression: submit() used to enqueue prompts one-by-one and raise
    on the first invalid member — the valid prefix of the batch stayed
    enqueued as orphans (rids the caller never received, burning pool
    space and polluting ``results``). The whole batch must validate
    before ANY request is accepted."""
    cfg, params = gemma
    sched = Scheduler(cfg, params, SchedulerConfig(
        num_slots=1, max_len=16, prefill_chunk=8, cache_requests=False))
    good = np.arange(4, dtype=np.int32)
    bad = np.arange(14, dtype=np.int32)         # 14 + 4 > max_len
    with pytest.raises(ValueError, match="exceeds"):
        sched.submit([good, bad], max_new_tokens=4)
    # nothing leaked: no pending orphan, no phantom completion
    assert sched.pending == 0 and sched.live == 0
    assert sched.counters["submitted"] == 0
    assert sched.drain() == [] and sched.results == {}
    # the paged feasibility check participates in the same all-or-nothing
    paged = Scheduler(cfg, params, SchedulerConfig(
        num_slots=1, max_len=64, prefill_chunk=8, cache_requests=False,
        allocator="paged", block_size=8, num_blocks=2))
    with pytest.raises(ValueError, match="blocks > pool"):
        paged.submit([good, np.arange(20, dtype=np.int32)],
                     max_new_tokens=8)
    assert paged.pending == 0 and paged.counters["submitted"] == 0
    # the good prompt on its own still goes through afterwards
    rids = sched.submit([good], max_new_tokens=4)
    done = sched.drain()
    assert [c.rid for c in done] == rids


# --------------------------------------------------------------------------
# request cache (zipfian traffic)
# --------------------------------------------------------------------------

def test_request_cache_key_includes_dtype_and_shape():
    """Regression: raw prompt bytes collide across dtypes/shapes — e.g.
    int64([1]) and int32([1, 0]) share little-endian bytes, as do (4,)
    and (2, 2) views of one buffer. The key must separate them."""
    a = np.asarray([1, 0], np.int32)
    b = np.asarray([1], np.int64)
    assert a.tobytes() == b.tobytes()           # the collision being fixed
    assert RequestCache.key(a, 4, None) != RequestCache.key(b, 4, None)
    c = np.asarray([[1, 0], [2, 0]], np.int32)
    d = np.asarray([1, 0, 2, 0], np.int32)
    assert c.tobytes() == d.tobytes()
    assert RequestCache.key(c, 4, None) != RequestCache.key(d, 4, None)
    # equal arrays still key equal (the cache still caches)
    assert RequestCache.key(a, 4, None) == RequestCache.key(a.copy(), 4, None)


def test_request_cache_hits_and_eviction():
    rc = RequestCache(maxsize=2)
    k1 = RequestCache.key(np.asarray([1, 2], np.int32), 4, None)
    k2 = RequestCache.key(np.asarray([1, 2], np.int32), 5, None)  # differs
    assert k1 != k2 and rc.get(k1) is None
    rc.put(k1, np.asarray([9], np.int32), "length")
    got = rc.get(k1)
    assert got is not None and got[0].tolist() == [9]
    rc.put(k2, np.asarray([8], np.int32), "length")
    rc.put(RequestCache.key(np.asarray([3], np.int32), 4, None),
           np.asarray([7], np.int32), "length")
    assert rc.get(k1) is None           # LRU evicted (maxsize=2)
    assert rc.hit_rate == pytest.approx(1 / 3)


def test_scheduler_zipf_repeats_served_from_cache(rwkv):
    cfg, params = rwkv
    sc = SchedulerConfig(num_slots=2, max_len=32, prefill_chunk=8)
    sched = Scheduler(cfg, params, sc)
    rng = np.random.default_rng(3)
    hot = _prompts(rng, cfg.vocab, [6])[0]
    r1 = sched.submit([hot], max_new_tokens=3)
    sched.drain()
    r2 = sched.submit([hot, hot], max_new_tokens=3)     # repeats: no decode
    steps_before = sched.counters["decode_steps"]
    sched.drain()
    assert sched.counters["decode_steps"] == steps_before
    for r in r2:
        assert sched.results[r].reason == "cached"
        assert sched.results[r].tokens.tolist() == \
            sched.results[r1[0]].tokens.tolist()
    assert sched.request_cache.hit_rate > 0
    # sampled requests must bypass the memo (not deterministic)
    r3 = sched.submit([hot], max_new_tokens=3, temperature=0.9)
    sched.drain()
    assert sched.results[r3[0]].reason != "cached"


def test_request_cache_put_copies_and_freezes():
    """Regression (unit): put() used to store the caller's array — a
    later in-place edit through EITHER handle silently rewrote what
    every future hit would see. The memo must own a frozen copy."""
    rc = RequestCache(maxsize=2)
    k = RequestCache.key(np.asarray([1], np.int32), 4, None)
    src = np.asarray([5, 6], np.int32)
    rc.put(k, src, "length")
    src[:] = 0                              # scribble after put
    got, reason, _ = rc.get(k)
    assert got.tolist() == [5, 6] and reason == "length"
    assert not got.flags.writeable          # hits can't poison it either


def test_request_cache_survives_completion_mutation(gemma):
    """Regression (end-to-end): _retire memoized the SAME tokens array
    it handed the original requester, so a caller mutating its
    completion in place rewrote the cache — every later duplicate
    request got the scribbled tokens with reason='cached'."""
    cfg, params = gemma
    sched = Scheduler(cfg, params, SchedulerConfig(
        num_slots=1, max_len=32, prefill_chunk=8))
    rng = np.random.default_rng(9)
    p = _prompts(rng, cfg.vocab, [6])[0]
    (r1,) = sched.submit([p], max_new_tokens=3)
    sched.drain()
    first = sched.results[r1]
    want = first.tokens.tolist()
    first.tokens[:] = -1                    # caller scribbles on its copy
    (r2,) = sched.submit([p], max_new_tokens=3)
    sched.drain()
    served = sched.results[r2]
    assert served.reason == "cached"
    assert served.tokens.tolist() == want   # memo unaffected
    # hits get their own copy too: scribbling on one cached completion
    # leaves the next hit pristine
    served.tokens[:] = -2
    (r3,) = sched.submit([p], max_new_tokens=3)
    sched.drain()
    assert sched.results[r3].tokens.tolist() == want


# --------------------------------------------------------------------------
# KernelService front door
# --------------------------------------------------------------------------

def test_kernel_service_generate_adapter(rwkv):
    from repro.runtime import KernelService, Request

    cfg, params = rwkv
    sched = Scheduler(cfg, params, SchedulerConfig(
        num_slots=2, max_len=32, prefill_chunk=8))
    svc = KernelService(lm=sched)
    assert "generate" in svc.kernels
    rng = np.random.default_rng(4)
    prompts = _prompts(rng, cfg.vocab, [5, 9, 13])
    got = svc.submit([Request("generate", {"prompt": p,
                                           "max_new_tokens": 4})
                      for p in prompts])
    for p, g in zip(prompts, got):
        ref, _ = generate(params, cfg, p, 4, prefill_chunk=8)
        assert g["tokens"].tolist() == ref.tolist()

    # pool-occupancy stats surface through the service front door
    st = svc.stats()
    assert "generate" in st["kernels"]
    assert st["lm"]["num_slots"] == 2 and "allocator" in st["lm"]

    svc_no_lm = KernelService()
    with pytest.raises(ValueError, match="generate kernel needs"):
        svc_no_lm.submit([Request("generate", {"prompt": prompts[0]})])
    assert "lm" not in svc_no_lm.stats()
