"""2-D wavefront engine: DTW and Smith-Waterman vs sequential oracles,
tile-size invariance (the Squire worker-partitioning claim: any chunking
is exact), and padding behaviour."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import align as A
from repro.core import dtw as D
from repro.core import wavefront as W


def _dtw_numpy(s, r):
    n, m = len(s), len(r)
    big = np.float64(1e30)
    mat = np.full((n + 1, m + 1), big)
    mat[0, 0] = 0.0
    for i in range(1, n + 1):
        for j in range(1, m + 1):
            mat[i, j] = abs(s[i - 1] - r[j - 1]) + min(
                mat[i - 1, j - 1], mat[i - 1, j], mat[i, j - 1])
    return mat[1:, 1:]


def _sw_numpy(a, b, match=2.0, mismatch=-4.0, gap=4.0):
    n, m = len(a), len(b)
    h = np.zeros((n + 1, m + 1))
    for i in range(1, n + 1):
        for j in range(1, m + 1):
            sub = match if a[i - 1] == b[j - 1] else mismatch
            h[i, j] = max(0.0, h[i - 1, j - 1] + sub,
                          h[i - 1, j] - gap, h[i, j - 1] - gap)
    return h[1:, 1:]


@pytest.mark.parametrize("n,m", [(16, 16), (24, 40), (7, 13)])
def test_dtw_ref_matches_numpy(n, m):
    rng = np.random.default_rng(0)
    s = rng.normal(size=n).astype(np.float32)
    r = rng.normal(size=m).astype(np.float32)
    got = D.dtw_ref(jnp.asarray(s), jnp.asarray(r))
    np.testing.assert_allclose(got, _dtw_numpy(s, r), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("tiles", [(4, 4), (8, 8), (16, 8), (5, 7)])
def test_dtw_tiled_tile_invariance(tiles):
    """Any tile partitioning gives the identical matrix (exactness of the
    local-counter decomposition)."""
    rng = np.random.default_rng(1)
    s = rng.normal(size=40).astype(np.float32)
    r = rng.normal(size=56).astype(np.float32)
    ref = D.dtw_ref(jnp.asarray(s), jnp.asarray(r))
    tr, tc = tiles
    mat, dist = D.dtw_tiled(jnp.asarray(s), jnp.asarray(r),
                            tile_r=tr, tile_c=tc)
    np.testing.assert_allclose(mat, ref, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(dist, np.asarray(ref)[-1, -1], atol=1e-4)


def test_dtw_diag_matches_ref():
    rng = np.random.default_rng(2)
    s = rng.normal(size=20).astype(np.float32)
    r = rng.normal(size=30).astype(np.float32)
    got = D.dtw_diag(jnp.asarray(s), jnp.asarray(r))
    np.testing.assert_allclose(got, D.dtw_ref(jnp.asarray(s),
                                              jnp.asarray(r)),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("n,m,tile", [(32, 32, 8), (48, 24, 16), (17, 29, 8)])
def test_sw_tiled_vs_numpy(n, m, tile):
    rng = np.random.default_rng(3)
    a = rng.integers(0, 4, n).astype(np.int32)
    b = rng.integers(0, 4, m).astype(np.int32)
    want = _sw_numpy(a, b)
    mat, best = A.sw_tiled(jnp.asarray(a), jnp.asarray(b),
                           tile_r=tile, tile_c=tile)
    np.testing.assert_allclose(mat, want, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(best, want.max(), atol=1e-4)


def test_sw_detects_planted_alignment():
    rng = np.random.default_rng(4)
    ref = rng.integers(0, 4, 200).astype(np.int32)
    read = ref[60:110].copy()
    mat, best = A.sw_tiled(jnp.asarray(read), jnp.asarray(ref),
                           tile_r=16, tile_c=16)
    assert float(best) == pytest.approx(2.0 * 50)     # perfect match score
    ei, ej = A.sw_end_position(mat)
    assert int(ej) == 109


def test_wavefront_requires_tile_multiple():
    with pytest.raises(ValueError):
        W.run_wavefront(lambda *a: None, jnp.zeros(10), jnp.zeros(8),
                        jnp.zeros(8), jnp.zeros(10), jnp.zeros(()), 4, 3)


def test_pad_to_multiple():
    x = jnp.arange(10.0)
    y = W.pad_to_multiple(x, 8, 0, -1.0)
    assert y.shape == (16,)
    assert float(y[10]) == -1.0
    z = W.pad_to_multiple(x, 5, 0, 0.0)
    assert z.shape == (10,)


def test_dp_tile_diagonal_boundaries():
    """Tile function must honor top/left/corner exactly: computing a matrix
    in one tile equals computing it in four quadrant tiles."""
    rng = np.random.default_rng(5)
    s = rng.normal(size=16).astype(np.float32)
    r = rng.normal(size=16).astype(np.float32)
    full = D.dtw_ref(jnp.asarray(s), jnp.asarray(r))
    mat, _ = D.dtw_tiled(jnp.asarray(s), jnp.asarray(r), tile_r=8, tile_c=8)
    np.testing.assert_allclose(mat, full, rtol=1e-5, atol=1e-4)
