"""Per-architecture smoke tests (the brief's requirement): every assigned
arch instantiates a REDUCED same-family config and runs one forward/train
step on CPU, asserting output shapes + finiteness. Also prefill/decode
consistency for the cache paths."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as T
from repro.optim import AdamWConfig
from repro.train import init_train_state, make_train_step

ARCHS = list(configs.ARCH_NAMES)


def _batch(cfg, b=2, s=32, key=None):
    if key is None:
        key = jax.random.PRNGKey(0)
    if cfg.input_mode == "embeds":
        return {"embeds": jax.random.normal(key, (b, s, cfg.d_model),
                                            jnp.bfloat16),
                "labels": jnp.zeros((b, s), jnp.int32)}
    return {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
            "labels": jax.random.randint(key, (b, s), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.reduced_config(arch)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    b, s = 2, 32
    batch = _batch(cfg, b, s)
    logits, aux, _ = T.apply_model(params, cfg,
                                   tokens=batch.get("tokens"),
                                   embeds=batch.get("embeds"), mode="train")
    assert logits.shape == (b, s, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_nothing_nan(arch):
    cfg = configs.reduced_config(arch)
    state = init_train_state(jax.random.PRNGKey(1), cfg)
    step = jax.jit(make_train_step(cfg, AdamWConfig(peak_lr=1e-3,
                                                    warmup_steps=1)))
    batch = _batch(cfg)
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually moved
    leaf = jax.tree_util.tree_leaves(state.params)[0]
    assert np.isfinite(np.asarray(leaf, np.float32)).all()
    assert int(state.step) == 1


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "jamba-v0.1-52b",
                                  "gemma3-12b", "deepseek-7b",
                                  "olmoe-1b-7b"])
def test_prefill_then_decode_matches_full_forward(arch):
    """Teacher-forced decode after prefill must reproduce the full-sequence
    forward logits (the cache paths are exact). MoE capacity is raised to
    the drop-free regime: capacity-bounded token dropping legitimately
    depends on sequence length, which is orthogonal to cache correctness."""
    import dataclasses
    cfg = configs.reduced_config(arch)
    # fp32: bf16 noise can flip MoE top-k at decision boundaries, which is
    # real router nondeterminism, not a cache defect.
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    params = T.init_model(jax.random.PRNGKey(2), cfg)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab)

    full_logits, _, _ = T.apply_model(params, cfg, tokens=toks, mode="train")

    npre = 8
    pre_logits, _, caches = T.apply_model(params, cfg,
                                          tokens=toks[:, :npre],
                                          mode="prefill", cache_slots=s)
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, -1], np.float32),
        np.asarray(full_logits[:, npre - 1], np.float32),
        rtol=2e-2, atol=2e-2)

    for t in range(npre, s):
        logits, _, caches = T.apply_model(
            params, cfg, tokens=toks[:, t:t + 1], mode="decode",
            caches=caches, pos_scalar=jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=3e-2, atol=3e-2,
            err_msg=f"{arch}: decode step {t} diverged from full forward")


def test_param_counts_match_published_scale():
    """Full configs must land near their published parameter counts."""
    import math
    expect = {
        "deepseek-7b": (6.5e9, 7.5e9),
        "gemma-2b": (2.0e9, 3.3e9),       # incl. 256k-vocab embeddings
        "qwen2.5-14b": (13e9, 15.5e9),
        "rwkv6-1.6b": (1.4e9, 1.8e9),
        "olmoe-1b-7b": (6.0e9, 7.5e9),
        "jamba-v0.1-52b": (49e9, 56e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = configs.get_config(arch)
        from repro.launch import specs
        shapes = specs.params_specs(cfg)
        n = sum(math.prod(l.shape)
                for l in jax.tree_util.tree_leaves(shapes))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside " \
                              f"[{lo/1e9:.1f}, {hi/1e9:.1f}]B"


def test_moe_aux_loss_nonzero_and_balanced():
    cfg = configs.reduced_config("olmoe-1b-7b")
    params = T.init_model(jax.random.PRNGKey(4), cfg)
    batch = _batch(cfg, 2, 32, jax.random.PRNGKey(5))
    _, aux, _ = T.apply_model(params, cfg, tokens=batch["tokens"],
                              mode="train")
    # Switch aux loss is ~1x router_aux_weight per MoE layer at init balance
    assert 0.0 < float(aux) < 1.0


def test_long_context_decode_state_is_o1_for_ssm():
    """SSM decode cache size is independent of context length."""
    cfg = configs.reduced_config("rwkv6-1.6b")
    c_small = T.init_caches(cfg, batch=1, slots=128)
    c_large = T.init_caches(cfg, batch=1, slots=131072)
    sz = lambda c: sum(x.size for x in jax.tree_util.tree_leaves(c))
    assert sz(c_small) == sz(c_large)


def test_attention_cache_is_bounded_by_window():
    """gemma3 local layers allocate window slots, not full context."""
    cfg = configs.get_config("gemma3-12b")
    local = [sp for sp in cfg.pattern if sp.window > 0]
    assert local, "gemma3 must have sliding-window layers"
    caches = jax.eval_shape(lambda: T.init_caches(cfg, batch=1, slots=32768))
    sizes = {}
    for i, sp in enumerate(cfg.pattern):
        kv = caches[f"p{i}"]["attn"]
        sizes[i] = kv.k.shape[2]
        if sp.window:
            assert kv.k.shape[2] <= sp.window
        else:
            assert kv.k.shape[2] == 32768
