"""scan1d: sequential == chunked == associative, for every semiring
(property), plus the matrix-state diag_rank1 recurrence vs a numpy oracle.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.scan1d import affine_scan, diag_rank1_scan
from repro.core.semiring import SEMIRINGS

finite = st.floats(min_value=-10, max_value=10, allow_nan=False, width=32)


@st.composite
def scan_cases(draw):
    t = draw(st.integers(1, 64))
    a = draw(st.lists(finite, min_size=t, max_size=t))
    b = draw(st.lists(finite, min_size=t, max_size=t))
    x0 = draw(finite)
    chunks = draw(st.sampled_from([1, 2, 3, 4, 8]))
    return (jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32),
            jnp.asarray(x0, jnp.float32), chunks)


@given(scan_cases(), st.sampled_from(sorted(SEMIRINGS)))
@settings(max_examples=60, deadline=None)
def test_modes_agree(case, srname):
    sr = SEMIRINGS[srname]
    a, b, x0, chunks = case
    seq = affine_scan(a, b, x0, sr, mode="sequential")
    chk = affine_scan(a, b, x0, sr, mode="chunked", num_chunks=chunks)
    ass = affine_scan(a, b, x0, sr, mode="associative")
    np.testing.assert_allclose(chk, seq, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(ass, seq, rtol=1e-4, atol=1e-3)


@given(scan_cases())
@settings(max_examples=30, deadline=None)
def test_chunked_boundary_modes_agree(case):
    sr = SEMIRINGS["maxplus"]
    a, b, x0, chunks = case
    s1 = affine_scan(a, b, x0, sr, mode="chunked", num_chunks=chunks,
                     boundary_mode="sequential")
    s2 = affine_scan(a, b, x0, sr, mode="chunked", num_chunks=chunks,
                     boundary_mode="associative")
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-3)


def _dr1_oracle(w, k, v, s0):
    t, dk = w.shape
    dv = v.shape[-1]
    s = np.array(s0, np.float64)
    out = np.zeros((t, dk, dv))
    for i in range(t):
        s = w[i][:, None] * s + np.outer(k[i], v[i])
        out[i] = s
    return out


def test_diag_rank1_scan_modes():
    rng = np.random.default_rng(0)
    t, dk, dv = 50, 8, 6
    w = rng.uniform(0.5, 1.0, (t, dk)).astype(np.float32)
    k = rng.normal(size=(t, dk)).astype(np.float32)
    v = rng.normal(size=(t, dv)).astype(np.float32)
    s0 = rng.normal(size=(dk, dv)).astype(np.float32)
    want = _dr1_oracle(w, k, v, s0)
    got_seq = diag_rank1_scan(jnp.asarray(w), jnp.asarray(k), jnp.asarray(v),
                              jnp.asarray(s0), mode="sequential")
    got_chk = diag_rank1_scan(jnp.asarray(w), jnp.asarray(k), jnp.asarray(v),
                              jnp.asarray(s0), mode="chunked", chunk=16)
    np.testing.assert_allclose(got_seq, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_chk, want, rtol=1e-3, atol=1e-3)


def test_scan_shapes_and_dtypes():
    for t in (1, 7, 64, 129):
        a = jnp.ones((t, 3))
        b = jnp.zeros((t, 3))
        x0 = jnp.zeros((3,))
        for mode in ("sequential", "chunked", "associative"):
            out = affine_scan(a, b, x0, SEMIRINGS["real"], mode=mode)
            assert out.shape == (t, 3)
            assert out.dtype == jnp.float32
