"""Observability layer (PR 6): metrics registry semantics, tracer
event/ring behavior, Chrome trace-event schema validation, scheduler
span monotonicity, stats-key regression across both slot backings, the
Completion per-phase timeline, and the hypothesis counter-reconciliation
invariant ``submitted == completed + live + pending + coalesced_waiting``
across random submit/step/drain interleavings."""

import gc
import json

import numpy as np
import pytest

import jax

from repro import configs
from repro.models import transformer as T
from repro.obs import (PAGED_STATS, REGISTRY, SCHEDULER_STATS, SLOTS_STATS,
                       Registry, Tracer, get_tracer, instrumented_jit,
                       set_tracer, validate_chrome_trace, validate_stats)
from repro.serve import Scheduler, SchedulerConfig


@pytest.fixture(scope="module")
def rwkv():
    cfg = configs.reduced_config("rwkv6-1.6b")
    return cfg, T.init_model(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def gemma():
    cfg = configs.reduced_config("gemma-2b")
    return cfg, T.init_model(jax.random.PRNGKey(0), cfg)


def _prompts(rng, vocab, lens):
    return [rng.integers(0, vocab, l).astype(np.int32) for l in lens]


def _serve(cfg, params, prompts, max_new=6, tracer=None, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", max(len(p) for p in prompts) + max_new + 2)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("cache_requests", False)
    sched = Scheduler(cfg, params, SchedulerConfig(**kw), tracer=tracer)
    for p in prompts:
        sched.submit([p], max_new_tokens=max_new)
    sched.drain()
    return sched


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------

def test_registry_counter_gauge_histogram():
    reg = Registry()
    reg.counter("a.hits").inc()
    reg.counter("a.hits").inc(4)            # get-or-create: same counter
    reg.gauge("a.depth").set(7)
    for v in [1.0, 2.0, 3.0, 4.0]:
        reg.histogram("a.ms").observe(v)
    snap = reg.snapshot()
    assert snap["a.hits"] == 5
    assert snap["a.depth"] == 7
    assert snap["a.ms.count"] == 4
    assert snap["a.ms.sum"] == pytest.approx(10.0)
    assert snap["a.ms.max"] == pytest.approx(4.0)
    assert snap["a.ms.p50"] == pytest.approx(2.0, abs=1.0)


def test_registry_provider_prefix_and_weakref():
    reg = Registry()

    class Prov:
        def __init__(self, n):
            self.n = n

        def metrics(self):
            return {"n": self.n}

    p = Prov(3)
    reg.register_provider("x", p)
    assert reg.snapshot()["x.n"] == 3
    # latest registration wins for a prefix (schedulers re-register per
    # construction in benchmarks; dead ones must not shadow the live one)
    q = Prov(9)
    reg.register_provider("x", q)
    assert reg.snapshot()["x.n"] == 9
    # weakref: a dropped provider vanishes from the snapshot (no leak,
    # no stale numbers)
    del q
    gc.collect()
    assert "x.n" not in reg.snapshot()
    reg.register_provider("x", p)
    assert reg.snapshot()["x.n"] == 3


def test_registry_dump_json(tmp_path):
    reg = Registry()
    reg.counter("k").inc(2)
    out = tmp_path / "m.json"
    reg.dump_json(str(out))
    assert json.loads(out.read_text())["k"] == 2


def test_registry_dump_json_crash_mid_write_keeps_old_file(tmp_path,
                                                          monkeypatch):
    """Atomicity: a dump that dies mid-write must leave the previous
    snapshot intact on disk (and no litter) — dashboards tailing the
    file never see a truncated JSON."""
    reg = Registry()
    reg.counter("k").inc(2)
    out = tmp_path / "m.json"
    reg.dump_json(str(out))
    before = out.read_text()

    def boom(*a, **kw):
        raise RuntimeError("simulated crash mid-serialization")

    monkeypatch.setattr(json, "dump", boom)
    with pytest.raises(RuntimeError):
        reg.dump_json(str(out))
    monkeypatch.undo()
    assert out.read_text() == before            # old snapshot survives
    assert list(tmp_path.iterdir()) == [out]    # no tmp litter


def test_histogram_lifetime_count_sum_beyond_window():
    """count/sum are MONOTONIC lifetime totals even after the percentile
    window (512) wraps — the sampler differentiates them into rates, so
    a windowed reset would fabricate negative traffic."""
    from repro.obs import Histogram

    h = Histogram(window=16)
    n = 100                                     # >> window
    for i in range(n):
        h.observe(float(i))
    s = h.summary()
    assert s["count"] == n
    assert s["sum"] == pytest.approx(sum(range(n)))
    assert s["max"] == pytest.approx(n - 1)
    # percentiles are over the recent window only (the last 16 values)
    assert s["p50"] >= n - 16


# --------------------------------------------------------------------------
# tracer
# --------------------------------------------------------------------------

def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("s", "scheduler", k=1):
        tr.instant("i", "scheduler")
    tr.complete("c", "dispatcher", 0.0, 1.0)
    assert len(tr.events) == 0
    assert tr.chrome_trace()["traceEvents"] == []
    # module default is disabled: event sites on the tier-1 path are a
    # single attribute check
    assert not get_tracer().enabled


def test_tracer_ring_bounded_and_counts_drops():
    tr = Tracer(enabled=True, capacity=4)
    for i in range(10):
        tr.instant(f"e{i}", "scheduler")
    assert len(tr.events) == 4
    data = tr.chrome_trace()
    assert data["otherData"]["dropped_events"] == 6
    names = [e["name"] for e in data["traceEvents"]
             if e["ph"] != "M"]
    assert names == ["e6", "e7", "e8", "e9"]    # oldest evicted first


def test_chrome_trace_schema_and_tracks():
    tr = Tracer(enabled=True)
    with tr.span("outer", "scheduler"):
        with tr.span("inner", "scheduler"):
            pass
    tr.instant("mark", "slot0", rid=3)
    data = tr.chrome_trace()
    assert validate_chrome_trace(data) == []
    meta = {e["args"]["name"]: e for e in data["traceEvents"]
            if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"scheduler", "slot0"} <= set(meta)
    xs = [e for e in data["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"outer", "inner"}
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0 and e["pid"] == 1


def test_validator_rejects_partial_overlap():
    bad = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": 0.0,
         "dur": 10.0, "args": {}},
        {"name": "b", "ph": "X", "pid": 1, "tid": 1, "ts": 5.0,
         "dur": 10.0, "args": {}},
    ], "displayTimeUnit": "ms", "otherData": {}}
    assert validate_chrome_trace(bad)


def test_instrumented_jit_classifies_compile_vs_hit():
    reg_before = REGISTRY.snapshot()
    f = instrumented_jit(jax.jit(lambda x: x * 2 + 1),
                        name="obs_test_fn", prefix="test.obsjit")
    f(np.float32(2.0))                          # compile
    f(np.float32(3.0))                          # hit
    f(np.ones(3, np.float32))                   # new shape: compile
    snap = REGISTRY.snapshot()
    assert snap["test.obsjit.cache_misses"] - \
        reg_before.get("test.obsjit.cache_misses", 0) == 2
    assert snap["test.obsjit.cache_hits"] - \
        reg_before.get("test.obsjit.cache_hits", 0) == 1
    assert snap["test.obsjit.compile_ms.count"] >= 2
    assert snap["test.obsjit.execute_ms.count"] >= 1


def test_tracer_ring_overflow_feeds_registry_counter():
    """Ring overwrites are data loss: each one must increment the
    ``obs.trace.dropped`` registry counter so a sampler/SLO rule can
    alarm on the drop rate, not just the export metadata."""
    before = REGISTRY.counter("obs.trace.dropped").value
    tr = Tracer(enabled=True, capacity=3)
    for i in range(8):
        tr.instant(f"e{i}", "scheduler")
    assert tr.dropped == 5
    assert REGISTRY.counter("obs.trace.dropped").value - before == 5


def test_counter_events_export_and_validate():
    """'C' (counter) events: numeric args, rendered as Perfetto counter
    tracks, accepted by the schema validator; empty/non-numeric args
    must be rejected."""
    tr = Tracer(enabled=True)
    tr.counter("serve.pending", "metrics", value=3)
    tr.counter("tok_per_s", "metrics", value=812.5)
    data = tr.chrome_trace()
    assert validate_chrome_trace(data) == []
    cs = [e for e in data["traceEvents"] if e["ph"] == "C"]
    assert len(cs) == 2
    assert cs[0]["args"] == {"value": 3.0}
    bad = dict(data)
    bad["traceEvents"] = data["traceEvents"] + [
        {"name": "x", "ph": "C", "pid": 1, "tid": 0, "ts": 0.0,
         "args": {}}]
    assert validate_chrome_trace(bad)


# --------------------------------------------------------------------------
# sampler: tick cadence, rates, reset tolerance, export
# --------------------------------------------------------------------------

def test_sampler_tick_cadence_and_rates():
    from repro.obs import Sampler

    reg = Registry()
    c = reg.counter("k.events")
    smp = Sampler(registry=reg, every_ticks=2)
    assert smp.tick() is not None       # first tick always samples
    c.inc(10)
    assert smp.tick() is None           # cadence: every 2nd tick
    s = smp.tick()
    assert s is not None and s.values["k.events"] == 10
    assert s.rates["k.events"] > 0      # 10 events over the interval
    # series() reads the retained ring
    ser = smp.series("k.events")
    assert [v for _, v in ser] == [0.0, 10.0]


def test_sampler_counter_reset_skips_rate():
    """A provider re-registration can make a counter DECREASE between
    samples; that is a reset, not negative traffic — the rate for that
    key must be absent, never negative (Prometheus semantics)."""
    from repro.obs import Sampler

    reg = Registry()

    class Prov:
        def __init__(self, n):
            self.n = n

        def metrics(self):
            return {"done": self.n}

    p = Prov(100)
    reg.register_provider("x", p)
    smp = Sampler(registry=reg)
    smp.tick()
    p2 = Prov(3)                        # fresh component, counter reset
    reg.register_provider("x", p2)
    s = smp.tick()
    assert s.values["x.done"] == 3
    assert "x.done" not in s.rates
    p2.n = 7                            # and rates resume next sample
    s = smp.tick()
    assert s.rates["x.done"] > 0


def test_sampler_ring_bounded_and_steady_rate():
    from repro.obs import Sampler

    reg = Registry()
    c = reg.counter("k.n")
    smp = Sampler(registry=reg, capacity=4)
    for _ in range(10):
        c.inc(5)
        smp.tick()
    assert len(smp.samples) == 4        # ring evicts oldest
    assert smp.sample_count == 10       # monotonic
    r = smp.steady_rate("k.n")
    assert r is not None and r > 0
    assert smp.steady_rate("missing.key") is None


def test_sampler_jsonl_export_and_self_metrics(tmp_path):
    from repro.obs import Sampler

    reg = Registry()
    reg.counter("k.n").inc(2)
    smp = Sampler(registry=reg)
    smp.tick()
    smp.tick()
    out = tmp_path / "samples.jsonl"
    smp.export_jsonl(str(out))
    lines = [json.loads(l) for l in out.read_text().splitlines()]
    assert len(lines) == 2
    assert lines[0]["values"]["k.n"] == 2
    assert smp.metrics() == {"ticks": 2, "samples": 2, "retained": 2}


def test_sampler_counter_tracks_mirror_into_tracer():
    from repro.obs import Sampler

    reg = Registry()
    reg.counter("k.n").inc(4)
    tr = Tracer(enabled=True)
    smp = Sampler(registry=reg, tracer=tr,
                  counter_tracks=(("k.n", "value"), ("k.n", "rate")))
    smp.tick()
    smp.tick()
    cs = [e for e in tr.events if e.ph == "C"]
    assert {e.name for e in cs} == {"k.n", "k.n/s"}
    assert all(e.track == "metrics" for e in cs)
    assert validate_chrome_trace(tr.chrome_trace()) == []


def test_module_tick_hook_installs_and_uninstalls():
    from repro.obs import Sampler, get_sampler, set_sampler
    from repro.obs import sampler as sampler_mod

    reg = Registry()
    smp = Sampler(registry=reg)
    prev = set_sampler(smp)
    try:
        sampler_mod.tick("test")
        assert smp.ticks == 1
        assert get_sampler() is smp
        # installed sampler is a registry provider of its own cadence
        assert reg.snapshot()["obs.sampler.ticks"] == 1
    finally:
        set_sampler(prev)
    sampler_mod.tick("test")            # uninstalled: no-op, no error
    assert smp.ticks == 1


# --------------------------------------------------------------------------
# scheduler tracing: lifecycle, per-slot monotonicity
# --------------------------------------------------------------------------

def test_traced_serve_validates_and_slot_spans_are_monotonic(rwkv):
    cfg, params = rwkv
    rng = np.random.default_rng(0)
    tr = Tracer(enabled=True)
    _serve(cfg, params, _prompts(rng, cfg.vocab, [5, 9, 3, 7, 6]),
           tracer=tr)
    data = tr.chrome_trace()
    assert validate_chrome_trace(data) == []
    names = {e["name"] for e in data["traceEvents"]}
    assert {"submit", "admit", "prefill", "decode", "decode-tick",
            "retire"} <= names
    # per-slot phase spans never overlap and strictly advance in time:
    # a slot serves one request phase at a time
    by_tid = {}
    tids = {e["args"]["name"]: e["tid"] for e in data["traceEvents"]
            if e.get("ph") == "M" and e["name"] == "thread_name"}
    for e in data["traceEvents"]:
        if e["ph"] == "X":
            by_tid.setdefault(e["tid"], []).append(e)
    slot_tids = [t for n, t in tids.items() if n.startswith("slot")]
    assert len(slot_tids) >= 2
    for tid in slot_tids:
        spans = sorted(by_tid.get(tid, []), key=lambda e: e["ts"])
        assert spans, "slot track with no phase spans"
        for a, b in zip(spans, spans[1:]):
            assert a["ts"] + a["dur"] <= b["ts"] + 1e-3, \
                f"overlapping phase spans on tid {tid}"
    # instants on every track are time-ordered as emitted (ring preserves
    # emission order; ts monotone within a track)
    for tid, evs in by_tid.items():
        ts = [e["ts"] for e in sorted(evs, key=lambda e: e["ts"])]
        assert ts == sorted(ts)


def test_tracer_off_serve_emits_zero_events(rwkv):
    cfg, params = rwkv
    rng = np.random.default_rng(1)
    tr = Tracer(enabled=False)
    sched = _serve(cfg, params, _prompts(rng, cfg.vocab, [4, 6]),
                   tracer=tr)
    assert len(tr.events) == 0
    assert sched.counters["completed"] == 2


# --------------------------------------------------------------------------
# stats schema: both backings expose the same keys/types
# --------------------------------------------------------------------------

@pytest.mark.parametrize("allocator", ["contiguous", "paged"])
def test_stats_keys_stable_across_backings(gemma, allocator):
    cfg, params = gemma
    rng = np.random.default_rng(2)
    kw = {} if allocator == "contiguous" else {
        "allocator": "paged", "block_size": 4}
    fresh = Scheduler(cfg, params, SchedulerConfig(
        num_slots=2, max_len=16, prefill_chunk=4, cache_requests=False,
        **kw))
    schema = dict(SCHEDULER_STATS, **SLOTS_STATS)
    if allocator == "paged":
        schema.update(PAGED_STATS)
    fresh_keys = set(fresh.stats())
    assert validate_stats(fresh.stats(), schema) == []
    served = _serve(cfg, params, _prompts(rng, cfg.vocab, [5, 3, 7]),
                    max_new=4, **kw)
    assert validate_stats(served.stats(), schema) == []
    # regression: serving must not invent or drop keys — dashboards and
    # the benchmark emitters index these names
    assert set(served.stats()) == fresh_keys


# --------------------------------------------------------------------------
# per-request timeline (Completion phases)
# --------------------------------------------------------------------------

def test_completion_phase_stamps(rwkv):
    cfg, params = rwkv
    rng = np.random.default_rng(3)
    sched = _serve(cfg, params, _prompts(rng, cfg.vocab, [6, 8, 4, 9]),
                   max_new=5, admit="continuous")
    done = [sched.results[r] for r in sorted(sched.results)]
    assert len(done) == 4
    for c in done:
        assert c.queue_wait >= 0.0
        assert c.ttft >= c.queue_wait
        assert c.ttft <= c.latency + 1e-9
        assert c.prefill_s >= 0.0 and c.decode_s >= 0.0
        assert c.ttft == pytest.approx(c.queue_wait + c.prefill_s,
                                       abs=1e-6)
        assert c.itl >= 0.0
        assert c.swapped_s == 0.0 and c.recomputed_steps == 0


# --------------------------------------------------------------------------
# counter reconciliation (hypothesis)
# --------------------------------------------------------------------------

def test_property_counters_reconcile_across_interleavings(rwkv):
    """At every observable point, every submitted request is in exactly
    one place: finished, on a slot, queued, or waiting behind an
    identical in-flight request (coalesced)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    cfg, params = rwkv

    def check(sched):
        m = sched.metrics()
        assert m["submitted"] == (m["completed"] + m["live"] +
                                  m["pending"] + m["coalesced_waiting"]), m
        assert m["live"] == sched.stats()["live"]   # slots agree

    @settings(max_examples=8, deadline=None)
    @given(st.data())
    def prop(data):
        rng = np.random.default_rng(data.draw(st.integers(0, 2**16)))
        sched = Scheduler(cfg, params, SchedulerConfig(
            num_slots=2, max_len=12, prefill_chunk=4,
            cache_requests=True, admit="continuous"))
        pool = _prompts(rng, cfg.vocab, [3, 4, 5])
        check(sched)
        for _ in range(data.draw(st.integers(2, 8))):
            op = data.draw(st.sampled_from(["submit", "dup", "step"]))
            if op == "submit":
                sched.submit([pool[data.draw(st.integers(0, 2))]],
                             max_new_tokens=3)
            elif op == "dup":                   # coalesce candidate
                sched.submit([pool[0], pool[0]], max_new_tokens=3)
            else:
                sched.step()
            check(sched)
        sched.drain()
        check(sched)
        m = sched.metrics()
        assert m["live"] == m["pending"] == m["coalesced_waiting"] == 0
        assert m["submitted"] == m["completed"]

    prop()
