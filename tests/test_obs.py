"""Observability layer (PR 6): metrics registry semantics, tracer
event/ring behavior, Chrome trace-event schema validation, scheduler
span monotonicity, stats-key regression across both slot backings, the
Completion per-phase timeline, and the hypothesis counter-reconciliation
invariant ``submitted == completed + live + pending + coalesced_waiting``
across random submit/step/drain interleavings."""

import gc
import json

import numpy as np
import pytest

import jax

from repro import configs
from repro.models import transformer as T
from repro.obs import (PAGED_STATS, REGISTRY, SCHEDULER_STATS, SLOTS_STATS,
                       Registry, Tracer, get_tracer, instrumented_jit,
                       set_tracer, validate_chrome_trace, validate_stats)
from repro.serve import Scheduler, SchedulerConfig


@pytest.fixture(scope="module")
def rwkv():
    cfg = configs.reduced_config("rwkv6-1.6b")
    return cfg, T.init_model(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def gemma():
    cfg = configs.reduced_config("gemma-2b")
    return cfg, T.init_model(jax.random.PRNGKey(0), cfg)


def _prompts(rng, vocab, lens):
    return [rng.integers(0, vocab, l).astype(np.int32) for l in lens]


def _serve(cfg, params, prompts, max_new=6, tracer=None, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", max(len(p) for p in prompts) + max_new + 2)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("cache_requests", False)
    sched = Scheduler(cfg, params, SchedulerConfig(**kw), tracer=tracer)
    for p in prompts:
        sched.submit([p], max_new_tokens=max_new)
    sched.drain()
    return sched


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------

def test_registry_counter_gauge_histogram():
    reg = Registry()
    reg.counter("a.hits").inc()
    reg.counter("a.hits").inc(4)            # get-or-create: same counter
    reg.gauge("a.depth").set(7)
    for v in [1.0, 2.0, 3.0, 4.0]:
        reg.histogram("a.ms").observe(v)
    snap = reg.snapshot()
    assert snap["a.hits"] == 5
    assert snap["a.depth"] == 7
    assert snap["a.ms.count"] == 4
    assert snap["a.ms.sum"] == pytest.approx(10.0)
    assert snap["a.ms.max"] == pytest.approx(4.0)
    assert snap["a.ms.p50"] == pytest.approx(2.0, abs=1.0)


def test_registry_provider_prefix_and_weakref():
    reg = Registry()

    class Prov:
        def __init__(self, n):
            self.n = n

        def metrics(self):
            return {"n": self.n}

    p = Prov(3)
    reg.register_provider("x", p)
    assert reg.snapshot()["x.n"] == 3
    # latest registration wins for a prefix (schedulers re-register per
    # construction in benchmarks; dead ones must not shadow the live one)
    q = Prov(9)
    reg.register_provider("x", q)
    assert reg.snapshot()["x.n"] == 9
    # weakref: a dropped provider vanishes from the snapshot (no leak,
    # no stale numbers)
    del q
    gc.collect()
    assert "x.n" not in reg.snapshot()
    reg.register_provider("x", p)
    assert reg.snapshot()["x.n"] == 3


def test_registry_dump_json(tmp_path):
    reg = Registry()
    reg.counter("k").inc(2)
    out = tmp_path / "m.json"
    reg.dump_json(str(out))
    assert json.loads(out.read_text())["k"] == 2


# --------------------------------------------------------------------------
# tracer
# --------------------------------------------------------------------------

def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("s", "scheduler", k=1):
        tr.instant("i", "scheduler")
    tr.complete("c", "dispatcher", 0.0, 1.0)
    assert len(tr.events) == 0
    assert tr.chrome_trace()["traceEvents"] == []
    # module default is disabled: event sites on the tier-1 path are a
    # single attribute check
    assert not get_tracer().enabled


def test_tracer_ring_bounded_and_counts_drops():
    tr = Tracer(enabled=True, capacity=4)
    for i in range(10):
        tr.instant(f"e{i}", "scheduler")
    assert len(tr.events) == 4
    data = tr.chrome_trace()
    assert data["otherData"]["dropped_events"] == 6
    names = [e["name"] for e in data["traceEvents"]
             if e["ph"] != "M"]
    assert names == ["e6", "e7", "e8", "e9"]    # oldest evicted first


def test_chrome_trace_schema_and_tracks():
    tr = Tracer(enabled=True)
    with tr.span("outer", "scheduler"):
        with tr.span("inner", "scheduler"):
            pass
    tr.instant("mark", "slot0", rid=3)
    data = tr.chrome_trace()
    assert validate_chrome_trace(data) == []
    meta = {e["args"]["name"]: e for e in data["traceEvents"]
            if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"scheduler", "slot0"} <= set(meta)
    xs = [e for e in data["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"outer", "inner"}
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0 and e["pid"] == 1


def test_validator_rejects_partial_overlap():
    bad = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": 0.0,
         "dur": 10.0, "args": {}},
        {"name": "b", "ph": "X", "pid": 1, "tid": 1, "ts": 5.0,
         "dur": 10.0, "args": {}},
    ], "displayTimeUnit": "ms", "otherData": {}}
    assert validate_chrome_trace(bad)


def test_instrumented_jit_classifies_compile_vs_hit():
    reg_before = REGISTRY.snapshot()
    f = instrumented_jit(jax.jit(lambda x: x * 2 + 1),
                        name="obs_test_fn", prefix="test.obsjit")
    f(np.float32(2.0))                          # compile
    f(np.float32(3.0))                          # hit
    f(np.ones(3, np.float32))                   # new shape: compile
    snap = REGISTRY.snapshot()
    assert snap["test.obsjit.cache_misses"] - \
        reg_before.get("test.obsjit.cache_misses", 0) == 2
    assert snap["test.obsjit.cache_hits"] - \
        reg_before.get("test.obsjit.cache_hits", 0) == 1
    assert snap["test.obsjit.compile_ms.count"] >= 2
    assert snap["test.obsjit.execute_ms.count"] >= 1


# --------------------------------------------------------------------------
# scheduler tracing: lifecycle, per-slot monotonicity
# --------------------------------------------------------------------------

def test_traced_serve_validates_and_slot_spans_are_monotonic(rwkv):
    cfg, params = rwkv
    rng = np.random.default_rng(0)
    tr = Tracer(enabled=True)
    _serve(cfg, params, _prompts(rng, cfg.vocab, [5, 9, 3, 7, 6]),
           tracer=tr)
    data = tr.chrome_trace()
    assert validate_chrome_trace(data) == []
    names = {e["name"] for e in data["traceEvents"]}
    assert {"submit", "admit", "prefill", "decode", "decode-tick",
            "retire"} <= names
    # per-slot phase spans never overlap and strictly advance in time:
    # a slot serves one request phase at a time
    by_tid = {}
    tids = {e["args"]["name"]: e["tid"] for e in data["traceEvents"]
            if e.get("ph") == "M" and e["name"] == "thread_name"}
    for e in data["traceEvents"]:
        if e["ph"] == "X":
            by_tid.setdefault(e["tid"], []).append(e)
    slot_tids = [t for n, t in tids.items() if n.startswith("slot")]
    assert len(slot_tids) >= 2
    for tid in slot_tids:
        spans = sorted(by_tid.get(tid, []), key=lambda e: e["ts"])
        assert spans, "slot track with no phase spans"
        for a, b in zip(spans, spans[1:]):
            assert a["ts"] + a["dur"] <= b["ts"] + 1e-3, \
                f"overlapping phase spans on tid {tid}"
    # instants on every track are time-ordered as emitted (ring preserves
    # emission order; ts monotone within a track)
    for tid, evs in by_tid.items():
        ts = [e["ts"] for e in sorted(evs, key=lambda e: e["ts"])]
        assert ts == sorted(ts)


def test_tracer_off_serve_emits_zero_events(rwkv):
    cfg, params = rwkv
    rng = np.random.default_rng(1)
    tr = Tracer(enabled=False)
    sched = _serve(cfg, params, _prompts(rng, cfg.vocab, [4, 6]),
                   tracer=tr)
    assert len(tr.events) == 0
    assert sched.counters["completed"] == 2


# --------------------------------------------------------------------------
# stats schema: both backings expose the same keys/types
# --------------------------------------------------------------------------

@pytest.mark.parametrize("allocator", ["contiguous", "paged"])
def test_stats_keys_stable_across_backings(gemma, allocator):
    cfg, params = gemma
    rng = np.random.default_rng(2)
    kw = {} if allocator == "contiguous" else {
        "allocator": "paged", "block_size": 4}
    fresh = Scheduler(cfg, params, SchedulerConfig(
        num_slots=2, max_len=16, prefill_chunk=4, cache_requests=False,
        **kw))
    schema = dict(SCHEDULER_STATS, **SLOTS_STATS)
    if allocator == "paged":
        schema.update(PAGED_STATS)
    fresh_keys = set(fresh.stats())
    assert validate_stats(fresh.stats(), schema) == []
    served = _serve(cfg, params, _prompts(rng, cfg.vocab, [5, 3, 7]),
                    max_new=4, **kw)
    assert validate_stats(served.stats(), schema) == []
    # regression: serving must not invent or drop keys — dashboards and
    # the benchmark emitters index these names
    assert set(served.stats()) == fresh_keys


# --------------------------------------------------------------------------
# per-request timeline (Completion phases)
# --------------------------------------------------------------------------

def test_completion_phase_stamps(rwkv):
    cfg, params = rwkv
    rng = np.random.default_rng(3)
    sched = _serve(cfg, params, _prompts(rng, cfg.vocab, [6, 8, 4, 9]),
                   max_new=5, admit="continuous")
    done = [sched.results[r] for r in sorted(sched.results)]
    assert len(done) == 4
    for c in done:
        assert c.queue_wait >= 0.0
        assert c.ttft >= c.queue_wait
        assert c.ttft <= c.latency + 1e-9
        assert c.prefill_s >= 0.0 and c.decode_s >= 0.0
        assert c.ttft == pytest.approx(c.queue_wait + c.prefill_s,
                                       abs=1e-6)
        assert c.itl >= 0.0
        assert c.swapped_s == 0.0 and c.recomputed_steps == 0


# --------------------------------------------------------------------------
# counter reconciliation (hypothesis)
# --------------------------------------------------------------------------

def test_property_counters_reconcile_across_interleavings(rwkv):
    """At every observable point, every submitted request is in exactly
    one place: finished, on a slot, queued, or waiting behind an
    identical in-flight request (coalesced)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    cfg, params = rwkv

    def check(sched):
        m = sched.metrics()
        assert m["submitted"] == (m["completed"] + m["live"] +
                                  m["pending"] + m["coalesced_waiting"]), m
        assert m["live"] == sched.stats()["live"]   # slots agree

    @settings(max_examples=8, deadline=None)
    @given(st.data())
    def prop(data):
        rng = np.random.default_rng(data.draw(st.integers(0, 2**16)))
        sched = Scheduler(cfg, params, SchedulerConfig(
            num_slots=2, max_len=12, prefill_chunk=4,
            cache_requests=True, admit="continuous"))
        pool = _prompts(rng, cfg.vocab, [3, 4, 5])
        check(sched)
        for _ in range(data.draw(st.integers(2, 8))):
            op = data.draw(st.sampled_from(["submit", "dup", "step"]))
            if op == "submit":
                sched.submit([pool[data.draw(st.integers(0, 2))]],
                             max_new_tokens=3)
            elif op == "dup":                   # coalesce candidate
                sched.submit([pool[0], pool[0]], max_new_tokens=3)
            else:
                sched.step()
            check(sched)
        sched.drain()
        check(sched)
        m = sched.metrics()
        assert m["live"] == m["pending"] == m["coalesced_waiting"] == 0
        assert m["submitted"] == m["completed"]

    prop()
