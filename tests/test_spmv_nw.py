"""SpMV (paper Fig. 1c) and Needleman-Wunsch (paper §V-C): the remaining
motivating kernels, exact vs dense/numpy oracles for any chunking."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import spmv as S
from repro.core.align import SWParams, nw_ref, nw_tiled, sw_ref


# --------------------------------------------------------------------------
# SpMV
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n_rows,n_cols,density,skew,chunks", [
    (32, 40, 0.2, 0.0, 4),
    (100, 64, 0.1, 0.5, 8),     # power-law row lengths (load imbalance)
    (17, 23, 0.3, 0.0, 5),      # odd sizes
])
def test_spmv_matches_dense(n_rows, n_cols, density, skew, chunks):
    m = S.random_csr(n_rows, n_cols, density, seed=n_rows, skew=skew)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=n_cols).astype(np.float32))
    want = S.to_dense(m, n_rows) @ np.asarray(x)
    got_chunk = S.spmv_chunked(m, x, n_rows, num_chunks=chunks)
    got_seg = S.spmv_segsum(m, x, n_rows)
    np.testing.assert_allclose(np.asarray(got_chunk), want, rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_seg), want, rtol=1e-4,
                               atol=1e-4)


@given(st.integers(1, 12), st.integers(0, 6))
@settings(max_examples=15, deadline=None)
def test_spmv_chunk_invariance(chunks, seed):
    """Any worker chunking gives identical results (the Squire claim)."""
    n_rows, n_cols = 24, 16
    m = S.random_csr(n_rows, n_cols, 0.25, seed=seed)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=n_cols).astype(np.float32))
    base = S.spmv_segsum(m, x, n_rows)
    got = S.spmv_chunked(m, x, n_rows, num_chunks=chunks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# Needleman-Wunsch
# --------------------------------------------------------------------------

def _nw_numpy(a, b, match=2.0, mismatch=-4.0, gap=4.0):
    n, m = len(a), len(b)
    h = np.zeros((n + 1, m + 1))
    h[0, :] = -gap * np.arange(m + 1)
    h[:, 0] = -gap * np.arange(n + 1)
    for i in range(1, n + 1):
        for j in range(1, m + 1):
            sub = match if a[i - 1] == b[j - 1] else mismatch
            h[i, j] = max(h[i - 1, j - 1] + sub, h[i - 1, j] - gap,
                          h[i, j - 1] - gap)
    return h[1:, 1:]


@pytest.mark.parametrize("n,m,tile", [(16, 16, 8), (24, 40, 8), (13, 9, 4)])
def test_nw_matches_numpy(n, m, tile):
    rng = np.random.default_rng(n * 100 + m)
    a = rng.integers(0, 4, n).astype(np.int32)
    b = rng.integers(0, 4, m).astype(np.int32)
    want = _nw_numpy(a, b)
    got_ref = nw_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got_ref), want, rtol=1e-5,
                               atol=1e-4)
    mat, score = nw_tiled(jnp.asarray(a), jnp.asarray(b),
                          tile_r=tile, tile_c=tile)
    np.testing.assert_allclose(np.asarray(mat), want, rtol=1e-5, atol=1e-4)
    assert float(score) == pytest.approx(want[-1, -1], abs=1e-4)


def test_nw_identical_sequences_score():
    a = jnp.asarray(np.arange(12) % 4, jnp.int32)
    mat, score = nw_tiled(a, a, tile_r=4, tile_c=4)
    assert float(score) == pytest.approx(2.0 * 12)   # all matches


def test_nw_vs_sw_global_vs_local():
    """NW must pay for flanking mismatches that SW ignores."""
    rng = np.random.default_rng(3)
    core = rng.integers(0, 4, 10).astype(np.int32)
    a = np.concatenate([np.full(5, 0, np.int32), core])
    b = np.concatenate([np.full(5, 3, np.int32), core])  # mismatched flank
    p = SWParams()
    sw_best = float(jnp.max(sw_ref(jnp.asarray(a), jnp.asarray(b), p)))
    _, nw_score = nw_tiled(jnp.asarray(a), jnp.asarray(b), p,
                           tile_r=5, tile_c=5)
    assert sw_best >= 2.0 * 10 - 1e-6
    assert float(nw_score) < sw_best
