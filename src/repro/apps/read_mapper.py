"""End-to-end read mapper (paper §VI-C): seed -> chain -> align.

The paper combines SEED, CHAIN and SW into a minimap2-skeleton read mapper
and uses it as the test-bench for end-to-end acceleration (Fig. 8). This
module is that application on the JAX substrate:

  1. **seed** — window minimizers over the read, vectorized hash-index
     probe against the reference, chunk-parallel radix sort by reference
     position (core.seeding / core.sort).
  2. **chain** — banded max-plus DP over the sorted anchors with the
     paper's loop fission + T=64 band truncation (core.chain), backtracked
     on the host to the best chain.
  3. **align** — Smith-Waterman of the read against the reference window
     the chain selected, on the tiled wavefront engine (core.align).

Shape discipline and execution both come from ``repro.runtime``: stage
inputs are padded to shape buckets (``runtime.bucketing``, sentinel-masked)
and dispatched through a ``runtime.dispatch.Dispatcher`` whose compile
cache holds one program per bucket. The stage payload builders and stage
functions are module-level so the batched ``runtime.service.KernelService``
path runs the *same* computations over whole request batches — per-read
and batched mapping are bit-identical.

``mode`` selects the execution strategy per stage, mirroring the paper's
baseline-vs-Squire comparison (Fig. 8):
  * ``baseline`` — single-chunk sort, sequential chain scan, sequential SW
    (the 1-worker / host-core-only configuration).
  * ``squire``   — chunk-parallel sort, fission/blocked chain, tiled
    wavefront SW (the accelerated configuration).
Both modes are exact: results agree anchor-for-anchor and score-for-score.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import align as align_lib
from repro.core import chain as chain_lib
from repro.core import seeding
from repro.core.chain import ChainParams
from repro.runtime import bucketing
from repro.runtime.dispatch import Dispatcher


@dataclasses.dataclass(frozen=True)
class MapperConfig:
    k: int = 15                 # minimizer k-mer size
    w: int = 10                 # minimizer window
    max_occ: int = 8            # max hits per minimizer
    band_T: int = 64            # chain band (the paper's T=64)
    min_chain_score: float = 40.0
    sw_window_pad: int = 64     # reference slack around the chain span
    sw_params: align_lib.SWParams = align_lib.SWParams()
    num_workers: int = 8        # sort chunks / chain blocks knob
    mode: str = "squire"        # squire | baseline
    use_pallas: bool = False    # route SW/chain through the Pallas kernels
    read_bucket: int = 256      # reads padded to multiples of this
    anchor_bucket: int = 512    # anchor arrays padded to multiples of this
    sw_tile: int = 64           # wavefront tile (squire mode)


@dataclasses.dataclass
class MapResult:
    pos: int                    # mapped reference position (-1 = unmapped)
    sw_score: float
    chain_score: float
    n_anchors: int
    align_cells: int            # SW matrix cells (the align-stage work)


# --------------------------------------------------------------------------
# stage payload builders (runtime.bucketing; shared with runtime.service)
# --------------------------------------------------------------------------

def seed_payload(read: np.ndarray, cfg: MapperConfig
                 ) -> Tuple[np.ndarray, np.int32]:
    """Read padded to its read bucket + its true length."""
    nb = bucketing.round_up(len(read), cfg.read_bucket)
    padded = bucketing.pad_to(np.asarray(read, np.int32), nb, 0)
    return padded, np.int32(len(read))


def chain_payload(q: np.ndarray, r: np.ndarray, cfg: MapperConfig
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Anchors padded to their anchor bucket with sentinel positions."""
    nv = len(q)
    nb = bucketing.round_up(max(nv, 1), cfg.anchor_bucket)
    qp = bucketing.pad_to(np.asarray(q, np.int32), nb, 0)
    rp = bucketing.pad_to(np.asarray(r, np.int32), nb, 2**30)  # far sentinel
    vp = bucketing.pad_to(np.ones(nv, bool), nb, False)
    return qp, rp, vp


def align_payload(read: np.ndarray, window: np.ndarray, cfg: MapperConfig
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Read/window padded to buckets with mutually-mismatching sentinels."""
    na = bucketing.round_up(len(read), cfg.read_bucket)
    nb = bucketing.round_up(len(window), cfg.read_bucket)
    a = bucketing.pad_to(np.asarray(read, np.int32), na, 254)
    b = bucketing.pad_to(np.asarray(window, np.int32), nb, 255)
    return a, b


def chain_window(qv: np.ndarray, rv: np.ndarray, members: List[int],
                 read_len: int, ref_len: int, cfg: MapperConfig
                 ) -> Tuple[int, int]:
    """Best chain's span -> reference window for the align stage."""
    lo_anchor, hi_anchor = members[0], members[-1]
    ref_lo = max(0, int(rv[lo_anchor]) - int(qv[lo_anchor])
                 - cfg.sw_window_pad)
    ref_hi = min(ref_len,
                 int(rv[hi_anchor]) + (read_len - int(qv[hi_anchor]))
                 + cfg.sw_window_pad)
    return ref_lo, ref_hi


# --------------------------------------------------------------------------
# per-bucket stage functions (plain; the Dispatcher jits + caches them, and
# the service vmaps the same objects — one compile cache either way)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _seed_fn(k: int, w: int, max_occ: int, n_chunks: int):
    def run(idx_h, idx_p, read, valid_len):
        return seeding.seed(seeding.Index(idx_h, idx_p), read, k, w,
                            max_occ=max_occ, num_sort_chunks=n_chunks,
                            valid_len=valid_len)
    return run


@functools.lru_cache(maxsize=None)
def _chain_fn(T: int, mode: str, block: int):
    def run(q, r, valid):
        return chain_lib.chain_anchors(q, r, T=T, mode=mode, block=block,
                                       anchor_valid=valid)
    return run


@functools.lru_cache(maxsize=None)
def _chain_fn_pallas(T: int):
    from repro.kernels import ops

    def run(q, r, valid):
        params = ChainParams()
        n = q.shape[0]
        w = jnp.where(valid, float(params.kmer), chain_lib.NEG)
        scores = chain_lib.chain_scores(q, r, T, params, anchor_valid=valid)
        f, off = ops.chain_scan(scores, w)
        pred = jnp.where(off > 0, jnp.arange(n) - off, -1)
        return f, pred
    return run


@functools.lru_cache(maxsize=None)
def _sw_fn(mode: str, tile: int, use_pallas: bool,
           params: align_lib.SWParams):
    """-> (fn(a, b) -> (mat, score), whole_jit: bool).

    ``whole_jit=False`` marks eager wavefront schedules (only the tile is
    jitted — tracing the whole matrix would unroll thousands of tiles);
    the Dispatcher passes such fns through un-jitted.
    """
    if use_pallas:
        from repro.kernels import ops
        fn = ops.make_sw_tile_fn(params.match, params.mismatch, params.gap)

        def run(a, b):
            return align_lib.sw_tiled(a, b, params, tile_r=tile,
                                      tile_c=tile, tile_fn=fn)
        return run, False
    if mode == "squire":
        tile_fn = jax.jit(functools.partial(align_lib._sw_tile_fn, params))

        def run(a, b):
            return align_lib.sw_tiled(a, b, params, tile_r=tile,
                                      tile_c=tile, tile_fn=tile_fn)
        return run, False

    def run_base(a, b):
        mat = align_lib.sw_ref(a, b, params)
        return mat, jnp.max(mat)
    return run_base, True


class ReadMapper:
    def __init__(self, reference: np.ndarray, cfg: MapperConfig,
                 runtime: Optional[Dispatcher] = None):
        self.cfg = cfg
        self.reference = np.asarray(reference, np.int8)
        self.index = seeding.build_index(self.reference, cfg.k, cfg.w)
        self.runtime = runtime or Dispatcher()

    # -- stages --------------------------------------------------------------

    def _seed(self, read: np.ndarray):
        cfg = self.cfg
        n_chunks = cfg.num_workers if cfg.mode == "squire" else 1
        padded, true_len = seed_payload(read, cfg)
        fn = _seed_fn(cfg.k, cfg.w, cfg.max_occ, n_chunks)
        q, r, valid = self.runtime.run_one(
            fn, (self.index.hashes, self.index.positions,
                 jnp.asarray(padded), jnp.asarray(true_len)))
        return np.asarray(q), np.asarray(r), np.asarray(valid)

    def _chain(self, q: np.ndarray, r: np.ndarray):
        cfg = self.cfg
        nv = len(q)
        qp, rp, vp = chain_payload(q, r, cfg)
        if cfg.use_pallas:
            f, pred = self.runtime.run_one(
                _chain_fn_pallas(cfg.band_T),
                (jnp.asarray(qp), jnp.asarray(rp), jnp.asarray(vp)),
                jit=False)
        else:
            mode = "blocked" if cfg.mode == "squire" else "sequential"
            f, pred = self.runtime.run_one(
                _chain_fn(cfg.band_T, mode, 16),
                (jnp.asarray(qp), jnp.asarray(rp), jnp.asarray(vp)))
        return np.asarray(f)[:nv], np.asarray(pred)[:nv]

    def _align(self, read: np.ndarray, ref_lo: int, ref_hi: int
               ) -> Tuple[float, int, int]:
        cfg = self.cfg
        window = self.reference[ref_lo:ref_hi].astype(np.int32)
        a, b = align_payload(read, window, cfg)
        fn, whole_jit = _sw_fn(cfg.mode, cfg.sw_tile, cfg.use_pallas,
                               cfg.sw_params)
        mat, score = self.runtime.run_one(
            fn, (jnp.asarray(a), jnp.asarray(b)), jit=whole_jit)
        end_i, end_j = align_lib.sw_end_position(mat)
        return float(score), int(end_j), len(read) * len(window)

    # -- end to end ------------------------------------------------------------

    def map_read(self, read: np.ndarray) -> MapResult:
        cfg = self.cfg
        read = np.asarray(read)
        if len(read) < cfg.k + cfg.w:
            return MapResult(-1, 0.0, 0.0, 0, 0)

        q, r, valid = self._seed(read)
        nv = int(valid.sum())
        if nv < 2:
            return MapResult(-1, 0.0, 0.0, nv, 0)
        qv, rv = q[valid], r[valid]

        f, pred = self._chain(qv, rv)
        chains = chain_lib.backtrack(f, pred,
                                     min_score=cfg.min_chain_score)
        if not chains:
            return MapResult(-1, 0.0, 0.0, nv, 0)
        score, members = chains[0]

        ref_lo, ref_hi = chain_window(qv, rv, members, len(read),
                                      len(self.reference), cfg)
        if ref_hi - ref_lo < cfg.k:
            return MapResult(-1, 0.0, score, nv, 0)

        sw_score, end_j, cells = self._align(read, ref_lo, ref_hi)
        return MapResult(pos=ref_lo, sw_score=sw_score, chain_score=score,
                         n_anchors=nv, align_cells=cells)

    def map_reads(self, reads: List[np.ndarray]) -> List[MapResult]:
        return [self.map_read(rd) for rd in reads]


def mapping_accuracy(results: List[MapResult], truths: List[int],
                     tol: int = 200) -> float:
    """Fraction of reads mapped within ``tol`` bases of their true start."""
    ok = sum(1 for res, t in zip(results, truths)
             if res.pos >= 0 and abs(res.pos - t) <= tol)
    return ok / max(len(results), 1)
