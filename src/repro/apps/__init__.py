"""End-to-end applications built on repro.core (paper §VI-C)."""

from repro.apps import read_mapper  # noqa: F401
