"""Documented schemas for the observability surface.

Two things are pinned here so they can't drift silently:

  * ``SCHEDULER_STATS``, ``SLOTS_STATS``, ``PAGED_STATS`` — the
    documented ``stats()`` keys and their types. Every key must be
    present (counters are pre-declared at zero, not grown lazily) and
    correctly typed for BOTH slot backings; ``tests/test_obs.py`` is the
    regression test, the README table is the human copy.
  * ``validate_chrome_trace`` — structural validation of the exported
    Chrome trace-event JSON (the thing the CI smoke run gates on): known
    phases, required fields, non-negative durations, and per-track spans
    that either nest properly or don't overlap at all. A trace that
    passes loads in Perfetto with one named track per slot plus
    scheduler/dispatcher tracks.
"""

from __future__ import annotations

from typing import Any, Dict, List

# -- documented stats() keys -------------------------------------------------

#: serve.Scheduler.stats() — scheduler-owned keys (slots keys merge in).
#: Counts are int, ratios float; every key present from construction.
SCHEDULER_STATS: Dict[str, type] = {
    "submitted": int, "admitted": int, "completed": int, "steps": int,
    "decode_steps": int, "chunk_steps": int, "generated_tokens": int,
    "prefill_tokens": int, "live_decode_slots": int, "preempted": int,
    "swapped_in": int, "swapped_out": int, "recomputed_decode_steps": int,
    # prompt positions admitted already-written via prefix sharing
    # (0 unless SchedulerConfig.prefix_sharing)
    "prefix_shared_tokens": int,
    # work-stealing rebalance: queue heads migrated off a full shard
    # (0 unless SchedulerConfig.mesh_shards >= 2)
    "steals": int,
    "pending": int, "live": int, "coalesced_waiting": int,
    "cache_hits": int, "cache_misses": int,
    "cache_hit_rate": float, "mean_occupancy": float,
    # the live overload signal the SLO layer monitors: how long the
    # current queue head has been waiting (0.0 when the queue is empty)
    "queue_head_wait_s": float,
    # backpressure-controller knobs, surfaced so every actuation is
    # visible in the same snapshot the monitors read (-1 = uncapped)
    "admit_cap": int, "preempt_policy": str,
    # speculative decoding (SchedulerConfig.speculate=k; all 0 when
    # speculation is off — pre-declared so the keys never appear
    # lazily). Teacher-forced ramp positions are excluded: these count
    # REAL drafts only, so accepted/drafted is a true acceptance rate.
    "spec.drafted_tokens": int, "spec.accepted_tokens": int,
    "spec.rejected_tokens": int, "spec.rollbacks": int,
}

#: per-request latency histograms the scheduler owns (flattened into
#: stats() as ``<name>.<field>`` — lifetime count/sum, windowed
#: percentiles): the series SLO rules like ``ttft_p95 < X`` read.
#: ``spec.accept_len`` observes accepted REAL draft length per slot per
#: verify tick (unit: tokens, not ms; only observed while speculating).
SCHEDULER_LATENCY_HISTS = ("queue_wait_ms", "ttft_ms", "itl_ms",
                           "spec.accept_len")
_HIST_FIELDS: Dict[str, type] = {"count": int, "sum": float, "p50": float,
                                 "p95": float, "max": float}
SCHEDULER_STATS.update({f"{h}.{f}": t for h in SCHEDULER_LATENCY_HISTS
                        for f, t in _HIST_FIELDS.items()})

#: serve.SlotManager.stats() — present for BOTH backings.
SLOTS_STATS: Dict[str, type] = {
    "num_slots": int, "live": int, "free": int, "cache_slots": int,
    "position_capacity": int, "total_rows": int, "allocator": str,
}

#: additional SlotManager.stats() keys for the paged backing
#: (per-window ``ring<L>_blocks_*`` keys are workload-dependent extras).
PAGED_STATS: Dict[str, type] = {
    "page_groups": int, "blocks_total": int, "blocks_used": int,
    "blocks_free": int, "block_size": int, "block_utilization": float,
    # prefix sharing / copy-on-write (all 0 when sharing is off —
    # pre-declared so the keys never appear lazily)
    "shared_blocks": int, "cow_copies": int, "prefix_shared_chunks": int,
    "prefix_entries": int, "prefix_lookups": int, "prefix_hit_chunks": int,
    "prefix_published": int, "prefix_evicted": int,
    "swapped_held": int, "swap_bytes_held": int, "swap_bytes_budget": int,
    "swap_rejected": int, "swap_bytes_out": int, "swap_bytes_in": int,
    # cross-shard work-stealing migrations of parked SwapEntries
    # (0 unless the pool is sharded; host bytes change owner, so these
    # are NOT counted in swap_bytes_out/in)
    "swap_migrated_out": int, "swap_migrated_in": int,
}

#: registry ``serve.shard.*`` gauges (sharded pools only; absent
#: otherwise). Per-shard keys are ``shard<i>.<suffix>`` for suffixes
#: SHARD_GAUGE_SUFFIXES, plus the pool-wide totals below. Pinned here so
#: dashboards can rely on the names; tests/test_sharded.py is the
#: regression test.
SHARD_GAUGE_SUFFIXES = (
    "live_slots", "free_slots",         # slot occupancy per shard
    "blocks_free", "blocks_used",       # block-pool levels per shard
    "swapped_held",                     # parked SwapEntries per shard
    "placed",                           # admissions placed on the shard
    "steals",                           # heads stolen TO the shard
    "queued",                           # current queue depth
)
SHARD_TOTALS: Dict[str, type] = {"num_shards": int, "steals": int}


def validate_shard_metrics(metrics: Dict[str, Any],
                           num_shards: int) -> List[str]:
    """Problems with a ``serve.shard`` provider snapshot (empty ==
    valid): every pinned per-shard gauge present for every shard, ints
    throughout, totals present."""
    schema = dict(SHARD_TOTALS)
    for s in range(num_shards):
        for suffix in SHARD_GAUGE_SUFFIXES:
            schema[f"shard{s}.{suffix}"] = int
    return validate_stats(metrics, schema)


def validate_stats(stats: Dict[str, Any],
                   schema: Dict[str, type]) -> List[str]:
    """Problems with ``stats`` against ``schema`` (empty == valid).
    ints must be real ints (bool excluded); floats accept ints too."""
    problems = []
    for key, typ in schema.items():
        if key not in stats:
            problems.append(f"missing key {key!r}")
            continue
        v = stats[key]
        if isinstance(v, bool):
            problems.append(f"{key!r} is bool, wanted {typ.__name__}")
        elif typ is float:
            if not isinstance(v, (int, float)):
                problems.append(f"{key!r} is {type(v).__name__}, "
                                f"wanted float")
        elif not isinstance(v, typ):
            problems.append(f"{key!r} is {type(v).__name__}, "
                            f"wanted {typ.__name__}")
    return problems


# -- chrome trace validation -------------------------------------------------

_PHASES = {"X", "i", "M", "C"}


def validate_chrome_trace(data: Any) -> List[str]:
    """Structural problems with a Chrome trace-event JSON object (empty
    list == valid). Checks: top-level shape, per-event required fields,
    non-negative ts/dur, counter ('C') events carrying numeric series,
    the ``otherData.dropped_events`` loss metadata (a trace whose ring
    overflowed silently is not trustworthy — the count must be present),
    and per-(pid, tid) 'X' spans that either nest properly (a span
    entirely inside another — how jit-compile sits inside
    bucket-dispatch) or are disjoint; partial overlap on one track is
    corruption."""
    problems: List[str] = []
    if not isinstance(data, dict) or "traceEvents" not in data:
        return ["top level must be a dict with 'traceEvents'"]
    evs = data["traceEvents"]
    if not isinstance(evs, list):
        return ["'traceEvents' must be a list"]
    other = data.get("otherData")
    if not isinstance(other, dict):
        problems.append("'otherData' metadata missing")
    else:
        dropped = other.get("dropped_events")
        if not isinstance(dropped, int) or isinstance(dropped, bool) \
                or dropped < 0:
            problems.append(
                f"otherData.dropped_events must be a non-negative int, "
                f"got {dropped!r}")
    spans: Dict[Any, List] = {}
    for i, e in enumerate(evs):
        if not isinstance(e, dict):
            problems.append(f"event {i}: not a dict")
            continue
        ph = e.get("ph")
        if ph not in _PHASES:
            problems.append(f"event {i}: unknown phase {ph!r}")
            continue
        if "name" not in e or "pid" not in e or "tid" not in e:
            problems.append(f"event {i}: missing name/pid/tid")
            continue
        if ph == "M":
            continue
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i} ({e['name']}): bad ts {ts!r}")
            continue
        if ph == "C":
            args = e.get("args")
            if not isinstance(args, dict) or not args or not all(
                    isinstance(v, (int, float)) and not isinstance(v, bool)
                    for v in args.values()):
                problems.append(f"event {i} ({e['name']}): counter args "
                                f"must be a non-empty numeric dict")
            continue
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i} ({e['name']}): bad dur "
                                f"{dur!r}")
                continue
            spans.setdefault((e["pid"], e["tid"]), []).append(
                (ts, ts + dur, e["name"]))
    eps = 1e-3          # µs slop for float round-trips
    for key, ss in spans.items():
        ss.sort(key=lambda s: (s[0], -s[1]))
        stack: List = []            # open span end-times
        for t0, t1, name in ss:
            while stack and t0 >= stack[-1][0] - eps:
                stack.pop()
            if stack and t1 > stack[-1][0] + eps:
                problems.append(
                    f"track {key}: span {name!r} [{t0:.1f}, {t1:.1f}] "
                    f"partially overlaps {stack[-1][1]!r} "
                    f"(ends {stack[-1][0]:.1f})")
                continue
            stack.append((t1, name))
    return problems
