"""Structured tracer: a bounded ring of typed span/instant events.

The paper reasons about where *cycles* go (sync overhead vs compute,
Fig. 7); the serving runtime needs the same story for where *ticks* go —
which slot was prefilling, decoding, swapped out or idle at every
moment. Components record events through a context-manager/stamp API
that compiles to a no-op when the tracer is disabled (the hot decode
loop pays one attribute check per event site), into a bounded ring
buffer (oldest events drop, ``dropped`` counts them — tracing never
OOMs a long serve).

Event kinds (``name`` on a ``track``):

  scheduler track  — ``decode-tick``, ``prefill-chunk`` spans; ``admit``
                     instants
  slot<N> tracks   — per-request phase spans ``prefill`` / ``decode``
                     (args carry the rid) bracketed by ``admit`` /
                     ``retire`` / ``preempt`` / ``swap-out`` /
                     ``swap-in`` instants
  dispatcher track — ``bucket-dispatch`` spans, ``jit-compile`` spans
                     (recorded by ``instrumented_jit`` wrappers)

Exporters:

  * ``export_jsonl``  — one event dict per line (grep/pandas-friendly).
  * ``export_chrome`` — Chrome trace-event JSON: open chrome://tracing
    or https://ui.perfetto.dev and drop the file in. One thread (track)
    per slot plus scheduler/dispatcher threads, named and sorted.

``get_tracer()`` returns the process-wide tracer (disabled by default);
benchmarks/examples enable tracing by installing their own with
``set_tracer(Tracer(enabled=True))`` or by passing a Tracer explicitly
to the component (``Scheduler(..., tracer=t)``).
"""

from __future__ import annotations

import collections
import dataclasses
import json
import time
from typing import Any, Dict, List, Optional

from repro.obs import metrics as _metrics


@dataclasses.dataclass
class Event:
    """One trace event. ``ph`` follows the Chrome trace-event phases:
    'X' = complete span (``dur`` > 0 possible), 'i' = instant,
    'C' = counter sample (args carry the numeric series values)."""
    name: str
    track: str
    ph: str                     # 'X' | 'i' | 'C'
    ts: float                   # perf_counter seconds (span start)
    dur: float = 0.0            # seconds ('X' only)
    args: Optional[Dict[str, Any]] = None


class _Noop:
    """Shared do-nothing context manager — the disabled-tracer fast
    path allocates nothing per span."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _Noop()


class _Span:
    """Open span: records a complete event at __exit__."""

    __slots__ = ("tracer", "name", "track", "args", "t0")

    def __init__(self, tracer: "Tracer", name: str, track: str, args):
        self.tracer = tracer
        self.name = name
        self.track = track
        self.args = args
        self.t0 = 0.0

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.tracer.complete(self.name, self.track, self.t0,
                             time.perf_counter(), **(self.args or {}))
        return False


class Tracer:
    """Bounded ring buffer of Events; disabled == hard no-op."""

    def __init__(self, enabled: bool = False, capacity: int = 65536):
        self.enabled = enabled
        self.capacity = capacity
        self.events: "collections.deque[Event]" = collections.deque(
            maxlen=capacity)
        self.dropped = 0        # ring overwrites (oldest-first)

    # -- recording -------------------------------------------------------

    def _push(self, ev: Event):
        if len(self.events) == self.capacity:
            # ring overflow is data LOSS, not just recycling: count it
            # both locally (export metadata) and in the registry so a
            # sampler/SLO rule can alarm on a drop rate — a silent ring
            # overwrite would undermine every trace-derived conclusion
            self.dropped += 1
            _metrics.REGISTRY.counter("obs.trace.dropped").inc()
        self.events.append(ev)

    def span(self, name: str, track: str, **args):
        """``with tracer.span("decode-tick", "scheduler", live=3):`` —
        records a complete event at exit; the shared no-op when
        disabled."""
        if not self.enabled:
            return _NOOP
        return _Span(self, name, track, args or None)

    def instant(self, name: str, track: str, **args):
        if not self.enabled:
            return
        self._push(Event(name, track, "i", time.perf_counter(),
                         args=args or None))

    def counter(self, name: str, track: str, **values):
        """Counter sample ('C'): Perfetto renders one counter track per
        ``name`` with the numeric ``values`` series stacked — the
        sampler's live metric feeds (``tokens_per_s``, ``blocks_free``)
        next to the span tracks, so a throttling decision lines up with
        the level that triggered it."""
        if not self.enabled:
            return
        self._push(Event(name, track, "C", time.perf_counter(),
                         args={k: float(v) for k, v in values.items()}))

    def complete(self, name: str, track: str, t0: float, t1: float,
                 **args):
        """Record a span whose endpoints the caller stamped (phases that
        straddle many scheduler ticks can't use the context manager)."""
        if not self.enabled:
            return
        self._push(Event(name, track, "X", t0, max(t1 - t0, 0.0),
                         args=args or None))

    def clear(self):
        self.events.clear()
        self.dropped = 0

    # -- export ----------------------------------------------------------

    @staticmethod
    def _track_order(track: str):
        """scheduler, dispatcher, then slots in numeric order."""
        if track == "scheduler":
            return (0, 0, track)
        if track == "dispatcher":
            return (1, 0, track)
        if track.startswith("slot") and track[4:].isdigit():
            return (2, int(track[4:]), track)
        return (3, 0, track)

    def chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event JSON object (Perfetto-loadable): one pid,
        one named+sorted tid per track, ts/dur in microseconds relative
        to the first event."""
        evs = list(self.events)
        t_base = min((e.ts for e in evs), default=0.0)
        tracks = sorted({e.track for e in evs}, key=self._track_order)
        tid = {t: i for i, t in enumerate(tracks)}
        out: List[Dict[str, Any]] = []
        for t in tracks:
            out.append({"ph": "M", "pid": 1, "tid": tid[t],
                        "name": "thread_name", "args": {"name": t}})
            out.append({"ph": "M", "pid": 1, "tid": tid[t],
                        "name": "thread_sort_index",
                        "args": {"sort_index": tid[t]}})
        for e in evs:
            d: Dict[str, Any] = {"name": e.name, "ph": e.ph, "pid": 1,
                                 "tid": tid[e.track],
                                 "ts": (e.ts - t_base) * 1e6}
            if e.ph == "X":
                d["dur"] = e.dur * 1e6
            elif e.ph == "i":
                d["s"] = "t"                # instant scope: thread
            # 'C' (counter) carries its series in args, nothing extra
            if e.args:
                d["args"] = dict(e.args)
            out.append(d)
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def export_chrome(self, path: str):
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def export_jsonl(self, path: str):
        with open(path, "w") as f:
            for e in self.events:
                f.write(json.dumps({
                    "name": e.name, "track": e.track, "ph": e.ph,
                    "ts": e.ts, "dur": e.dur, "args": e.args or {}},
                    default=str) + "\n")


#: process-wide tracer, disabled by default (every event site is then a
#: single attribute check)
_TRACER = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    """Install ``tracer`` process-wide; returns the previous one."""
    global _TRACER
    prev, _TRACER = _TRACER, tracer
    return prev


# ---------------------------------------------------------------------------
# jit instrumentation: compile-vs-execute split for cached programs
# ---------------------------------------------------------------------------

def instrumented_jit(jfn, name: str, prefix: str):
    """Wrap a ``jax.jit``-ed callable: each call is timed, and a call
    that grew the function's compile cache (``_cache_size()`` — a new
    (shape, dtype) signature traced+compiled) is counted as a *compile*
    and recorded as a ``jit-compile`` span on the dispatcher track;
    steady-state calls count as cache hits.

    Registry names (under ``prefix``): ``.cache_hits``,
    ``.cache_misses`` counters; ``.compile_ms``, ``.execute_ms``
    histograms. Execute time is the *dispatch* wall (JAX dispatch is
    async; the pipeline fences later), so treat it as a lower bound.
    """
    cache_size = getattr(jfn, "_cache_size", None)
    reg = _metrics.REGISTRY
    hits = reg.counter(f"{prefix}.cache_hits")
    misses = reg.counter(f"{prefix}.cache_misses")
    h_compile = reg.histogram(f"{prefix}.compile_ms")
    h_execute = reg.histogram(f"{prefix}.execute_ms")

    def wrapper(*args, **kwargs):
        n0 = cache_size() if cache_size is not None else -1
        t0 = time.perf_counter()
        out = jfn(*args, **kwargs)
        t1 = time.perf_counter()
        if cache_size is not None and cache_size() > n0:
            misses.inc()
            h_compile.observe((t1 - t0) * 1e3)
            get_tracer().complete("jit-compile", "dispatcher", t0, t1,
                                  fn=name)
        else:
            hits.inc()
            h_execute.observe((t1 - t0) * 1e3)
        return out

    wrapper.__name__ = getattr(jfn, "__name__", name)
    wrapper.__wrapped__ = jfn
    return wrapper
