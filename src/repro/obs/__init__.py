"""repro.obs — unified observability: metrics registry + structured
tracer + the schemas that pin both.

  * metrics — Counter/Gauge/Histogram under stable dotted names
              (``serve.decode_steps``, ``paging.blocks_free``,
              ``runtime.dispatch.compile_ms``) plus weakref *providers*
              so the legacy per-component ``stats()`` dicts stay the
              source of truth and one ``REGISTRY.snapshot()`` sees the
              whole stack.
  * trace   — bounded ring buffer of typed span/instant events
              (admit / prefill-chunk / decode-tick / preempt / swap /
              retire / bucket-dispatch / jit-compile), a no-op when
              disabled, exported to JSONL or Chrome trace-event JSON
              (drop into https://ui.perfetto.dev: one track per slot
              plus scheduler/dispatcher tracks).
  * schema  — documented stats() keys/types and Chrome-trace structural
              validation (what CI gates the smoke export on).
"""

from repro.obs.metrics import (REGISTRY, Counter, Gauge, Histogram,
                               Registry, get_registry)
from repro.obs.schema import (PAGED_STATS, SCHEDULER_STATS, SLOTS_STATS,
                              validate_chrome_trace, validate_stats)
from repro.obs.trace import (Event, Tracer, get_tracer, instrumented_jit,
                             set_tracer)

__all__ = ["REGISTRY", "Counter", "Gauge", "Histogram", "Registry",
           "get_registry", "PAGED_STATS", "SCHEDULER_STATS",
           "SLOTS_STATS", "validate_chrome_trace", "validate_stats",
           "Event", "Tracer", "get_tracer", "instrumented_jit",
           "set_tracer"]
