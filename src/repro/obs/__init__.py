"""repro.obs — unified observability: metrics registry + structured
tracer + live sampling + SLO monitors + controllers, and the schemas
that pin the surface.

  * metrics — Counter/Gauge/Histogram under stable dotted names
              (``serve.decode_steps``, ``paging.blocks_free``,
              ``runtime.dispatch.compile_ms``) plus weakref *providers*
              so the legacy per-component ``stats()`` dicts stay the
              source of truth and one ``REGISTRY.snapshot()`` sees the
              whole stack.
  * trace   — bounded ring buffer of typed span/instant/counter events
              (admit / prefill-chunk / decode-tick / preempt / swap /
              retire / bucket-dispatch / jit-compile / slo-fire /
              backpressure-on / metric counter tracks), a no-op when
              disabled, exported to JSONL or Chrome trace-event JSON
              (drop into https://ui.perfetto.dev: one track per slot
              plus scheduler/dispatcher/slo/control/metrics tracks).
  * sampler — tick-driven snapshot ring over the registry: timestamped
              samples, counter rates (tokens/sec, swap bytes/sec), a
              JSONL time-series export and Perfetto counter tracks —
              live numbers, no background thread.
  * slo     — declarative rules over sampled series with hysteresis
              (N consecutive breaches to fire, M to clear), alerts as
              trace events + ``obs.slo.*`` metrics.
  * control — actuators driven by fired monitors: overload backpressure
              on the scheduler, bounded online autotune re-sweeps —
              timing/admission only, never outputs.
  * schema  — documented stats() keys/types and Chrome-trace structural
              validation (what CI gates the smoke export on).
"""

from repro.obs.control import (AutotuneController, BackpressureController,
                               build_serve_loop, dispatch_imbalance_rule)
from repro.obs.metrics import (REGISTRY, Counter, Gauge, Histogram,
                               Registry, get_registry)
from repro.obs.sampler import Sample, Sampler, get_sampler, set_sampler
from repro.obs.schema import (PAGED_STATS, SCHEDULER_STATS, SLOTS_STATS,
                              validate_chrome_trace, validate_stats)
from repro.obs.slo import Monitor, Rule, SLOManager, default_serve_rules
from repro.obs.trace import (Event, Tracer, get_tracer, instrumented_jit,
                             set_tracer)

__all__ = ["REGISTRY", "Counter", "Gauge", "Histogram", "Registry",
           "get_registry", "PAGED_STATS", "SCHEDULER_STATS",
           "SLOTS_STATS", "validate_chrome_trace", "validate_stats",
           "Event", "Tracer", "get_tracer", "instrumented_jit",
           "set_tracer", "Sample", "Sampler", "get_sampler",
           "set_sampler", "Monitor", "Rule", "SLOManager",
           "default_serve_rules", "AutotuneController",
           "BackpressureController", "build_serve_loop",
           "dispatch_imbalance_rule"]
