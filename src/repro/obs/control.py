"""Controllers: pluggable actuators closing the observe -> decide -> act
loop that the sampler + SLO monitors open.

The paper's Squire workers react to shared-resource state at fine grain
instead of being statically scheduled; these controllers give the serving
layer the same reflexes. Each subscribes to an :class:`~repro.obs.slo.
SLOManager` and actuates on alert transitions — and every actuation is
itself observable: a trace instant on the ``control`` track plus
``obs.control.*`` registry counters, so a Perfetto open shows *why* the
scheduler throttled, right next to the SLO alert and the queue levels
that caused it.

Invariant (enforced by the forced-overload differential in
``tests/test_obs_loop.py``): controllers may change **timing and
admission only**, never outputs — under greedy sampling the token
streams with a controller engaged are bit-identical to the uncontrolled
run. Both actuators below satisfy it by construction: capping
admissions only delays FCFS admission, and flipping the preempt policy
toward swap is the PR-4 bit-identical resume path.

  * :class:`BackpressureController` — overload reflex: while the
    queue-wait SLO fires, cap admissions per scheduler tick and prefer
    swap-preemption (preserve work when the pool thrashes); restore the
    configured FCFS behavior when the alert clears.
  * :class:`AutotuneController` — online tuning: a sustained
    compile-vs-execute imbalance on a dispatch bucket triggers a bounded
    ``Autotuner.retune`` re-sweep of that bucket's knob, applied only on
    measured improvement (never a regression by construction).
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterable, List, Optional

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.obs.slo import Rule


class _ControllerBase:
    def __init__(self, registry: Optional[_metrics.Registry],
                 tracer: Optional[_trace.Tracer]):
        self.registry = registry if registry is not None \
            else _metrics.REGISTRY
        self._tracer = tracer

    @property
    def tracer(self) -> _trace.Tracer:
        return self._tracer if self._tracer is not None \
            else _trace.get_tracer()


class BackpressureController(_ControllerBase):
    """Cap admissions / flip preempt policy while an SLO alert fires.

    Binds to a live :class:`~repro.serve.scheduler.Scheduler` and one
    rule name (default ``'queue_wait'``). On fire it saves the
    scheduler's knobs, installs ``admit_cap`` admissions-per-tick and
    (when the scheduler can swap) a ``'swap'`` preempt override; on
    clear it restores exactly what it saved — the configured behavior
    returns the moment the breach ends.
    """

    def __init__(self, scheduler, rule_name: str = "queue_wait",
                 admit_cap: int = 1, preempt: Optional[str] = "swap",
                 registry: Optional[_metrics.Registry] = None,
                 tracer: Optional[_trace.Tracer] = None):
        super().__init__(registry, tracer)
        if admit_cap < 1:
            raise ValueError("admit_cap must be >= 1 (0 would starve "
                             "the pool and break the progress guarantee)")
        self.scheduler = scheduler
        self.rule_name = rule_name
        self.admit_cap = admit_cap
        self.preempt = preempt
        self.engaged = False
        self._saved = None
        self.registry.counter("obs.control.backpressure.engaged")
        self.registry.counter("obs.control.backpressure.released")
        self.registry.gauge("obs.control.backpressure.active").set(0)

    def on_fire(self, rule: Rule, value: float):
        if rule.name != self.rule_name or self.engaged:
            return
        sched = self.scheduler
        self._saved = (sched.admit_cap, sched.preempt_override)
        sched.admit_cap = self.admit_cap
        # only actuate the preempt flip where swap exists (paged pools);
        # the override is a no-op on contiguous backings anyway but keep
        # the recorded actuation honest
        if self.preempt is not None and sched.slots.paged:
            sched.preempt_override = self.preempt
        self.engaged = True
        self.registry.counter("obs.control.backpressure.engaged").inc()
        self.registry.gauge("obs.control.backpressure.active").set(1)
        self.tracer.instant("backpressure-on", "control", rule=rule.name,
                            value=round(value, 6),
                            admit_cap=self.admit_cap,
                            preempt=sched.preempt_policy)

    def on_clear(self, rule: Rule, value: float):
        if rule.name != self.rule_name or not self.engaged:
            return
        sched = self.scheduler
        sched.admit_cap, sched.preempt_override = self._saved
        self._saved = None
        self.engaged = False
        self.registry.counter("obs.control.backpressure.released").inc()
        self.registry.gauge("obs.control.backpressure.active").set(0)
        self.tracer.instant("backpressure-off", "control", rule=rule.name,
                            value=round(value, 6))


class AutotuneController(_ControllerBase):
    """Bounded online re-sweep of one knob when its bucket's
    compile-vs-execute split goes out of balance.

    ``apply(best_value)`` is the caller's installer (e.g. rebuild a
    ServiceConfig); it runs only when :meth:`~repro.runtime.autotune.
    Autotuner.retune` measured a genuine improvement over the incumbent.
    ``cooldown_s`` rate-limits re-sweeps — a persistent breach must not
    burn the serve's cycles re-measuring every sample.
    """

    def __init__(self, tuner, key: str, candidates,
                 make_thunk: Callable[[Any], Callable[[], Any]],
                 apply: Optional[Callable[[Any], None]] = None,
                 rule_name: str = "dispatch_imbalance",
                 cooldown_s: float = 30.0,
                 registry: Optional[_metrics.Registry] = None,
                 tracer: Optional[_trace.Tracer] = None):
        super().__init__(registry, tracer)
        self.tuner = tuner
        self.key = key
        self.candidates = candidates
        self.make_thunk = make_thunk
        self.apply = apply
        self.rule_name = rule_name
        self.cooldown_s = cooldown_s
        self._last_sweep: Optional[float] = None
        self.resweeps = 0
        self.applied = 0
        self.registry.counter("obs.control.autotune.resweeps")
        self.registry.counter("obs.control.autotune.applied")

    def on_fire(self, rule: Rule, value: float):
        if rule.name != self.rule_name:
            return
        now = time.perf_counter()
        if self._last_sweep is not None and \
                now - self._last_sweep < self.cooldown_s:
            return
        self._last_sweep = now
        t0 = time.perf_counter()
        best, improved = self.tuner.retune(self.key, self.candidates,
                                           self.make_thunk)
        self.resweeps += 1
        self.registry.counter("obs.control.autotune.resweeps").inc()
        if improved:
            self.applied += 1
            self.registry.counter("obs.control.autotune.applied").inc()
            if self.apply is not None:
                self.apply(best)
        self.tracer.complete("autotune-resweep", "control", t0,
                             time.perf_counter(), key=self.key,
                             best=str(best), applied=improved,
                             trigger=round(value, 6))

    def on_clear(self, rule: Rule, value: float):
        pass                    # nothing to undo: retune never regresses


def dispatch_imbalance_rule(bucket_key: str, ratio: float = 1.0,
                            min_execute_ms: float = 1.0,
                            fire_after: int = 2, clear_after: int = 2
                            ) -> Rule:
    """Rule for the AutotuneController: fire when a dispatch bucket's
    cumulative compile wall exceeds ``ratio`` x its execute wall (the
    bucket keeps paying compiles instead of amortizing them — the knob
    choice is wrong for the traffic). ``bucket_key`` is the
    ``runtime.dispatch.bucket`` name, e.g. ``'run[b32]'``; samples where
    the bucket has executed under ``min_execute_ms`` are skipped (no
    signal yet)."""
    c_key = f"runtime.dispatch.bucket.{bucket_key}.compile_ms"
    e_key = f"runtime.dispatch.bucket.{bucket_key}.execute_ms"

    def balance(values: Dict[str, float], rates: Dict[str, float]
                ) -> Optional[float]:
        execute = values.get(e_key, 0.0)
        if execute < min_execute_ms:
            return None
        return values.get(c_key, 0.0) / execute

    return Rule("dispatch_imbalance", op="<=", threshold=ratio,
                value_fn=balance, fire_after=fire_after,
                clear_after=clear_after)


# ---------------------------------------------------------------------------
# one-call wiring: sampler + monitors + backpressure on a scheduler
# ---------------------------------------------------------------------------

def build_serve_loop(scheduler, rules: Optional[List[Rule]] = None,
                     controllers: Optional[Iterable[Any]] = None,
                     sampler_kw: Optional[Dict[str, Any]] = None,
                     install: bool = True, **rule_kw):
    """Wire the standard closed loop onto a scheduler: a Sampler ticking
    off ``Scheduler.step``, the default serve rules (``rule_kw``
    forwards thresholds to :func:`~repro.obs.slo.default_serve_rules`),
    and a :class:`BackpressureController`. Returns ``(sampler, slo,
    controllers)``; with ``install=True`` the sampler is installed
    process-wide (undo with ``set_sampler(prev)`` — the previous sampler
    is NOT returned here, use ``repro.obs.sampler.set_sampler``
    directly for nesting)."""
    from repro.obs import sampler as _sampler
    from repro.obs.slo import SLOManager, default_serve_rules

    if rules is None:
        rules = default_serve_rules(**rule_kw)
    smp = _sampler.Sampler(**(sampler_kw or {}))
    slo = SLOManager(rules)
    if controllers is None:
        controllers = [BackpressureController(scheduler)]
    for c in controllers:
        slo.subscribe(c)
    smp.add_listener(slo.on_sample)
    if install:
        _sampler.set_sampler(smp)
    return smp, slo, list(controllers)
