"""Live metric sampling: periodic ``REGISTRY.snapshot()`` into a bounded
ring of timestamped samples, with counter rates derived between ticks.

PR 6 made every number *readable* post-mortem; this module makes them
consumable **while the system runs** — the paper's low-latency
worker<->shared-resource feedback (Squire cores polling L2 state) applied
one level up: the scheduler and dispatcher poll their own registry and
feed SLO monitors (``repro.obs.slo``) and controllers
(``repro.obs.control``) on the same tick that did the work.

Design constraints, in order:

  * **No background thread.** Sampling is *tick-driven*: the scheduler's
    ``step()``, the kernel service's ``submit()`` and the dispatcher's
    ``run()`` call the module-level :func:`tick` hook, which is a single
    global load + ``None`` check when no sampler is installed (the same
    disabled-cost discipline as the tracer). An optional wall-clock mode
    rate-limits samples to ``min_interval_s`` for long serves.
  * **Bounded memory.** Samples live in a ring (``capacity`` deep);
    steady-state rates survive ring eviction because they only need the
    previous sample.
  * **Counter-reset tolerance.** Registry providers re-register per
    component instance (a benchmark churns through Schedulers), so a
    counter can *decrease* between samples. A negative delta means reset,
    not negative traffic — the rate for that key is skipped for that
    sample (Prometheus counter semantics).

Each :class:`Sample` carries the numeric snapshot (``values``) and the
per-second deltas vs the previous sample (``rates`` — tokens/sec, swap
bytes/sec, compile events/sec...). Listeners (the SLO manager) run
synchronously on every new sample; ``export_jsonl`` writes the ring as a
time-series next to the Chrome trace, and ``counter_tracks`` mirrors
chosen series into the tracer as Perfetto counter ('C') events so the
levels line up with the span tracks in one UI.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace


@dataclasses.dataclass
class Sample:
    """One timestamped registry snapshot.

    ``values`` is the numeric subset of ``Registry.snapshot()`` (strings
    dropped — rules index numbers). ``rates`` maps the same keys to
    per-second deltas vs the previous sample; keys whose delta was
    negative (provider re-registration reset the counter) are absent.
    """
    t: float                    # perf_counter stamp
    tick: int                   # ticks seen when this sample was taken
    values: Dict[str, float]
    rates: Dict[str, float]


class Sampler:
    """Tick-driven snapshot ring + rate derivation + listeners."""

    def __init__(self, registry: Optional[_metrics.Registry] = None,
                 every_ticks: int = 1, min_interval_s: float = 0.0,
                 wall_clock: bool = False, capacity: int = 1024,
                 tracer: Optional[_trace.Tracer] = None,
                 counter_tracks: Sequence[Tuple[str, str]] = ()):
        """``every_ticks``: sample every N-th tick (tick mode).
        ``wall_clock=True``: ignore tick counts and sample whenever
        ``min_interval_s`` wall time has passed since the last sample
        (``min_interval_s`` also lower-bounds tick mode when set).
        ``counter_tracks``: ``(key, 'value'|'rate')`` pairs mirrored into
        the tracer as Perfetto counter events on the ``metrics`` track.
        """
        if every_ticks < 1:
            raise ValueError("every_ticks must be >= 1")
        self.registry = registry if registry is not None \
            else _metrics.REGISTRY
        self.every_ticks = every_ticks
        self.min_interval_s = min_interval_s
        self.wall_clock = wall_clock
        self.samples: "collections.deque[Sample]" = collections.deque(
            maxlen=capacity)
        self._tracer = tracer
        self.counter_tracks = tuple(counter_tracks)
        self._listeners: List[Callable[[Sample], None]] = []
        self.ticks = 0
        self.sample_count = 0           # monotonic (ring may evict)
        self._last_t: Optional[float] = None
        self._last_tick = 0
        self._prev: Optional[Sample] = None
        self._sampling = False          # re-entrancy guard

    @property
    def tracer(self) -> _trace.Tracer:
        return self._tracer if self._tracer is not None \
            else _trace.get_tracer()

    def add_listener(self, fn: Callable[[Sample], None]):
        """``fn(sample)`` runs synchronously after every new sample (the
        SLO manager's entry point)."""
        self._listeners.append(fn)

    # -- tick / sample ---------------------------------------------------

    def tick(self, source: str = "") -> Optional[Sample]:
        """One unit of work happened (a scheduler step, a bulk submit);
        take a sample if the cadence says so. Returns the new sample or
        None."""
        self.ticks += 1
        now = time.perf_counter()
        if self._last_t is not None:
            if now - self._last_t < self.min_interval_s:
                return None
            if not self.wall_clock and \
                    self.ticks - self._last_tick < self.every_ticks:
                return None
        return self.sample(now)

    def sample(self, now: Optional[float] = None) -> Optional[Sample]:
        """Snapshot unconditionally (ticks aside). Re-entrant calls are
        dropped: a listener that triggers more work (an autotune re-sweep
        dispatching kernels) must not recurse into sampling."""
        if self._sampling:
            return None
        self._sampling = True
        try:
            now = time.perf_counter() if now is None else now
            values = {k: float(v)
                      for k, v in self.registry.snapshot().items()
                      if isinstance(v, (int, float))
                      and not isinstance(v, bool)}
            rates: Dict[str, float] = {}
            prev = self._prev
            if prev is not None and now > prev.t:
                dt = now - prev.t
                for k, v in values.items():
                    v0 = prev.values.get(k)
                    if v0 is not None and v >= v0:
                        rates[k] = (v - v0) / dt
            s = Sample(t=now, tick=self.ticks, values=values, rates=rates)
            self.samples.append(s)
            self.sample_count += 1
            self._prev = s
            self._last_t = now
            self._last_tick = self.ticks
            self._emit_counter_tracks(s)
            for fn in self._listeners:
                fn(s)
            return s
        finally:
            self._sampling = False

    def _emit_counter_tracks(self, s: Sample):
        tr = self.tracer
        if not tr.enabled or not self.counter_tracks:
            return
        for key, mode in self.counter_tracks:
            src = s.rates if mode == "rate" else s.values
            v = src.get(key)
            if v is not None:
                tr.counter(f"{key}/s" if mode == "rate" else key,
                           "metrics", value=v)

    # -- reading the series ----------------------------------------------

    def series(self, key: str, source: str = "value"
               ) -> List[Tuple[float, float]]:
        """``[(t, v)]`` for one key over the retained ring
        (``source='rate'`` reads the derived per-second series)."""
        out = []
        for s in self.samples:
            v = (s.rates if source == "rate" else s.values).get(key)
            if v is not None:
                out.append((s.t, v))
        return out

    def steady_rate(self, key: str, skip: int = 1) -> Optional[float]:
        """Overall per-second rate of a counter between sample ``skip``
        (warmup excluded) and the last retained sample — the steady-state
        number bench_history folds into BENCH_*.json. None when fewer
        than two usable samples or on counter reset."""
        ss = list(self.samples)
        if len(ss) <= skip + 1:
            return None
        a, b = ss[skip], ss[-1]
        va, vb = a.values.get(key), b.values.get(key)
        if va is None or vb is None or vb < va or b.t <= a.t:
            return None
        return (vb - va) / (b.t - a.t)

    # -- export ----------------------------------------------------------

    def export_jsonl(self, path: str):
        """One sample per line: ``{"t", "tick", "values", "rates"}`` —
        the grep/pandas-friendly time-series next to the Chrome trace."""
        with open(path, "w") as f:
            for s in self.samples:
                f.write(json.dumps(
                    {"t": s.t, "tick": s.tick, "values": s.values,
                     "rates": s.rates}, sort_keys=True) + "\n")

    def metrics(self) -> Dict[str, Any]:
        """Registry ``obs.sampler`` provider (the sampler observes
        itself: sample cadence drift is an observability failure too)."""
        return {"ticks": self.ticks, "samples": self.sample_count,
                "retained": len(self.samples)}


# ---------------------------------------------------------------------------
# process-wide hook: components tick the installed sampler, if any
# ---------------------------------------------------------------------------

_SAMPLER: Optional[Sampler] = None


def get_sampler() -> Optional[Sampler]:
    return _SAMPLER


def set_sampler(sampler: Optional[Sampler]) -> Optional[Sampler]:
    """Install ``sampler`` process-wide (None uninstalls); returns the
    previous one. Registers it as the registry's ``obs.sampler``
    provider so snapshots include the sampler's own cadence counters."""
    global _SAMPLER
    prev, _SAMPLER = _SAMPLER, sampler
    if sampler is not None:
        sampler.registry.register_provider("obs.sampler", sampler)
    return prev


def tick(source: str = ""):
    """The hot-path hook (Scheduler.step / KernelService.submit /
    Dispatcher.run): one global load + None check when no sampler is
    installed."""
    s = _SAMPLER
    if s is not None:
        s.tick(source)
