"""Declarative SLO monitors with hysteresis over sampled metric series.

A :class:`Rule` states an objective over the live registry — ``serve.
ttft_ms.p95 < 500``, ``serve.queue_head_wait_s < 0.25``, a useful-
occupancy floor, a ``paging.swap_rejected`` rate ceiling — and a
:class:`Monitor` tracks it with hysteresis: ``fire_after`` *consecutive*
breaching samples to raise the alert, ``clear_after`` consecutive
conforming samples to clear it. Hysteresis is what makes the alert
*actionable*: a single noisy sample must neither throttle the scheduler
nor flap it back.

The :class:`SLOManager` is a sampler listener (``sampler.add_listener
(mgr.on_sample)``): each new :class:`~repro.obs.sampler.Sample` is
evaluated against every rule, and transitions emit

  * structured trace events — ``slo-fire`` / ``slo-clear`` instants on
    the ``slo`` track (a Perfetto open shows the alert next to the
    scheduler spans that caused it), and
  * registry metrics under ``obs.slo.<rule>.*`` — ``firing`` gauge
    (0/1), ``fired`` / ``cleared`` counters, ``breaches`` counter — so
    alerts are themselves sampled series.

Controllers (``repro.obs.control``) subscribe for ``on_fire(rule,
value)`` / ``on_clear(rule, value)`` callbacks; the manager guarantees
fire/clear strictly alternate per rule.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.obs.sampler import Sample

#: objective comparators: the SLO HOLDS when ``op(value, threshold)``
_OPS: Dict[str, Callable[[float, float], bool]] = {
    "<": lambda v, t: v < t,
    "<=": lambda v, t: v <= t,
    ">": lambda v, t: v > t,
    ">=": lambda v, t: v >= t,
}


@dataclasses.dataclass(frozen=True)
class Rule:
    """One declarative objective: ``<key> <op> <threshold>`` must hold.

    ``source`` picks the series: ``'value'`` reads the sampled level,
    ``'rate'`` the derived per-second delta (``swap_rejected`` rate).
    ``value_fn`` is the escape hatch for computed series (e.g. a
    compile-vs-execute ratio over two keys) — it receives ``(values,
    rates)`` and returns the number to test, or None to skip the sample
    (no hysteresis state change). A missing ``key`` likewise skips.
    """
    name: str
    key: str = ""
    op: str = "<"
    threshold: float = 0.0
    source: str = "value"               # 'value' | 'rate'
    fire_after: int = 3                 # N consecutive breaches to fire
    clear_after: int = 2                # M consecutive OKs to clear
    value_fn: Optional[Callable[[Dict[str, float], Dict[str, float]],
                                Optional[float]]] = None

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"rule {self.name!r}: op {self.op!r} not in "
                             f"{sorted(_OPS)}")
        if self.source not in ("value", "rate"):
            raise ValueError(f"rule {self.name!r}: source {self.source!r}")
        if self.fire_after < 1 or self.clear_after < 1:
            raise ValueError(f"rule {self.name!r}: fire_after/clear_after "
                             f"must be >= 1")
        if not self.key and self.value_fn is None:
            raise ValueError(f"rule {self.name!r}: need key or value_fn")

    def extract(self, values: Dict[str, float],
                rates: Dict[str, float]) -> Optional[float]:
        if self.value_fn is not None:
            return self.value_fn(values, rates)
        src = rates if self.source == "rate" else values
        return src.get(self.key)

    def holds(self, value: float) -> bool:
        return _OPS[self.op](value, self.threshold)


class Monitor:
    """Hysteresis state machine for one rule.

    Exactly-per-N/M semantics (the property test pins them): the alert
    fires on the sample completing the ``fire_after``-th *consecutive*
    breach while not firing, and clears on the sample completing the
    ``clear_after``-th consecutive OK while firing. Any conforming
    sample resets the breach streak and vice versa.
    """

    def __init__(self, rule: Rule):
        self.rule = rule
        self.firing = False
        self.breach_streak = 0
        self.ok_streak = 0
        self.last_value: Optional[float] = None

    def observe(self, value: float) -> Optional[str]:
        """Feed one sample's value; returns 'fire' | 'clear' | None."""
        self.last_value = value
        if self.rule.holds(value):
            self.ok_streak += 1
            self.breach_streak = 0
            if self.firing and self.ok_streak >= self.rule.clear_after:
                self.firing = False
                return "clear"
            return None
        self.breach_streak += 1
        self.ok_streak = 0
        if not self.firing and self.breach_streak >= self.rule.fire_after:
            self.firing = True
            return "fire"
        return None


class SLOManager:
    """Evaluate rules per sample; emit events, metrics and callbacks."""

    def __init__(self, rules: List[Rule],
                 registry: Optional[_metrics.Registry] = None,
                 tracer: Optional[_trace.Tracer] = None):
        names = [r.name for r in rules]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate rule names: {names}")
        self.monitors: Dict[str, Monitor] = {r.name: Monitor(r)
                                             for r in rules}
        self.registry = registry if registry is not None \
            else _metrics.REGISTRY
        self._tracer = tracer
        self._subscribers: List[Any] = []
        # pre-declare so the alert namespace is stable from construction
        for name in self.monitors:
            self.registry.gauge(f"obs.slo.{name}.firing").set(0)
            self.registry.counter(f"obs.slo.{name}.fired")
            self.registry.counter(f"obs.slo.{name}.cleared")
            self.registry.counter(f"obs.slo.{name}.breaches")

    @property
    def tracer(self) -> _trace.Tracer:
        return self._tracer if self._tracer is not None \
            else _trace.get_tracer()

    def subscribe(self, controller: Any):
        """``controller.on_fire(rule, value)`` / ``.on_clear(rule,
        value)`` run synchronously on transitions, in subscription
        order."""
        self._subscribers.append(controller)

    @property
    def firing(self) -> Dict[str, bool]:
        return {name: m.firing for name, m in self.monitors.items()}

    def on_sample(self, sample: Sample):
        """Sampler listener: one hysteresis step per rule."""
        self.evaluate(sample.values, sample.rates)

    def evaluate(self, values: Dict[str, float],
                 rates: Dict[str, float]) -> List[str]:
        """Feed one sample to every monitor; returns the transition
        events emitted (``'<rule>:fire'`` / ``'<rule>:clear'``)."""
        out: List[str] = []
        for name, mon in self.monitors.items():
            value = mon.rule.extract(values, rates)
            if value is None:
                continue
            if not mon.rule.holds(value):
                self.registry.counter(f"obs.slo.{name}.breaches").inc()
            transition = mon.observe(value)
            if transition is None:
                continue
            out.append(f"{name}:{transition}")
            fired = transition == "fire"
            self.registry.gauge(f"obs.slo.{name}.firing").set(
                1 if fired else 0)
            self.registry.counter(
                f"obs.slo.{name}.{'fired' if fired else 'cleared'}").inc()
            self.tracer.instant(f"slo-{transition}", "slo", rule=name,
                                key=mon.rule.key or "<fn>",
                                value=round(value, 6),
                                op=mon.rule.op,
                                threshold=mon.rule.threshold)
            for sub in self._subscribers:
                hook = getattr(sub, "on_fire" if fired else "on_clear",
                               None)
                if hook is not None:
                    hook(mon.rule, value)
        return out


# ---------------------------------------------------------------------------
# the serving defaults: the ROADMAP's SLO set, thresholds caller-tunable
# ---------------------------------------------------------------------------

def default_serve_rules(queue_wait_s: float = 0.25,
                        ttft_p95_ms: float = 2000.0,
                        itl_p95_ms: float = 500.0,
                        swap_rejected_per_s: float = 1.0,
                        occupancy_floor: float = 0.0,
                        fire_after: int = 3,
                        clear_after: int = 2) -> List[Rule]:
    """The standard serving objectives over the scheduler's registry
    namespace. ``occupancy_floor=0`` disables the floor (a drained pool
    legitimately idles at 0)."""
    rules = [
        Rule("queue_wait", key="serve.queue_head_wait_s", op="<",
             threshold=queue_wait_s, fire_after=fire_after,
             clear_after=clear_after),
        Rule("ttft_p95", key="serve.ttft_ms.p95", op="<",
             threshold=ttft_p95_ms, fire_after=fire_after,
             clear_after=clear_after),
        Rule("itl_p95", key="serve.itl_ms.p95", op="<",
             threshold=itl_p95_ms, fire_after=fire_after,
             clear_after=clear_after),
        Rule("swap_rejected", key="paging.swap_rejected", op="<",
             threshold=swap_rejected_per_s, source="rate",
             fire_after=fire_after, clear_after=clear_after),
    ]
    if occupancy_floor > 0.0:
        rules.append(Rule("occupancy_floor", key="serve.mean_occupancy",
                          op=">=", threshold=occupancy_floor,
                          fire_after=fire_after, clear_after=clear_after))
    return rules
