"""Metrics registry: counters, gauges and histograms under stable
dotted names.

The paper's whole case is made by *measurement* — per-kernel speedup,
synchronization overhead, energy split (Figs. 6-8) — and the runtime
reproduces that discipline at serving scale. Before this module every
component grew its own ``stats()`` dict with ad-hoc keys; the registry
gives them one namespace (``serve.decode_steps``,
``paging.blocks_free``, ``runtime.dispatch.compile_ms``) so benchmarks,
the autotuner and the (ROADMAP) SLO scheduler read one snapshot instead
of five dicts.

Two kinds of sources coexist:

  * owned metrics — ``registry.counter/gauge/histogram(name)`` returns a
    live object the caller mutates (the dispatcher's compile counters).
  * providers     — a component registers itself under a prefix
    (``register_provider("serve", scheduler)``) and its ``metrics()``
    method is called at snapshot time, so the legacy ``stats()`` dicts
    keep being the single source of truth and the registry is a *view*
    over them (nothing double-counts).

Providers are held by weakref: benchmarks churn through Scheduler
instances, and a dead provider silently drops out of the snapshot. A
prefix re-registered by a newer instance wins (latest-owner semantics —
exactly what a long-lived process redeploying a scheduler wants).

``REGISTRY`` is the process-wide default; components default to it so
one ``snapshot()`` sees the whole stack, but every constructor accepts a
private ``Registry`` for isolation (tests).
"""

from __future__ import annotations

import collections
import json
import os
import weakref
from typing import Any, Callable, Dict, List, Optional, Tuple


class Counter:
    """Monotonic event count (``serve.decode_steps``)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1):
        self.value += n


class Gauge:
    """Point-in-time level (``paging.blocks_free``)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v):
        self.value = v


class Histogram:
    """Distribution of observations (``runtime.dispatch.compile_ms``):
    exact count/sum/min/max plus a bounded window of the most recent
    observations for percentiles (host-side, O(window) memory)."""

    __slots__ = ("count", "total", "min", "max", "_window")

    def __init__(self, window: int = 512):
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._window: "collections.deque[float]" = collections.deque(
            maxlen=window)

    def observe(self, v: float):
        v = float(v)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self._window.append(v)

    @staticmethod
    def _pick(xs: List[float], p: float) -> float:
        i = min(int(round(p / 100.0 * (len(xs) - 1))), len(xs) - 1)
        return xs[i]

    def percentile(self, p: float) -> float:
        """p in [0, 100] over the recent window; 0.0 when empty."""
        if not self._window:
            return 0.0
        return self._pick(sorted(self._window), p)

    def summary(self) -> Dict[str, float]:
        """Flat summary. ``count``/``sum`` are the MONOTONIC lifetime
        totals (not the percentile window's): the sampler differentiates
        them into rates, and a bursty phase that blows past the window
        must still account for every observation. Percentiles (p50/p95)
        are over the recent window only (one shared sort — summary is on
        the sampler's per-sample path)."""
        if not self.count:
            return {"count": 0, "sum": 0.0, "p50": 0.0, "p95": 0.0,
                    "max": 0.0}
        xs = sorted(self._window)
        return {"count": self.count, "sum": round(self.total, 3),
                "p50": round(self._pick(xs, 50), 3),
                "p95": round(self._pick(xs, 95), 3),
                "max": round(self.max, 3)}


class Registry:
    """Get-or-create typed metrics + weakref providers, one namespace."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}
        # prefix -> (weakref to provider object, method name)
        self._providers: Dict[str, Tuple[weakref.ref, str]] = {}

    # -- owned metrics ---------------------------------------------------

    def _get(self, name: str, kind):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = kind()
        elif not isinstance(m, kind):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"asked for {kind.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    # -- providers (legacy stats() dicts as views) -----------------------

    def register_provider(self, prefix: str, obj: Any,
                          method: str = "metrics"):
        """At snapshot time call ``obj.<method>()`` (a flat dict) and
        merge it under ``<prefix>.<key>``. Weakly referenced: a dead
        provider drops out; re-registering a prefix replaces the owner."""
        self._providers[prefix] = (weakref.ref(obj), method)

    def unregister_provider(self, prefix: str):
        self._providers.pop(prefix, None)

    # -- snapshot ----------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """One flat {dotted-name: value} view over owned metrics and
        every live provider. Histograms flatten to .count/.sum/.p50/.max
        sub-keys. Deterministically sorted."""
        out: Dict[str, Any] = {}
        for name, m in self._metrics.items():
            if isinstance(m, Histogram):
                for k, v in m.summary().items():
                    out[f"{name}.{k}"] = v
            else:
                out[name] = m.value
        dead: List[str] = []
        for prefix, (ref, method) in self._providers.items():
            obj = ref()
            if obj is None:
                dead.append(prefix)
                continue
            for k, v in getattr(obj, method)().items():
                out[f"{prefix}.{k}"] = v
        for prefix in dead:
            del self._providers[prefix]
        return dict(sorted(out.items()))

    def dump_json(self, path: str):
        """Atomic snapshot dump: write to a per-pid tempfile and rename.
        Concurrent dumpers (a sweep fanned out over processes, the same
        lesson as Autotuner.save) can't clobber each other's half-written
        file, and a crash mid-write leaves any existing ``path`` intact."""
        tmp = f"{path}.{os.getpid()}.tmp"
        try:
            with open(tmp, "w") as f:
                json.dump(self.snapshot(), f, indent=1, sort_keys=True,
                          default=str)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise


#: process-wide default registry (components register into it unless
#: handed a private one)
REGISTRY = Registry()


def get_registry() -> Registry:
    return REGISTRY
