"""musicgen-large [audio]: decoder-only over EnCodec tokens.

48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048. [arXiv:2306.05284; hf]

The EnCodec frontend (and the 4-codebook interleaving) is a STUB per the
brief: input_specs() provides precomputed frame embeddings (B, S, d_model);
labels index the 2048-entry codebook vocab. Pure full attention ->
long_500k skipped.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=2048,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    input_mode="embeds",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab=64, pattern=(LayerSpec(mixer="attn"),),
        input_mode="embeds")
