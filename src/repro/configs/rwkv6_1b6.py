"""rwkv6-1.6b [ssm]: RWKV-6 "Finch" 1.6B — attention-free, data-dependent
decay. 24L d_model=2048 d_ff=7168 vocab=65536. [arXiv:2404.05892]

The WKV6 recurrence is the paper-technique core path (DESIGN.md §3.1):
chunk-parallel training (core.linear_attn.wkv_chunked) and O(1)-state
decode, which is what makes the long_500k shape runnable.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,            # d_model / rwkv_head_dim
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab=65536,
    pattern=(LayerSpec(mixer="rwkv", mlp="rwkv_ffn"),),
    rwkv_head_dim=64,
    subquadratic=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab=128,
        pattern=(LayerSpec(mixer="rwkv", mlp="rwkv_ffn"),),
        rwkv_head_dim=16, subquadratic=True)
