"""moonshot-v1-16b-a3b [moe]: Moonlight-16B-A3B (kimi).

48L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=163840, MoE 64 experts
top-6. [hf:moonshotai/Moonlight-16B-A3B; hf]

All layers MoE (the released model keeps layer 0 dense; we follow the
assignment's uniform spec and note the difference in DESIGN.md). Full
attention -> long_500k skipped.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=163840,
    pattern=(LayerSpec(mixer="attn", mlp="moe"),),
    num_experts=64,
    experts_per_token=6,
    moe_d_ff=1408,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=96, vocab=128, pattern=(LayerSpec(mixer="attn", mlp="moe"),),
        num_experts=8, experts_per_token=3, moe_d_ff=96)
