"""Architecture registry: the 10 assigned architectures."""

from __future__ import annotations

from repro.configs.base import (LayerSpec, ModelConfig, ShapeConfig, SHAPES,
                                shape_applicable)

from repro.configs import (deepseek_7b, gemma3_12b, gemma_2b,
                           jamba_v0_1_52b, llava_next_34b,
                           moonshot_v1_16b_a3b, musicgen_large, olmoe_1b_7b,
                           qwen2_5_14b, rwkv6_1b6)

_MODULES = {
    "llava-next-34b": llava_next_34b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b,
    "rwkv6-1.6b": rwkv6_1b6,
    "deepseek-7b": deepseek_7b,
    "gemma-2b": gemma_2b,
    "gemma3-12b": gemma3_12b,
    "qwen2.5-14b": qwen2_5_14b,
    "musicgen-large": musicgen_large,
    "jamba-v0.1-52b": jamba_v0_1_52b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    return _MODULES[name].CONFIG


def reduced_config(name: str) -> ModelConfig:
    return _MODULES[name].reduced()


__all__ = ["ARCH_NAMES", "LayerSpec", "ModelConfig", "SHAPES", "ShapeConfig",
           "get_config", "reduced_config", "shape_applicable"]
