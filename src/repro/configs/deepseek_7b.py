"""deepseek-7b [dense]: llama-arch. 30L d_model=4096 32H (kv=32)
d_ff=11008 vocab=102400. [arXiv:2401.02954; hf]

Pure full attention -> long_500k skipped.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab=102400,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab=128, pattern=(LayerSpec(mixer="attn"),))
