"""jamba-v0.1-52b [hybrid]: Mamba + attention 1:7 interleave, MoE.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16 experts
top-2. [arXiv:2403.19887; hf]

Period-8 pattern (Jamba block): one attention layer per 8 (position 4),
seven Mamba layers; MoE replaces the dense FFN on every other layer
(e = 16, top-2), matching the published 1:7 attn ratio and e/2 MoE ratio.
Mamba layers run on core.linear_attn.mamba_chunked (the paper-technique
core path) -> subquadratic, long_500k RUNS.
"""

from repro.configs.base import LayerSpec, ModelConfig

_M_D = LayerSpec(mixer="mamba", mlp="dense")
_M_E = LayerSpec(mixer="mamba", mlp="moe")
_A_E = LayerSpec(mixer="attn", mlp="moe")

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=65536,
    # positions 0..7; attention at 4 (1:7), MoE on odd positions (1:2)
    pattern=(_M_D, _M_E, _M_D, _M_E, LayerSpec(mixer="attn", mlp="dense"),
             _M_E, _M_D, _M_E),
    num_experts=16,
    experts_per_token=2,
    moe_d_ff=14336,
    ssm_state=16,
    ssm_expand=2,
    subquadratic=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-smoke", family="hybrid",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=96, vocab=128,
        pattern=(LayerSpec(mixer="mamba", mlp="dense"),
                 LayerSpec(mixer="mamba", mlp="moe"),
                 LayerSpec(mixer="attn", mlp="dense"),
                 LayerSpec(mixer="mamba", mlp="moe")),
        num_experts=4, experts_per_token=2, moe_d_ff=96,
        ssm_state=4, ssm_expand=2, subquadratic=True)
