"""gemma-2b [dense]: 18L d_model=2048 8H MQA (kv=1) d_ff=16384
vocab=256000. GeGLU, head_dim=256, tied + scaled embeddings.
[arXiv:2403.08295; hf]

Pure full attention -> long_500k skipped. MQA (kv=1) stresses the KV
replication path in the sharding rules.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=256000,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    act="geglu",
    tie_embeddings=True,
    scale_embed=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=1, head_dim=32,
        d_ff=128, vocab=128, pattern=(LayerSpec(mixer="attn"),),
        act="geglu", tie_embeddings=True, scale_embed=True)
