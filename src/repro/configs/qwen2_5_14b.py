"""qwen2.5-14b [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064. QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]

Pure full attention -> long_500k skipped.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab=152064,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    qkv_bias=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab=128, pattern=(LayerSpec(mixer="attn"),),
        qkv_bias=True)
