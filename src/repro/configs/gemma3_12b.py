"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144. 5:1 local:global attention, 128k context, qk-norm.
[hf:google/gemma-3-1b-pt; unverified]

Pattern period 6: five sliding-window (1024) layers at rope theta 1e4,
one global layer at theta 1e6. The 5:1 local ratio bounds the quadratic
term, so long_500k RUNS for this arch (decode over the window cache is
O(window) for 5/6 of layers; global layers are O(seq) per token, linear
in decode). The window band-mask shares the chain band machinery
conceptually (DESIGN.md §3.3).
"""

from repro.configs.base import LayerSpec, ModelConfig

_LOCAL = LayerSpec(mixer="attn", window=1024, mlp="dense", rope_theta=1e4)
_GLOBAL = LayerSpec(mixer="attn", window=0, mlp="dense", rope_theta=1e6)

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    qk_norm=True,
    tie_embeddings=True,
    scale_embed=True,
    subquadratic=True,
    remat_policy="dots",   # §Perf gemma3 iteration 6 (banded+dots)
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab=128,
        pattern=(LayerSpec(mixer="attn", window=16),
                 LayerSpec(mixer="attn", window=0, rope_theta=1e6)),
        qk_norm=True, tie_embeddings=True, scale_embed=True,
        subquadratic=True)
