"""Model / shape configuration dataclasses.

Each assigned architecture gets a `configs/<id>.py` exporting `CONFIG`
(the exact published shape) and `reduced()` (a tiny same-family config for
CPU smoke tests). The decoder is composed from a *period pattern* of
LayerSpecs — heterogeneous stacks (jamba 1:7 mamba:attn, gemma3 5:1
local:global) repeat their pattern depth/period times, and the runtime
scans over periods so HLO size stays flat in depth.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer of the period pattern."""
    mixer: str = "attn"          # attn | mamba | rwkv
    window: int = 0              # attn only; 0 = global, >0 sliding window
    mlp: str = "dense"           # dense | moe | rwkv_ffn
    rope_theta: float = 1e4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    act: str = "swiglu"          # swiglu | geglu
    qkv_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = False
    scale_embed: bool = False    # gemma-style sqrt(d_model) embed scaling
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM / RWKV
    ssm_state: int = 16
    ssm_expand: int = 2
    rwkv_head_dim: int = 64
    scan_chunk: int = 64
    # frontend: tokens (LM) or precomputed embeddings (vlm/audio stubs)
    input_mode: str = "tokens"
    # numerics / runtime
    dtype: object = jnp.bfloat16
    remat: bool = True
    remat_policy: str = "full"   # full | dots (save MXU outputs, §Perf)
    kv_block: int = 512
    # long-context applicability (pure full-attention archs skip long_500k)
    subquadratic: bool = False

    def __post_init__(self):
        assert self.num_layers % len(self.pattern) == 0, \
            (self.name, self.num_layers, len(self.pattern))

    @property
    def num_periods(self) -> int:
        return self.num_layers // len(self.pattern)

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        return self.pattern * self.num_periods


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell of the assignment."""
    name: str
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """None if the cell runs; otherwise the documented skip reason."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("pure full-attention arch: 524k-token decode needs "
                "sub-quadratic attention (DESIGN.md §3.3)")
    return None
