"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (kv=16) expert d_ff=1024
vocab=50304, MoE 64 experts top-8. [arXiv:2409.02060; hf]

Every layer's FFN is MoE (OLMoE uses no dense layers). Full attention ->
long_500k skipped.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab=50304,
    pattern=(LayerSpec(mixer="attn", mlp="moe"),),
    num_experts=64,
    experts_per_token=8,
    moe_d_ff=1024,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=96, vocab=128, pattern=(LayerSpec(mixer="attn", mlp="moe"),),
        num_experts=8, experts_per_token=2, moe_d_ff=96)
