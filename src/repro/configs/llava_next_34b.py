"""llava-next-34b [vlm]: dense transformer backbone of LLaVA-NeXT-34B.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The anyres image frontend is a STUB per the brief: input_specs() provides
precomputed patch embeddings (B, S, d_model); the backbone trains/serves
over them. Pure full attention -> long_500k is skipped.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64000,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    input_mode="embeds",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab=128, pattern=(LayerSpec(mixer="attn"),),
        input_mode="embeds")
