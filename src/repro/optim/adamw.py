"""AdamW with warmup+cosine schedule and global-norm clipping — pure JAX.

Optimizer state shards exactly like the parameters (ZeRO-3): the mu/nu
trees reuse the params' NamedShardings, so optimizer memory is
2 x params / num_devices.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: AdamWConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree_util.tree_leaves(grads))
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def adamw_update(grads, opt_state, params, cfg: AdamWConfig
                 ) -> Tuple[dict, dict, jnp.ndarray]:
    """Returns (new_params, new_opt_state, lr). All trees share structure."""
    count = opt_state["count"] + 1
    lr = lr_at(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        p32 = p.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p32
        return (p32 - lr * step).astype(p.dtype), m, v

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["mu"])
    flat_v = jax.tree_util.tree_leaves(opt_state["nu"])
    flat_p = jax.tree_util.tree_leaves(params)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        np_, nm, nv = upd(g, m, v, p)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    unf = lambda leaves: jax.tree_util.tree_unflatten(tdef, leaves)
    return unf(new_p), {"mu": unf(new_m), "nu": unf(new_v),
                        "count": count}, lr
