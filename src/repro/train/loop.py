"""Fault-tolerant training loop: checkpoint/restart, stragglers, elasticity.

The loop composes the pure train step (train.step) with the runtime
concerns a 1000-node job actually has:

  * **checkpoint/restart** — async step-atomic snapshots every
    ``ckpt_every`` steps (train.checkpoint); on start the loop resumes
    from the newest complete checkpoint automatically.
  * **straggler mitigation** — a wall-clock watchdog keeps a robust EMA of
    step time; steps slower than ``straggler_factor``× the EMA are counted
    and reported (on real pods this signal feeds the re-scheduler; here it
    drives the `on_straggler` hook + tests inject delays to exercise it).
  * **failure handling / elasticity** — any exception from the step
    triggers ``elastic_restart``: rebuild a (possibly smaller) mesh from
    the surviving device count, re-jit against it, restore the last
    checkpoint *onto the new mesh* (checkpoints are mesh-agnostic), and
    continue. ``FailureInjector`` simulates device loss for tests.
  * **data determinism** — batches are pure functions of the step index
    (data.lm), so restart/elastic paths replay the exact stream with no
    cursor state.

The loop is deliberately host-driven and synchronous-dispatch: one jitted
step per iteration, metrics fetched every ``log_every`` (fetching forces a
sync; keeping it sparse preserves dispatch pipelining).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

import jax

from repro.configs.base import ModelConfig
from repro.optim import AdamWConfig
from repro.train import step as step_lib
from repro.train.checkpoint import Checkpointer


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    keep_ckpts: int = 3
    straggler_factor: float = 3.0
    straggler_warmup: int = 5      # steps before the EMA is trusted
    ema_beta: float = 0.9
    max_restarts: int = 3


class StragglerWatchdog:
    """Robust step-time EMA + slow-step detector (the mitigation signal)."""

    def __init__(self, cfg: LoopConfig):
        self.cfg = cfg
        self.ema: Optional[float] = None
        self.n = 0
        self.events: List[Dict[str, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n == 1:
            return False        # first step is compile time; never seed EMA
        if self.ema is None:
            self.ema = dt
            return False
        slow = (self.n > self.cfg.straggler_warmup
                and dt > self.cfg.straggler_factor * self.ema)
        if slow:
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
        else:
            # stragglers are excluded from the EMA (robustness)
            b = self.cfg.ema_beta
            self.ema = b * self.ema + (1 - b) * dt
        return slow


class FailureInjector:
    """Deterministic failure schedule for tests/examples.

    ``fail_at``: steps at which the injected exception fires (once each).
    """

    def __init__(self, fail_at=(), exc_factory=None):
        self.pending = set(fail_at)
        self.exc_factory = exc_factory or (
            lambda s: RuntimeError(f"injected device failure at step {s}"))

    def maybe_fail(self, step: int):
        if step in self.pending:
            self.pending.discard(step)
            raise self.exc_factory(step)


@dataclasses.dataclass
class TrainResult:
    final_step: int
    metrics_history: List[Dict[str, float]]
    straggler_events: List[Dict[str, float]]
    restarts: int
    losses: List[float]


def _jit_step(cfg: ModelConfig, opt_cfg: AdamWConfig, mesh,
              state_shapes, compress: bool):
    fn = step_lib.make_train_step(cfg, opt_cfg, compress=compress)
    if mesh is None:
        return jax.jit(fn, donate_argnums=(0,))
    state_sh = step_lib.state_shardings(state_shapes, mesh)
    return jax.jit(fn, in_shardings=(state_sh, None),
                   out_shardings=(state_sh, None), donate_argnums=(0,))


def train(cfg: ModelConfig,
          batch_fn: Callable[[int], Dict[str, Any]],
          loop_cfg: LoopConfig = LoopConfig(),
          opt_cfg: AdamWConfig = AdamWConfig(),
          ckpt_dir: Optional[str] = None,
          mesh=None,
          seed: int = 0,
          compress: bool = False,
          failure_injector: Optional[FailureInjector] = None,
          make_mesh_after_failure: Optional[Callable[[int], Any]] = None,
          on_straggler: Optional[Callable[[int, float], None]] = None,
          verbose: bool = True) -> TrainResult:
    """Run the loop; returns the metric history (losses fetched to host)."""
    ckpt = Checkpointer(ckpt_dir, keep=loop_cfg.keep_ckpts) \
        if ckpt_dir else None

    key = jax.random.PRNGKey(seed)
    state = step_lib.init_train_state(key, cfg, compress=compress)
    state_shapes = jax.eval_shape(lambda: state)
    step_fn = _jit_step(cfg, opt_cfg, mesh, state_shapes, compress)

    start = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        shardings = (step_lib.state_shardings(state_shapes, mesh)
                     if mesh is not None else None)
        state, extra = ckpt.restore(state_shapes, shardings=shardings)
        start = int(extra.get("next_step", ckpt.latest_step()))
        if verbose:
            print(f"[loop] resumed from checkpoint at step {start}")

    watchdog = StragglerWatchdog(loop_cfg)
    history: List[Dict[str, float]] = []
    losses: List[float] = []
    restarts = 0
    i = start
    while i < loop_cfg.total_steps:
        t0 = time.time()
        try:
            if failure_injector is not None:
                failure_injector.maybe_fail(i)
            batch = batch_fn(i)
            state, metrics = step_fn(state, batch)
        except Exception as e:  # noqa: BLE001 — any step failure
            if restarts >= loop_cfg.max_restarts or ckpt is None:
                raise
            restarts += 1
            if verbose:
                print(f"[loop] step {i} failed ({e}); elastic restart "
                      f"#{restarts}")
            if make_mesh_after_failure is not None:
                mesh = make_mesh_after_failure(restarts)
            # re-jit against the (new) mesh and restore the newest snapshot
            step_fn = _jit_step(cfg, opt_cfg, mesh, state_shapes, compress)
            shardings = (step_lib.state_shardings(state_shapes, mesh)
                         if mesh is not None else None)
            if ckpt.latest_step() is not None:
                state, extra = ckpt.restore(state_shapes,
                                            shardings=shardings)
                i = int(extra.get("next_step", ckpt.latest_step()))
            else:
                key = jax.random.PRNGKey(seed)
                state = step_lib.init_train_state(key, cfg,
                                                  compress=compress)
                i = 0
            continue

        if (i + 1) % loop_cfg.log_every == 0 or i + 1 == loop_cfg.total_steps:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            history.append(m)
            losses.append(m["loss"])
            if verbose:
                print(f"[loop] step {i:5d} loss={m['loss']:.4f} "
                      f"lr={m['lr']:.2e} gnorm={m['grad_norm']:.2f}")
        dt = time.time() - t0
        if watchdog.observe(i, dt) and on_straggler is not None:
            on_straggler(i, dt)

        i += 1
        if ckpt is not None and i % loop_cfg.ckpt_every == 0:
            ckpt.save_async(i, state, extra={"next_step": i})

    if ckpt is not None:
        ckpt.save(loop_cfg.total_steps, state,
                  extra={"next_step": loop_cfg.total_steps})
    return TrainResult(final_step=i, metrics_history=history,
                       straggler_events=watchdog.events, restarts=restarts,
                       losses=losses)
