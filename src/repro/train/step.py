"""Training step: loss -> grad -> clip -> (optional compression) -> AdamW.

The step is a pure function over a TrainState pytree; launchers jit it with
NamedShardings derived from the logical rule table (sharding.partition) and
donate the state. Gradient int8 compression with error feedback
(train.grad_compress) is an optional all-reduce transform, off by default
(a §Perf lever for collective-bound cells).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_update, clip_by_global_norm, \
    init_opt_state
from repro.sharding import make_param_shardings, named_sharding
from repro.train import grad_compress as gc


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jnp.ndarray            # int32 scalar
    ef: Any = None               # error-feedback residuals (compression)


def init_train_state(key, cfg: ModelConfig,
                     compress: bool = False) -> TrainState:
    params = T.init_model(key, cfg)
    ef = jax.tree.map(jnp.zeros_like, params) if compress else None
    return TrainState(params=params, opt=init_opt_state(params),
                      step=jnp.zeros((), jnp.int32), ef=ef)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    compress: bool = False, accum_steps: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    batch: {"tokens"| "embeds", "labels", optional "mask"}.

    ``accum_steps > 1``: gradient accumulation — the batch is split into
    microbatches scanned sequentially, dividing peak activation memory by
    ``accum_steps`` at the cost of serializing the microbatch forwards.
    This is the production knob for the cells whose dry-run
    ``temp_size_in_bytes`` exceeds HBM (EXPERIMENTS.md §Dry-run note);
    results match the single-pass step up to fp reassociation (tested).
    """

    def loss_for(params, mb):
        logits, aux, _ = T.apply_model(
            params, cfg, tokens=mb.get("tokens"),
            embeds=mb.get("embeds"), mode="train")
        loss, metrics = T.lm_loss(logits, mb["labels"], mb.get("mask"))
        return loss + aux, (metrics, aux)

    def grads_single(params, batch):
        return jax.value_and_grad(loss_for, has_aux=True)(params, batch)

    def grads_accum(params, batch):
        def split(x):
            b = x.shape[0]
            assert b % accum_steps == 0, (b, accum_steps)
            return x.reshape((accum_steps, b // accum_steps) + x.shape[1:])

        micro = {k: split(v) for k, v in batch.items()}
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, mb):
            (l, (mets, aux)), g = grads_single(params, mb)
            acc = jax.tree.map(
                lambda a, gi: a + gi.astype(jnp.float32) / accum_steps,
                acc, g)
            return acc, (l, mets, aux)

        grads, (ls, mets, auxs) = jax.lax.scan(body, zeros, micro)
        metrics = jax.tree.map(jnp.mean, mets)
        return (jnp.mean(ls), (metrics, jnp.mean(auxs))), grads

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        fn = grads_single if accum_steps <= 1 else grads_accum
        (loss, (metrics, aux)), grads = fn(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.clip_norm)

        ef = state.ef
        if compress:
            grads, ef = gc.compress_decompress(grads, ef)

        new_params, new_opt, lr = adamw_update(grads, state.opt,
                                               state.params, opt_cfg)
        new_state = TrainState(params=new_params, opt=new_opt,
                               step=state.step + 1, ef=ef)
        metrics = dict(metrics, loss=loss, aux=aux, grad_norm=gnorm, lr=lr)
        return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# sharding helpers for launchers
# ---------------------------------------------------------------------------

def state_shardings(state_shapes: TrainState, mesh) -> TrainState:
    """NamedShardings for a TrainState (from its eval_shape pytree)."""
    p_sh = make_param_shardings(state_shapes.params, mesh)
    mu_sh = make_param_shardings(state_shapes.opt["mu"], mesh)
    nu_sh = make_param_shardings(state_shapes.opt["nu"], mesh)
    rep = named_sharding((), ())
    ef_sh = (make_param_shardings(state_shapes.ef, mesh)
             if state_shapes.ef is not None else None)
    return TrainState(params=p_sh,
                      opt={"mu": mu_sh, "nu": nu_sh, "count": rep},
                      step=rep, ef=ef_sh)


def batch_shardings(cfg: ModelConfig, batch_shapes: Dict[str, Any]):
    out = {}
    for k, v in batch_shapes.items():
        names: tuple
        if k == "embeds":
            names = ("batch", "seq", None)
        else:                       # tokens / labels / mask: (B, S)
            names = ("batch", "seq")
        out[k] = named_sharding(v.shape, names)
    return out
