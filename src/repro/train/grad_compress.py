"""int8 gradient compression with error feedback.

Distributed-optimization trick for collective-bound steps: gradients are
quantized to int8 (per-leaf absmax scale) before the data-parallel
all-reduce; the quantization error is fed back into the next step's
gradient (error feedback keeps SGD/Adam convergence, 1-bit-Adam style).

Under GSPMD we cannot literally intercept the all-reduce; instead the
quantize->dequantize pair is inserted on the gradient values, which lets
XLA all-reduce the int8 representation when profitable and — crucially for
this repo — models the accuracy contract so convergence tests can assert
training still works with compression on.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, error_feedback):
    """Apply int8 Q->DQ with error feedback. Returns (grads, new_ef)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
        q, s = quantize_int8(g32)
        dq = dequantize_int8(q, s)
        return dq.astype(g.dtype), (g32 - dq).astype(e.dtype)

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error_feedback)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        ng, ne = one(g, e)
        out_g.append(ng)
        out_e.append(ne)
    unf = lambda leaves: jax.tree_util.tree_unflatten(tdef, leaves)
    return unf(out_g), unf(out_e)
