from repro.train.step import (TrainState, init_train_state, make_train_step,
                              batch_shardings, state_shardings)
from repro.train.checkpoint import Checkpointer
from repro.train.loop import (FailureInjector, LoopConfig, StragglerWatchdog,
                              TrainResult, train)

__all__ = ["TrainState", "init_train_state", "make_train_step",
           "batch_shardings", "state_shardings", "Checkpointer",
           "FailureInjector", "LoopConfig", "StragglerWatchdog",
           "TrainResult", "train"]
