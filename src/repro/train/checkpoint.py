"""Step-atomic sharded checkpointing with an async writer — no orbax.

Layout (one directory per step):

    <root>/step_00000042/
        MANIFEST.json        # treedef, leaf paths/shapes/dtypes, metadata
        leaf_00000.npy ...   # one .npy per pytree leaf (host-gathered)

Atomicity: everything is written into ``step_N.tmp`` and the directory is
renamed to ``step_N`` only after an fsync'd manifest — a crash mid-write
leaves a ``.tmp`` that restore ignores and the next save garbage-collects.
This is the step-atomic contract a 1000-node job needs: the newest
complete directory is always a consistent (params, opt, step) snapshot.

Elasticity: leaves are saved as full (host-replicated) arrays and restored
with ``jax.device_put(value, sharding)`` against whatever mesh the *new*
job built — a 512-chip checkpoint restores onto 256 chips (or 1 CPU
device) unchanged, which is the elastic re-mesh path
(train.loop.elastic_restart, tested in tests/test_fault_tolerance.py).

Async: ``save_async`` snapshots to host memory synchronously (cheap) and
does the file I/O on a daemon thread, overlapping the write with the next
training steps; ``wait()`` joins before the next save or shutdown.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, List, Optional, Tuple

import numpy as np

import jax

PREFIX = "step_"
TMP_SUFFIX = ".tmp"


# ---------------------------------------------------------------------------
# pytree <-> leaf list with stable paths
# ---------------------------------------------------------------------------

def _flatten(tree) -> Tuple[List[str], List[Any], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in kp) for kp, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves, treedef


def _host_value(x) -> np.ndarray:
    """Fully-addressable host copy of a (possibly sharded) array."""
    if isinstance(x, jax.Array) and not x.is_fully_addressable:
        x = jax.experimental.multihost_utils.process_allgather(x)
    return np.asarray(x)


# np.save round-trips ml_dtypes (bfloat16, fp8) as raw void types that
# numpy cannot reload; store them bit-cast to a same-width integer and
# restore via the manifest dtype.
_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _to_savable(v: np.ndarray) -> np.ndarray:
    alt = _BITCAST.get(str(v.dtype))
    return v.view(alt) if alt is not None else v


def _from_saved(v: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str in _BITCAST:
        import ml_dtypes
        return v.view(np.dtype(getattr(ml_dtypes, dtype_str)))
    return v


# ---------------------------------------------------------------------------
# save
# ---------------------------------------------------------------------------

def _write_dir(root: Path, step: int, paths: List[str],
               host_leaves: List[np.ndarray], extra: dict) -> Path:
    final = root / f"{PREFIX}{step:08d}"
    tmp = Path(str(final) + TMP_SUFFIX)
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    manifest = {"step": step, "time": time.time(), "extra": extra,
                "leaves": []}
    for i, (p, v) in enumerate(zip(paths, host_leaves)):
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, _to_savable(v))
        manifest["leaves"].append(
            {"path": p, "file": fname, "shape": list(v.shape),
             "dtype": str(v.dtype)})
    mf = tmp / "MANIFEST.json"
    mf.write_text(json.dumps(manifest))
    fd = os.open(mf, os.O_RDONLY)
    os.fsync(fd)
    os.close(fd)
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class Checkpointer:
    """Async, step-atomic checkpointer with retention-based GC."""

    def __init__(self, root: os.PathLike, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, extra: Optional[dict] = None) -> Path:
        """Synchronous save (used at shutdown / in tests)."""
        self.wait()
        paths, leaves, _ = _flatten(tree)
        host = [_host_value(l) for l in leaves]
        out = _write_dir(self.root, step, paths, host, extra or {})
        self._gc()
        return out

    def save_async(self, step: int, tree, extra: Optional[dict] = None):
        """Snapshot to host now; write files on a daemon thread."""
        self.wait()
        paths, leaves, _ = _flatten(tree)
        host = [_host_value(l) for l in leaves]     # sync device->host copy

        def work():
            try:
                _write_dir(self.root, step, paths, host, extra or {})
                self._gc()
            except BaseException as e:  # surfaced by wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore --------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        steps = sorted(self._complete_steps())
        return steps[-1] if steps else None

    def restore(self, like_tree, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, dict]:
        """Restore into the structure of ``like_tree``.

        ``shardings``: optional matching pytree of NamedShardings (or a
        callable path->sharding); leaves are device_put against it — this
        is where a checkpoint re-shards onto a different mesh.
        Returns (tree, extra_metadata).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {self.root}")
        d = self.root / f"{PREFIX}{step:08d}"
        manifest = json.loads((d / "MANIFEST.json").read_text())

        by_path = {e["path"]: e for e in manifest["leaves"]}
        paths, leaves, treedef = _flatten(like_tree)
        shard_leaves = (jax.tree_util.tree_leaves(shardings)
                        if shardings is not None
                        and not callable(shardings) else None)
        out = []
        for i, (p, like) in enumerate(zip(paths, leaves)):
            e = by_path.get(p)
            if e is None:
                raise KeyError(f"checkpoint {d} missing leaf {p!r}")
            v = _from_saved(np.load(d / e["file"]), e["dtype"])
            want_shape = tuple(getattr(like, "shape", v.shape))
            if tuple(v.shape) != want_shape:
                raise ValueError(
                    f"leaf {p!r}: checkpoint shape {v.shape} != "
                    f"model shape {want_shape}")
            if callable(shardings):
                sh = shardings(p)
            elif shard_leaves is not None:
                sh = shard_leaves[i]
            else:
                sh = None
            out.append(jax.device_put(v, sh) if sh is not None
                       else jax.numpy.asarray(v))
        return (jax.tree_util.tree_unflatten(treedef, out),
                manifest.get("extra", {}))

    # -- util ---------------------------------------------------------------

    def _complete_steps(self) -> List[int]:
        out = []
        for d in self.root.iterdir():
            if (d.name.startswith(PREFIX) and not d.name.endswith(TMP_SUFFIX)
                    and (d / "MANIFEST.json").exists()):
                out.append(int(d.name[len(PREFIX):]))
        return out

    def _gc(self):
        # drop orphaned tmp dirs and checkpoints beyond the retention window
        for d in self.root.iterdir():
            if d.name.endswith(TMP_SUFFIX):
                mtime = d.stat().st_mtime
                if time.time() - mtime > 60:
                    shutil.rmtree(d, ignore_errors=True)
        steps = sorted(self._complete_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.root / f"{PREFIX}{s:08d}",
                          ignore_errors=True)
