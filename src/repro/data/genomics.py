"""Synthetic genomics inputs mirroring the paper's datasets (Table IV).

The paper evaluates on five long-read datasets with distinct sequencing
profiles; real FASTQ data is not shippable here, so we generate references
and reads with matching *statistical* profiles (length scale, error rate,
error mix). Lengths are scaled down ~10x so CPU wall-clock stays sane; the
relative behaviour across profiles (the paper's point: high-accuracy PBHF
inputs shift work from align to seed/chain) is preserved.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ReadProfile:
    name: str
    mean_len: int       # scaled-down from Table IV
    std_len: int
    accuracy: float     # per-base identity
    # error mix (fractions of errors): substitutions, insertions, deletions
    mix: Tuple[float, float, float] = (0.5, 0.25, 0.25)


# Table IV, lengths /10, accuracies as published.
PROFILES: List[ReadProfile] = [
    ReadProfile("ONT", 1771, 600, 0.85),
    ReadProfile("PBCLR", 674, 250, 0.88),
    ReadProfile("PBHF1", 1286, 400, 0.9999),
    ReadProfile("PBHF2", 1560, 450, 0.9999),
    ReadProfile("PBHF3", 1415, 420, 0.9999),
]
PROFILE_BY_NAME = {p.name: p for p in PROFILES}


def make_reference(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, 4, n).astype(np.int8)


def mutate(read: np.ndarray, accuracy: float, mix, rng) -> np.ndarray:
    """Apply sequencing errors; returns the errored read (variable length)."""
    err = rng.random(len(read)) > accuracy
    kinds = rng.choice(3, size=len(read), p=list(mix))
    out = []
    for base, e, kind in zip(read, err, kinds):
        if not e:
            out.append(base)
        elif kind == 0:                                  # substitution
            out.append((base + rng.integers(1, 4)) % 4)
        elif kind == 1:                                  # insertion
            out.append(base)
            out.append(rng.integers(0, 4))
        # kind == 2: deletion -> emit nothing
    return np.asarray(out, dtype=np.int8)


def sample_reads(ref: np.ndarray, profile: ReadProfile, n_reads: int,
                 seed: int = 1):
    """Sample reads from the reference with the profile's error process.

    Returns list of (read, true_start) pairs.
    """
    rng = np.random.default_rng(seed)
    reads = []
    for _ in range(n_reads):
        ln = int(np.clip(rng.normal(profile.mean_len, profile.std_len),
                         200, len(ref) // 2))
        start = int(rng.integers(0, len(ref) - ln))
        clean = ref[start:start + ln]
        reads.append((mutate(clean, profile.accuracy, profile.mix, rng),
                      start))
    return reads


def anchor_set(n: int, seed: int = 0, noise: int = 40,
               n_segments: int = 4, decoy_frac: float = 0.3
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Synthetic sorted anchor arrays for standalone chain benchmarks
    (Table III: ~53k anchors per input). Anchors fall on a few collinear
    segments plus a floor of decoy (repeat-hit) anchors interleaved in
    reference order — the decoys push true predecessors deeper into the
    band, which is what makes the T-truncation claim non-trivial."""
    rng = np.random.default_rng(seed)
    n_decoy = int(n * decoy_frac)
    n_real = n - n_decoy
    qs, rs = [], []
    per = max(n_real // n_segments, 1)
    for s in range(n_segments):
        q0 = rng.integers(0, 20_000)
        r0 = rng.integers(0, 1_000_000)
        q = np.sort(q0 + rng.integers(0, 8_000, per))
        r = r0 + (q - q0) + rng.integers(-noise, noise, per)
        qs.append(q)
        rs.append(r)
    if n_decoy:
        # decoys scatter across the same reference span (repeat hits)
        r_all = np.concatenate(rs)
        qd = rng.integers(0, 28_000, n_decoy)
        rd = rng.integers(int(r_all.min()), int(r_all.max()) + 1, n_decoy)
        qs.append(qd)
        rs.append(rd)
    q = np.concatenate(qs).astype(np.int32)
    r = np.concatenate(rs).astype(np.int32)
    order = np.argsort(r, kind="stable")
    return q[order], r[order]
