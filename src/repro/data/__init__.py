from repro.data import genomics, lm  # noqa: F401
from repro.data.lm import DataConfig, TokenStream  # noqa: F401
