"""Deterministic synthetic LM data pipeline.

Production shape without shipping a corpus: an order-2 Markov token source
with Zipfian emission tables, generated *statelessly* from (seed, step,
shard) — any batch is reproducible from its coordinates alone, which is
what makes checkpoint-resume and elastic re-sharding exact (the stream has
no cursor files; a restarted job replays from `step` with any host count).

The source has real structure (low-order entropy well below log V), so the
example trainers show a genuinely decreasing loss, and a fixed held-out
slice gives an eval metric.

API mirrors a real pipeline:
  * ``TokenStream(cfg).batch(step) -> {"tokens", "labels", "mask"}``
  * per-host sharding: ``TokenStream(..., shard=(i, n))`` yields the i-th
    of n disjoint substreams (what multi-host data loading does).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    branch: int = 8          # candidate successors per Markov state
    order: int = 1           # 1: state = prev token (learnable bigrams);
                             # 2: state = hash(prev2, prev1) (harder)
    n_states: int = 0        # 0 = vocab (order 1) / 4096 (order 2)
    eval_batches: int = 4    # held-out slice (steps < 0)

    @property
    def states(self) -> int:
        if self.n_states:
            return self.n_states
        return self.vocab if self.order == 1 else 4096


class TokenStream:
    """Stateless batched token source; batch(step) is pure in (cfg, step)."""

    def __init__(self, cfg: DataConfig, shard: Tuple[int, int] = (0, 1)):
        self.cfg = cfg
        self.shard = shard
        root = np.random.default_rng(cfg.seed)
        # per-state successor tables: (states, branch) token candidates
        self._succ = root.integers(
            0, cfg.vocab, (cfg.states, cfg.branch)).astype(np.int64)
        # Zipf-ish choice distribution over the branch slots
        w = 1.0 / np.arange(1, cfg.branch + 1) ** 1.2
        self._pw = (w / w.sum()).astype(np.float64)

    def _state(self, prev2: np.ndarray, prev1: np.ndarray) -> np.ndarray:
        if self.cfg.order == 1:
            return prev1 % self.cfg.states
        h = prev2 * np.int64(1000003) + prev1 * np.int64(10007) + 12345
        return (h ^ (h >> 7)) % self.cfg.states

    def _gen_tokens(self, rng: np.random.Generator, rows: int) -> np.ndarray:
        cfg = self.cfg
        length = cfg.seq_len + 1                     # +1 for the label shift
        toks = np.zeros((rows, length), np.int64)
        toks[:, 0] = rng.integers(0, cfg.vocab, rows)
        toks[:, 1] = rng.integers(0, cfg.vocab, rows)
        choices = rng.choice(cfg.branch, size=(rows, length), p=self._pw)
        for t in range(2, length):
            st = self._state(toks[:, t - 2], toks[:, t - 1])
            toks[:, t] = self._succ[st, choices[:, t]]
        return toks

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        """Batch for global step ``step`` (>=0 train; <0 held-out eval)."""
        cfg = self.cfg
        i, n = self.shard
        rows = cfg.batch // n
        assert rows * n == cfg.batch, (cfg.batch, n)
        # disjoint substream per (step, shard)
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + (step + 1_000_000) * 613 + i) % 2**63)
        toks = self._gen_tokens(rng, rows)
        return {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
            "mask": jnp.ones((rows, cfg.seq_len), jnp.float32),
        }

    def eval_batches(self):
        for b in range(self.cfg.eval_batches):
            yield self.batch(-(b + 1))


def bigram_entropy_estimate(cfg: DataConfig, n_samples: int = 20000) -> float:
    """Monte-Carlo estimate of the source's conditional entropy (nats).

    A perfectly learned model reaches this loss floor; tests assert training
    moves from ~log(V) toward it.
    """
    stream = TokenStream(cfg)
    p = stream._pw
    # entropy of the choice distribution, adjusted for duplicate successors
    rng = np.random.default_rng(0)
    states = rng.integers(0, cfg.states, n_samples)
    ent = 0.0
    for s in states:
        succ = stream._succ[s]
        probs: Dict[int, float] = {}
        for tok, w in zip(succ, p):
            probs[tok] = probs.get(tok, 0.0) + w
        ent += -sum(v * np.log(v) for v in probs.values())
    return float(ent / n_samples)
