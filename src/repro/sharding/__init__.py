from repro.sharding.partition import (BASELINE_RULES, configure,
                                      current_mesh, current_rules, logical,
                                      make_param_shardings, named_sharding,
                                      param_spec, resolve_axes,
                                      rules_overridden, shard_act,
                                      spec)  # noqa: F401
