"""Logical-axis sharding rules -> PartitionSpecs (MaxText-style).

Model code never names mesh axes; it annotates arrays with *logical* axis
names ("batch", "seq", "experts", ...). A rule table maps logical names to
mesh axes, filtered against the active mesh so the same model code runs on
(data, model), (pod, data, model), or a single device (all rules drop out).

The rule table is the primary hillclimb lever (EXPERIMENTS.md §Perf):
overriding e.g. {"seq": None, "heads": "model"} flips the whole network
from sequence-parallel to megatron tensor-parallel without touching model
code.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Union[None, str, Tuple[str, ...]]]

# Baseline: FSDP(+pod) over 'data', sequence parallelism over 'model',
# experts / SSM channels / cache head_dim over 'model'. DESIGN.md §3.2.
BASELINE_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": "model",          # activation sequence axis (attention/MLP)
    "d_model": None,
    "heads": None,
    "head_dim": None,
    "ffn": None,
    "vocab": None,           # logits vocab axis
    "kv_seq": None,
    # SSM blocks reshard: channels/heads parallel, sequence replicated
    "ssm_seq": None,
    "ssm_heads": "model",
    "ssm_fold": ("pod", "data", "model"),   # folded (batch*heads) axis
    "ssm_channels": "model",
    "ssm_state": None,
    # MoE
    "experts": "model",
    "expert_capacity": None,
    # KV cache (decode)
    "cache_batch": ("pod", "data"),
    "cache_seq": None,
    "cache_kv_heads": None,
    "cache_head_dim": "model",
    # parameter sharding (by position for 2D+ params)
    "param_dim0": "data",
    "param_dim1": "model",
    "param_experts": "model",
}


class _State(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Rules = dict(BASELINE_RULES)


_STATE = _State()


def configure(mesh: Optional[Mesh], overrides: Optional[Rules] = None):
    """Install the active mesh + rule overrides (call from launchers)."""
    _STATE.mesh = mesh
    _STATE.rules = dict(BASELINE_RULES)
    if overrides:
        _STATE.rules.update(overrides)


@contextlib.contextmanager
def rules_overridden(overrides: Rules):
    old_rules, old_mesh = dict(_STATE.rules), _STATE.mesh
    _STATE.rules.update(overrides)
    try:
        yield
    finally:
        _STATE.rules, _STATE.mesh = old_rules, old_mesh


def current_rules() -> Rules:
    return dict(_STATE.rules)


def current_mesh() -> Optional[Mesh]:
    return _STATE.mesh


def _mesh_axes() -> Tuple[str, ...]:
    if _STATE.mesh is not None:
        return tuple(_STATE.mesh.axis_names)
    return ()


def _resolve(name: Optional[str]):
    """logical name -> mesh axis (or tuple), dropping absent mesh axes."""
    if name is None:
        return None
    val = _STATE.rules.get(name, None)
    if val is None:
        return None
    axes = _mesh_axes()
    if isinstance(val, str):
        return val if val in axes else None
    got = tuple(a for a in val if a in axes)
    return got if got else None


def logical(*names: Optional[str]) -> P:
    """PartitionSpec from logical axis names (None = replicated dim)."""
    return P(*[_resolve(n) for n in names])


def spec(*names: Optional[str]) -> P:
    return logical(*names)


def resolve_axes(shape: Tuple[int, ...], names: Sequence[Optional[str]]) -> P:
    """Logical names -> PartitionSpec with divisibility guard.

    Dims the resolved mesh axes don't divide fall back to replicated (e.g.
    batch=1 long-context decode can't batch-shard; the rule silently drops;
    tuples degrade to the single largest dividing axis).
    """
    assert len(shape) == len(names), (shape, names)
    mesh = _STATE.mesh
    sizes = (dict(zip(mesh.axis_names, mesh.devices.shape))
             if mesh is not None else {})
    entries = []
    for dim, n in zip(shape, names):
        e = _resolve(n)
        if e is not None:
            axes = (e,) if isinstance(e, str) else tuple(e)
            ax_size = 1
            for a in axes:
                ax_size *= sizes.get(a, 1)
            if dim % max(ax_size, 1):
                # try partial: single axis from a tuple
                e = None
                for a in axes:
                    if dim % sizes.get(a, 1) == 0 and sizes.get(a, 1) > 1:
                        e = a
                        break
        entries.append(e)
    return P(*_dedupe(entries))


def _dedupe(entries):
    """A mesh axis may appear in at most one positional dim; keep first."""
    seen = set()
    out = []
    for e in entries:
        axes = () if e is None else ((e,) if isinstance(e, str) else tuple(e))
        if any(a in seen for a in axes):
            kept = tuple(a for a in axes if a not in seen)
            e = (kept[0] if len(kept) == 1 else (kept or None)) \
                if kept else None
        axes = () if e is None else ((e,) if isinstance(e, str) else tuple(e))
        seen.update(axes)
        out.append(e)
    return out


def named_sharding(shape: Tuple[int, ...],
                   names: Sequence[Optional[str]]) -> NamedSharding:
    """NamedSharding for an input/output array, by logical names."""
    assert _STATE.mesh is not None, "configure(mesh) first"
    return NamedSharding(_STATE.mesh, resolve_axes(shape, names))


def shard_act(x, *names: Optional[str]):
    """with_sharding_constraint by logical names; no-op without a mesh."""
    if _STATE.mesh is None or _STATE.mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_STATE.mesh, resolve_axes(x.shape, names)))


def param_spec(path: str, shape: Tuple[int, ...],
               stacked: bool = False) -> P:
    """Positional parameter sharding (ZeRO-3-ish).

    2D+ params: dim0 -> param_dim0 rule, dim1 -> param_dim1; expert-stacked
    params put 'experts' on their leading expert dim. 1D params replicate.
    `stacked`: a leading layer-period axis (from scan-over-layers) is
    replicated and the positional rules shift right by one.
    """
    lead: list = [None] if stacked else []
    dims = shape[len(lead):]
    if "expert" in path and len(dims) >= 3:
        # experts take the 'model' axis; dims shard over 'data' only
        names = ["param_experts", "param_dim0", None]
        names += [None] * (len(dims) - 3)
    elif len(dims) >= 2:
        names = ["param_dim0", "param_dim1"] + [None] * (len(dims) - 2)
    else:
        names = [None] * len(dims)
    entries = [None] * len(lead) + [_resolve(n) for n in names]
    # never shard a dim the mesh axis doesn't divide
    mesh = _STATE.mesh
    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        full = [1] * len(lead) + list(dims)
        for i, e in enumerate(entries):
            if e is None:
                continue
            axes = (e,) if isinstance(e, str) else tuple(e)
            ax_size = 1
            for a in axes:
                ax_size *= sizes.get(a, 1)
            if full[i] % max(ax_size, 1):
                entries[i] = None
    return P(*_dedupe(entries))


def make_param_shardings(params, mesh: Mesh, stacked_paths=()):
    """NamedShardings for a parameter pytree (path-aware)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for keypath, leaf in flat:
        path = "/".join(str(k) for k in keypath)
        stacked = any(sp in path for sp in stacked_paths) \
            if stacked_paths else "blocks" in path
        out.append(NamedSharding(
            mesh, param_spec(path, leaf.shape, stacked=stacked)))
    return jax.tree_util.tree_unflatten(treedef, out)
