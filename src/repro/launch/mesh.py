"""Production meshes.

Functions, not module-level constants — importing this module never
touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count before any jax init; the
smoke tests see the single real CPU device).

Production topology (TPU v5e target):
  * single pod: (data=16, model=16) = 256 chips,
  * multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is
    the DCN-connected dimension — only data parallelism (gradient
    all-reduce) crosses it, never tensor/expert collectives.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

import jax
from jax.sharding import Mesh


def make_worker_mesh(num_workers: Optional[int] = None,
                     axis: str = "workers") -> Mesh:
    """1-D mesh over the first ``num_workers`` local devices (default all).

    Canonical home of the worker-mesh constructor
    (``runtime.dispatch.make_worker_mesh`` re-exports it). Raises
    ``ValueError`` up front when more workers are requested than devices
    exist — the alternative is an opaque shard_map shape error deep
    inside the first dispatch.
    """
    devs = jax.devices()
    if num_workers is None:
        n = len(devs)
    else:
        if num_workers < 1:
            raise ValueError(f"num_workers must be >= 1, got {num_workers}")
        if num_workers > len(devs):
            raise ValueError(
                f"requested {num_workers} workers but only {len(devs)} "
                f"device(s) are available; set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{num_workers} before importing jax to force host devices")
        n = num_workers
    return Mesh(np.asarray(devs[:n]), (axis,))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(*, multi_pod: bool = False):
    """Tiny mesh for CI-style tests (8 forced host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
