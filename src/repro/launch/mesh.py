"""Production meshes.

Functions, not module-level constants — importing this module never
touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count before any jax init; the
smoke tests see the single real CPU device).

Production topology (TPU v5e target):
  * single pod: (data=16, model=16) = 256 chips,
  * multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is
    the DCN-connected dimension — only data parallelism (gradient
    all-reduce) crosses it, never tensor/expert collectives.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(*, multi_pod: bool = False):
    """Tiny mesh for CI-style tests (8 forced host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
