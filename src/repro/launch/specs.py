"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

`input_specs(cfg, shape)` returns the batch for a (arch x shape) cell:
  * train_*    — {"tokens"|"embeds", "labels"} at (global_batch, seq)
  * prefill_*  — {"tokens"|"embeds"}
  * decode_* / long_* — one new token + the full-context cache specs

Modality frontends are stubs per the brief: [vlm]/[audio] archs receive
precomputed patch/frame embeddings (B, S, d_model) instead of token ids.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T

SDS = jax.ShapeDtypeStruct


def _tokens_or_embeds(cfg: ModelConfig, b: int, s: int) -> Dict[str, Any]:
    if cfg.input_mode == "embeds":
        return {"embeds": SDS((b, s, cfg.d_model), jnp.bfloat16)}
    return {"tokens": SDS((b, s), jnp.int32)}


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = _tokens_or_embeds(cfg, b, s)
        batch["labels"] = SDS((b, s), jnp.int32)
        return {"batch": batch}
    if shape.kind == "prefill":
        return {"batch": _tokens_or_embeds(cfg, b, s)}
    if shape.kind == "decode":
        caches = jax.eval_shape(lambda: T.init_caches(cfg, b, s))
        return {"caches": caches,
                "inp": _tokens_or_embeds(cfg, b, 1),
                "pos": SDS((), jnp.int32)}
    raise ValueError(shape.kind)


def params_specs(cfg: ModelConfig) -> Any:
    key = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    return jax.eval_shape(lambda k: T.init_model(k, cfg), key)


def train_state_specs(cfg: ModelConfig) -> Any:
    from repro.train.step import init_train_state
    key = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    return jax.eval_shape(lambda k: init_train_state(k, cfg), key)
