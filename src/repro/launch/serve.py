"""Serving launcher: batched prefill + decode over static-shape caches.

Runs a reduced (or full, on real hardware) config through the serve
engine: a batch of prompts is prefilled once, then decoded token-by-token
— the decode loop is the 1-D dependency-bound recurrence of serving
(DESIGN.md: the global-counter pattern at request scale). SSM/hybrid archs
decode with O(1) state; attention archs with ring-buffer KV caches.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache-slots", type=int, default=0,
                    help="KV slots (0 = prompt+gen)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.models import transformer as T
    from repro.serve import engine

    cfg = (configs.reduced_config(args.arch) if args.reduced
           else configs.get_config(args.arch))
    slots = args.cache_slots or (args.prompt_len + args.gen)

    key = jax.random.PRNGKey(args.seed)
    kp, kt, ks = jax.random.split(key, 3)
    params = T.init_model(kp, cfg)

    b, s = args.batch, args.prompt_len
    if cfg.input_mode == "embeds":
        batch = {"embeds": jax.random.normal(kt, (b, s, cfg.d_model),
                                             jnp.bfloat16)}
        step_inp = lambda tok: {"embeds": jax.random.normal(
            jax.random.fold_in(ks, 0), (b, 1, cfg.d_model), jnp.bfloat16)}
    else:
        batch = {"tokens": jax.random.randint(kt, (b, s), 0, cfg.vocab)}
        step_inp = lambda tok: {"tokens": tok[:, None]}

    prefill = jax.jit(engine.make_prefill_step(cfg, cache_slots=slots))
    decode = jax.jit(engine.make_decode_step(cfg, args.temperature))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    tok = engine.sample_token(logits)

    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.asarray(s + i, jnp.int32)
        tok, logits, caches = decode(params, caches, step_inp(tok), pos)
        out_tokens.append(tok)
    jax.block_until_ready(out_tokens[-1])
    t_decode = time.time() - t0

    gen = jnp.stack(out_tokens, axis=1)
    print(f"[serve] arch={cfg.name} batch={b} prompt={s} gen={args.gen}")
    print(f"[serve] prefill: {t_prefill*1e3:.1f} ms "
          f"({b*s/max(t_prefill,1e-9):.0f} tok/s)")
    print(f"[serve] decode:  {t_decode*1e3:.1f} ms "
          f"({b*(args.gen-1)/max(t_decode,1e-9):.1f} tok/s)")
    print(f"[serve] sample row 0: {gen[0].tolist()}")
    assert bool(jnp.all(jnp.isfinite(logits))), "non-finite logits"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
