import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS_EXTRA", ""))
# The two lines above MUST run before any jax-importing module: jax locks
# the device count on first init. Everything below is a normal module.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh, constructs the jitted
train / prefill / decode step with NamedShardings from the logical rule
table, lowers it against ShapeDtypeStruct inputs (no allocation), compiles
it, and records memory_analysis() / cost_analysis() / roofline terms into
experiments/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
  python -m repro.launch.dryrun --all --mesh single
  python -m repro.launch.dryrun --all --mesh multi --force
"""

import argparse
import json
import math
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import ModelConfig, ShapeConfig, shape_applicable
from repro.launch import mesh as mesh_lib, roofline, specs
from repro.models import transformer as T
from repro.optim import AdamWConfig
from repro.serve import engine
from repro.sharding import configure, make_param_shardings, named_sharding
from repro.train import step as train_step_lib

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _tree_param_count(tree) -> int:
    return int(sum(math.prod(l.shape)
                   for l in jax.tree_util.tree_leaves(tree)))


def _active_param_count(tree, cfg: ModelConfig) -> int:
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        p = "/".join(str(k) for k in path)
        n = math.prod(leaf.shape)
        if "expert_" in p and cfg.num_experts:
            n = n * cfg.experts_per_token // cfg.num_experts
        total += n
    return int(total)


def _replicated_tree(shapes):
    rep = named_sharding((), ())
    return jax.tree.map(lambda _: rep, shapes)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Returns (jitted_fn, example_args, tokens_per_step, kind)."""
    ins = specs.input_specs(cfg, shape)

    if shape.kind == "train":
        state_shapes = specs.train_state_specs(cfg)
        state_sh = train_step_lib.state_shardings(state_shapes, mesh)
        batch_sh = train_step_lib.batch_shardings(cfg, ins["batch"])
        fn = train_step_lib.make_train_step(cfg, AdamWConfig())
        out_shapes = jax.eval_shape(fn, state_shapes, ins["batch"])
        out_sh = (state_sh, _replicated_tree(out_shapes[1]))
        jfn = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                      out_shardings=out_sh, donate_argnums=(0,))
        return jfn, (state_shapes, ins["batch"]), \
            shape.global_batch * shape.seq_len, "train"

    params_shapes = specs.params_specs(cfg)
    params_sh = make_param_shardings(params_shapes, mesh)

    if shape.kind == "prefill":
        fn = engine.make_prefill_step(cfg, cache_slots=shape.seq_len)
        batch_sh = train_step_lib.batch_shardings(cfg, ins["batch"])
        out_shapes = jax.eval_shape(fn, params_shapes, ins["batch"])
        logits_sh = named_sharding(out_shapes[0].shape,
                                   ("batch", None, "vocab"))
        cache_sh = engine.cache_shardings(cfg, out_shapes[1])
        jfn = jax.jit(fn, in_shardings=(params_sh, batch_sh),
                      out_shardings=(logits_sh, cache_sh))
        return jfn, (params_shapes, ins["batch"]), \
            shape.global_batch * shape.seq_len, "prefill"

    # decode
    fn = engine.make_decode_step(cfg)
    cache_sh = engine.cache_shardings(cfg, ins["caches"])
    inp_sh = {k: named_sharding(v.shape, ("cache_batch",) + (None,) *
                                (len(v.shape) - 1))
              for k, v in ins["inp"].items()}
    out_shapes = jax.eval_shape(fn, params_shapes, ins["caches"],
                                ins["inp"], ins["pos"])
    nxt_sh = named_sharding(out_shapes[0].shape, ("cache_batch",))
    logits_sh = named_sharding(out_shapes[1].shape,
                               ("cache_batch", None, "vocab"))
    jfn = jax.jit(fn, in_shardings=(params_sh, cache_sh, inp_sh,
                                    named_sharding((), ())),
                  out_shardings=(nxt_sh, logits_sh, cache_sh),
                  donate_argnums=(1,))
    return jfn, (params_shapes, ins["caches"], ins["inp"], ins["pos"]), \
        shape.global_batch, "decode"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path = OUT_DIR, verbose: bool = True,
             rule_overrides: dict | None = None,
             cfg_patch: dict | None = None, tag: str = "") -> dict:
    """Lower+compile one cell.

    ``rule_overrides``: sharding-rule table overrides (the §Perf lever).
    ``cfg_patch``: dataclasses.replace fields on the ModelConfig.
    ``tag``: suffix for the output json (perf experiments don't clobber
    baselines).
    """
    import dataclasses as _dc
    cfg = configs.get_config(arch)
    if cfg_patch:
        cfg = _dc.replace(cfg, **cfg_patch)
    shape = configs.SHAPES[shape_name]
    mesh_name = ("multi" if multi_pod else "single") + \
        (f"__{tag}" if tag else "")
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "rule_overrides": rule_overrides, "cfg_patch": cfg_patch}

    skip = shape_applicable(cfg, shape)
    if skip:
        rec.update(status="SKIP", reason=skip)
        return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    configure(mesh, rule_overrides)
    n_chips = math.prod(mesh.devices.shape)
    try:
        t0 = time.time()
        jfn, args, tokens, kind = build_cell(cfg, shape, mesh)
        with mesh:
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        try:
            mem = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(mem, k)) for k in
                ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)}
            if verbose:
                print(f"  memory_analysis: {rec['memory_analysis']}")
        except Exception as e:  # CPU backend may not support it
            rec["memory_analysis"] = f"unavailable: {e}"

        # raw XLA numbers kept for reference; NOTE they count while bodies
        # once (verified), so the roofline uses the trip-aware HLO walk.
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        rec["xla_cost_analysis_raw"] = {
            k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float))
            and k in ("flops", "bytes accessed", "transcendentals")}

        hlo = compiled.as_text()
        params_tree = args[0].params if kind == "train" else args[0]
        n_active = _active_param_count(params_tree, cfg)
        summary = roofline.summarize(
            hlo, n_active, tokens,
            "train" if kind == "train" else "inference")
        # useful-compute ratio: MODEL_FLOPS vs compiled global FLOPs
        global_flops = summary["hlo_flops_per_device"] * n_chips
        summary["hlo_flops_global"] = global_flops
        summary["useful_flops_ratio"] = (
            summary["model_flops_global"] / global_flops
            if global_flops else 0.0)
        rec.update(status="OK", kind=kind, chips=n_chips,
                   params=_tree_param_count(params_tree),
                   active_params=n_active, tokens_per_step=tokens,
                   lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
                   roofline=summary)
        if verbose:
            print(f"  cost_analysis: flops/device={summary['hlo_flops_per_device']:.3e} "
                  f"bytes/device={summary['hlo_bytes_per_device']:.3e} "
                  f"coll/device={summary['collective_bytes_per_device']:.3e}")
            print(f"  roofline: compute={summary['compute_s']*1e3:.2f}ms "
                  f"memory={summary['memory_s']*1e3:.2f}ms "
                  f"collective={summary['collective_s']*1e3:.2f}ms "
                  f"dominant={summary['dominant']} "
                  f"useful_ratio={summary['useful_flops_ratio']:.3f}")
    except Exception as e:
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    finally:
        configure(None)

    out_dir.mkdir(parents=True, exist_ok=True)
    out = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
    out.write_text(json.dumps(rec, indent=2, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=("single", "multi",
                                                         "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()
    out_dir = Path(args.out)

    archs = configs.ARCH_NAMES if (args.all or not args.arch) \
        else (args.arch,)
    shapes = tuple(configs.SHAPES) if (args.all or not args.shape) \
        else (args.shape,)
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)

    results = []
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                tag = f"{arch}__{shape_name}__{mesh_name}"
                path = out_dir / f"{tag}.json"
                if path.exists() and not args.force:
                    rec = json.loads(path.read_text())
                    if rec.get("status") in ("OK", "SKIP"):
                        print(f"[cached] {tag}: {rec['status']}")
                        results.append(rec)
                        continue
                print(f"[run] {tag}")
                t0 = time.time()
                rec = run_cell(arch, shape_name, mesh_name == "multi",
                               out_dir)
                print(f"  -> {rec['status']} ({time.time()-t0:.0f}s)"
                      + (f" {rec.get('error','')}"
                         if rec["status"] == "FAIL" else ""))
                results.append(rec)

    n_ok = sum(r["status"] == "OK" for r in results)
    n_skip = sum(r["status"] == "SKIP" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\n=== dry-run: {n_ok} OK, {n_skip} SKIP (documented), "
          f"{n_fail} FAIL of {len(results)} cells ===")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
