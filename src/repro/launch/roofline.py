"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, all in seconds-per-step on the
TPU v5e target:

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = collective_bytes_per_device / ICI_link_bw

cost_analysis() on the SPMD-partitioned module reports *per-device* flops
and bytes, so dividing by per-chip peaks is identical to the brief's
global/(chips x peak) formulation. collective_bytes is not in
cost_analysis — we parse the post-optimization HLO and sum the result-
shape bytes of every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute (start variants counted once, done variants skipped).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from typing import Dict, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# result types of a collective op line:
#   %x = f32[8,128]{1,0} all-gather(...)
#   %y = (f32[4,2]{...}, f32[4,2]{...}) all-reduce-start(...)
_LINE_RE = re.compile(
    r"=\s*(\(?)([a-z0-9\[\],{}/ _]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|"
                       r"u64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Sum result-shape bytes of collective ops in post-optimization HLO."""
    per_kind: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        kind = m.group(3).lower()
        # the result type precedes the op name on the line
        head = line.split("=", 1)
        if len(head) < 2:
            continue
        type_part = head[1].split(kind)[0]
        b = _shape_bytes(type_part)
        per_kind[kind] = per_kind.get(kind, 0) + b
    return sum(per_kind.values()), per_kind


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   coll_bytes_per_device: float) -> Dict[str, float]:
    compute = flops_per_device / PEAK_FLOPS
    memory = bytes_per_device / HBM_BW
    collective = coll_bytes_per_device / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dominant = max(terms, key=terms.get)
    bound = max(compute, memory, collective)
    terms["dominant"] = dominant.replace("_s", "")
    terms["step_lower_bound_s"] = bound
    # roofline fraction: how much of the bound is useful MXU time
    terms["compute_fraction_of_bound"] = compute / bound if bound else 0.0
    return terms


def model_flops(n_active_params: int, tokens: int,
                kind: str = "train") -> float:
    """6*N*D for train (fwd+bwd); 2*N*D for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * tokens


def summarize(hlo_text: str, n_active_params: int, tokens: int,
              kind: str) -> Dict:
    """Trip-count-aware roofline summary (launch.hlo_analysis).

    XLA's cost_analysis() counts while bodies once; with scan-over-periods
    that undercounts by ~depth, so the three terms here come from the
    trip-multiplied HLO walk instead.
    """
    from repro.launch import hlo_analysis
    mc = hlo_analysis.analyze(hlo_text)
    terms = roofline_terms(mc.flops, mc.bytes, mc.collective_bytes)
    mf = model_flops(n_active_params, tokens, kind)
    out = {
        "hlo_flops_per_device": mc.flops,
        "hlo_bytes_per_device": mc.bytes,
        "collective_bytes_per_device": mc.collective_bytes,
        "collective_breakdown": {k: float(v)
                                 for k, v in mc.collectives.items()},
        "while_trip_counts": mc.while_trips[:40],
        "model_flops_global": mf,
        **terms,
    }
    return out
