"""Trip-count-aware cost analysis over post-optimization HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts a while-loop body ONCE
regardless of trip count (verified in-repo; see EXPERIMENTS.md §Dry-run).
Since this framework deliberately scans over layer periods (and the
attention/SSM paths scan over KV blocks / time chunks), that undercounts
FLOPs, bytes, and — critically — the per-period FSDP all-gathers by 1-2
orders of magnitude.

This module re-derives the three roofline inputs from the HLO text itself:

  * flops        — 2 * numel(result) * prod(contracting dims) per dot,
                   multiplied by every enclosing while trip count
                   (``backend_config known_trip_count``, with a fallback to
                   the loop-condition compare constant).
  * bytes        — per materializing op: output + operand bytes, with
                   slice-aware charging (dynamic-slice / gather fusions
                   read only their slice; dynamic-update-slice fusions
                   write only their update) so scanning over stacked
                   per-period parameters is not billed as full-tensor
                   traffic per period.
  * collectives  — result bytes of all-gather / all-reduce / reduce-
                   scatter / all-to-all / collective-permute (and their
                   async -start forms), per kind, trip-multiplied.

Everything is computed per-device: the module XLA hands us is the SPMD-
partitioned per-device program.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128|"
    r"f8e4m3fn|f8e5m2)\[([0-9,]*)\]")

_COLL_OPS = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute", "ragged-all-to-all"}

_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "while", "conditional", "after-all",
                   "partition-id", "replica-id", "iota", "copy-done",
                   "all-gather-done", "all-reduce-done",
                   "collective-permute-done", "custom-call"}


def _dims_numel(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    return sum(_dims_numel(m.group(2)) * _DTYPE_BYTES[m.group(1)]
               for m in _SHAPE_RE.finditer(type_str))


def _type_max_array_bytes(type_str: str) -> int:
    """Largest array inside a (possibly tuple) type — async payload."""
    vals = [_dims_numel(m.group(2)) * _DTYPE_BYTES[m.group(1)]
            for m in _SHAPE_RE.finditer(type_str)]
    return max(vals) if vals else 0


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str              # text after the opening '('
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op] = dataclasses.field(default_factory=list)


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_BRANCH_RE = re.compile(r"(?:branch_computations|called_computations)="
                        r"\{([^}]*)\}")


def _split_type_opcode(defn: str) -> Optional[Tuple[str, str, str]]:
    """'f32[2]{0} add(%a, %b), meta' -> (type, opcode, rest-after-paren)."""
    s = defn.strip()
    if s.startswith("("):                      # tuple type: balance parens
        depth, i = 0, 0
        for i, ch in enumerate(s):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_str, tail = s[:i + 1], s[i + 1:]
    else:
        sp = s.find(" ")
        if sp < 0:
            return None
        type_str, tail = s[:sp], s[sp:]
    tail = tail.strip()
    par = tail.find("(")
    if par < 0:
        return None
    opcode = tail[:par].strip()
    if not opcode or not re.fullmatch(r"[a-z][\w\-\.]*", opcode):
        return None
    return type_str, opcode, tail[par + 1:]


def parse_module(text: str) -> Tuple[Dict[str, Computation], str,
                                     Dict[str, str]]:
    comps: Dict[str, Computation] = {}
    shapes: Dict[str, str] = {}
    entry = ""
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if (line.startswith("ENTRY") or
                (not line.startswith(" ") and "->" in line
                 and line.endswith("{"))):
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, defn = m.group(1), m.group(2)
        parsed = _split_type_opcode(defn)
        if not parsed:
            continue
        type_str, opcode, rest = parsed
        # operand names: %refs before the attribute section
        close = _find_args_end(rest)
        operands = _OPERAND_RE.findall(rest[:close])
        cur.ops.append(Op(name, type_str, opcode, rest, operands))
        shapes[name] = type_str
    return comps, entry, shapes


def _find_args_end(rest: str) -> int:
    depth = 1
    for i, ch in enumerate(rest):
        depth += ch == "("
        depth -= ch == ")"
        if depth == 0:
            return i
    return len(rest)


def _trip_count(op: Op, comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(op.rest)
    if m:
        return int(m.group(1))
    # fallback: largest integer constant in the condition computation
    mc = _COND_RE.search(op.rest)
    if mc and mc.group(1) in comps:
        best = 1
        for o in comps[mc.group(1)].ops:
            if o.opcode == "constant":
                mm = re.search(r"constant\((-?\d+)\)", "constant(" + o.rest)
                if mm:
                    best = max(best, abs(int(mm.group(1))))
        return best
    return 1


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    out_elems = sum(_dims_numel(m.group(2))
                    for m in _SHAPE_RE.finditer(op.type_str))
    mdim = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    k = 1
    if mdim and op.operands:
        lhs_type = shapes.get(op.operands[0], "")
        marr = _SHAPE_RE.search(lhs_type)
        if marr:
            dims = [int(d) for d in marr.group(2).split(",") if d]
            for ci in mdim.group(1).split(","):
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


def _fused_param_read_bytes(fused: Computation, shapes: Dict[str, str],
                            operands: List[str]) -> float:
    """Slice-aware operand read charging for a fusion call."""
    # map param order -> param op name
    params = []
    for o in fused.ops:
        if o.opcode == "parameter":
            mm = re.search(r"^\s*(\d+)", o.rest)
            idx = int(mm.group(1)) if mm else len(params)
            params.append((idx, o.name))
    params.sort()
    total = 0.0
    for order, (idx, pname) in enumerate(params):
        full = _type_bytes(shapes.get(operands[order], "")) \
            if order < len(operands) else 0
        # uses of this param inside the fused computation
        uses = [o for o in fused.ops if pname in o.operands]
        if uses and all(o.opcode in ("dynamic-slice", "gather")
                        and o.operands and o.operands[0] == pname
                        for o in uses):
            total += sum(_type_bytes(o.type_str) for o in uses)
        else:
            total += full
    return total


class ModuleCost:
    def __init__(self, text: str):
        self.comps, self.entry, self.shapes = parse_module(text)
        self._fused = self._find_fused()
        self._flops_cache: Dict[str, float] = {}
        self._bytes_cache: Dict[str, float] = {}
        self._coll_cache: Dict[str, Dict[str, float]] = {}
        self.while_trips: List[Tuple[str, int]] = []
        self.flops = self._flops(self.entry)
        self.bytes = self._bytes(self.entry)
        self.collectives = self._coll(self.entry)
        self.collective_bytes = sum(self.collectives.values())

    def _find_fused(self):
        fused = set()
        for comp in self.comps.values():
            for op in comp.ops:
                if op.opcode in ("fusion", "call", "custom-call"):
                    m = _CALLS_RE.search(op.rest)
                    if m:
                        fused.add(m.group(1))
        return fused

    # ----- flops ---------------------------------------------------------
    def _flops(self, cname: str) -> float:
        if cname in self._flops_cache:
            return self._flops_cache[cname]
        self._flops_cache[cname] = 0.0   # cycle guard
        comp = self.comps.get(cname)
        if comp is None:
            return 0.0
        total = 0.0
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                total += _dot_flops(op, self.shapes)
            elif op.opcode in ("fusion", "call"):
                m = _CALLS_RE.search(op.rest)
                if m:
                    total += self._flops(m.group(1))
            elif op.opcode == "while":
                m = _BODY_RE.search(op.rest)
                if m:
                    trips = _trip_count(op, self.comps)
                    self.while_trips.append((op.name, trips))
                    total += trips * self._flops(m.group(1))
            elif op.opcode == "conditional":
                m = _BRANCH_RE.search(op.rest)
                if m:
                    branches = _OPERAND_RE.findall(m.group(1))
                    vals = [self._flops(b) for b in branches]
                    total += max(vals) if vals else 0.0
        self._flops_cache[cname] = total
        return total

    # ----- bytes ---------------------------------------------------------
    def _op_bytes(self, op: Op) -> float:
        if op.opcode in _SKIP_BYTES_OPS:
            return 0.0
        out_b = float(_type_bytes(op.type_str))
        if op.opcode in ("fusion", "call"):
            m = _CALLS_RE.search(op.rest)
            fused = self.comps.get(m.group(1)) if m else None
            if fused is not None:
                root = fused.ops[-1] if fused.ops else None
                if root is not None and root.opcode == "dynamic-update-slice":
                    upd = (_type_bytes(self.shapes.get(root.operands[1], ""))
                           if len(root.operands) > 1 else out_b)
                    return 2.0 * upd
                return out_b + _fused_param_read_bytes(
                    fused, self.shapes, op.operands)
            return out_b
        if op.opcode == "dynamic-slice" or op.opcode == "gather":
            return 2.0 * out_b
        if op.opcode == "dynamic-update-slice":
            upd = (_type_bytes(self.shapes.get(op.operands[1], ""))
                   if len(op.operands) > 1 else out_b)
            return 2.0 * upd
        in_b = sum(_type_bytes(self.shapes.get(o, "")) for o in op.operands)
        return out_b + in_b

    def _bytes(self, cname: str) -> float:
        if cname in self._bytes_cache:
            return self._bytes_cache[cname]
        self._bytes_cache[cname] = 0.0
        comp = self.comps.get(cname)
        if comp is None:
            return 0.0
        total = 0.0
        for op in comp.ops:
            if op.opcode == "while":
                m = _BODY_RE.search(op.rest)
                if m:
                    total += _trip_count(op, self.comps) * \
                        self._bytes(m.group(1))
            elif op.opcode == "conditional":
                m = _BRANCH_RE.search(op.rest)
                if m:
                    branches = _OPERAND_RE.findall(m.group(1))
                    vals = [self._bytes(b) for b in branches]
                    total += max(vals) if vals else 0.0
            else:
                total += self._op_bytes(op)
        self._bytes_cache[cname] = total
        return total

    # ----- collectives ---------------------------------------------------
    def _coll(self, cname: str) -> Dict[str, float]:
        if cname in self._coll_cache:
            return dict(self._coll_cache[cname])
        self._coll_cache[cname] = {}
        comp = self.comps.get(cname)
        if comp is None:
            return {}
        total: Dict[str, float] = {}

        def add(kind: str, b: float):
            total[kind] = total.get(kind, 0.0) + b

        for op in comp.ops:
            base = op.opcode[:-6] if op.opcode.endswith("-start") \
                else op.opcode
            if base in _COLL_OPS:
                payload = (_type_max_array_bytes(op.type_str)
                           if op.opcode.endswith("-start")
                           else _type_bytes(op.type_str))
                add(base, float(payload))
            elif op.opcode in ("fusion", "call"):
                m = _CALLS_RE.search(op.rest)
                if m:
                    for k, v in self._coll(m.group(1)).items():
                        add(k, v)
            elif op.opcode == "while":
                m = _BODY_RE.search(op.rest)
                if m:
                    trips = _trip_count(op, self.comps)
                    for k, v in self._coll(m.group(1)).items():
                        add(k, trips * v)
            elif op.opcode == "conditional":
                m = _BRANCH_RE.search(op.rest)
                if m:
                    for b in _OPERAND_RE.findall(m.group(1)):
                        for k, v in self._coll(b).items():
                            add(k, v)
        self._coll_cache[cname] = total
        return dict(total)


def analyze(hlo_text: str) -> ModuleCost:
    return ModuleCost(hlo_text)


def top_bytes(hlo_text: str, k: int = 25) -> List[Tuple[str, float]]:
    """Trip-multiplied per-op byte attribution — the dry-run 'profile'.

    Returns the top-k [(descriptor, bytes)] where descriptor is
    ``computation/op_name opcode result_type``. Fusions are charged at the
    fusion call (their internal ops are free), matching _bytes().
    """
    mc = ModuleCost(hlo_text)

    # computation -> total trip multiplier (entry = 1)
    mult: Dict[str, float] = {mc.entry: 1.0}
    changed = True
    while changed:
        changed = False
        for cname, comp in mc.comps.items():
            m0 = mult.get(cname)
            if m0 is None:
                continue
            for op in comp.ops:
                target = None
                factor = 1.0
                if op.opcode == "while":
                    mm = _BODY_RE.search(op.rest)
                    if mm:
                        target = mm.group(1)
                        factor = _trip_count(op, mc.comps)
                elif op.opcode in ("fusion", "call"):
                    # fusion bodies are charged at the call site, but they
                    # may contain nested while/call in rare cases: skip.
                    continue
                elif op.opcode == "conditional":
                    mm = _BRANCH_RE.search(op.rest)
                    if mm:
                        for b in _OPERAND_RE.findall(mm.group(1)):
                            nv = m0
                            if mult.get(b, 0.0) < nv:
                                mult[b] = nv
                                changed = True
                        continue
                if target is not None:
                    nv = m0 * factor
                    if mult.get(target, 0.0) < nv:
                        mult[target] = nv
                        changed = True

    rows: List[Tuple[str, float]] = []
    for cname, m0 in mult.items():
        comp = mc.comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            if op.opcode in ("while", "conditional"):
                continue
            b = mc._op_bytes(op)
            if b > 0:
                short_t = op.type_str if len(op.type_str) < 48 \
                    else op.type_str[:45] + "..."
                rows.append((f"{cname}/{op.name} {op.opcode} {short_t}",
                             m0 * b))
    rows.sort(key=lambda x: -x[1])
    return rows[:k]
