"""Training launcher.

Single-process entry point that composes config -> mesh -> data -> loop.
On the CPU container it runs reduced configs on the real device (or a
forced-host smoke mesh); on a real TPU slice the same file launches the
full config against the production mesh — only ``--mesh`` changes.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \
      --reduced --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch rwkv6-1.6b \
      --reduced --steps 50 --mesh smoke   # 8 forced host devices
"""

from __future__ import annotations

import argparse
import dataclasses
import os


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compress", action="store_true",
                    help="int8 gradient compression + error feedback")
    ap.add_argument("--mesh", default="none",
                    choices=("none", "smoke", "single", "multi"))
    args = ap.parse_args(argv)

    if args.mesh == "smoke":
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    elif args.mesh in ("single", "multi"):
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=512")

    # import after XLA_FLAGS so the device count sticks
    from repro import configs
    from repro.data.lm import DataConfig, TokenStream
    from repro.launch import mesh as mesh_lib
    from repro.optim import AdamWConfig
    from repro.sharding import configure
    from repro.train.loop import LoopConfig, train

    cfg = (configs.reduced_config(args.arch) if args.reduced
           else configs.get_config(args.arch))
    if cfg.input_mode != "tokens":
        raise SystemExit(
            f"{args.arch} takes precomputed embeddings (modality stub); "
            "use examples/train_lm.py which wires the embedding stub")

    mesh = None
    if args.mesh == "smoke":
        mesh = mesh_lib.make_smoke_mesh()
    elif args.mesh != "none":
        mesh = mesh_lib.make_production_mesh(multi_pod=args.mesh == "multi")
    configure(mesh)

    ds = TokenStream(DataConfig(vocab=cfg.vocab, batch=args.batch,
                                seq_len=args.seq, seed=args.seed))
    loop_cfg = LoopConfig(total_steps=args.steps,
                          ckpt_every=args.ckpt_every,
                          log_every=args.log_every)
    opt_cfg = AdamWConfig(peak_lr=args.lr, warmup_steps=args.warmup,
                          decay_steps=max(args.steps, args.warmup + 1))

    ctx = mesh if mesh is not None else _nullcontext()
    with ctx:
        res = train(cfg, ds.batch, loop_cfg, opt_cfg,
                    ckpt_dir=args.ckpt_dir, mesh=mesh, seed=args.seed,
                    compress=args.compress)
    first = res.losses[0] if res.losses else float("nan")
    last = res.losses[-1] if res.losses else float("nan")
    print(f"[train] done: {res.final_step} steps, loss {first:.4f} -> "
          f"{last:.4f}, {len(res.straggler_events)} straggler events, "
          f"{res.restarts} restarts")
    return 0


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    raise SystemExit(main())
