import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS_EXTRA", ""))
# Must precede any jax import (device count locks at first init).

"""Perf-iteration tool (§Perf of EXPERIMENTS.md).

Lowers one (arch x shape) cell exactly like the dry-run, then prints the
trip-aware profile: top per-op byte contributors, collective breakdown,
and the three roofline terms. Variants are expressed as sharding-rule
overrides / config patches and tagged, so each hypothesis->change->measure
iteration is one invocation:

  python -m repro.launch.perf --arch rwkv6-1.6b --shape train_4k
  python -m repro.launch.perf --arch olmoe-1b-7b --shape train_4k \
      --rules '{"expert_capacity": "data"}' --tag cap_sharded
  python -m repro.launch.perf --arch gemma3-12b --shape train_4k \
      --cfg '{"remat": false}' --tag noremat
"""

import argparse
import json
from pathlib import Path

from repro.launch import dryrun, hlo_analysis

PERF_DIR = Path(__file__).resolve().parents[3] / "experiments" / "perf"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--rules", default=None, help="JSON rule overrides")
    ap.add_argument("--cfg", default=None, help="JSON ModelConfig patch")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--topk", type=int, default=20)
    ap.add_argument("--dump-hlo", action="store_true")
    args = ap.parse_args(argv)

    rules = json.loads(args.rules) if args.rules else None
    cfg_patch = json.loads(args.cfg) if args.cfg else None

    PERF_DIR.mkdir(parents=True, exist_ok=True)
    rec = dryrun.run_cell(args.arch, args.shape, args.multi,
                          out_dir=PERF_DIR, verbose=True,
                          rule_overrides=rules, cfg_patch=cfg_patch,
                          tag=f"perf_{args.tag}")
    if rec["status"] != "OK":
        print(json.dumps(rec, indent=2, default=str)[:3000])
        return 1

    # re-lower once more for the profile (run_cell doesn't keep the text)
    import dataclasses as dc

    from repro import configs
    from repro.launch import mesh as mesh_lib
    from repro.sharding import configure

    cfg = configs.get_config(args.arch)
    if cfg_patch:
        cfg = dc.replace(cfg, **cfg_patch)
    shape = configs.SHAPES[args.shape]
    mesh = mesh_lib.make_production_mesh(multi_pod=args.multi)
    configure(mesh, rules)
    try:
        jfn, cell_args, _, _ = dryrun.build_cell(cfg, shape, mesh)
        with mesh:
            hlo = jfn.lower(*cell_args).compile().as_text()
    finally:
        configure(None)

    if args.dump_hlo:
        p = PERF_DIR / f"{args.arch}__{args.shape}__{args.tag}.hlo"
        p.write_text(hlo)
        print(f"[perf] hlo dumped to {p} ({len(hlo)/1e6:.1f} MB)")

    print(f"\n=== top-{args.topk} byte contributors (trip-multiplied) ===")
    for desc, b in hlo_analysis.top_bytes(hlo, args.topk):
        print(f"  {b/1e9:10.2f} GB  {desc}")

    r = rec["roofline"]
    print("\n=== roofline ===")
    print(f"  compute={r['compute_s']*1e3:.1f}ms memory={r['memory_s']*1e3:.1f}ms "
          f"collective={r['collective_s']*1e3:.1f}ms dominant={r['dominant']}")
    print(f"  collectives: " + ", ".join(
        f"{k}={v/1e9:.1f}GB" for k, v in
        sorted(r["collective_breakdown"].items(), key=lambda x: -x[1])))
    print(f"  useful_flops_ratio={r['useful_flops_ratio']:.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
