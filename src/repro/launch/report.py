"""Render EXPERIMENTS.md tables from experiments/dryrun artifacts.

    PYTHONPATH=src python -m repro.launch.report [--mesh single]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[3]


def load(mesh: str):
    rows = []
    for f in sorted((ROOT / "experiments" / "dryrun").glob(
            f"*__{mesh}.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def dryrun_table(mesh: str) -> str:
    out = ["| arch | shape | status | params | GB/dev temp | GFLOP/dev | "
           "GB/dev mem | GB/dev coll |",
           "|---|---|---|---|---|---|---|---|"]
    for r in load(mesh):
        if r["status"] != "OK":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP | — | — | — | "
                       f"— | — |")
            continue
        mem = r.get("memory_analysis", {})
        temp = mem.get("temp_size_in_bytes", 0) / 1e9 \
            if isinstance(mem, dict) else 0
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | OK | "
            f"{r['params']/1e9:.2f}B | {temp:.1f} | "
            f"{rf['hlo_flops_per_device']/1e9:.0f} | "
            f"{rf['hlo_bytes_per_device']/1e9:.0f} | "
            f"{rf['collective_bytes_per_device']/1e9:.1f} |")
    return "\n".join(out)


def roofline_table(mesh: str) -> str:
    out = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) "
           "| dominant | bound (ms) | compute/bound | useful FLOPs |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in load(mesh):
        if r["status"] != "OK":
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']*1e3:.1f} | "
            f"{rf['memory_s']*1e3:.1f} | {rf['collective_s']*1e3:.1f} | "
            f"{rf['dominant']} | {rf['step_lower_bound_s']*1e3:.1f} | "
            f"{rf['compute_fraction_of_bound']:.3f} | "
            f"{rf['useful_flops_ratio']:.2f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--what", default="both",
                    choices=("dryrun", "roofline", "both"))
    args = ap.parse_args()
    if args.what in ("dryrun", "both"):
        print(dryrun_table(args.mesh))
        print()
    if args.what in ("roofline", "both"):
        print(roofline_table(args.mesh))


if __name__ == "__main__":
    main()
