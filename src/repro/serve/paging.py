"""Block-granular (paged) allocation for the serving pool's cache slots.

The contiguous SlotManager reserves ``cache_slots == max_len`` rows per
request — worst-case reservation, exactly the coarse-grain allocation
that strands the scarce shared resource (the paper's L2 argument at
serving scale). This module carves the slot axis into fixed-size
*blocks* instead:

  * ``BlockPool``   — a free list of physical blocks; the unit of
                      allocation and the unit the scheduler admits on.
  * ``PageTable``   — per-slot logical-block -> physical-block map.
                      Blocks are mapped on demand as a request's write
                      position crosses a block boundary and freed in one
                      batch at retire.

Both are host-side numpy/python (like the SlotManager free list): the
device only ever sees the *flat row index vectors* PageTable.rows()
derives, which the fused serve steps use to gather a per-slot contiguous
view before attending (models.attention.paged_view) and scatter updates
back after.

Unmapped logical blocks point at a single TRASH block appended past the
pool (physical index ``num_blocks``): gathers through a trash row are
masked to the empty-slot encoding (k=v=0, pos=-1), and scatters of rows
the model computed for dead/unmapped positions land there instead of
corrupting live blocks.

Sharing (prefix reuse): blocks carry *refcounts*. ``alloc`` hands out a
block at refcount 1; ``ref`` lets a second slot (or the ``PrefixIndex``)
map the same physical block read-shared; ``free`` drops one reference
and only returns the block to the free list at refcount 0. A block may
therefore be mapped under several page-table rows at once — the old
"mapped physical blocks are unique" invariant is replaced by a refcount
agreement invariant (mapping count + index holds == refcount, checked by
``check_invariants``). Scatters over shared rows stay deterministic *in
value* because every sharer writes back exactly the bytes it gathered
(the only row a step modifies is the current write position, which lives
in a private block — ``cow_block`` copies a shared block to a fresh one
before the first write into it, so no sharer ever observes another's
write).

Ring mode (``ring=True``): sliding-window attention layers keep a ring
buffer of ``window`` positions addressed ``pos % window``. A ring slot's
logical blocks cover ``min(window, pos + 1)`` positions — they map
lazily during ramp-up exactly like a growing global slot, then the full
ring stays resident at steady state (writes past the window land in
already-mapped blocks, so ``ensure`` clamps instead of erroring). The
gathered view is the ring itself, so ``pos % window`` addressing and
absolute-position masking resolve through the page table bit-identically
to the dense ring layout.

All state-guarding checks raise explicit ``ValueError``/``RuntimeError``
— never bare ``assert`` — because corruption of the pool/table must be
loud under ``python -O`` too (asserts are stripped there; exercised by
``tests/smoke_opt.py``).

Preemption support: ``PageTable.swap_out``/``swap_in`` evict a slot's
mapping and later re-map the same logical prefix onto fresh physical
blocks, and ``SwapStore`` is the host-side buffer holding the evicted
block *bytes* (plus how many blocks each page-table group had mapped)
keyed by request id — the time half of the paper's wasted-work argument:
preempting a victim should cost a block copy, not every decode step it
already paid for. The store takes an optional byte budget: under
sustained overload swapped-out bytes otherwise accumulate on the host
without bound, so an over-budget ``put`` is rejected loudly and the
scheduler falls back to recompute-preemption for that victim.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple


import numpy as np


class BlockPool:
    """Refcounted free list of ``num_blocks`` physical cache blocks of
    ``block_size`` positions each. LIFO reuse (like the slot free list)
    keeps hot blocks hot. ``alloc`` hands a block out at refcount 1;
    ``ref`` adds a sharer; ``free`` (== ``unref``) drops one reference
    and only returns the block to the free list when the count reaches
    zero — so a prefix block shared by many slots survives until the
    last sharer lets go. ``allocated`` stays the double-assignment
    guard for the free list itself."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1 or block_size < 1:
            raise ValueError(f"need num_blocks >= 1 and block_size >= 1, "
                             f"got {num_blocks}, {block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self.allocated = np.zeros(num_blocks, bool)
        self.refs = np.zeros(num_blocks, np.int32)

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def shared_count(self) -> int:
        """Blocks currently held by more than one reference."""
        return int(np.sum(self.refs > 1))

    def _check_id(self, block: int):
        """Reject out-of-range ids with ValueError (never IndexError, and
        never numpy negative indexing: ``free(-1)`` used to silently free
        the LAST block and push ``-1`` onto the free list, so a later
        ``alloc()`` returned ``-1`` and every derived flat row aliased
        another slot's KV)."""
        if not 0 <= block < self.num_blocks:
            raise ValueError(f"block id {block} outside pool "
                             f"[0, {self.num_blocks})")

    def alloc(self) -> Optional[int]:
        """Claim one block (refcount 1); None when the pool is
        exhausted."""
        if not self._free:
            return None
        b = self._free.pop()
        if self.allocated[b]:
            raise RuntimeError(f"block {b} double-assigned")
        self.allocated[b] = True
        self.refs[b] = 1
        return b

    def ref(self, block: int):
        """Add one reference to an allocated block (read-shared map)."""
        self._check_id(block)
        if not self.allocated[block]:
            raise ValueError(f"cannot ref unallocated block {block}")
        self.refs[block] += 1

    def refcount(self, block: int) -> int:
        self._check_id(block)
        return int(self.refs[block])

    def free(self, block: int) -> bool:
        """Drop one reference; the block returns to the free list only
        at refcount 0. Returns True when this call actually freed it."""
        self._check_id(block)
        if not self.allocated[block]:
            raise ValueError(f"block {block} is not allocated")
        self.refs[block] -= 1
        if self.refs[block] > 0:
            return False
        self.allocated[block] = False
        self._free.append(block)
        return True

    # ``unref`` is the refcount-native name; ``free`` predates sharing.
    unref = free


class PageTable:
    """Per-slot logical->physical block map over a shared BlockPool.

    ``slot_positions`` is the logical view length the fused steps gather:
    the contiguous allocator's ``cache_slots`` for global-attention
    layers, or the ring length ``min(window, cache_slots)`` for a
    sliding-window layer in ring mode. Ring addressing
    (``pos % slot_positions``) and blockwise-attention accumulation order
    resolve through the view bit-identically to the contiguous/dense
    layout. The last block of a slot may be partially used (internal
    fragmentation) when ``slot_positions % block_size != 0``.

    ``ring=True`` marks the view as a ring buffer: write positions past
    ``slot_positions`` wrap onto already-mapped blocks, so ``ensure``
    clamps its target instead of rejecting it, and the full ring is the
    steady-state mapping.
    """

    def __init__(self, pool: BlockPool, num_slots: int, slot_positions: int,
                 ring: bool = False):
        self.pool = pool
        self.num_slots = num_slots
        self.slot_positions = slot_positions
        self.ring = ring
        self.block_size = pool.block_size
        self.blocks_per_slot = -(-slot_positions // pool.block_size)
        self.trash = pool.num_blocks        # sentinel physical block
        self.table = np.full((num_slots, self.blocks_per_slot), self.trash,
                             np.int32)

    # -- sizing ---------------------------------------------------------

    def blocks_for(self, n_positions: int) -> int:
        """Blocks needed to back ``n_positions`` written positions. The
        clamp to ``blocks_per_slot`` is what makes this ring-correct: a
        ring never needs more than the full ring resident."""
        return min(-(-max(n_positions, 0) // self.block_size),
                   self.blocks_per_slot)

    def can_map(self, n_blocks: int) -> bool:
        return self.pool.free_count >= n_blocks

    def mapped_blocks(self, slot: int) -> int:
        return int(np.sum(self.table[slot] != self.trash))

    # -- lifecycle ------------------------------------------------------

    def ensure(self, slot: int, upto_pos: int) -> Tuple[bool, List[int]]:
        """Map every unmapped logical block covering positions
        [0, upto_pos]. Returns (fully_mapped, newly_mapped_physical).
        Ring mode clamps ``upto_pos`` to the ring: a write at
        ``pos >= slot_positions`` lands at ``pos % slot_positions``,
        inside the fully-mapped steady-state ring. On pool exhaustion the
        blocks mapped so far stay mapped (they are valid — the caller
        either retries after preempting a victim or frees the whole
        slot)."""
        if self.ring:
            upto_pos = min(upto_pos, self.slot_positions - 1)
        if not 0 <= upto_pos < self.slot_positions:
            raise ValueError(f"position {upto_pos} outside slot of "
                             f"{self.slot_positions}")
        new: List[int] = []
        for lb in range(upto_pos // self.block_size + 1):
            if self.table[slot, lb] != self.trash:
                continue
            b = self.pool.alloc()
            if b is None:
                return False, new
            self.table[slot, lb] = b
            new.append(b)
        return True, new

    def free_slot(self, slot: int) -> List[int]:
        """Unmap ``slot`` and drop its reference on every block it held
        (retire/preempt). Returns the blocks *released from this slot* —
        shared blocks stay allocated for their remaining sharers (and
        the PrefixIndex), only refcount-0 blocks hit the free list."""
        released = [int(b) for b in self.table[slot] if b != self.trash]
        for b in released:
            self.pool.free(b)
        self.table[slot] = self.trash
        return released

    # -- prefix sharing / copy-on-write ---------------------------------

    def map_shared(self, slot: int, blocks: Sequence[int]):
        """Map ``blocks`` (already-allocated physical ids, e.g. a prefix
        hit from the PrefixIndex) as the logical prefix of ``slot``,
        read-shared: each gains one reference. The target logical slots
        must be unmapped."""
        if len(blocks) > self.blocks_per_slot:
            raise ValueError(f"{len(blocks)} shared blocks into a slot "
                             f"of {self.blocks_per_slot}")
        for lb, b in enumerate(blocks):
            if self.table[slot, lb] != self.trash:
                raise RuntimeError(f"slot {slot} logical block {lb} is "
                                   f"already mapped")
            self.pool.ref(int(b))       # raises on unallocated / bad id
            self.table[slot, lb] = int(b)

    def is_shared(self, slot: int, lb: int) -> bool:
        b = int(self.table[slot, lb])
        return b != self.trash and self.pool.refs[b] > 1

    def write_blocks(self, slot: int, lo_pos: int, hi_pos: int) -> List[int]:
        """Logical blocks an upcoming write over positions
        [``lo_pos``, ``hi_pos``] will touch — the set a caller must CoW
        if shared. Ring mode reduces positions mod the ring (a wrapped
        write lands at ``pos % slot_positions``, possibly inside a
        shared prefix block); a span covering the whole ring touches
        every block."""
        if hi_pos < lo_pos:
            raise ValueError(f"empty write span [{lo_pos}, {hi_pos}]")
        if self.ring and hi_pos - lo_pos + 1 >= self.slot_positions:
            return list(range(self.blocks_per_slot))
        if self.ring:
            vps = {p % self.slot_positions
                   for p in range(lo_pos, hi_pos + 1)}
            return sorted({vp // self.block_size for vp in vps})
        hi = min(hi_pos, self.slot_positions - 1)
        if lo_pos > hi:
            return []
        return list(range(lo_pos // self.block_size,
                          hi // self.block_size + 1))

    def cow_block(self, slot: int, lb: int) -> Optional[Tuple[int, int]]:
        """Give ``slot`` a private copy of shared logical block ``lb``:
        allocate a fresh physical block, remap, and drop this slot's
        reference on the old one (its other sharers keep theirs).
        Returns (old_phys, new_phys) — the caller must copy the old
        block's device rows into the new one (engine.copy_block_rows)
        before the next step reads them — or None when the pool is
        exhausted (state unchanged; the caller preempts or retries)."""
        old = int(self.table[slot, lb])
        if old == self.trash:
            raise RuntimeError(f"cow of unmapped logical block {lb} "
                               f"of slot {slot}")
        if self.pool.refs[old] <= 1:
            raise RuntimeError(f"cow of private block {old} (slot {slot}, "
                               f"logical {lb})")
        new = self.pool.alloc()
        if new is None:
            return None
        self.table[slot, lb] = new
        self.pool.free(old)             # drop our share; old stays alive
        return old, new

    # -- swap-out preemption --------------------------------------------

    def swap_out(self, slot: int) -> Tuple[np.ndarray, List[int]]:
        """Evict ``slot`` for a later resume: returns (saved page-table
        row, freed physical blocks in logical order). The physical ids in
        the saved row are dead the moment this returns — what the resume
        needs is WHICH logical blocks were mapped, and ``ensure`` maps
        bottom-up so that is always the [0, n) prefix. The caller copies
        the blocks' bytes out (engine.gather_block_rows) BEFORE calling
        this, then parks both in a SwapStore."""
        row = self.table[slot].copy()
        mapped = np.flatnonzero(row != self.trash)
        if mapped.size and not (mapped == np.arange(mapped.size)).all():
            raise RuntimeError(f"slot {slot} mapping is not a logical "
                               f"prefix: {row.tolist()}")
        # Shared blocks are *released*, not stolen: free() only drops this
        # slot's reference, so other sharers (and the PrefixIndex) keep
        # the block — the victim's bytes were gathered to host before
        # this call, a copy, never a steal.
        freed = self.free_slot(slot)
        return row, freed

    def swap_in(self, slot: int, n_blocks: int) -> Optional[List[int]]:
        """Re-map ``n_blocks`` fresh physical blocks as the logical
        prefix of an empty slot — the resume half of swap preemption.
        All-or-nothing: returns the new physical blocks in logical order,
        or None (nothing mapped) when the pool cannot supply them. The
        caller uploads the saved bytes into the returned blocks' rows
        (engine.upload_block_rows); it must NOT zero them."""
        if not 0 <= n_blocks <= self.blocks_per_slot:
            raise ValueError(f"swap_in of {n_blocks} blocks into a slot "
                             f"of {self.blocks_per_slot}")
        if not (self.table[slot] == self.trash).all():
            raise RuntimeError(f"slot {slot} is not empty: "
                               f"{self.table[slot].tolist()}")
        if not self.can_map(n_blocks):
            return None
        new: List[int] = []
        for lb in range(n_blocks):
            b = self.pool.alloc()
            if b is None:
                raise RuntimeError("can_map lied about pool capacity")
            self.table[slot, lb] = b
            new.append(b)
        return new

    # -- device-facing index vectors ------------------------------------

    def rows(self, slots: Optional[Sequence[int]] = None) -> np.ndarray:
        """Flat physical row per view position: (len(slots),
        slot_positions) int32. View position v of slot s lives at
        physical row table[s, v // bs] * bs + v % bs; unmapped blocks
        resolve to trash rows (>= num_blocks * bs), which the gather
        masks and the scatter sacrifices."""
        tab = self.table if slots is None else self.table[list(slots)]
        bs = self.block_size
        full = (tab[:, :, None] * bs
                + np.arange(bs, dtype=np.int32)[None, None, :])
        return full.reshape(tab.shape[0], -1)[:, :self.slot_positions] \
                   .astype(np.int32)

    @staticmethod
    def block_rows(blocks: Sequence[int], block_size: int) -> np.ndarray:
        """Flat physical rows covered by ``blocks`` (for block resets)."""
        b = np.asarray(list(blocks), np.int32)
        return (b[:, None] * block_size
                + np.arange(block_size, dtype=np.int32)[None, :]).reshape(-1)

    # -- introspection ---------------------------------------------------

    def check_invariants(self, external_refs: Optional[np.ndarray] = None):
        """Refcount agreement: every block's mapping count in the table,
        plus any references held outside it (``external_refs`` — e.g.
        the PrefixIndex's holds), equals ``pool.refs``; refcount > 0 iff
        allocated; the free list is exactly the unallocated blocks, no
        duplicates. (Exercised by the property tests on every
        operation.) Raises RuntimeError — must fire under ``python -O``
        too."""
        mapped = self.table[self.table != self.trash]
        counts = np.bincount(mapped, minlength=self.pool.num_blocks)
        if external_refs is not None:
            counts = counts + np.asarray(external_refs, np.int64)
        if not (counts == self.pool.refs).all():
            raise RuntimeError("table/index mapping counts disagree with "
                               "pool refcounts")
        if not ((self.pool.refs > 0) == self.pool.allocated).all():
            raise RuntimeError("refcount > 0 iff allocated violated")
        free = self.pool._free
        if len(free) != len(set(free)):
            raise RuntimeError("duplicate block on the free list")
        if set(free) != set(np.flatnonzero(~self.pool.allocated).tolist()):
            raise RuntimeError("table / pool free list disagree")

    def stats(self) -> Dict[str, Any]:
        """Counts are int, utilization float (obs.schema pins this)."""
        used = self.pool.used_count
        return {"blocks_total": self.pool.num_blocks,
                "blocks_used": used,
                "blocks_free": self.pool.num_blocks - used,
                "block_size": self.block_size,
                "block_utilization": used / self.pool.num_blocks,
                "shared_blocks": self.pool.shared_count}


# ---------------------------------------------------------------------------
# prefix index (hash of block-aligned prompt chunks -> physical blocks)
# ---------------------------------------------------------------------------

class PrefixIndex:
    """LRU map from a *chained* hash of block-aligned prompt-token chunks
    to the physical blocks holding that chunk's KV, one block per
    page-table group (keyed by view length).

    The hash chains (digest of chunk i folds in chunk i-1's digest)
    because KV at a position depends on the entire prefix before it —
    two prompts sharing chunk i's tokens but diverging earlier must NOT
    share chunk i's blocks. Matching therefore walks chunks 0, 1, ...
    and stops at the first miss.

    The index itself is a *reference holder*: the owning backing refs a
    block once per entry it appears in, so published blocks survive
    their donor's retirement. Entries are bounded (``capacity``, LRU)
    and evictable under pool pressure — evicting an entry only returns
    blocks nobody else maps (refcount reaching 0); blocks still shared
    by live slots merely lose their index hold.

    Pure bookkeeping: the backing does the pool ref/unref around
    ``publish``/``evict_lru`` (it owns the per-group pools)."""

    def __init__(self, capacity: int = 512):
        if capacity < 1:
            raise ValueError(f"need capacity >= 1, got {capacity}")
        from collections import OrderedDict
        self.capacity = capacity
        self._entries: "OrderedDict[bytes, Dict[int, int]]" = OrderedDict()
        self.lookups = 0        # match() calls
        self.hit_chunks = 0     # chunks matched, cumulative
        self.published = 0      # entries inserted, cumulative
        self.evicted = 0        # entries evicted (LRU or pressure)

    @staticmethod
    def chunk_keys(tokens: Sequence[int], block_size: int,
                   max_chunks: int) -> List[bytes]:
        """Chained digests of the leading full ``block_size`` chunks of
        ``tokens`` (at most ``max_chunks``)."""
        import hashlib
        toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
        n = min(len(toks) // block_size, max(max_chunks, 0))
        keys: List[bytes] = []
        digest = b""
        for i in range(n):
            chunk = toks[i * block_size:(i + 1) * block_size]
            digest = hashlib.blake2b(digest + chunk.tobytes(),
                                     digest_size=16).digest()
            keys.append(digest)
        return keys

    def match(self, keys: Sequence[bytes]) -> List[Dict[int, int]]:
        """Longest indexed prefix of ``keys``: per-chunk
        {view_len: physical block} dicts, stopping at the first miss.
        Hits refresh LRU order."""
        out: List[Dict[int, int]] = []
        for k in keys:
            entry = self._entries.get(k)
            if entry is None:
                break
            self._entries.move_to_end(k)
            out.append(entry)
        self.lookups += 1
        self.hit_chunks += len(out)
        return out

    def publish(self, key: bytes, blocks: Dict[int, int]) -> bool:
        """Insert ``key`` -> ``blocks`` if absent. Returns True when
        inserted (the caller must have ref'd every block first); False
        when the chunk is already indexed (concurrent prefills of the
        same new prefix: first publisher wins)."""
        if key in self._entries:
            self._entries.move_to_end(key)
            return False
        self._entries[key] = dict(blocks)
        self.published += 1
        return True

    def evict_lru(self, keep: Optional[set] = None) \
            -> Optional[Dict[int, int]]:
        """Drop the least-recently-used entry whose key is not in
        ``keep``, returning its blocks so the caller can unref them;
        None when nothing is evictable (empty, or only kept entries
        remain — an admission must not evict the very chain it is about
        to map)."""
        for key in self._entries:           # LRU -> MRU order
            if not keep or key not in keep:
                blocks = self._entries.pop(key)
                self.evicted += 1
                return blocks
        return None

    def holds(self, num_blocks_by_view: Dict[int, int]) \
            -> Dict[int, np.ndarray]:
        """Per-group reference counts this index holds, as
        {view_len: int64[num_blocks]} — the ``external_refs`` argument
        of PageTable.check_invariants."""
        out = {vl: np.zeros(n, np.int64)
               for vl, n in num_blocks_by_view.items()}
        for blocks in self._entries.values():
            for vl, b in blocks.items():
                if vl in out:
                    out[vl][b] += 1
        return out

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> Dict[str, int]:
        return {"prefix_entries": len(self._entries),
                "prefix_lookups": self.lookups,
                "prefix_hit_chunks": self.hit_chunks,
                "prefix_published": self.published,
                "prefix_evicted": self.evicted}


# ---------------------------------------------------------------------------
# host-side swap buffer (preempt="swap")
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SwapEntry:
    """Everything a preempted request needs to resume in a fresh slot
    with zero recomputed decode steps: how many logical blocks each
    page-table group (keyed by view length — the global-KV group plus
    one per distinct window-ring length) had mapped, the blocks' KV
    bytes per paged cache key (host numpy, logical order), and the
    slot's dense per-slot leaves (SSM state, per-row pos, any unpaged
    rings)."""
    blocks: Dict[int, int]      # view_len -> mapped logical-prefix blocks
    paged: Dict[str, Any]       # pattern key -> host KVCache block bytes
    dense: Any

    @property
    def nbytes(self) -> int:
        import jax
        return int(sum(np.asarray(l).nbytes for l in
                       jax.tree_util.tree_leaves((self.paged, self.dense))))


class SwapStore:
    """Host-side parking lot for swapped-out requests, keyed by rid.

    The paged backing fills it on ``swap_out`` (block bytes gathered to
    host + dense snapshot) and drains it on ``swap_in``; byte counters
    feed fig_serve's swap-traffic report.

    ``max_bytes`` bounds the held bytes: the store is otherwise unbounded
    — under sustained overload, swapped-out requests that never re-admit
    would accumulate host memory forever. ``can_hold`` is the caller's
    admission check (the scheduler falls back to recompute-preemption on
    rejection); an over-budget ``put`` that sneaks past it raises."""

    def __init__(self, max_bytes: Optional[int] = None):
        self.max_bytes = max_bytes
        self._d: Dict[int, SwapEntry] = {}
        self.held_bytes = 0     # resident right now (drops on pop)
        self.bytes_out = 0      # device -> host (swap_out), cumulative
        self.bytes_in = 0       # host -> device (swap_in), cumulative
        self.rejected = 0       # puts refused by the byte budget
        self.migrated_out = 0   # entries handed to another shard's store
        self.migrated_in = 0    # entries accepted from another store

    def can_hold(self, nbytes: int) -> bool:
        return self.max_bytes is None \
            or self.held_bytes + nbytes <= self.max_bytes

    def reject(self):
        """Record a budget rejection — the store owns the count, whether
        the caller prechecked with can_hold (the backing's path) or an
        over-budget put raised."""
        self.rejected += 1

    def put(self, rid: int, entry: SwapEntry) -> int:
        if rid in self._d:
            raise ValueError(f"rid {rid} already swapped out")
        n = entry.nbytes
        if not self.can_hold(n):
            self.reject()
            raise RuntimeError(
                f"swap budget exceeded: holding {self.held_bytes} + "
                f"{n} > {self.max_bytes} bytes (rid {rid})")
        self._d[rid] = entry
        self.held_bytes += n
        self.bytes_out += n
        return n

    def get(self, rid: int) -> SwapEntry:
        return self._d[rid]

    def pop(self, rid: int) -> SwapEntry:
        entry = self._d.pop(rid)
        self.held_bytes -= entry.nbytes
        self.bytes_in += entry.nbytes
        return entry

    def __contains__(self, rid: int) -> bool:
        return rid in self._d

    def __len__(self) -> int:
        return len(self._d)

    # -- cross-store migration (work-stealing a swapped request) --------

    def migrate_out(self, rid: int) -> SwapEntry:
        """Remove ``rid`` for transfer to another shard's store. Unlike
        ``pop`` the bytes never move host<->device, so the swap traffic
        counters are untouched (``migrated_out`` records the event)."""
        entry = self._d.pop(rid)
        self.held_bytes -= entry.nbytes
        self.migrated_out += 1
        return entry

    def migrate_in(self, rid: int, entry: SwapEntry) -> int:
        """Accept an entry migrated from another shard's store, against
        this store's byte budget. Returns bytes now held here; raises
        when over budget (callers precheck with ``can_hold`` — a refused
        migration simply leaves the request on its home shard)."""
        if rid in self._d:
            raise ValueError(f"rid {rid} already swapped out")
        n = entry.nbytes
        if not self.can_hold(n):
            self.reject()
            raise RuntimeError(
                f"swap budget exceeded: holding {self.held_bytes} + "
                f"{n} > {self.max_bytes} bytes (migrated rid {rid})")
        self._d[rid] = entry
        self.held_bytes += n
        self.migrated_in += 1
        return n

    def stats(self) -> Dict[str, int]:
        return {"swapped_held": len(self._d),
                "swap_bytes_held": self.held_bytes,
                "swap_bytes_budget": (-1 if self.max_bytes is None
                                      else self.max_bytes),
                "swap_rejected": self.rejected,
                "swap_bytes_out": self.bytes_out,
                "swap_bytes_in": self.bytes_in,
                "swap_migrated_out": self.migrated_out,
                "swap_migrated_in": self.migrated_in}
