"""repro.serve — LM serving: stateless engine steps + continuous batching.

  * engine    — prefill / decode / chunked-prefill step builders (chunk
                steps return per-position logits), per-slot position
                vectors, SamplingPolicy + top-k/top-p sampling, the
                verify-accept step for speculative decoding, per-request
                ``generate``, fused paged (page-gather -> step ->
                page-scatter) steps.
  * paging    — BlockPool / PageTable: block-granular allocation for the
                slot pool's attention KV — global layers and (ring-mode
                page tables) sliding-window rings — plus the
                byte-budgeted SwapStore backing zero-recompute
                (swap-out) preemption.
  * slots     — SlotManager: the fixed pool of static-shape cache slots
                (contiguous or paged backing behind one facade).
  * scheduler — Scheduler: admit -> chunk-prefill -> fused decode ->
                retire continuous batching, plus the memoizing
                RequestCache for zipfian traffic and preempt-on-OOB for
                the paged allocator.
"""

from repro.serve.engine import (SamplingPolicy, cache_shardings, generate,
                                make_chunk_step, make_decode_step,
                                make_prefill_step, make_slot_decode_step,
                                make_verify_step, sample_token)
from repro.serve.paging import BlockPool, PageTable, SwapStore
from repro.serve.scheduler import (Completion, RequestCache, Scheduler,
                                   SchedulerConfig)
from repro.serve.slots import SlotManager

__all__ = ["cache_shardings", "generate", "make_chunk_step",
           "make_decode_step", "make_prefill_step", "make_slot_decode_step",
           "make_verify_step", "sample_token", "BlockPool", "Completion",
           "PageTable", "RequestCache", "SamplingPolicy", "Scheduler",
           "SchedulerConfig", "SlotManager", "SwapStore"]
