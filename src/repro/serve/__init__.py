"""repro.serve — LM serving: stateless engine steps + continuous batching.

  * engine    — prefill / decode / chunked-prefill step builders, per-slot
                position vectors, sampling, per-request ``generate``.
  * slots     — SlotManager: the fixed pool of static-shape cache slots.
  * scheduler — Scheduler: admit -> chunk-prefill -> fused decode ->
                retire continuous batching, plus the memoizing
                RequestCache for zipfian traffic.
"""

from repro.serve.engine import (cache_shardings, generate, make_chunk_step,
                                make_decode_step, make_prefill_step,
                                make_slot_decode_step, sample_token)
from repro.serve.scheduler import (Completion, RequestCache, Scheduler,
                                   SchedulerConfig)
from repro.serve.slots import SlotManager

__all__ = ["cache_shardings", "generate", "make_chunk_step",
           "make_decode_step", "make_prefill_step", "make_slot_decode_step",
           "sample_token", "Completion", "RequestCache", "Scheduler",
           "SchedulerConfig", "SlotManager"]
