from repro.serve.engine import (cache_shardings, make_decode_step,
                                make_prefill_step, sample_token)

__all__ = ["cache_shardings", "make_decode_step", "make_prefill_step",
           "sample_token"]
