"""SlotManager — a fixed pool of cache slots for continuous batching.

The paper's runtime keeps a pool of workers saturated on dependency-bound
work; at LM-serving scale the scarce resource is the static-shape decode
cache. This module owns a pool of B cache slots over the engine's
KV/recurrent caches (``transformer.init_caches(per_slot_pos=True)``):
requests are *allocated* a slot, their prefilled state lives in that
slot's rows of every cache leaf, and eviction on EOS/max-tokens frees the
slot for the next admission — the batch shape never changes, only the
masks do.

Two storage backings sit behind one facade:

  * contiguous — every slot reserves its worst-case rows of every leaf
    (``cache_slots`` for global attention, the full ``window`` ring for
    sliding-window layers; the original layout).
  * paged      — attention KV leaves live in shared block pools
    (``serve.paging``: BlockPool + PageTable, blocks mapped on demand as
    a request's write position grows, freed at retire), so short
    requests stop stranding pool memory the way coarse-grain reservation
    strands the paper's L2. Keys sharing a view length form one
    *page-table group* over one pool: the global-KV group (view =
    ``cache_slots``) plus one ring-mode group per distinct window length
    (view = ``min(window, cache_slots)``; blocks map lazily while the
    request ramps up to ``window`` written positions, then the full ring
    stays resident). The fused steps gather a per-slot contiguous view
    through each group's page table before attending and scatter updates
    back (models.attention.paged_view / paged_writeback), keeping the
    one-fused-program-per-tick property — and every view is
    bit-identical to the contiguous layout, so greedy token streams are
    too.

With the per-row position layout every cache leaf carries the slot axis
at position 1 ((periods, B, ...)), so gather/scatter/reset are single-axis
indexing ops over the whole pytree, jitted once per sub-batch shape.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, transformer as T
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.runtime import bucketing
from repro.serve import engine
from repro.serve.paging import (BlockPool, PageTable, PrefixIndex,
                                SwapEntry, SwapStore)

_SLOT_AXIS = 1      # every per_slot_pos cache leaf: (periods, B, ...)


@jax.jit
def _gather(caches, idx):
    return jax.tree_util.tree_map(
        lambda l: jnp.take(l, idx, axis=_SLOT_AXIS), caches)


# pool-sized updates donate the pool: without donation every scatter /
# reset / chunk step materializes a second full copy of the cache pool
@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter(caches, sub, idx):
    return jax.tree_util.tree_map(
        lambda l, s: l.at[:, idx].set(s.astype(l.dtype)), caches, sub)


@functools.lru_cache(maxsize=None)
def _pooled_chunk_step(cfg: ModelConfig):
    """Fused gather -> chunk-prefill -> scatter over the pooled caches,
    returning (logits (m, C, V), caches).

    One jitted program (per cfg and sub-batch shape) instead of three
    dispatches: at small sub-batches the per-call overhead of separate
    gather/chunk/scatter calls rivals the chunk compute itself."""
    step = engine.make_chunk_step(cfg)

    @functools.partial(jax.jit, donate_argnums=(1,))
    def run(params, caches, idx, tokens, pos):
        sub = jax.tree_util.tree_map(
            lambda l: jnp.take(l, idx, axis=_SLOT_AXIS), caches)
        logits, sub = step(params, sub, tokens, pos)
        return logits, jax.tree_util.tree_map(
            lambda l, s: l.at[:, idx].set(s.astype(l.dtype)), caches, sub)

    return obs_trace.instrumented_jit(
        run, name=f"pooled_chunk_step[{cfg.name}]", prefix="serve.engine")


def _pad_rows(arr: np.ndarray, pad: int) -> np.ndarray:
    """Pad a saved block-bytes leaf (P, rows, ...) with ``pad`` zero rows
    — the payload for the trash rows a pow2-padded upload writes."""
    if pad == 0:
        return arr
    z = np.zeros((arr.shape[0], pad) + arr.shape[2:], arr.dtype)
    return np.concatenate([np.asarray(arr), z], axis=1)


@functools.partial(jax.jit, donate_argnums=(0,))
def _reset(caches, template, idx):
    """Write the zero-state template (slot axis = 1) into slots ``idx``."""

    def wipe(l, t):
        fresh = jnp.broadcast_to(
            t, t.shape[:_SLOT_AXIS] + (idx.shape[0],) + t.shape[2:])
        return l.at[:, idx].set(fresh.astype(l.dtype))

    return jax.tree_util.tree_map(wipe, caches, template)


def _attn_view_len(spec, cache_slots: int) -> int:
    """Positions an attention layer's slot view spans: the full
    ``cache_slots`` for global attention (or window >= cache_slots), the
    ring length for a shorter sliding window."""
    return min(cache_slots, spec.window) if spec.window else cache_slots


# ---------------------------------------------------------------------------
# storage backings
# ---------------------------------------------------------------------------

class _ContiguousBacking:
    """Every slot owns its worst-case rows of every leaf (the original
    reservation layout)."""

    is_paged = False

    def __init__(self, cfg: ModelConfig, num_slots: int, cache_slots: int):
        self.cfg = cfg
        self.num_slots = num_slots
        self.cache_slots = cache_slots
        self.caches = T.init_caches(cfg, num_slots, cache_slots,
                                    per_slot_pos=True)
        # one-slot zero template: reset = scatter-broadcast of this
        self._template = T.init_caches(cfg, 1, cache_slots,
                                       per_slot_pos=True)
        self.position_capacity = num_slots * cache_slots

    @property
    def total_rows(self) -> int:
        """Total attention cache positions reserved across the pool
        (global KV + window rings) — the equal-memory axis the windowed
        fig_serve arm compares allocators on (every attn leaf of one cfg
        has the same per-position byte cost)."""
        return sum(self.num_slots * _attn_view_len(s, self.cache_slots)
                   for s in self.cfg.pattern if s.mixer == "attn")

    def can_admit(self, prompt_len: int, prompt=None,
                  span: Optional[int] = None) -> bool:
        return True                     # a free slot is the only gate

    def fits_pool(self, n_positions: int) -> Optional[str]:
        return None                     # rows are pre-reserved

    def alloc_reset(self, slot: int, prompt_len: int, prompt=None,
                    span: Optional[int] = None) -> int:
        self.caches = _reset(self.caches, self._template,
                             jnp.asarray([slot], jnp.int32))
        return 0                        # no prefix sharing: prefill from 0

    def ensure(self, slot: int, upto_pos: int,
               write_from: Optional[int] = None) -> bool:
        return True                     # rows are pre-reserved

    def release_slot(self, slot: int) -> List[int]:
        return []                       # nothing block-granular to free

    def prefill_start(self, slot: int) -> int:
        return 0                        # no prefix sharing

    def register_prefix(self, slot: int, prompt, span: int,
                        upto_tokens: int) -> int:
        return 0                        # no prefix sharing

    def flush_prefix(self) -> int:
        return 0

    def gather(self, idx):
        return _gather(self.caches, jnp.asarray(idx, jnp.int32))

    def scatter(self, sub, idx):
        self.caches = _scatter(self.caches, sub,
                               jnp.asarray(idx, jnp.int32))

    def run_chunk(self, params, idx, tokens, pos):
        logits, self.caches = _pooled_chunk_step(self.cfg)(
            params, self.caches, jnp.asarray(idx, jnp.int32),
            jnp.asarray(tokens), jnp.asarray(pos))
        return logits

    def run_decode(self, params, tokens, pos, temps, key,
                   top_ks=None, top_ps=None):
        nxt, logits, self.caches = engine.jit_slot_decode_step(self.cfg)(
            params, self.caches, tokens, pos, temps, key, top_ks, top_ps)
        return nxt, logits

    def run_verify(self, params, tokens, pos, prompt_len, max_pos, score,
                   active, temps, top_ks, top_ps, key):
        out_tok, n, lp, self.caches = engine.jit_verify_step(self.cfg)(
            params, self.caches, tokens, pos, prompt_len, max_pos, score,
            active, temps, top_ks, top_ps, key)
        return out_tok, n, lp

    def stats(self) -> dict:
        return {"allocator": "contiguous"}


class _PageGroup:
    """One BlockPool + PageTable shared by the pattern keys whose slot
    views have the same length: the global-KV group (``view_len ==
    cache_slots``) or one ring group per distinct window length. Keys in
    a group advance in lockstep (every layer writes the same position
    each tick), so one logical->physical map serves them all — block b
    means rows [b*bs, (b+1)*bs) of every member key's flat pool."""

    def __init__(self, keys: List[str], num_slots: int, view_len: int,
                 cache_slots: int, block_size: int,
                 num_blocks: Optional[int]):
        self.keys = keys
        self.view_len = view_len
        self.ring = view_len < cache_slots
        if num_blocks is None:
            # equal-memory default: same position capacity as the dense
            # layout (num_slots full views)
            num_blocks = num_slots * (-(-view_len // block_size))
        self.pool = BlockPool(num_blocks, block_size)
        self.pt = PageTable(self.pool, num_slots, view_len, ring=self.ring)


class _PagedBacking:
    """Attention KV lives in shared block pools — one page-table group
    per view length (global KV + window rings when ``paged_window``);
    per-slot dense leaves (SSM state, rings kept dense when
    ``paged_window=False``) keep the contiguous layout. Each group's page
    table maps a slot's logical blocks to physical ones on demand; the
    fused steps read/write through flat row index vectors derived per
    group (gather-before-attend)."""

    is_paged = True

    def __init__(self, cfg: ModelConfig, num_slots: int, cache_slots: int,
                 block_size: int, num_blocks: Optional[int],
                 paged_window: bool = True,
                 num_window_blocks: Optional[int] = None,
                 swap_bytes_budget: Optional[int] = None,
                 prefix_sharing: bool = False,
                 prefix_align: Optional[int] = None,
                 prefix_capacity: int = 512,
                 create_arrays: bool = True,
                 dense_probe=None, template=None):
        self.cfg = cfg
        self.num_slots = num_slots
        self.cache_slots = cache_slots
        self.block_size = block_size
        # create_arrays=False is the per-shard mode (_ShardState): the
        # device arrays live stacked in the sharded owner; this instance
        # keeps only host state (pools, page tables, swaps, prefix index)
        # and redirects its device ops through the _dev_* hooks.
        if create_arrays:
            self.dense = T.init_caches(cfg, num_slots, cache_slots,
                                       per_slot_pos=True,
                                       paged_global_attn=True,
                                       paged_window_attn=paged_window)
            self._template = T.init_caches(cfg, 1, cache_slots,
                                           per_slot_pos=True,
                                           paged_global_attn=True,
                                           paged_window_attn=paged_window)
        else:
            self.dense = None
            self._template = template
        probe = self.dense if self.dense is not None else dense_probe
        # group the paged keys by view length: one pool + page table per
        # distinct length (the global group, rings per window size)
        by_view: Dict[int, List[str]] = {}
        self.key_view: Dict[str, int] = {}
        for i, spec in enumerate(cfg.pattern):
            key = f"p{i}"
            entry = probe.get(key)
            if not (entry and "attn" in entry and entry["attn"] is None):
                continue
            vl = _attn_view_len(spec, cache_slots)
            by_view.setdefault(vl, []).append(key)
            self.key_view[key] = vl
        self.groups: Dict[int, _PageGroup] = {
            vl: _PageGroup(keys, num_slots, vl, cache_slots, block_size,
                           num_blocks if vl == cache_slots
                           else num_window_blocks)
            for vl, keys in sorted(by_view.items(), reverse=True)}
        self.paged = ({
            key: attention.make_paged_cache(
                g.pool.num_blocks, block_size, cfg.num_kv_heads,
                cfg.head_dim, periods=cfg.num_periods)
            for g in self.groups.values() for key in g.keys}
            if create_arrays else None)
        g_global = self.groups.get(cache_slots)
        self.position_capacity = (g_global.pool.num_blocks * block_size
                                  if g_global else num_slots * cache_slots)
        self.swaps = SwapStore(max_bytes=swap_bytes_budget)
        # prefix sharing: only sound when EVERY layer's per-position state
        # is paged attention KV — a dense recurrent leaf (SSM state, an
        # unpaged ring) is a function of the whole prefix that skipping
        # prefill would leave stale
        shareable = (all(s.mixer == "attn" for s in cfg.pattern)
                     and len(self.key_view) == len(cfg.pattern))
        self.prefix: Optional[PrefixIndex] = (
            PrefixIndex(capacity=prefix_capacity)
            if prefix_sharing and shareable else None)
        # shared_pos must stay aligned to the scheduler's prefill-chunk
        # quantum (lcm'd with the block size by the caller): chunk-step
        # and decode-ramp KV are not interchangeable bitwise, and chunk
        # boundaries are absolute — so a sharer's remaining prefill must
        # chunk at the same offsets the unshared run would
        self.prefix_align = max(prefix_align or block_size, block_size)
        self._shared_pos: Dict[int, int] = {}   # slot -> prefill start
        self.cow_copies = 0         # CoW block copies, cumulative
        self.shared_chunks_mapped = 0   # chunks admitted read-shared
        # one-slot dense snapshot size is a constant (the template IS
        # that snapshot's shape): precompute for swap_bytes_estimate
        self._dense_slot_bytes = int(sum(
            l.nbytes for l in jax.tree_util.tree_leaves(self._template)))
        self._rows_cache: Optional[Dict[str, jnp.ndarray]] = None
        # bumped on every mapping change; the sharded owner keys its
        # concatenated rows cache on the tuple of shard epochs
        self._rows_epoch = 0

    def _invalidate_rows(self):
        self._rows_cache = None
        self._rows_epoch += 1

    # -- device-op hooks -------------------------------------------------
    # All device-array access funnels through these so _ShardState can
    # redirect a shard's ops into the owner's STACKED arrays (offsetting
    # slot indices and block rows) while the host-side bookkeeping above
    # stays byte-for-byte the same code path.

    def _dev_dense_reset(self, slot: int):
        self.dense = _reset(self.dense, self._template,
                            jnp.asarray([slot], jnp.int32))

    def _dev_dense_gather(self, slot: int):
        return jax.device_get(
            _gather(self.dense, jnp.asarray([slot], jnp.int32)))

    def _dev_dense_scatter(self, slot: int, sub):
        self.dense = _scatter(self.dense, sub,
                              jnp.asarray([slot], jnp.int32))

    def _dev_block_copy(self, g: "_PageGroup", src_rows, dst_rows):
        sub = {k: self.paged[k] for k in g.keys}
        self.paged.update(engine.copy_block_rows(sub, src_rows, dst_rows))

    def _dev_block_reset(self, g: "_PageGroup", rows):
        sub = {k: self.paged[k] for k in g.keys}
        self.paged.update(engine.reset_block_rows(sub, rows))

    def _dev_block_gather(self, g: "_PageGroup", rows):
        sub = {k: self.paged[k] for k in g.keys}
        return jax.device_get(engine.gather_block_rows(sub, rows))

    def _dev_block_upload(self, g: "_PageGroup", saved, rows):
        sub = {k: self.paged[k] for k in g.keys}
        self.paged.update(engine.upload_block_rows(sub, saved, rows))

    def _key_cache(self, key: str):
        """The flat paged array for ``key`` (shape queries only)."""
        return self.paged[key]

    @property
    def total_rows(self) -> int:
        """Attention cache positions actually allocated (physical block
        rows incl. each group's trash sentinel, plus any rings kept
        dense) — the equal-memory axis."""
        total = sum(len(g.keys) * (g.pool.num_blocks + 1) * self.block_size
                    for g in self.groups.values())
        for i, spec in enumerate(self.cfg.pattern):
            if spec.mixer == "attn" and f"p{i}" not in self.key_view:
                total += self.num_slots * _attn_view_len(spec,
                                                         self.cache_slots)
        return total

    # -- prefix sharing --------------------------------------------------

    def _share_cap(self, prompt_len: int, span: int) -> int:
        """Leading blocks of a ``prompt_len`` prompt eligible for
        read-sharing, given the request will write ``span`` positions
        total (prompt + generation budget). The block holding the last
        prompt position stays private (its KV is written during this
        request's prefill/decode), and a ring group only shares when the
        whole span fits its ring — a wrapped write would land inside the
        shared prefix, forcing a CoW the reserved-admission path could
        not absorb. 0 disables sharing for this request."""
        if self.prefix is None or prompt_len < 2:
            return 0
        cap = (prompt_len - 1) // self.block_size
        for g in self.groups.values():
            if g.ring:
                if span > g.view_len:
                    return 0
                cap = min(cap, g.view_len // self.block_size)
            else:
                cap = min(cap, g.pt.blocks_per_slot)
        return max(cap, 0)

    def _match_shared(self, prompt, prompt_len: int, span: int) \
            -> Tuple[int, List[Dict[int, int]], List[bytes]]:
        """Longest admissible shared prefix for ``prompt``: number of
        blocks (aligned down to the prefill-chunk quantum), the per-chunk
        {view_len: block} entries, and the chunk digests (LRU-refreshed —
        reclaim spares them)."""
        cap = self._share_cap(prompt_len, span)
        if cap <= 0:
            return 0, [], []
        keys = PrefixIndex.chunk_keys(prompt, self.block_size, cap)
        hit = self.prefix.match(keys)
        # align the shared region down to whole prefill chunks
        step = self.prefix_align // self.block_size
        n = (len(hit) // max(step, 1)) * max(step, 1)
        return n, hit[:n], keys

    def _reclaim(self, g: _PageGroup, need: int,
                 keep: Sequence[bytes] = ()) -> bool:
        """Free blocks for ``need`` new mappings in group ``g`` by
        evicting cold PrefixIndex entries (skipping ``keep`` — the chain
        the current admission is about to map). Evicting an entry only
        liberates blocks no live slot still shares; the loop runs until
        the group can map or the index is dry."""
        if self.prefix is None:
            return g.pt.can_map(need)
        keep_set = set(keep)
        while not g.pt.can_map(need):
            dropped = self.prefix.evict_lru(keep=keep_set)
            if dropped is None:
                return False
            for vl, b in dropped.items():
                self.groups[vl].pool.free(b)
        return True

    def prefill_start(self, slot: int) -> int:
        """First position ``slot``'s prefill must write — nonzero when
        admission mapped a shared prefix (its KV is already resident)."""
        return self._shared_pos.get(slot, 0)

    def register_prefix(self, slot: int, prompt, span: int,
                        upto_tokens: int) -> int:
        """Publish ``slot``'s fully-prefilled leading blocks into the
        PrefixIndex (called once prefill completes). Only positions
        consumed via chunk steps or inherited shared blocks
        (``upto_tokens``) are eligible — decode-ramp KV is not
        bitwise-interchangeable with the chunk-step KV an unshared run
        would compute. Each published block gains an index-held
        reference, so it outlives this donor. Returns entries
        inserted."""
        if self.prefix is None:
            return 0
        cap = min(self._share_cap(len(prompt), span),
                  max(upto_tokens, 0) // self.block_size)
        if cap <= 0:
            return 0
        keys = PrefixIndex.chunk_keys(prompt, self.block_size, cap)
        inserted = 0
        for i, key in enumerate(keys):
            blocks: Dict[int, int] = {}
            for vl, g in self.groups.items():
                b = int(g.pt.table[slot, i])
                if b == g.pt.trash:
                    blocks = {}
                    break
                blocks[vl] = b
            if not blocks:
                break
            for vl, b in blocks.items():
                self.groups[vl].pool.ref(b)
            if self.prefix.publish(key, blocks):
                inserted += 1
            else:           # already indexed (first publisher won)
                for vl, b in blocks.items():
                    self.groups[vl].pool.free(b)
        while len(self.prefix) > self.prefix.capacity:
            dropped = self.prefix.evict_lru()
            for vl, b in dropped.items():
                self.groups[vl].pool.free(b)
        return inserted

    def flush_prefix(self) -> int:
        """Drop every PrefixIndex entry (releasing the index's block
        references) — the test/leak-check hook: after a flush and full
        retire, blocks_used must be 0 again."""
        if self.prefix is None:
            return 0
        n = 0
        while True:
            dropped = self.prefix.evict_lru()
            if dropped is None:
                return n
            for vl, b in dropped.items():
                self.groups[vl].pool.free(b)
            n += 1

    def prefix_holds(self) -> Dict[int, np.ndarray]:
        """Per-group index-held refcounts (check_invariants helper)."""
        if self.prefix is None:
            return {vl: np.zeros(g.pool.num_blocks, np.int64)
                    for vl, g in self.groups.items()}
        return self.prefix.holds(
            {vl: g.pool.num_blocks for vl, g in self.groups.items()})

    # -- page-table lifecycle -------------------------------------------

    def can_admit(self, prompt_len: int, prompt=None,
                  span: Optional[int] = None) -> bool:
        n = max(prompt_len, 1)
        shared, _, keys = (self._match_shared(prompt, len(prompt),
                                              span or prompt_len)
                           if prompt is not None and self.prefix is not None
                           else (0, [], []))
        return all(self._reclaim(g, g.pt.blocks_for(n) - shared, keep=keys)
                   for g in self.groups.values())

    def fits_pool(self, n_positions: int) -> Optional[str]:
        """None if a request spanning ``n_positions`` could be mapped on
        an EMPTY pool (every group), else why not — the submit-time
        feasibility check behind the scheduler's progress guarantee.
        Ring groups clamp via blocks_for: a ring never needs more than
        the full ring resident."""
        for g in self.groups.values():
            need = g.pt.blocks_for(n_positions)
            if need > g.pool.num_blocks:
                what = (f"window-{g.view_len} ring" if g.ring
                        else "global-KV")
                return (f"request needs {need} {what} blocks > pool "
                        f"{g.pool.num_blocks}")
        return None

    def alloc_reset(self, slot: int, prompt_len: int, prompt=None,
                    span: Optional[int] = None) -> int:
        """Reset ``slot`` and map its prompt blocks. With prefix sharing
        on and ``prompt`` given, the longest indexed chunk-aligned
        prefix is mapped read-shared first (its KV is already resident —
        prefill starts past it); the remainder maps private as usual.
        Returns the prefill start position (0 without a hit)."""
        self._dev_dense_reset(slot)
        shared_pos = 0
        if self.prefix is not None and prompt is not None:
            n, hit, _ = self._match_shared(prompt, len(prompt),
                                           span or prompt_len)
            if n:
                for vl, g in self.groups.items():
                    g.pt.map_shared(slot, [e[vl] for e in hit])
                shared_pos = n * self.block_size
                self.shared_chunks_mapped += n
                self._invalidate_rows()
        self._shared_pos[slot] = shared_pos
        ok = self.ensure(slot, max(prompt_len, 1) - 1)
        if not ok:
            raise RuntimeError(
                "alloc_reset after can_admit ran out of blocks")
        return shared_pos

    def _cow_copy(self, g: _PageGroup, pairs: List[Tuple[int, int]]):
        """Duplicate each (old, new) physical block pair on device —
        pow2-padded with trash->trash pairs like every block-rows
        kernel."""
        n = bucketing.round_up_pow2(len(pairs), 1)
        srcs = [p[0] for p in pairs] + [g.pt.trash] * (n - len(pairs))
        dsts = [p[1] for p in pairs] + [g.pt.trash] * (n - len(pairs))
        self._dev_block_copy(
            g, jnp.asarray(PageTable.block_rows(srcs, self.block_size)),
            jnp.asarray(PageTable.block_rows(dsts, self.block_size)))
        self.cow_copies += len(pairs)
        self._invalidate_rows()

    def ensure(self, slot: int, upto_pos: int,
               write_from: Optional[int] = None) -> bool:
        """Map (and zero) every block covering positions [0, upto_pos] in
        every group — ring groups clamp to their ring, so past the window
        they are a no-op — and copy-on-write any *shared* block the
        upcoming write over [``write_from``, ``upto_pos``] (default: just
        ``upto_pos``, the decode case) would touch: the writer gets a
        private copy, so no sharer ever observes the write. False on pool
        exhaustion (the scheduler's preempt-on-OOB path); blocks mapped
        or copied so far stay, and a retry after preemption is
        idempotent."""
        lo = upto_pos if write_from is None else write_from
        ok_all = True
        for g in self.groups.values():
            if g.pool.shared_count:
                pairs: List[Tuple[int, int]] = []
                for lb in g.pt.write_blocks(slot, lo, upto_pos):
                    if not g.pt.is_shared(slot, lb):
                        continue
                    got = g.pt.cow_block(slot, lb)
                    if got is None and self._reclaim(g, 1):
                        got = g.pt.cow_block(slot, lb)
                    if got is None:
                        ok_all = False
                        break
                    pairs.append(got)
                if pairs:
                    self._cow_copy(g, pairs)
            ok, new = g.pt.ensure(slot, upto_pos)
            if not ok and self._reclaim(g, 1):
                ok, more = g.pt.ensure(slot, upto_pos)
                new = new + more
            if new:
                # pow2-pad the reset batch with trash-block rows so the
                # jitted reset compiles O(log blocks_per_slot) shapes,
                # not one per count
                n = bucketing.round_up_pow2(len(new), 1)
                blocks = list(new) + [g.pt.trash] * (n - len(new))
                rows = PageTable.block_rows(blocks, self.block_size)
                self._dev_block_reset(g, jnp.asarray(rows))
                self._invalidate_rows()
            ok_all = ok_all and ok
        return ok_all

    def release_slot(self, slot: int) -> List[int]:
        freed: List[int] = []
        for g in self.groups.values():
            freed += g.pt.free_slot(slot)
        self._shared_pos.pop(slot, None)
        if freed:
            self._invalidate_rows()
        return freed

    # -- swap-out preemption --------------------------------------------

    def _swap_rows(self, g: _PageGroup, blocks: List[int]) -> jnp.ndarray:
        """Flat rows for a block list, pow2-padded with trash rows so the
        jitted gather/upload compile O(log blocks_per_slot) shapes."""
        n = bucketing.round_up_pow2(len(blocks), 1)
        padded = list(blocks) + [g.pt.trash] * (n - len(blocks))
        return jnp.asarray(PageTable.block_rows(padded, self.block_size))

    def swap_bytes_estimate(self, slot: int) -> int:
        """Bytes a swap_out of ``slot`` would park host-side — computed
        from shapes BEFORE any device gather, so a SwapStore budget
        rejection costs nothing."""
        bs = self.block_size
        total = self._dense_slot_bytes
        for g in self.groups.values():
            nb = g.pt.mapped_blocks(slot)
            for key in g.keys:
                c = self._key_cache(key)
                row = (int(np.prod(c.k.shape[2:])) * c.k.dtype.itemsize
                       + int(np.prod(c.v.shape[2:])) * c.v.dtype.itemsize
                       + c.pos.dtype.itemsize)
                total += nb * bs * row * c.k.shape[0]
        return total

    def swap_out(self, slot: int, rid: int) -> Optional[int]:
        """Copy ``slot``'s mapped block bytes (every group) + dense
        leaves to the host SwapStore (keyed by ``rid``) and free the
        physical blocks — the victim's decode work survives eviction.
        Returns bytes moved, or None when the store's byte budget cannot
        hold the entry (nothing is gathered or freed; the scheduler falls
        back to recompute-preemption for this victim)."""
        if self.swaps.max_bytes is not None \
                and not self.swaps.can_hold(self.swap_bytes_estimate(slot)):
            self.swaps.reject()         # the store owns the count
            return None
        bs = self.block_size
        blocks: Dict[int, int] = {}
        paged_host: Dict[str, attention.KVCache] = {}
        for vl, g in self.groups.items():
            phys = [int(b) for b in g.pt.table[slot] if b != g.pt.trash]
            blocks[vl] = len(phys)
            if phys and g.keys:
                keep = len(phys) * bs
                got = self._dev_block_gather(g, self._swap_rows(g, phys))
                paged_host.update({
                    key: attention.KVCache(k=c.k[:, :keep], v=c.v[:, :keep],
                                           pos=c.pos[:, :keep])
                    for key, c in got.items()})
            # shared blocks are RELEASED, not stolen: the bytes were just
            # gathered (a copy), and swap_out only drops this slot's
            # reference — sharers and the PrefixIndex keep theirs
            _, released = g.pt.swap_out(slot)
            if sorted(released) != sorted(phys):
                raise RuntimeError(f"swap_out released {released} != "
                                   f"mapped {phys} (group {vl})")
            if released:
                self._invalidate_rows()
        dense_host = self._dev_dense_gather(slot)
        self._shared_pos.pop(slot, None)
        return self.swaps.put(rid, SwapEntry(
            blocks=blocks, paged=paged_host, dense=dense_host))

    def can_admit_swapped(self, rid: int) -> bool:
        entry = self.swaps.get(rid)
        return all(self._reclaim(g, entry.blocks.get(vl, 0))
                   for vl, g in self.groups.items())

    def swap_in(self, slot: int, rid: int) -> int:
        """Resume ``rid`` in (free, unreset) ``slot``: map fresh blocks
        for each group's saved logical prefix, upload the saved bytes,
        scatter the dense snapshot — every cache row the request had
        written reads bit-identically to the never-preempted layout.
        Returns bytes moved. Caller guarantees can_admit_swapped just
        held."""
        bs = self.block_size
        entry = self.swaps.pop(rid)
        for vl, g in self.groups.items():
            nb = entry.blocks.get(vl, 0)
            if not nb:
                continue
            new = g.pt.swap_in(slot, nb)
            if new is None:
                raise RuntimeError(
                    "swap_in after can_admit_swapped ran out of blocks")
            if g.keys:
                rows = self._swap_rows(g, new)
                pad = int(rows.shape[0]) - nb * bs
                saved = {
                    key: attention.KVCache(
                        k=_pad_rows(entry.paged[key].k, pad),
                        v=_pad_rows(entry.paged[key].v, pad),
                        pos=_pad_rows(entry.paged[key].pos, pad))
                    for key in g.keys}
                self._dev_block_upload(g, saved, rows)
            self._invalidate_rows()
        self._dev_dense_scatter(slot, entry.dense)
        self._shared_pos[slot] = 0      # resumed mappings are private
        return entry.nbytes

    # -- device-facing row vectors --------------------------------------

    def _rows_all(self) -> Dict[str, jnp.ndarray]:
        if self._rows_cache is None:
            per_group = {vl: jnp.asarray(g.pt.rows())
                         for vl, g in self.groups.items()}
            self._rows_cache = {key: per_group[vl]
                                for key, vl in self.key_view.items()}
        return self._rows_cache

    def _rows_for(self, idx) -> Dict[str, jnp.ndarray]:
        per_group = {vl: jnp.asarray(g.pt.rows(idx))
                     for vl, g in self.groups.items()}
        return {key: per_group[vl] for key, vl in self.key_view.items()}

    # -- data movement ---------------------------------------------------

    def gather(self, idx):
        sub = _gather(self.dense, jnp.asarray(idx, jnp.int32))
        rows = self._rows_for(idx)
        for key, flat in self.paged.items():
            sub[key] = dict(sub[key])
            sub[key]["attn"] = attention.paged_view(
                flat, rows[key],
                attention.paged_live_rows(flat, self.block_size))
        return sub

    def scatter(self, sub, idx):
        """Write a gathered sub-tree back. View positions whose blocks are
        unmapped scatter into the trash block (dropped) — callers only
        write back what gather handed out, so mapped data round-trips."""
        rows = self._rows_for(idx)
        stripped = {}
        for key, entry in sub.items():
            if key in self.paged:
                entry = dict(entry)
                self.paged[key] = attention.paged_writeback(
                    self.paged[key], entry["attn"], rows[key])
                entry["attn"] = None
            stripped[key] = entry
        self.dense = _scatter(self.dense, stripped,
                              jnp.asarray(idx, jnp.int32))

    def run_chunk(self, params, idx, tokens, pos):
        rows = self._rows_for(idx)
        logits, self.dense, self.paged = engine.jit_paged_chunk_step(
            self.cfg)(
            params, self.dense, self.paged, jnp.asarray(idx, jnp.int32),
            rows, jnp.asarray(tokens), jnp.asarray(pos), self.block_size)
        return logits

    def run_decode(self, params, tokens, pos, temps, key,
                   top_ks=None, top_ps=None):
        b = tokens.shape[0]
        if top_ks is None:
            top_ks = jnp.zeros((b,), jnp.int32)
        if top_ps is None:
            top_ps = jnp.ones((b,), jnp.float32)
        nxt, logits, self.dense, self.paged = engine.jit_paged_decode_step(
            self.cfg)(params, self.dense, self.paged, self._rows_all(),
                      tokens, pos, temps, key, top_ks, top_ps,
                      self.block_size)
        return nxt, logits

    def run_verify(self, params, tokens, pos, prompt_len, max_pos, score,
                   active, temps, top_ks, top_ps, key):
        out_tok, n, lp, self.dense, self.paged = engine.jit_paged_verify_step(
            self.cfg)(params, self.dense, self.paged, self._rows_all(),
                      tokens, pos, prompt_len, max_pos, score, active,
                      temps, top_ks, top_ps, key, self.block_size)
        return out_tok, n, lp

    def stats(self) -> dict:
        used = sum(g.pool.used_count for g in self.groups.values())
        total = sum(g.pool.num_blocks for g in self.groups.values())
        prefix_stats = (self.prefix.stats() if self.prefix is not None
                        else {"prefix_entries": 0, "prefix_lookups": 0,
                              "prefix_hit_chunks": 0, "prefix_published": 0,
                              "prefix_evicted": 0})
        out = {"allocator": "paged",
               "page_groups": len(self.groups),
               "blocks_total": total,
               "blocks_used": used,
               "blocks_free": total - used,
               "block_size": self.block_size,
               "block_utilization": used / max(total, 1),
               "shared_blocks": sum(g.pool.shared_count
                                    for g in self.groups.values()),
               "cow_copies": self.cow_copies,
               "prefix_shared_chunks": self.shared_chunks_mapped,
               **prefix_stats,
               **self.swaps.stats()}
        for vl, g in self.groups.items():
            if g.ring:
                out[f"ring{vl}_blocks_total"] = g.pool.num_blocks
                out[f"ring{vl}_blocks_used"] = g.pool.used_count
        return out

    def metrics(self) -> dict:
        """Registry 'paging' provider: the numeric stats() keys."""
        return {k: v for k, v in self.stats().items() if k != "allocator"}


# ---------------------------------------------------------------------------
# the sharded backing: per-shard block pools over stacked device arrays
# ---------------------------------------------------------------------------

class _ShardState(_PagedBacking):
    """Host-side state of ONE shard of a sharded pool: its own
    BlockPool/PageTable groups, SwapStore, PrefixIndex and shared-pos map
    — block ids never cross shards, so paging, CoW sharing, swap and the
    window rings stay shard-local by construction. Device ops are
    redirected into the owner's STACKED arrays: slot indices offset by
    the shard's dense segment, block rows by its flat-pool segment."""

    def __init__(self, owner: "_ShardedPagedBacking", shard: int,
                 *args, **kw):
        self._owner = owner
        self.shard = shard
        super().__init__(*args, create_arrays=False,
                         dense_probe=owner.dense,
                         template=owner._template, **kw)

    # -- offsets ---------------------------------------------------------

    def _gslot(self, slot: int) -> jnp.ndarray:
        return jnp.asarray([self.shard * self.num_slots + slot], jnp.int32)

    def _row_base(self, g: _PageGroup) -> int:
        return self.shard * (g.pool.num_blocks + 1) * self.block_size

    # -- device-op hooks over the owner's stacked arrays -----------------

    def _dev_dense_reset(self, slot: int):
        o = self._owner
        o.dense = _reset(o.dense, o._template, self._gslot(slot))

    def _dev_dense_gather(self, slot: int):
        return jax.device_get(
            _gather(self._owner.dense, self._gslot(slot)))

    def _dev_dense_scatter(self, slot: int, sub):
        o = self._owner
        o.dense = _scatter(o.dense, sub, self._gslot(slot))

    def _dev_block_copy(self, g: _PageGroup, src_rows, dst_rows):
        o, base = self._owner, self._row_base(g)
        sub = {k: o.paged[k] for k in g.keys}
        o.paged.update(engine.copy_block_rows(sub, src_rows + base,
                                              dst_rows + base))

    def _dev_block_reset(self, g: _PageGroup, rows):
        o = self._owner
        sub = {k: o.paged[k] for k in g.keys}
        o.paged.update(engine.reset_block_rows(
            sub, rows + self._row_base(g)))

    def _dev_block_gather(self, g: _PageGroup, rows):
        o = self._owner
        sub = {k: o.paged[k] for k in g.keys}
        return jax.device_get(engine.gather_block_rows(
            sub, rows + self._row_base(g)))

    def _dev_block_upload(self, g: _PageGroup, saved, rows):
        o = self._owner
        sub = {k: o.paged[k] for k in g.keys}
        o.paged.update(engine.upload_block_rows(
            sub, saved, rows + self._row_base(g)))

    def _key_cache(self, key: str):
        return self._owner.paged[key]


class _ShardedPagedBacking:
    """The paged slot pool sharded over a 1-D device mesh.

    Stacked device arrays hold every shard's segment back-to-back —
    dense leaves carry ``num_shards * slots_per_shard`` slots on the
    slot axis; each paged flat pool holds ``num_shards`` segments of
    ``(num_blocks + 1) * block_size`` rows, each segment ending in its
    OWN trash block — and one fused program per tick spans all shards
    (``engine.jit_sharded_*_step``: a delegate to the unsharded program
    at ``num_shards == 1``, vmap over the shard axis without a mesh,
    ``shard_map`` over ``mesh``'s axis with one). All host bookkeeping
    (pools, page tables, swap stores, prefix indices) lives per shard in
    ``_ShardState``s: a block id is only ever meaningful within its
    shard, so nothing block-granular crosses shards — the ONLY cross-
    shard channel is ``migrate_swapped``, which hands a host-side
    SwapEntry between shard SwapStores (work-stealing a preempted
    request without losing its prefill progress)."""

    is_paged = True
    is_sharded = True

    def __init__(self, cfg: ModelConfig, num_slots: int, cache_slots: int,
                 block_size: int, num_blocks: Optional[int],
                 paged_window: bool = True,
                 num_window_blocks: Optional[int] = None,
                 swap_bytes_budget: Optional[int] = None,
                 prefix_sharing: bool = False,
                 prefix_align: Optional[int] = None,
                 prefix_capacity: int = 512, *,
                 num_shards: int = 1, mesh=None,
                 axis: Optional[str] = None):
        engine._check_shard_mesh(num_shards, mesh, axis)
        if num_slots % num_shards:
            raise ValueError(f"num_slots={num_slots} must divide evenly "
                             f"over {num_shards} shard(s)")
        self.cfg = cfg
        self.num_slots = num_slots
        self.num_shards = num_shards
        self.slots_per_shard = num_slots // num_shards
        self.cache_slots = cache_slots
        self.block_size = block_size
        self.mesh = mesh
        self.axis = axis
        self.dense = T.init_caches(cfg, num_slots, cache_slots,
                                   per_slot_pos=True, paged_global_attn=True,
                                   paged_window_attn=paged_window)
        self._template = T.init_caches(cfg, 1, cache_slots,
                                       per_slot_pos=True,
                                       paged_global_attn=True,
                                       paged_window_attn=paged_window)
        # num_blocks / num_window_blocks / swap_bytes_budget are PER
        # SHARD: mesh scaling holds per-device cache memory constant and
        # multiplies capacity by the shard count
        self.shards = [
            _ShardState(self, s, cfg, self.slots_per_shard, cache_slots,
                        block_size, num_blocks, paged_window=paged_window,
                        num_window_blocks=num_window_blocks,
                        swap_bytes_budget=swap_bytes_budget,
                        prefix_sharing=prefix_sharing,
                        prefix_align=prefix_align,
                        prefix_capacity=prefix_capacity)
            for s in range(num_shards)]
        s0 = self.shards[0]
        self.key_view = s0.key_view
        self.paged = {
            key: attention.make_paged_cache(
                num_shards * (g.pool.num_blocks + 1) - 1, block_size,
                cfg.num_kv_heads, cfg.head_dim, periods=cfg.num_periods)
            for g in s0.groups.values() for key in g.keys}
        self.position_capacity = num_shards * s0.position_capacity
        self._rows_cache: Optional[Dict[str, jnp.ndarray]] = None
        self._rows_key: Optional[Tuple[int, ...]] = None

    @property
    def total_rows(self) -> int:
        return self.num_shards * self.shards[0].total_rows

    def _loc(self, slot: int) -> Tuple[_ShardState, int]:
        return (self.shards[slot // self.slots_per_shard],
                slot % self.slots_per_shard)

    def shard_of(self, slot: int) -> int:
        return slot // self.slots_per_shard

    def shard_free_blocks(self, shard: int) -> int:
        """Free blocks across the shard's groups — the least-loaded
        placement signal."""
        return sum(g.pool.num_blocks - g.pool.used_count
                   for g in self.shards[shard].groups.values())

    # -- routed lifecycle (slot ids are GLOBAL; block state shard-local) -

    def can_admit(self, prompt_len: int, prompt=None,
                  span: Optional[int] = None, shard: int = 0) -> bool:
        return self.shards[shard].can_admit(prompt_len, prompt=prompt,
                                            span=span)

    def fits_pool(self, n_positions: int) -> Optional[str]:
        return self.shards[0].fits_pool(n_positions)

    def alloc_reset(self, slot: int, prompt_len: int, prompt=None,
                    span: Optional[int] = None) -> int:
        sh, loc = self._loc(slot)
        return sh.alloc_reset(loc, prompt_len, prompt=prompt, span=span)

    def prefill_start(self, slot: int) -> int:
        sh, loc = self._loc(slot)
        return sh.prefill_start(loc)

    def register_prefix(self, slot: int, prompt, span: int,
                        upto_tokens: int) -> int:
        sh, loc = self._loc(slot)
        return sh.register_prefix(loc, prompt, span, upto_tokens)

    def flush_prefix(self) -> int:
        return sum(sh.flush_prefix() for sh in self.shards)

    def ensure(self, slot: int, upto_pos: int,
               write_from: Optional[int] = None) -> bool:
        sh, loc = self._loc(slot)
        return sh.ensure(loc, upto_pos, write_from=write_from)

    def release_slot(self, slot: int) -> List[int]:
        sh, loc = self._loc(slot)
        return sh.release_slot(loc)

    # -- swap + cross-shard migration ------------------------------------

    def swap_bytes_estimate(self, slot: int) -> int:
        sh, loc = self._loc(slot)
        return sh.swap_bytes_estimate(loc)

    def swap_out(self, slot: int, rid: int) -> Optional[int]:
        sh, loc = self._loc(slot)
        return sh.swap_out(loc, rid)

    def swapped_shard(self, rid: int) -> Optional[int]:
        for s, sh in enumerate(self.shards):
            if rid in sh.swaps:
                return s
        return None

    def can_admit_swapped(self, rid: int) -> bool:
        s = self.swapped_shard(rid)
        return s is not None and self.shards[s].can_admit_swapped(rid)

    def swap_in(self, slot: int, rid: int) -> int:
        sh, loc = self._loc(slot)
        if rid not in sh.swaps:
            raise RuntimeError(
                f"rid {rid} is not swapped on shard {sh.shard} — "
                "migrate_swapped before a cross-shard swap_in")
        return sh.swap_in(loc, rid)

    def migrate_swapped(self, rid: int, dst_shard: int) -> bool:
        """Move ``rid``'s parked SwapEntry from its home shard's store to
        ``dst_shard``'s (the work-stealing path: host bytes change owner,
        nothing touches the device, prefill progress is preserved).
        False when the entry isn't swapped, is already there, or the
        destination's byte budget can't hold it — the caller simply
        leaves the request where it is."""
        src = self.swapped_shard(rid)
        if src is None or src == dst_shard:
            return False
        dst = self.shards[dst_shard].swaps
        entry = self.shards[src].swaps.get(rid)
        if dst.max_bytes is not None and not dst.can_hold(entry.nbytes):
            return False
        dst.migrate_in(rid, self.shards[src].swaps.migrate_out(rid))
        return True

    def can_steal_swapped(self, rid: int, dst_shard: int) -> bool:
        """True when ``dst_shard`` could hold AND admit ``rid``'s parked
        entry right now: its SwapStore budget fits the bytes and every
        page-table group can reclaim the saved block count. The steal
        pass checks this BEFORE migrating, so a steal never strands an
        entry on a shard that can't admit it."""
        src = self.swapped_shard(rid)
        if src is None or src == dst_shard:
            return False
        entry = self.shards[src].swaps.get(rid)
        dst = self.shards[dst_shard]
        if dst.swaps.max_bytes is not None \
                and not dst.swaps.can_hold(entry.nbytes):
            return False
        return all(dst._reclaim(g, entry.blocks.get(vl, 0))
                   for vl, g in dst.groups.items())

    # -- device-facing row vectors ---------------------------------------

    def _rows_all(self) -> Dict[str, jnp.ndarray]:
        """Shard-LOCAL rows, concatenated (num_slots, V) per key — the
        fused sharded steps split the slot axis so each shard indexes its
        own flat-pool segment. Cached on the tuple of shard epochs."""
        key = tuple(sh._rows_epoch for sh in self.shards)
        if self._rows_cache is None or self._rows_key != key:
            per = [sh._rows_all() for sh in self.shards]
            self._rows_cache = {
                k: jnp.concatenate([p[k] for p in per], axis=0)
                for k in per[0]}
            self._rows_key = key
        return self._rows_cache

    def _rows_for(self, idx) -> Dict[str, jnp.ndarray]:
        """GLOBAL stacked-array rows for slots ``idx`` (host gather /
        scatter paths): shard-local rows offset into the shard's flat
        segment, with each shard's local trash rows canonicalized onto
        the stacked pool's LAST block — paged_view/paged_writeback treat
        rows past ``total - block_size`` as trash, so per-shard trash
        keeps masking globally."""
        n, bs = self.num_shards, self.block_size
        per_vl: Dict[int, jnp.ndarray] = {}
        for vl, g0 in self.shards[0].groups.items():
            nb = g0.pool.num_blocks
            seg, live = (nb + 1) * bs, nb * bs
            rows = []
            for slot in idx:
                sh, loc = self._loc(slot)
                r = np.asarray(sh.groups[vl].pt.rows([loc]))[0]
                rows.append(np.where(r >= live,
                                     n * seg - bs + (r - live),
                                     sh.shard * seg + r))
            per_vl[vl] = jnp.asarray(np.stack(rows))
        return {k: per_vl[vl] for k, vl in self.key_view.items()}

    # gather/scatter operate on self.dense/self.paged/self._rows_for with
    # GLOBAL rows — the _PagedBacking bodies apply verbatim
    gather = _PagedBacking.gather
    scatter = _PagedBacking.scatter

    # -- fused steps ------------------------------------------------------

    def _keys_for(self, key) -> jnp.ndarray:
        """(num_shards, 2) per-shard PRNG keys. One shard passes the key
        through untouched (the delegate path consumes the same bits the
        unsharded step would — bit-identical sampled streams); more
        shards split it (sampled streams legitimately diverge across
        shard counts; greedy is the cross-count correctness bar)."""
        return key[None] if self.num_shards == 1 \
            else jax.random.split(key, self.num_shards)

    def run_chunk(self, params, idx, tokens, pos):
        """Chunk-prefill slots ``idx`` (GLOBAL ids, UNPADDED — unlike the
        single-pool backing, the owner pads per shard: each shard's
        sub-batch pads by repeating its first entry to a common pow2
        width; a shard with nothing to prefill runs dead — its rows point
        at its trash block and its dense writes are reverted in-program).
        Returns per-position logits (len(idx), C, V) in input order."""
        n, k = self.num_shards, self.slots_per_shard
        tokens = np.asarray(tokens)
        pos_in = np.asarray(pos)
        per: List[List[int]] = [[] for _ in range(n)]
        t_of = np.zeros(len(idx), np.int32)
        for j, slot in enumerate(idx):
            s = slot // k
            t_of[j] = len(per[s])
            per[s].append(j)
        m = bucketing.round_up_pow2(max(len(p) for p in per), 1)
        idx_a = np.zeros((n, m), np.int32)
        tok_a = np.zeros((n, m) + tokens.shape[1:], tokens.dtype)
        pos_a = np.zeros((n, m), pos_in.dtype)
        live = np.zeros((n,), bool)
        shard_rows: List[Dict[str, jnp.ndarray]] = []
        for s in range(n):
            js = per[s]
            if js:
                live[s] = True
                js = js + [js[0]] * (m - len(js))   # pad-by-repeat
                loc = [idx[j] - s * k for j in js]
                idx_a[s] = loc
                tok_a[s] = tokens[js]
                pos_a[s] = pos_in[js]
                shard_rows.append(self.shards[s]._rows_for(loc))
            else:
                sh = self.shards[s]
                shard_rows.append({
                    key: jnp.full(
                        (m, vl),
                        sh.groups[vl].pool.num_blocks * self.block_size,
                        jnp.int32)
                    for key, vl in self.key_view.items()})
        rows = {key: jnp.stack([sr[key] for sr in shard_rows])
                for key in self.key_view}
        step = engine.jit_sharded_chunk_step(self.cfg, n, self.block_size,
                                             self.mesh, self.axis)
        logits, self.dense, self.paged = step(
            params, self.dense, self.paged, jnp.asarray(idx_a), rows,
            jnp.asarray(tok_a), jnp.asarray(pos_a), jnp.asarray(live))
        s_of = jnp.asarray([slot // k for slot in idx])
        return logits[s_of, jnp.asarray(t_of)]

    def run_decode(self, params, tokens, pos, temps, key,
                   top_ks=None, top_ps=None):
        b = tokens.shape[0]
        if top_ks is None:
            top_ks = jnp.zeros((b,), jnp.int32)
        if top_ps is None:
            top_ps = jnp.ones((b,), jnp.float32)
        step = engine.jit_sharded_decode_step(
            self.cfg, self.num_shards, self.block_size, self.mesh,
            self.axis)
        nxt, logits, self.dense, self.paged = step(
            params, self.dense, self.paged, self._rows_all(), tokens, pos,
            temps, self._keys_for(key), top_ks, top_ps)
        return nxt, logits

    def run_verify(self, params, tokens, pos, prompt_len, max_pos, score,
                   active, temps, top_ks, top_ps, key):
        step = engine.jit_sharded_verify_step(
            self.cfg, self.num_shards, self.block_size, self.mesh,
            self.axis)
        out_tok, acc, lp, self.dense, self.paged = step(
            params, self.dense, self.paged, self._rows_all(), tokens, pos,
            prompt_len, max_pos, score, active, temps, top_ks, top_ps,
            self._keys_for(key))
        return out_tok, acc, lp

    # -- stats ------------------------------------------------------------

    def stats(self) -> dict:
        agg: Dict[str, object] = {}
        for sh in self.shards:
            for k2, v in sh.stats().items():
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    continue
                agg[k2] = agg.get(k2, 0) + v
        agg["allocator"] = "paged"
        agg["page_groups"] = len(self.shards[0].groups)
        agg["block_size"] = self.block_size
        agg["block_utilization"] = (agg["blocks_used"]
                                    / max(agg["blocks_total"], 1))
        agg["num_shards"] = self.num_shards
        return agg

    metrics = _PagedBacking.metrics

    def shard_metrics(self) -> dict:
        """Per-shard block/swap gauges, ``shard<i>.``-prefixed (the
        SlotManager adds slot occupancy; the scheduler adds placement and
        steal counters on top under ``serve.shard``)."""
        out = {}
        for s, sh in enumerate(self.shards):
            st = sh.stats()
            out[f"shard{s}.blocks_free"] = st["blocks_free"]
            out[f"shard{s}.blocks_used"] = st["blocks_used"]
            out[f"shard{s}.swapped_held"] = st["swapped_held"]
        return out


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------

class SlotManager:
    """Fixed pool of ``num_slots`` decode-cache slots.

    Host-side bookkeeping (free list, per-slot owner + validity mask)
    plus jitted whole-pytree gather/scatter/reset over the pooled caches.
    Each slot's clock lives in the caches' per-row ``pos`` leaves (and
    the scheduler's request state); ``valid[i]`` masks live slots (the
    scheduler decodes the full pool every step; dead rows compute but
    are never read).

    ``paged=True`` swaps the storage backing for the block-granular
    allocator (module docstring): ``alloc`` then also needs the prompt's
    blocks free in every page-table group, ``ensure`` must be called
    before a slot's write position grows, and ``release`` returns the
    physical blocks it freed. ``paged_window`` (default on) pages
    sliding-window rings through ring-mode groups as well; off keeps
    them dense per slot (the PR-3/4 layout).
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, cache_slots: int,
                 *, paged: bool = False, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 paged_window: bool = True,
                 num_window_blocks: Optional[int] = None,
                 swap_bytes_budget: Optional[int] = None,
                 prefix_sharing: bool = False,
                 prefix_align: Optional[int] = None,
                 prefix_capacity: int = 512,
                 mesh_shards: Optional[int] = None,
                 mesh=None, mesh_axis: str = "slots"):
        self.cfg = cfg
        self.num_slots = num_slots
        self.cache_slots = cache_slots
        if prefix_sharing and not paged:
            raise ValueError("prefix_sharing needs the paged backing "
                             "(blocks are the sharing granule)")
        self.sharded = mesh_shards is not None
        if self.sharded and not paged:
            raise ValueError("mesh_shards needs the paged backing "
                             "(blocks are the per-shard granule)")
        if mesh is not None and not self.sharded:
            raise ValueError("mesh without mesh_shards: pass "
                             "mesh_shards=len(mesh devices)")
        self.num_shards = mesh_shards if self.sharded else 1
        if num_slots % self.num_shards:
            raise ValueError(f"num_slots={num_slots} must divide evenly "
                             f"over {self.num_shards} shard(s)")
        self.slots_per_shard = num_slots // self.num_shards
        if self.sharded:
            self.backing = _ShardedPagedBacking(
                cfg, num_slots, cache_slots, block_size, num_blocks,
                paged_window=paged_window,
                num_window_blocks=num_window_blocks,
                swap_bytes_budget=swap_bytes_budget,
                prefix_sharing=prefix_sharing, prefix_align=prefix_align,
                prefix_capacity=prefix_capacity, num_shards=mesh_shards,
                mesh=mesh, axis=mesh_axis if mesh is not None else None)
        else:
            self.backing = (_PagedBacking(
                cfg, num_slots, cache_slots, block_size, num_blocks,
                paged_window=paged_window,
                num_window_blocks=num_window_blocks,
                swap_bytes_budget=swap_bytes_budget,
                prefix_sharing=prefix_sharing, prefix_align=prefix_align,
                prefix_capacity=prefix_capacity)
                if paged else
                _ContiguousBacking(cfg, num_slots, cache_slots))
        self._free: List[int] = list(range(num_slots - 1, -1, -1))
        self.owner: List[Optional[int]] = [None] * num_slots
        self.valid = np.zeros(num_slots, bool)
        obs_metrics.REGISTRY.register_provider("serve.slots", self)
        if paged:
            obs_metrics.REGISTRY.register_provider("paging", self.backing)

    @property
    def paged(self) -> bool:
        return self.backing.is_paged

    @property
    def caches(self):
        """The pooled cache pytree (contiguous backing only — the paged
        backing's state is ``backing.dense`` + ``backing.paged``)."""
        return self.backing.caches

    @caches.setter
    def caches(self, value):
        assert not self.backing.is_paged, \
            "caches is the contiguous backing's state; the paged backing " \
            "holds backing.dense + backing.paged (use gather/scatter)"
        self.backing.caches = value

    @property
    def position_capacity(self) -> int:
        """Global-KV cache positions backing the pool (the equal-memory
        axis fig_serve's global-attention comparison uses)."""
        return self.backing.position_capacity

    @property
    def total_rows(self) -> int:
        """ALL attention cache positions allocated (global KV + window
        rings, paged incl. trash sentinels) — the equal-memory axis for
        windowed models."""
        return self.backing.total_rows

    # -- lifecycle -----------------------------------------------------------

    @property
    def free_count(self) -> int:
        return len(self._free)

    def free_count_shard(self, shard: int) -> int:
        k = self.slots_per_shard
        return sum(1 for i in self._free if i // k == shard)

    def shard_of_slot(self, slot: int) -> int:
        return slot // self.slots_per_shard

    def shard_free_blocks(self, shard: int) -> int:
        """Free blocks on ``shard`` (sharded backing) — the least-loaded
        placement signal the scheduler reads."""
        return self.backing.shard_free_blocks(shard)

    def _pop_free(self, shard: Optional[int]) -> int:
        """Claim a free slot — the most recently freed one (LIFO), or the
        most recently freed one WITHIN ``shard`` when given. With one
        shard both forms pop the same slot, so the sharded n=1 admission
        path allocates bit-identically to the unsharded one."""
        if shard is None:
            return self._free.pop()
        k = self.slots_per_shard
        for i in range(len(self._free) - 1, -1, -1):
            if self._free[i] // k == shard:
                return self._free.pop(i)
        raise RuntimeError(f"no free slot on shard {shard}")

    @property
    def live(self) -> List[int]:
        return [i for i in range(self.num_slots) if self.valid[i]]

    def can_admit(self, prompt_len: int = 0, prompt=None,
                  span: Optional[int] = None,
                  shard: Optional[int] = None) -> bool:
        """A free slot AND (paged) enough free blocks for the prompt in
        every page-table group. With prefix sharing, ``prompt`` (tokens)
        discounts blocks an indexed shared prefix already holds, and
        ``span`` (prompt + generation budget) bounds ring-group
        eligibility. On a sharded pool ``shard`` scopes both checks to
        that shard's slots and block pools."""
        if shard is not None:
            return (self.free_count_shard(shard) > 0
                    and self.backing.can_admit(prompt_len, prompt=prompt,
                                               span=span, shard=shard))
        return bool(self._free) and self.backing.can_admit(
            prompt_len, prompt=prompt, span=span)

    def fits_pool(self, n_positions: int) -> Optional[str]:
        """None if a request spanning ``n_positions`` could ever be
        mapped on an empty pool; else the reason it can't (the
        scheduler's submit-time ValueError)."""
        return self.backing.fits_pool(n_positions)

    def alloc(self, owner: int, prompt_len: int = 0, prompt=None,
              span: Optional[int] = None,
              shard: Optional[int] = None) -> Optional[int]:
        """Claim a free slot for request ``owner``; zero its cache rows
        (paged: map + zero the blocks covering the prompt — an indexed
        shared prefix of ``prompt`` maps read-shared instead, see
        ``prefill_start``). ``shard`` pins the slot to one shard of a
        sharded pool. Returns the slot index, or None when the
        pool/blocks are exhausted."""
        if not self.can_admit(prompt_len, prompt=prompt, span=span,
                              shard=shard):
            return None
        slot = self._pop_free(shard)
        self.backing.alloc_reset(slot, prompt_len, prompt=prompt, span=span)
        self.owner[slot] = owner
        self.valid[slot] = True
        return slot

    def prefill_start(self, slot: int) -> int:
        """First position ``slot``'s prefill must write: 0 normally, the
        shared-prefix length when the last alloc mapped indexed blocks
        (their KV is already resident — prefill skips them)."""
        return self.backing.prefill_start(slot)

    def register_prefix(self, slot: int, prompt, span: int,
                        upto_tokens: int) -> int:
        """Publish ``slot``'s prefilled leading blocks into the prefix
        index (paged + prefix_sharing only; no-op otherwise)."""
        return self.backing.register_prefix(slot, prompt, span, upto_tokens)

    def flush_prefix(self) -> int:
        """Drop every prefix-index entry (releases index block holds)."""
        return self.backing.flush_prefix()

    def ensure(self, slot: int, upto_pos: int,
               write_from: Optional[int] = None) -> bool:
        """Grow slot storage to cover writes over
        [``write_from`` (default ``upto_pos``), ``upto_pos``]. Always
        True for contiguous; paged backing also copies-on-write any
        shared block in the write span, and returns False when the pool
        is out of blocks (the scheduler then preempts)."""
        if not self.valid[slot]:
            raise RuntimeError(f"slot {slot} is not live")
        return self.backing.ensure(slot, upto_pos, write_from=write_from)

    def release(self, slot: int) -> List[int]:
        """Evict (EOS / max-tokens / abort / preempt): mark free; returns
        the physical blocks handed back (paged) — the stale cache rows are
        masked out by ``valid`` until the next alloc resets them."""
        if not self.valid[slot]:
            raise RuntimeError(f"slot {slot} is not live")
        self.owner[slot] = None
        self.valid[slot] = False
        self._free.append(slot)
        return self.backing.release_slot(slot)

    # -- swap-out preemption (paged backing only) -----------------------

    def swap_out(self, slot: int) -> Optional[int]:
        """Preempt WITHOUT discarding work: park the slot's mapped block
        bytes + dense leaves in the backing's SwapStore (keyed by the
        owning rid), free the blocks and the slot. Returns bytes moved
        to host — or None when the SwapStore byte budget rejects the
        entry, in which case the slot stays LIVE and the caller must
        fall back to recompute-preemption."""
        if not self.valid[slot]:
            raise RuntimeError(f"slot {slot} is not live")
        if not self.backing.is_paged:
            raise RuntimeError("swap-out needs the paged backing")
        rid = self.owner[slot]
        nbytes = self.backing.swap_out(slot, rid)
        if nbytes is None:
            return None
        self.owner[slot] = None
        self.valid[slot] = False
        self._free.append(slot)
        return nbytes

    def is_swapped(self, rid: int) -> bool:
        if not self.backing.is_paged:
            return False
        if self.sharded:
            return self.backing.swapped_shard(rid) is not None
        return rid in self.backing.swaps

    def swapped_shard(self, rid: int) -> Optional[int]:
        """Shard whose SwapStore holds ``rid`` (sharded backing)."""
        return self.backing.swapped_shard(rid)

    def migrate_swapped(self, rid: int, dst_shard: int) -> bool:
        """Work-steal a swapped-out request to ``dst_shard``'s SwapStore
        (host bytes change owner; prefill progress is preserved). False
        when not swapped / already there / over the destination budget."""
        return self.backing.migrate_swapped(rid, dst_shard)

    def can_steal_swapped(self, rid: int, dst_shard: int) -> bool:
        """Could ``dst_shard`` hold and admit ``rid``'s swapped entry
        right now (free slot + swap budget + free blocks)?"""
        return (self.free_count_shard(dst_shard) > 0
                and self.backing.can_steal_swapped(rid, dst_shard))

    def can_admit_swapped(self, rid: int) -> bool:
        """A free slot AND blocks for the request's saved prefix in
        every page-table group (sharded: both scoped to the shard whose
        store holds the entry)."""
        if self.sharded:
            s = self.backing.swapped_shard(rid)
            return (s is not None and self.free_count_shard(s) > 0
                    and self.backing.can_admit_swapped(rid))
        return bool(self._free) and self.backing.can_admit_swapped(rid)

    def swap_in(self, rid: int) -> Optional[Tuple[int, int]]:
        """Resume a swapped-out request: claim a free slot, remap fresh
        blocks and upload the saved bytes — the slot reads bit-identical
        to the never-preempted layout, so decode continues at the saved
        position with zero recomputed steps. Returns (slot, bytes moved),
        or None when the pool can't host it yet."""
        if not self.can_admit_swapped(rid):
            return None
        slot = self._pop_free(self.backing.swapped_shard(rid)
                              if self.sharded else None)
        nbytes = self.backing.swap_in(slot, rid)
        self.owner[slot] = rid
        self.valid[slot] = True
        return slot, nbytes

    # -- pooled-cache data movement -----------------------------------------

    def gather(self, idx: Sequence[int]):
        """Sub-caches for slots ``idx`` (batch axis = len(idx)). The paged
        backing materializes the page-table views — bit-identical to the
        contiguous rows for every mapped position."""
        return self.backing.gather(idx)

    def scatter(self, sub, idx: Sequence[int]):
        """Write sub-caches (from a bucketed chunk step) back into slots.
        Duplicate indices must carry identical rows (the pad-by-repeat
        contract): the scatter then stays deterministic."""
        self.backing.scatter(sub, idx)

    def run_chunk(self, params, idx: Sequence[int], tokens, pos):
        """Chunk-prefill slots ``idx`` in place (fused gather -> chunk ->
        scatter, one dispatch); returns the per-position chunk logits
        (len(idx), C, V) — prompt scoring reads them, plain prefill
        ignores them. Same pad-by-repeat contract as scatter."""
        return self.backing.run_chunk(params, idx, tokens, pos)

    def run_decode(self, params, tokens, pos, temps, key,
                   top_ks=None, top_ps=None):
        """ONE fused decode over the whole pool; returns (next tokens,
        logits (B, 1, V)). top_ks/top_ps are optional (B,) per-slot
        sampling filters (None = disabled). (Paged:
        gather-through-page-tables -> decode -> scatter, still one jitted
        program per tick.)"""
        return self.backing.run_decode(params, tokens, pos, temps, key,
                                       top_ks, top_ps)

    def run_verify(self, params, tokens, pos, prompt_len, max_pos, score,
                   active, temps, top_ks, top_ps, key):
        """ONE fused speculative verify-accept tick over the whole pool
        (engine.make_verify_step contract): teacher-forces tokens
        (B, k+1), returns (out_tok (B, k+1), accept_n (B,), logprobs
        (B, k+1)); rejected cache writes are rolled back in-program, so
        the pool only ever holds committed rows."""
        return self.backing.run_verify(params, tokens, pos, prompt_len,
                                       max_pos, score, active, temps,
                                       top_ks, top_ps, key)

    def metrics(self) -> dict:
        """Registry 'serve.slots' provider: pool-facade levels (the
        backing's keys go out under 'paging' when paged)."""
        return {"num_slots": self.num_slots,
                "live": int(self.valid.sum()),
                "free": self.free_count,
                "cache_slots": self.cache_slots,
                "position_capacity": self.position_capacity,
                "total_rows": self.total_rows}

    def shard_metrics(self) -> dict:
        """Per-shard occupancy gauges (sharded backing only):
        ``shard<i>.live_slots`` / ``free_slots`` plus the backing's
        per-shard block/swap levels. The scheduler layers placement and
        steal counters on top under the ``serve.shard`` prefix."""
        out = {}
        k = self.slots_per_shard
        for s in range(self.num_shards):
            free = self.free_count_shard(s)
            out[f"shard{s}.live_slots"] = k - free
            out[f"shard{s}.free_slots"] = free
        if self.sharded:
            out.update(self.backing.shard_metrics())
        return out

    def stats(self) -> dict:
        return {**self.metrics(), **self.backing.stats()}
