"""SlotManager — a fixed pool of cache slots for continuous batching.

The paper's runtime keeps a pool of workers saturated on dependency-bound
work; at LM-serving scale the scarce resource is the static-shape decode
cache. This module owns a pool of B cache slots over the engine's
KV/recurrent caches (``transformer.init_caches(per_slot_pos=True)``):
requests are *allocated* a slot, their prefilled state lives in that
slot's rows of every cache leaf, and eviction on EOS/max-tokens frees the
slot for the next admission — the batch shape never changes, only the
masks do.

Two storage backings sit behind one facade:

  * contiguous — every slot reserves its worst-case rows of every leaf
    (``cache_slots`` for global attention, the full ``window`` ring for
    sliding-window layers; the original layout).
  * paged      — attention KV leaves live in shared block pools
    (``serve.paging``: BlockPool + PageTable, blocks mapped on demand as
    a request's write position grows, freed at retire), so short
    requests stop stranding pool memory the way coarse-grain reservation
    strands the paper's L2. Keys sharing a view length form one
    *page-table group* over one pool: the global-KV group (view =
    ``cache_slots``) plus one ring-mode group per distinct window length
    (view = ``min(window, cache_slots)``; blocks map lazily while the
    request ramps up to ``window`` written positions, then the full ring
    stays resident). The fused steps gather a per-slot contiguous view
    through each group's page table before attending and scatter updates
    back (models.attention.paged_view / paged_writeback), keeping the
    one-fused-program-per-tick property — and every view is
    bit-identical to the contiguous layout, so greedy token streams are
    too.

With the per-row position layout every cache leaf carries the slot axis
at position 1 ((periods, B, ...)), so gather/scatter/reset are single-axis
indexing ops over the whole pytree, jitted once per sub-batch shape.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, transformer as T
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.runtime import bucketing
from repro.serve import engine
from repro.serve.paging import BlockPool, PageTable, SwapEntry, SwapStore

_SLOT_AXIS = 1      # every per_slot_pos cache leaf: (periods, B, ...)


@jax.jit
def _gather(caches, idx):
    return jax.tree_util.tree_map(
        lambda l: jnp.take(l, idx, axis=_SLOT_AXIS), caches)


# pool-sized updates donate the pool: without donation every scatter /
# reset / chunk step materializes a second full copy of the cache pool
@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter(caches, sub, idx):
    return jax.tree_util.tree_map(
        lambda l, s: l.at[:, idx].set(s.astype(l.dtype)), caches, sub)


@functools.lru_cache(maxsize=None)
def _pooled_chunk_step(cfg: ModelConfig):
    """Fused gather -> chunk-prefill -> scatter over the pooled caches.

    One jitted program (per cfg and sub-batch shape) instead of three
    dispatches: at small sub-batches the per-call overhead of separate
    gather/chunk/scatter calls rivals the chunk compute itself."""
    step = engine.make_chunk_step(cfg)

    @functools.partial(jax.jit, donate_argnums=(1,))
    def run(params, caches, idx, tokens, pos):
        sub = jax.tree_util.tree_map(
            lambda l: jnp.take(l, idx, axis=_SLOT_AXIS), caches)
        _, sub = step(params, sub, tokens, pos)
        return jax.tree_util.tree_map(
            lambda l, s: l.at[:, idx].set(s.astype(l.dtype)), caches, sub)

    return obs_trace.instrumented_jit(
        run, name=f"pooled_chunk_step[{cfg.name}]", prefix="serve.engine")


def _pad_rows(arr: np.ndarray, pad: int) -> np.ndarray:
    """Pad a saved block-bytes leaf (P, rows, ...) with ``pad`` zero rows
    — the payload for the trash rows a pow2-padded upload writes."""
    if pad == 0:
        return arr
    z = np.zeros((arr.shape[0], pad) + arr.shape[2:], arr.dtype)
    return np.concatenate([np.asarray(arr), z], axis=1)


@functools.partial(jax.jit, donate_argnums=(0,))
def _reset(caches, template, idx):
    """Write the zero-state template (slot axis = 1) into slots ``idx``."""

    def wipe(l, t):
        fresh = jnp.broadcast_to(
            t, t.shape[:_SLOT_AXIS] + (idx.shape[0],) + t.shape[2:])
        return l.at[:, idx].set(fresh.astype(l.dtype))

    return jax.tree_util.tree_map(wipe, caches, template)


def _attn_view_len(spec, cache_slots: int) -> int:
    """Positions an attention layer's slot view spans: the full
    ``cache_slots`` for global attention (or window >= cache_slots), the
    ring length for a shorter sliding window."""
    return min(cache_slots, spec.window) if spec.window else cache_slots


# ---------------------------------------------------------------------------
# storage backings
# ---------------------------------------------------------------------------

class _ContiguousBacking:
    """Every slot owns its worst-case rows of every leaf (the original
    reservation layout)."""

    is_paged = False

    def __init__(self, cfg: ModelConfig, num_slots: int, cache_slots: int):
        self.cfg = cfg
        self.num_slots = num_slots
        self.cache_slots = cache_slots
        self.caches = T.init_caches(cfg, num_slots, cache_slots,
                                    per_slot_pos=True)
        # one-slot zero template: reset = scatter-broadcast of this
        self._template = T.init_caches(cfg, 1, cache_slots,
                                       per_slot_pos=True)
        self.position_capacity = num_slots * cache_slots

    @property
    def total_rows(self) -> int:
        """Total attention cache positions reserved across the pool
        (global KV + window rings) — the equal-memory axis the windowed
        fig_serve arm compares allocators on (every attn leaf of one cfg
        has the same per-position byte cost)."""
        return sum(self.num_slots * _attn_view_len(s, self.cache_slots)
                   for s in self.cfg.pattern if s.mixer == "attn")

    def can_admit(self, prompt_len: int) -> bool:
        return True                     # a free slot is the only gate

    def fits_pool(self, n_positions: int) -> Optional[str]:
        return None                     # rows are pre-reserved

    def alloc_reset(self, slot: int, prompt_len: int):
        self.caches = _reset(self.caches, self._template,
                             jnp.asarray([slot], jnp.int32))

    def ensure(self, slot: int, upto_pos: int) -> bool:
        return True                     # rows are pre-reserved

    def release_slot(self, slot: int) -> List[int]:
        return []                       # nothing block-granular to free

    def gather(self, idx):
        return _gather(self.caches, jnp.asarray(idx, jnp.int32))

    def scatter(self, sub, idx):
        self.caches = _scatter(self.caches, sub,
                               jnp.asarray(idx, jnp.int32))

    def run_chunk(self, params, idx, tokens, pos):
        self.caches = _pooled_chunk_step(self.cfg)(
            params, self.caches, jnp.asarray(idx, jnp.int32),
            jnp.asarray(tokens), jnp.asarray(pos))

    def run_decode(self, params, tokens, pos, temps, key):
        nxt, _, self.caches = engine.jit_slot_decode_step(self.cfg)(
            params, self.caches, tokens, pos, temps, key)
        return nxt

    def stats(self) -> dict:
        return {"allocator": "contiguous"}


class _PageGroup:
    """One BlockPool + PageTable shared by the pattern keys whose slot
    views have the same length: the global-KV group (``view_len ==
    cache_slots``) or one ring group per distinct window length. Keys in
    a group advance in lockstep (every layer writes the same position
    each tick), so one logical->physical map serves them all — block b
    means rows [b*bs, (b+1)*bs) of every member key's flat pool."""

    def __init__(self, keys: List[str], num_slots: int, view_len: int,
                 cache_slots: int, block_size: int,
                 num_blocks: Optional[int]):
        self.keys = keys
        self.view_len = view_len
        self.ring = view_len < cache_slots
        if num_blocks is None:
            # equal-memory default: same position capacity as the dense
            # layout (num_slots full views)
            num_blocks = num_slots * (-(-view_len // block_size))
        self.pool = BlockPool(num_blocks, block_size)
        self.pt = PageTable(self.pool, num_slots, view_len, ring=self.ring)


class _PagedBacking:
    """Attention KV lives in shared block pools — one page-table group
    per view length (global KV + window rings when ``paged_window``);
    per-slot dense leaves (SSM state, rings kept dense when
    ``paged_window=False``) keep the contiguous layout. Each group's page
    table maps a slot's logical blocks to physical ones on demand; the
    fused steps read/write through flat row index vectors derived per
    group (gather-before-attend)."""

    is_paged = True

    def __init__(self, cfg: ModelConfig, num_slots: int, cache_slots: int,
                 block_size: int, num_blocks: Optional[int],
                 paged_window: bool = True,
                 num_window_blocks: Optional[int] = None,
                 swap_bytes_budget: Optional[int] = None):
        self.cfg = cfg
        self.num_slots = num_slots
        self.cache_slots = cache_slots
        self.block_size = block_size
        self.dense = T.init_caches(cfg, num_slots, cache_slots,
                                   per_slot_pos=True, paged_global_attn=True,
                                   paged_window_attn=paged_window)
        self._template = T.init_caches(cfg, 1, cache_slots,
                                       per_slot_pos=True,
                                       paged_global_attn=True,
                                       paged_window_attn=paged_window)
        # group the paged keys by view length: one pool + page table per
        # distinct length (the global group, rings per window size)
        by_view: Dict[int, List[str]] = {}
        self.key_view: Dict[str, int] = {}
        for i, spec in enumerate(cfg.pattern):
            key = f"p{i}"
            entry = self.dense.get(key)
            if not (entry and "attn" in entry and entry["attn"] is None):
                continue
            vl = _attn_view_len(spec, cache_slots)
            by_view.setdefault(vl, []).append(key)
            self.key_view[key] = vl
        self.groups: Dict[int, _PageGroup] = {
            vl: _PageGroup(keys, num_slots, vl, cache_slots, block_size,
                           num_blocks if vl == cache_slots
                           else num_window_blocks)
            for vl, keys in sorted(by_view.items(), reverse=True)}
        self.paged = {
            key: attention.make_paged_cache(
                g.pool.num_blocks, block_size, cfg.num_kv_heads,
                cfg.head_dim, periods=cfg.num_periods)
            for g in self.groups.values() for key in g.keys}
        g_global = self.groups.get(cache_slots)
        self.position_capacity = (g_global.pool.num_blocks * block_size
                                  if g_global else num_slots * cache_slots)
        self.swaps = SwapStore(max_bytes=swap_bytes_budget)
        # one-slot dense snapshot size is a constant (the template IS
        # that snapshot's shape): precompute for swap_bytes_estimate
        self._dense_slot_bytes = int(sum(
            l.nbytes for l in jax.tree_util.tree_leaves(self._template)))
        self._rows_cache: Optional[Dict[str, jnp.ndarray]] = None

    @property
    def total_rows(self) -> int:
        """Attention cache positions actually allocated (physical block
        rows incl. each group's trash sentinel, plus any rings kept
        dense) — the equal-memory axis."""
        total = sum(len(g.keys) * (g.pool.num_blocks + 1) * self.block_size
                    for g in self.groups.values())
        for i, spec in enumerate(self.cfg.pattern):
            if spec.mixer == "attn" and f"p{i}" not in self.key_view:
                total += self.num_slots * _attn_view_len(spec,
                                                         self.cache_slots)
        return total

    # -- page-table lifecycle -------------------------------------------

    def can_admit(self, prompt_len: int) -> bool:
        n = max(prompt_len, 1)
        return all(g.pt.can_map(g.pt.blocks_for(n))
                   for g in self.groups.values())

    def fits_pool(self, n_positions: int) -> Optional[str]:
        """None if a request spanning ``n_positions`` could be mapped on
        an EMPTY pool (every group), else why not — the submit-time
        feasibility check behind the scheduler's progress guarantee.
        Ring groups clamp via blocks_for: a ring never needs more than
        the full ring resident."""
        for g in self.groups.values():
            need = g.pt.blocks_for(n_positions)
            if need > g.pool.num_blocks:
                what = (f"window-{g.view_len} ring" if g.ring
                        else "global-KV")
                return (f"request needs {need} {what} blocks > pool "
                        f"{g.pool.num_blocks}")
        return None

    def alloc_reset(self, slot: int, prompt_len: int):
        self.dense = _reset(self.dense, self._template,
                            jnp.asarray([slot], jnp.int32))
        ok = self.ensure(slot, max(prompt_len, 1) - 1)
        if not ok:
            raise RuntimeError(
                "alloc_reset after can_admit ran out of blocks")

    def ensure(self, slot: int, upto_pos: int) -> bool:
        """Map (and zero) every block covering positions [0, upto_pos] in
        every group — ring groups clamp to their ring, so past the window
        they are a no-op. False on pool exhaustion (the scheduler's
        preempt-on-OOB path); blocks mapped so far stay mapped, and a
        retry after preemption is idempotent."""
        ok_all = True
        for g in self.groups.values():
            ok, new = g.pt.ensure(slot, upto_pos)
            if new:
                # pow2-pad the reset batch with trash-block rows so the
                # jitted reset compiles O(log blocks_per_slot) shapes,
                # not one per count
                n = bucketing.round_up_pow2(len(new), 1)
                blocks = list(new) + [g.pt.trash] * (n - len(new))
                rows = PageTable.block_rows(blocks, self.block_size)
                sub = {k: self.paged[k] for k in g.keys}
                self.paged.update(engine.reset_block_rows(
                    sub, jnp.asarray(rows)))
                self._rows_cache = None
            ok_all = ok_all and ok
        return ok_all

    def release_slot(self, slot: int) -> List[int]:
        freed: List[int] = []
        for g in self.groups.values():
            freed += g.pt.free_slot(slot)
        if freed:
            self._rows_cache = None
        return freed

    # -- swap-out preemption --------------------------------------------

    def _swap_rows(self, g: _PageGroup, blocks: List[int]) -> jnp.ndarray:
        """Flat rows for a block list, pow2-padded with trash rows so the
        jitted gather/upload compile O(log blocks_per_slot) shapes."""
        n = bucketing.round_up_pow2(len(blocks), 1)
        padded = list(blocks) + [g.pt.trash] * (n - len(blocks))
        return jnp.asarray(PageTable.block_rows(padded, self.block_size))

    def swap_bytes_estimate(self, slot: int) -> int:
        """Bytes a swap_out of ``slot`` would park host-side — computed
        from shapes BEFORE any device gather, so a SwapStore budget
        rejection costs nothing."""
        bs = self.block_size
        total = self._dense_slot_bytes
        for g in self.groups.values():
            nb = g.pt.mapped_blocks(slot)
            for key in g.keys:
                c = self.paged[key]
                row = (int(np.prod(c.k.shape[2:])) * c.k.dtype.itemsize
                       + int(np.prod(c.v.shape[2:])) * c.v.dtype.itemsize
                       + c.pos.dtype.itemsize)
                total += nb * bs * row * c.k.shape[0]
        return total

    def swap_out(self, slot: int, rid: int) -> Optional[int]:
        """Copy ``slot``'s mapped block bytes (every group) + dense
        leaves to the host SwapStore (keyed by ``rid``) and free the
        physical blocks — the victim's decode work survives eviction.
        Returns bytes moved, or None when the store's byte budget cannot
        hold the entry (nothing is gathered or freed; the scheduler falls
        back to recompute-preemption for this victim)."""
        if self.swaps.max_bytes is not None \
                and not self.swaps.can_hold(self.swap_bytes_estimate(slot)):
            self.swaps.reject()         # the store owns the count
            return None
        bs = self.block_size
        blocks: Dict[int, int] = {}
        paged_host: Dict[str, attention.KVCache] = {}
        for vl, g in self.groups.items():
            phys = [int(b) for b in g.pt.table[slot] if b != g.pt.trash]
            blocks[vl] = len(phys)
            if phys and g.keys:
                keep = len(phys) * bs
                sub = {k: self.paged[k] for k in g.keys}
                got = jax.device_get(engine.gather_block_rows(
                    sub, self._swap_rows(g, phys)))
                paged_host.update({
                    key: attention.KVCache(k=c.k[:, :keep], v=c.v[:, :keep],
                                           pos=c.pos[:, :keep])
                    for key, c in got.items()})
            _, freed = g.pt.swap_out(slot)
            if sorted(freed) != sorted(phys):
                raise RuntimeError(f"swap_out freed {freed} != mapped "
                                   f"{phys} (group {vl})")
            if freed:
                self._rows_cache = None
        dense_host = jax.device_get(
            _gather(self.dense, jnp.asarray([slot], jnp.int32)))
        return self.swaps.put(rid, SwapEntry(
            blocks=blocks, paged=paged_host, dense=dense_host))

    def can_admit_swapped(self, rid: int) -> bool:
        entry = self.swaps.get(rid)
        return all(g.pt.can_map(entry.blocks.get(vl, 0))
                   for vl, g in self.groups.items())

    def swap_in(self, slot: int, rid: int) -> int:
        """Resume ``rid`` in (free, unreset) ``slot``: map fresh blocks
        for each group's saved logical prefix, upload the saved bytes,
        scatter the dense snapshot — every cache row the request had
        written reads bit-identically to the never-preempted layout.
        Returns bytes moved. Caller guarantees can_admit_swapped just
        held."""
        bs = self.block_size
        entry = self.swaps.pop(rid)
        for vl, g in self.groups.items():
            nb = entry.blocks.get(vl, 0)
            if not nb:
                continue
            new = g.pt.swap_in(slot, nb)
            if new is None:
                raise RuntimeError(
                    "swap_in after can_admit_swapped ran out of blocks")
            if g.keys:
                rows = self._swap_rows(g, new)
                pad = int(rows.shape[0]) - nb * bs
                saved = {
                    key: attention.KVCache(
                        k=_pad_rows(entry.paged[key].k, pad),
                        v=_pad_rows(entry.paged[key].v, pad),
                        pos=_pad_rows(entry.paged[key].pos, pad))
                    for key in g.keys}
                sub = {k: self.paged[k] for k in g.keys}
                self.paged.update(engine.upload_block_rows(sub, saved,
                                                           rows))
            self._rows_cache = None
        self.dense = _scatter(self.dense, entry.dense,
                              jnp.asarray([slot], jnp.int32))
        return entry.nbytes

    # -- device-facing row vectors --------------------------------------

    def _rows_all(self) -> Dict[str, jnp.ndarray]:
        if self._rows_cache is None:
            per_group = {vl: jnp.asarray(g.pt.rows())
                         for vl, g in self.groups.items()}
            self._rows_cache = {key: per_group[vl]
                                for key, vl in self.key_view.items()}
        return self._rows_cache

    def _rows_for(self, idx) -> Dict[str, jnp.ndarray]:
        per_group = {vl: jnp.asarray(g.pt.rows(idx))
                     for vl, g in self.groups.items()}
        return {key: per_group[vl] for key, vl in self.key_view.items()}

    # -- data movement ---------------------------------------------------

    def gather(self, idx):
        sub = _gather(self.dense, jnp.asarray(idx, jnp.int32))
        rows = self._rows_for(idx)
        for key, flat in self.paged.items():
            sub[key] = dict(sub[key])
            sub[key]["attn"] = attention.paged_view(
                flat, rows[key],
                attention.paged_live_rows(flat, self.block_size))
        return sub

    def scatter(self, sub, idx):
        """Write a gathered sub-tree back. View positions whose blocks are
        unmapped scatter into the trash block (dropped) — callers only
        write back what gather handed out, so mapped data round-trips."""
        rows = self._rows_for(idx)
        stripped = {}
        for key, entry in sub.items():
            if key in self.paged:
                entry = dict(entry)
                self.paged[key] = attention.paged_writeback(
                    self.paged[key], entry["attn"], rows[key])
                entry["attn"] = None
            stripped[key] = entry
        self.dense = _scatter(self.dense, stripped,
                              jnp.asarray(idx, jnp.int32))

    def run_chunk(self, params, idx, tokens, pos):
        rows = self._rows_for(idx)
        self.dense, self.paged = engine.jit_paged_chunk_step(self.cfg)(
            params, self.dense, self.paged, jnp.asarray(idx, jnp.int32),
            rows, jnp.asarray(tokens), jnp.asarray(pos), self.block_size)

    def run_decode(self, params, tokens, pos, temps, key):
        nxt, _, self.dense, self.paged = engine.jit_paged_decode_step(
            self.cfg)(params, self.dense, self.paged, self._rows_all(),
                      tokens, pos, temps, key, self.block_size)
        return nxt

    def stats(self) -> dict:
        used = sum(g.pool.used_count for g in self.groups.values())
        total = sum(g.pool.num_blocks for g in self.groups.values())
        out = {"allocator": "paged",
               "page_groups": len(self.groups),
               "blocks_total": total,
               "blocks_used": used,
               "blocks_free": total - used,
               "block_size": self.block_size,
               "block_utilization": used / max(total, 1),
               **self.swaps.stats()}
        for vl, g in self.groups.items():
            if g.ring:
                out[f"ring{vl}_blocks_total"] = g.pool.num_blocks
                out[f"ring{vl}_blocks_used"] = g.pool.used_count
        return out

    def metrics(self) -> dict:
        """Registry 'paging' provider: the numeric stats() keys."""
        return {k: v for k, v in self.stats().items() if k != "allocator"}


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------

class SlotManager:
    """Fixed pool of ``num_slots`` decode-cache slots.

    Host-side bookkeeping (free list, per-slot owner + validity mask)
    plus jitted whole-pytree gather/scatter/reset over the pooled caches.
    Each slot's clock lives in the caches' per-row ``pos`` leaves (and
    the scheduler's request state); ``valid[i]`` masks live slots (the
    scheduler decodes the full pool every step; dead rows compute but
    are never read).

    ``paged=True`` swaps the storage backing for the block-granular
    allocator (module docstring): ``alloc`` then also needs the prompt's
    blocks free in every page-table group, ``ensure`` must be called
    before a slot's write position grows, and ``release`` returns the
    physical blocks it freed. ``paged_window`` (default on) pages
    sliding-window rings through ring-mode groups as well; off keeps
    them dense per slot (the PR-3/4 layout).
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, cache_slots: int,
                 *, paged: bool = False, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 paged_window: bool = True,
                 num_window_blocks: Optional[int] = None,
                 swap_bytes_budget: Optional[int] = None):
        self.cfg = cfg
        self.num_slots = num_slots
        self.cache_slots = cache_slots
        self.backing = (_PagedBacking(cfg, num_slots, cache_slots,
                                      block_size, num_blocks,
                                      paged_window=paged_window,
                                      num_window_blocks=num_window_blocks,
                                      swap_bytes_budget=swap_bytes_budget)
                        if paged else
                        _ContiguousBacking(cfg, num_slots, cache_slots))
        self._free: List[int] = list(range(num_slots - 1, -1, -1))
        self.owner: List[Optional[int]] = [None] * num_slots
        self.valid = np.zeros(num_slots, bool)
        obs_metrics.REGISTRY.register_provider("serve.slots", self)
        if paged:
            obs_metrics.REGISTRY.register_provider("paging", self.backing)

    @property
    def paged(self) -> bool:
        return self.backing.is_paged

    @property
    def caches(self):
        """The pooled cache pytree (contiguous backing only — the paged
        backing's state is ``backing.dense`` + ``backing.paged``)."""
        return self.backing.caches

    @caches.setter
    def caches(self, value):
        assert not self.backing.is_paged, \
            "caches is the contiguous backing's state; the paged backing " \
            "holds backing.dense + backing.paged (use gather/scatter)"
        self.backing.caches = value

    @property
    def position_capacity(self) -> int:
        """Global-KV cache positions backing the pool (the equal-memory
        axis fig_serve's global-attention comparison uses)."""
        return self.backing.position_capacity

    @property
    def total_rows(self) -> int:
        """ALL attention cache positions allocated (global KV + window
        rings, paged incl. trash sentinels) — the equal-memory axis for
        windowed models."""
        return self.backing.total_rows

    # -- lifecycle -----------------------------------------------------------

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def live(self) -> List[int]:
        return [i for i in range(self.num_slots) if self.valid[i]]

    def can_admit(self, prompt_len: int = 0) -> bool:
        """A free slot AND (paged) enough free blocks for the prompt in
        every page-table group."""
        return bool(self._free) and self.backing.can_admit(prompt_len)

    def fits_pool(self, n_positions: int) -> Optional[str]:
        """None if a request spanning ``n_positions`` could ever be
        mapped on an empty pool; else the reason it can't (the
        scheduler's submit-time ValueError)."""
        return self.backing.fits_pool(n_positions)

    def alloc(self, owner: int, prompt_len: int = 0) -> Optional[int]:
        """Claim a free slot for request ``owner``; zero its cache rows
        (paged: map + zero the blocks covering the prompt). Returns the
        slot index, or None when the pool/blocks are exhausted."""
        if not self.can_admit(prompt_len):
            return None
        slot = self._free.pop()
        self.backing.alloc_reset(slot, prompt_len)
        self.owner[slot] = owner
        self.valid[slot] = True
        return slot

    def ensure(self, slot: int, upto_pos: int) -> bool:
        """Grow slot storage to cover writes up to ``upto_pos``. Always
        True for contiguous; False when a paged pool is out of blocks
        (the scheduler then preempts)."""
        if not self.valid[slot]:
            raise RuntimeError(f"slot {slot} is not live")
        return self.backing.ensure(slot, upto_pos)

    def release(self, slot: int) -> List[int]:
        """Evict (EOS / max-tokens / abort / preempt): mark free; returns
        the physical blocks handed back (paged) — the stale cache rows are
        masked out by ``valid`` until the next alloc resets them."""
        if not self.valid[slot]:
            raise RuntimeError(f"slot {slot} is not live")
        self.owner[slot] = None
        self.valid[slot] = False
        self._free.append(slot)
        return self.backing.release_slot(slot)

    # -- swap-out preemption (paged backing only) -----------------------

    def swap_out(self, slot: int) -> Optional[int]:
        """Preempt WITHOUT discarding work: park the slot's mapped block
        bytes + dense leaves in the backing's SwapStore (keyed by the
        owning rid), free the blocks and the slot. Returns bytes moved
        to host — or None when the SwapStore byte budget rejects the
        entry, in which case the slot stays LIVE and the caller must
        fall back to recompute-preemption."""
        if not self.valid[slot]:
            raise RuntimeError(f"slot {slot} is not live")
        if not self.backing.is_paged:
            raise RuntimeError("swap-out needs the paged backing")
        rid = self.owner[slot]
        nbytes = self.backing.swap_out(slot, rid)
        if nbytes is None:
            return None
        self.owner[slot] = None
        self.valid[slot] = False
        self._free.append(slot)
        return nbytes

    def is_swapped(self, rid: int) -> bool:
        return self.backing.is_paged and rid in self.backing.swaps

    def can_admit_swapped(self, rid: int) -> bool:
        """A free slot AND blocks for the request's saved prefix in
        every page-table group."""
        return bool(self._free) and self.backing.can_admit_swapped(rid)

    def swap_in(self, rid: int) -> Optional[Tuple[int, int]]:
        """Resume a swapped-out request: claim a free slot, remap fresh
        blocks and upload the saved bytes — the slot reads bit-identical
        to the never-preempted layout, so decode continues at the saved
        position with zero recomputed steps. Returns (slot, bytes moved),
        or None when the pool can't host it yet."""
        if not self.can_admit_swapped(rid):
            return None
        slot = self._free.pop()
        nbytes = self.backing.swap_in(slot, rid)
        self.owner[slot] = rid
        self.valid[slot] = True
        return slot, nbytes

    # -- pooled-cache data movement -----------------------------------------

    def gather(self, idx: Sequence[int]):
        """Sub-caches for slots ``idx`` (batch axis = len(idx)). The paged
        backing materializes the page-table views — bit-identical to the
        contiguous rows for every mapped position."""
        return self.backing.gather(idx)

    def scatter(self, sub, idx: Sequence[int]):
        """Write sub-caches (from a bucketed chunk step) back into slots.
        Duplicate indices must carry identical rows (the pad-by-repeat
        contract): the scatter then stays deterministic."""
        self.backing.scatter(sub, idx)

    def run_chunk(self, params, idx: Sequence[int], tokens, pos):
        """Chunk-prefill slots ``idx`` in place (fused gather -> chunk ->
        scatter, one dispatch). Same pad-by-repeat contract as scatter."""
        self.backing.run_chunk(params, idx, tokens, pos)

    def run_decode(self, params, tokens, pos, temps, key):
        """ONE fused decode over the whole pool; returns next tokens.
        (Paged: gather-through-page-tables -> decode -> scatter, still
        one jitted program per tick.)"""
        return self.backing.run_decode(params, tokens, pos, temps, key)

    def metrics(self) -> dict:
        """Registry 'serve.slots' provider: pool-facade levels (the
        backing's keys go out under 'paging' when paged)."""
        return {"num_slots": self.num_slots,
                "live": int(self.valid.sum()),
                "free": self.free_count,
                "cache_slots": self.cache_slots,
                "position_capacity": self.position_capacity,
                "total_rows": self.total_rows}

    def stats(self) -> dict:
        return {**self.metrics(), **self.backing.stats()}
