"""SlotManager — a fixed pool of cache slots for continuous batching.

The paper's runtime keeps a pool of workers saturated on dependency-bound
work; at LM-serving scale the scarce resource is the static-shape decode
cache. This module owns a pool of B cache slots over the engine's
KV/recurrent caches (``transformer.init_caches(per_slot_pos=True)``):
requests are *allocated* a slot, their prefilled state lives in that
slot's rows of every cache leaf, and eviction on EOS/max-tokens frees the
slot for the next admission — the batch shape never changes, only the
masks do.

Two storage backings sit behind one facade:

  * contiguous — every slot reserves ``cache_slots`` rows of every leaf
    (worst-case reservation; the original layout).
  * paged      — global-attention KV leaves live in a shared block pool
    (``serve.paging``: BlockPool + PageTable, blocks mapped on demand as
    a request's write position grows, freed at retire), so short
    requests stop stranding pool memory the way coarse-grain reservation
    strands the paper's L2. The fused steps gather a per-slot contiguous
    view through the page table before attending and scatter updates
    back (models.attention.paged_view / paged_writeback), keeping the
    one-fused-program-per-tick property — and the view is bit-identical
    to the contiguous layout, so greedy token streams are too.

With the per-row position layout every cache leaf carries the slot axis
at position 1 ((periods, B, ...)), so gather/scatter/reset are single-axis
indexing ops over the whole pytree, jitted once per sub-batch shape.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, transformer as T
from repro.runtime import bucketing
from repro.serve import engine
from repro.serve.paging import BlockPool, PageTable, SwapEntry, SwapStore

_SLOT_AXIS = 1      # every per_slot_pos cache leaf: (periods, B, ...)


@jax.jit
def _gather(caches, idx):
    return jax.tree_util.tree_map(
        lambda l: jnp.take(l, idx, axis=_SLOT_AXIS), caches)


# pool-sized updates donate the pool: without donation every scatter /
# reset / chunk step materializes a second full copy of the cache pool
@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter(caches, sub, idx):
    return jax.tree_util.tree_map(
        lambda l, s: l.at[:, idx].set(s.astype(l.dtype)), caches, sub)


@functools.lru_cache(maxsize=None)
def _pooled_chunk_step(cfg: ModelConfig):
    """Fused gather -> chunk-prefill -> scatter over the pooled caches.

    One jitted program (per cfg and sub-batch shape) instead of three
    dispatches: at small sub-batches the per-call overhead of separate
    gather/chunk/scatter calls rivals the chunk compute itself."""
    step = engine.make_chunk_step(cfg)

    @functools.partial(jax.jit, donate_argnums=(1,))
    def run(params, caches, idx, tokens, pos):
        sub = jax.tree_util.tree_map(
            lambda l: jnp.take(l, idx, axis=_SLOT_AXIS), caches)
        _, sub = step(params, sub, tokens, pos)
        return jax.tree_util.tree_map(
            lambda l, s: l.at[:, idx].set(s.astype(l.dtype)), caches, sub)

    return run


def _pad_rows(arr: np.ndarray, pad: int) -> np.ndarray:
    """Pad a saved block-bytes leaf (P, rows, ...) with ``pad`` zero rows
    — the payload for the trash rows a pow2-padded upload writes."""
    if pad == 0:
        return arr
    z = np.zeros((arr.shape[0], pad) + arr.shape[2:], arr.dtype)
    return np.concatenate([np.asarray(arr), z], axis=1)


@functools.partial(jax.jit, donate_argnums=(0,))
def _reset(caches, template, idx):
    """Write the zero-state template (slot axis = 1) into slots ``idx``."""

    def wipe(l, t):
        fresh = jnp.broadcast_to(
            t, t.shape[:_SLOT_AXIS] + (idx.shape[0],) + t.shape[2:])
        return l.at[:, idx].set(fresh.astype(l.dtype))

    return jax.tree_util.tree_map(wipe, caches, template)


# ---------------------------------------------------------------------------
# storage backings
# ---------------------------------------------------------------------------

class _ContiguousBacking:
    """Every slot owns ``cache_slots`` rows of every leaf (the original
    worst-case-reservation layout)."""

    is_paged = False

    def __init__(self, cfg: ModelConfig, num_slots: int, cache_slots: int):
        self.cfg = cfg
        self.caches = T.init_caches(cfg, num_slots, cache_slots,
                                    per_slot_pos=True)
        # one-slot zero template: reset = scatter-broadcast of this
        self._template = T.init_caches(cfg, 1, cache_slots,
                                       per_slot_pos=True)
        self.position_capacity = num_slots * cache_slots

    def can_admit(self, prompt_len: int) -> bool:
        return True                     # a free slot is the only gate

    def alloc_reset(self, slot: int, prompt_len: int):
        self.caches = _reset(self.caches, self._template,
                             jnp.asarray([slot], jnp.int32))

    def ensure(self, slot: int, upto_pos: int) -> bool:
        return True                     # rows are pre-reserved

    def release_slot(self, slot: int) -> List[int]:
        return []                       # nothing block-granular to free

    def gather(self, idx):
        return _gather(self.caches, jnp.asarray(idx, jnp.int32))

    def scatter(self, sub, idx):
        self.caches = _scatter(self.caches, sub,
                               jnp.asarray(idx, jnp.int32))

    def run_chunk(self, params, idx, tokens, pos):
        self.caches = _pooled_chunk_step(self.cfg)(
            params, self.caches, jnp.asarray(idx, jnp.int32),
            jnp.asarray(tokens), jnp.asarray(pos))

    def run_decode(self, params, tokens, pos, temps, key):
        nxt, _, self.caches = engine.jit_slot_decode_step(self.cfg)(
            params, self.caches, tokens, pos, temps, key)
        return nxt

    def stats(self) -> dict:
        return {"allocator": "contiguous"}


class _PagedBacking:
    """Global-attention KV lives in a shared block pool; per-slot dense
    leaves (SSM state, sub-``cache_slots`` window rings) keep the
    contiguous layout. The page table maps each slot's logical blocks to
    physical ones on demand; the fused steps read/write through flat row
    index vectors derived from it (gather-before-attend)."""

    is_paged = True

    def __init__(self, cfg: ModelConfig, num_slots: int, cache_slots: int,
                 block_size: int, num_blocks: Optional[int]):
        self.cfg = cfg
        if num_blocks is None:
            # equal-memory default: same position capacity as contiguous
            num_blocks = num_slots * (-(-cache_slots // block_size))
        self.pool = BlockPool(num_blocks, block_size)
        self.pt = PageTable(self.pool, num_slots, cache_slots)
        self.live_rows = num_blocks * block_size
        self.position_capacity = self.live_rows
        self.dense = T.init_caches(cfg, num_slots, cache_slots,
                                   per_slot_pos=True, paged_global_attn=True)
        self._template = T.init_caches(cfg, 1, cache_slots,
                                       per_slot_pos=True,
                                       paged_global_attn=True)
        self.paged = {
            key: attention.make_paged_cache(
                num_blocks, block_size, cfg.num_kv_heads, cfg.head_dim,
                periods=cfg.num_periods)
            for key, entry in self.dense.items()
            if "attn" in entry and entry["attn"] is None}
        self.swaps = SwapStore()
        self._rows_cache: Optional[jnp.ndarray] = None

    # -- page-table lifecycle -------------------------------------------

    def can_admit(self, prompt_len: int) -> bool:
        return self.pt.can_map(self.pt.blocks_for(max(prompt_len, 1)))

    def alloc_reset(self, slot: int, prompt_len: int):
        self.dense = _reset(self.dense, self._template,
                            jnp.asarray([slot], jnp.int32))
        ok = self.ensure(slot, max(prompt_len, 1) - 1)
        assert ok, "alloc_reset after can_admit cannot run out of blocks"

    def ensure(self, slot: int, upto_pos: int) -> bool:
        """Map (and zero) every block covering positions [0, upto_pos].
        False on pool exhaustion — the scheduler's preempt-on-OOB path."""
        ok, new = self.pt.ensure(slot, upto_pos)
        if new and self.paged:
            # pow2-pad the reset batch with trash-block rows so the jitted
            # reset compiles O(log blocks_per_slot) shapes, not one per count
            n = bucketing.round_up_pow2(len(new), 1)
            blocks = list(new) + [self.pt.trash] * (n - len(new))
            rows = PageTable.block_rows(blocks, self.pool.block_size)
            self.paged = engine.reset_block_rows(self.paged,
                                                 jnp.asarray(rows))
        if new:
            self._rows_cache = None
        return ok

    def release_slot(self, slot: int) -> List[int]:
        freed = self.pt.free_slot(slot)
        if freed:
            self._rows_cache = None
        return freed

    # -- swap-out preemption --------------------------------------------

    def _swap_rows(self, blocks: List[int]) -> jnp.ndarray:
        """Flat rows for a block list, pow2-padded with trash rows so the
        jitted gather/upload compile O(log blocks_per_slot) shapes."""
        n = bucketing.round_up_pow2(len(blocks), 1)
        padded = list(blocks) + [self.pt.trash] * (n - len(blocks))
        return jnp.asarray(PageTable.block_rows(padded,
                                                self.pool.block_size))

    def swap_out(self, slot: int, rid: int) -> int:
        """Copy ``slot``'s mapped block bytes + dense leaves to the host
        SwapStore (keyed by ``rid``) and free the physical blocks — the
        victim's decode work survives eviction. Returns bytes moved."""
        bs = self.pool.block_size
        phys = [int(b) for b in self.pt.table[slot]
                if b != self.pt.trash]
        paged_host = {}
        if phys and self.paged:
            keep = len(phys) * bs
            got = jax.device_get(engine.gather_block_rows(
                self.paged, self._swap_rows(phys)))
            paged_host = {
                key: attention.KVCache(k=c.k[:, :keep], v=c.v[:, :keep],
                                       pos=c.pos[:, :keep])
                for key, c in got.items()}
        dense_host = jax.device_get(
            _gather(self.dense, jnp.asarray([slot], jnp.int32)))
        row, freed = self.pt.swap_out(slot)
        assert sorted(freed) == sorted(phys)
        if freed:
            self._rows_cache = None
        return self.swaps.put(rid, SwapEntry(
            n_blocks=len(phys), table_row=row, paged=paged_host,
            dense=dense_host))

    def can_admit_swapped(self, rid: int) -> bool:
        return self.pt.can_map(self.swaps.get(rid).n_blocks)

    def swap_in(self, slot: int, rid: int) -> int:
        """Resume ``rid`` in (free, unreset) ``slot``: map fresh blocks
        for the saved logical prefix, upload the saved bytes, scatter the
        dense snapshot — every cache row the request had written reads
        bit-identically to the never-preempted layout. Returns bytes
        moved. Caller guarantees can_admit_swapped just held."""
        bs = self.pool.block_size
        entry = self.swaps.pop(rid)
        if entry.n_blocks:
            new = self.pt.swap_in(slot, entry.n_blocks)
            assert new is not None, \
                "swap_in after can_admit_swapped cannot run out of blocks"
            if self.paged:
                rows = self._swap_rows(new)
                pad = int(rows.shape[0]) - entry.n_blocks * bs
                saved = {
                    key: attention.KVCache(
                        k=_pad_rows(c.k, pad), v=_pad_rows(c.v, pad),
                        pos=_pad_rows(c.pos, pad))
                    for key, c in entry.paged.items()}
                self.paged = engine.upload_block_rows(self.paged, saved,
                                                      rows)
            self._rows_cache = None
        self.dense = _scatter(self.dense, entry.dense,
                              jnp.asarray([slot], jnp.int32))
        return entry.nbytes

    def _rows_all(self) -> jnp.ndarray:
        if self._rows_cache is None:
            self._rows_cache = jnp.asarray(self.pt.rows())
        return self._rows_cache

    # -- data movement ---------------------------------------------------

    def gather(self, idx):
        sub = _gather(self.dense, jnp.asarray(idx, jnp.int32))
        rows = jnp.asarray(self.pt.rows(idx))
        for key, flat in self.paged.items():
            sub[key] = dict(sub[key])
            sub[key]["attn"] = attention.paged_view(flat, rows,
                                                    self.live_rows)
        return sub

    def scatter(self, sub, idx):
        """Write a gathered sub-tree back. View positions whose blocks are
        unmapped scatter into the trash block (dropped) — callers only
        write back what gather handed out, so mapped data round-trips."""
        rows = jnp.asarray(self.pt.rows(idx))
        stripped = {}
        for key, entry in sub.items():
            if key in self.paged:
                entry = dict(entry)
                self.paged[key] = attention.paged_writeback(
                    self.paged[key], entry["attn"], rows)
                entry["attn"] = None
            stripped[key] = entry
        self.dense = _scatter(self.dense, stripped,
                              jnp.asarray(idx, jnp.int32))

    def run_chunk(self, params, idx, tokens, pos):
        rows = jnp.asarray(self.pt.rows(idx))
        self.dense, self.paged = engine.jit_paged_chunk_step(self.cfg)(
            params, self.dense, self.paged, jnp.asarray(idx, jnp.int32),
            rows, jnp.asarray(tokens), jnp.asarray(pos), self.live_rows)

    def run_decode(self, params, tokens, pos, temps, key):
        nxt, _, self.dense, self.paged = engine.jit_paged_decode_step(
            self.cfg)(params, self.dense, self.paged, self._rows_all(),
                      tokens, pos, temps, key, self.live_rows)
        return nxt

    def stats(self) -> dict:
        return {"allocator": "paged", **self.pt.stats(),
                **self.swaps.stats()}


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------

class SlotManager:
    """Fixed pool of ``num_slots`` decode-cache slots.

    Host-side bookkeeping (free list, per-slot owner + validity mask)
    plus jitted whole-pytree gather/scatter/reset over the pooled caches.
    Each slot's clock lives in the caches' per-row ``pos`` leaves (and
    the scheduler's request state); ``valid[i]`` masks live slots (the
    scheduler decodes the full pool every step; dead rows compute but
    are never read).

    ``paged=True`` swaps the storage backing for the block-granular
    allocator (module docstring): ``alloc`` then also needs the prompt's
    blocks free, ``ensure`` must be called before a slot's write position
    grows, and ``release`` returns the physical blocks it freed.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, cache_slots: int,
                 *, paged: bool = False, block_size: int = 16,
                 num_blocks: Optional[int] = None):
        self.cfg = cfg
        self.num_slots = num_slots
        self.cache_slots = cache_slots
        self.backing = (_PagedBacking(cfg, num_slots, cache_slots,
                                      block_size, num_blocks)
                        if paged else
                        _ContiguousBacking(cfg, num_slots, cache_slots))
        self._free: List[int] = list(range(num_slots - 1, -1, -1))
        self.owner: List[Optional[int]] = [None] * num_slots
        self.valid = np.zeros(num_slots, bool)

    @property
    def paged(self) -> bool:
        return self.backing.is_paged

    @property
    def caches(self):
        """The pooled cache pytree (contiguous backing only — the paged
        backing's state is ``backing.dense`` + ``backing.paged``)."""
        return self.backing.caches

    @caches.setter
    def caches(self, value):
        assert not self.backing.is_paged, \
            "caches is the contiguous backing's state; the paged backing " \
            "holds backing.dense + backing.paged (use gather/scatter)"
        self.backing.caches = value

    @property
    def position_capacity(self) -> int:
        """Total cache positions backing the pool (the equal-memory axis
        fig_serve compares allocators on)."""
        return self.backing.position_capacity

    # -- lifecycle -----------------------------------------------------------

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def live(self) -> List[int]:
        return [i for i in range(self.num_slots) if self.valid[i]]

    def can_admit(self, prompt_len: int = 0) -> bool:
        """A free slot AND (paged) enough free blocks for the prompt."""
        return bool(self._free) and self.backing.can_admit(prompt_len)

    def alloc(self, owner: int, prompt_len: int = 0) -> Optional[int]:
        """Claim a free slot for request ``owner``; zero its cache rows
        (paged: map + zero the blocks covering the prompt). Returns the
        slot index, or None when the pool/blocks are exhausted."""
        if not self.can_admit(prompt_len):
            return None
        slot = self._free.pop()
        self.backing.alloc_reset(slot, prompt_len)
        self.owner[slot] = owner
        self.valid[slot] = True
        return slot

    def ensure(self, slot: int, upto_pos: int) -> bool:
        """Grow slot storage to cover writes up to ``upto_pos``. Always
        True for contiguous; False when the paged pool is out of blocks
        (the scheduler then preempts)."""
        assert self.valid[slot], f"slot {slot} is not live"
        return self.backing.ensure(slot, upto_pos)

    def release(self, slot: int) -> List[int]:
        """Evict (EOS / max-tokens / abort / preempt): mark free; returns
        the physical blocks handed back (paged) — the stale cache rows are
        masked out by ``valid`` until the next alloc resets them."""
        assert self.valid[slot], f"slot {slot} is not live"
        self.owner[slot] = None
        self.valid[slot] = False
        self._free.append(slot)
        return self.backing.release_slot(slot)

    # -- swap-out preemption (paged backing only) -----------------------

    def swap_out(self, slot: int) -> int:
        """Preempt WITHOUT discarding work: park the slot's mapped block
        bytes + dense leaves in the backing's SwapStore (keyed by the
        owning rid), free the blocks and the slot. Returns bytes moved
        to host."""
        assert self.valid[slot], f"slot {slot} is not live"
        assert self.backing.is_paged, "swap-out needs the paged backing"
        rid = self.owner[slot]
        nbytes = self.backing.swap_out(slot, rid)
        self.owner[slot] = None
        self.valid[slot] = False
        self._free.append(slot)
        return nbytes

    def is_swapped(self, rid: int) -> bool:
        return self.backing.is_paged and rid in self.backing.swaps

    def can_admit_swapped(self, rid: int) -> bool:
        """A free slot AND blocks for the request's saved prefix."""
        return bool(self._free) and self.backing.can_admit_swapped(rid)

    def swap_in(self, rid: int) -> Optional[Tuple[int, int]]:
        """Resume a swapped-out request: claim a free slot, remap fresh
        blocks and upload the saved bytes — the slot reads bit-identical
        to the never-preempted layout, so decode continues at the saved
        position with zero recomputed steps. Returns (slot, bytes moved),
        or None when the pool can't host it yet."""
        if not self.can_admit_swapped(rid):
            return None
        slot = self._free.pop()
        nbytes = self.backing.swap_in(slot, rid)
        self.owner[slot] = rid
        self.valid[slot] = True
        return slot, nbytes

    # -- pooled-cache data movement -----------------------------------------

    def gather(self, idx: Sequence[int]):
        """Sub-caches for slots ``idx`` (batch axis = len(idx)). The paged
        backing materializes the page-table view — bit-identical to the
        contiguous rows for every mapped position."""
        return self.backing.gather(idx)

    def scatter(self, sub, idx: Sequence[int]):
        """Write sub-caches (from a bucketed chunk step) back into slots.
        Duplicate indices must carry identical rows (the pad-by-repeat
        contract): the scatter then stays deterministic."""
        self.backing.scatter(sub, idx)

    def run_chunk(self, params, idx: Sequence[int], tokens, pos):
        """Chunk-prefill slots ``idx`` in place (fused gather -> chunk ->
        scatter, one dispatch). Same pad-by-repeat contract as scatter."""
        self.backing.run_chunk(params, idx, tokens, pos)

    def run_decode(self, params, tokens, pos, temps, key):
        """ONE fused decode over the whole pool; returns next tokens.
        (Paged: gather-through-page-table -> decode -> scatter, still one
        jitted program per tick.)"""
        return self.backing.run_decode(params, tokens, pos, temps, key)

    def stats(self) -> dict:
        return {"num_slots": self.num_slots,
                "live": int(self.valid.sum()),
                "free": self.free_count,
                "cache_slots": self.cache_slots,
                "position_capacity": self.position_capacity,
                **self.backing.stats()}
