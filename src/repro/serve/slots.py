"""SlotManager — a fixed pool of cache slots for continuous batching.

The paper's runtime keeps a pool of workers saturated on dependency-bound
work; at LM-serving scale the scarce resource is the static-shape decode
cache. This module owns a pool of B cache slots over the engine's
KV/recurrent caches (``transformer.init_caches(per_slot_pos=True)``):
requests are *allocated* a slot, their prefilled state lives in that
slot's rows of every cache leaf, and eviction on EOS/max-tokens frees the
slot for the next admission — the batch shape never changes, only the
masks do.

With the per-row position layout every cache leaf carries the slot axis
at position 1 ((periods, B, ...)), so gather/scatter/reset are single-axis
indexing ops over the whole pytree, jitted once per sub-batch shape.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T

_SLOT_AXIS = 1      # every per_slot_pos cache leaf: (periods, B, ...)


@jax.jit
def _gather(caches, idx):
    return jax.tree_util.tree_map(
        lambda l: jnp.take(l, idx, axis=_SLOT_AXIS), caches)


# pool-sized updates donate the pool: without donation every scatter /
# reset / chunk step materializes a second full copy of the cache pool
@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter(caches, sub, idx):
    return jax.tree_util.tree_map(
        lambda l, s: l.at[:, idx].set(s.astype(l.dtype)), caches, sub)


@functools.lru_cache(maxsize=None)
def _pooled_chunk_step(cfg: ModelConfig):
    """Fused gather -> chunk-prefill -> scatter over the pooled caches.

    One jitted program (per cfg and sub-batch shape) instead of three
    dispatches: at small sub-batches the per-call overhead of separate
    gather/chunk/scatter calls rivals the chunk compute itself."""
    from repro.serve import engine     # local: slots is engine-agnostic

    step = engine.make_chunk_step(cfg)

    @functools.partial(jax.jit, donate_argnums=(1,))
    def run(params, caches, idx, tokens, pos):
        sub = jax.tree_util.tree_map(
            lambda l: jnp.take(l, idx, axis=_SLOT_AXIS), caches)
        _, sub = step(params, sub, tokens, pos)
        return jax.tree_util.tree_map(
            lambda l, s: l.at[:, idx].set(s.astype(l.dtype)), caches, sub)

    return run


@functools.partial(jax.jit, donate_argnums=(0,))
def _reset(caches, template, idx):
    """Write the zero-state template (slot axis = 1) into slots ``idx``."""

    def wipe(l, t):
        fresh = jnp.broadcast_to(
            t, t.shape[:_SLOT_AXIS] + (idx.shape[0],) + t.shape[2:])
        return l.at[:, idx].set(fresh.astype(l.dtype))

    return jax.tree_util.tree_map(wipe, caches, template)


class SlotManager:
    """Fixed pool of ``num_slots`` decode-cache slots.

    Host-side bookkeeping (free list, per-slot owner + validity mask)
    plus jitted whole-pytree gather/scatter/reset over the pooled caches.
    Each slot's clock lives in the caches' per-row ``pos`` leaves (and
    the scheduler's request state); ``valid[i]`` masks live slots (the
    scheduler decodes the full pool every step; dead rows compute but
    are never read).
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, cache_slots: int):
        self.cfg = cfg
        self.num_slots = num_slots
        self.cache_slots = cache_slots
        self.caches = T.init_caches(cfg, num_slots, cache_slots,
                                    per_slot_pos=True)
        # one-slot zero template: reset = scatter-broadcast of this
        self._template = T.init_caches(cfg, 1, cache_slots,
                                       per_slot_pos=True)
        self._free: List[int] = list(range(num_slots - 1, -1, -1))
        self.owner: List[Optional[int]] = [None] * num_slots
        self.valid = np.zeros(num_slots, bool)

    # -- lifecycle -----------------------------------------------------------

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def live(self) -> List[int]:
        return [i for i in range(self.num_slots) if self.valid[i]]

    def alloc(self, owner: int) -> Optional[int]:
        """Claim a free slot for request ``owner``; zero its cache rows.
        Returns the slot index, or None when the pool is full."""
        if not self._free:
            return None
        slot = self._free.pop()
        self.caches = _reset(self.caches, self._template,
                             jnp.asarray([slot], jnp.int32))
        self.owner[slot] = owner
        self.valid[slot] = True
        return slot

    def release(self, slot: int):
        """Evict (EOS / max-tokens / abort): mark free; the stale cache
        rows are masked out by ``valid`` until the next alloc resets them."""
        assert self.valid[slot], f"slot {slot} is not live"
        self.owner[slot] = None
        self.valid[slot] = False
        self._free.append(slot)

    # -- pooled-cache data movement -----------------------------------------

    def gather(self, idx: Sequence[int]):
        """Sub-caches for slots ``idx`` (batch axis = len(idx))."""
        return _gather(self.caches, jnp.asarray(idx, jnp.int32))

    def scatter(self, sub, idx: Sequence[int]):
        """Write sub-caches (from a bucketed chunk step) back into slots.
        Duplicate indices must carry identical rows (the pad-by-repeat
        contract): the scatter then stays deterministic."""
        self.caches = _scatter(self.caches, sub,
                               jnp.asarray(idx, jnp.int32))

    def run_chunk(self, params, idx: Sequence[int], tokens, pos):
        """Chunk-prefill slots ``idx`` in place (fused gather -> chunk ->
        scatter, one dispatch). Same pad-by-repeat contract as scatter."""
        self.caches = _pooled_chunk_step(self.cfg)(
            params, self.caches, jnp.asarray(idx, jnp.int32),
            jnp.asarray(tokens), jnp.asarray(pos))

    def stats(self) -> dict:
        return {"num_slots": self.num_slots,
                "live": int(self.valid.sum()),
                "free": self.free_count,
                "cache_slots": self.cache_slots}
