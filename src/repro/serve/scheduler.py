"""Continuous-batching LM scheduler on the serve engine's slot pool.

Decode is serving's request-scale 1-D dependency-bound recurrence: each
step consumes the previous step's cache. A static batch pads every
request to the slowest member; this scheduler instead admits, interleaves
and retires requests *per decode step* (the paper's fine-grain scheduling
argument applied to traffic):

  admit    — FCFS queue; a request claims a free cache slot the moment
             one exists (SlotManager.alloc zeroes the slot rows).
  prefill  — prompts are consumed as full ``prefill_chunk`` chunks
             through the batched chunk step (exact: chunks are never
             padded), the < chunk remainder rides the decode ramp as
             teacher-forced single tokens.
  decode   — ONE fused step over the whole pool each tick: per-slot
             position vector, per-slot temperature, masked sampling;
             free slots compute junk that is never read.
  retire   — EOS / max-tokens eviction frees the slot immediately; the
             next queued request is admitted on the same tick.

Under greedy sampling the emitted streams are token-identical to
per-request ``engine.generate`` (same chunk policy, same kernels) for
dense/SSM architectures. MoE capacity is shared across the pool batch,
so MoE token streams can legitimately diverge from B=1 at tight capacity
(documented per-group semantics, models/moe.py).

``speculate=k`` replaces the one-token decode tick with a speculative
verify tick (attention-only models): each greedy slot drafts k tokens
(teacher-forced prompt tokens through the ramp, prompt-lookup self-draft
past it), ONE fused chunk call verifies all k+1 positions, the longest
prefix of drafts agreeing with the model's own greedy predictions is
accepted, and the cache rows of rejected positions are rolled back
in-program — so decode's serial dependency chain advances up to k+1
positions per tick while greedy streams stay bit-identical to the
oracle. ``score(prompts)`` rides the same per-chunk-logits seam: prompt
tokens are teacher-forced through chunk/verify steps and every
position's logprob is collected (``Completion.logprobs``), no tokens
generated. Per-slot ``SamplingPolicy`` (temperature/top-k/top-p)
threads through the fused steps; sampled rows never speculate (they
accept nothing and sample exactly one policy-correct token per tick).

A memoizing request cache (prompt+params -> tokens) fronts the pool for
zipfian traffic — deterministic (greedy) requests only; hit/miss
counters feed the fig_serve benchmark.

With ``allocator='paged'`` the slot pool stores attention KV at block
granularity (serve.paging): admission gates on free *blocks* in every
page-table group — the global-KV group plus (``paged_window_attn``, the
default) one ring-mode group per distinct sliding-window length — live
slots map blocks on demand as their write position grows (ring groups
stop growing once the full ring is resident), retire frees them, and a
growth failure preempts the youngest slot back to the front of the
queue. At the equal-memory defaults (num_blocks=num_window_blocks=None)
scheduling is identical to contiguous; smaller pools admit more
concurrent mixed-length requests per byte at the cost of preemptions.

What preemption discards is the ``preempt`` policy:

  recompute — the victim restarts from scratch (greedy streams unchanged
              by determinism, but every decode step it had paid for is
              redone: counters['recomputed_decode_steps']).
  swap      — the victim's mapped blocks are copied to a host SwapStore
              and its freed; on re-admission fresh blocks are mapped and
              the bytes uploaded, so it RESUMES at its saved position —
              zero recomputed decode steps, bit-identical streams.

``admission='reserved'`` books blocks_for(prompt + max_new) at admit
instead of blocks_for(prompt) — growth can then never fail, so admitted
(QoS) traffic is never preempted, at the cost of admitted concurrency.

Observability (repro.obs): the scheduler registers itself as the
``serve`` provider of the metrics registry (all ``stats()`` keys,
pre-declared so they never appear lazily), stamps every request's
per-phase timeline (queue-wait, prefill, first token, swapped-out time,
recompute waste — surfaced as ``Completion.queue_wait`` / ``ttft`` /
``decode_s`` / ``itl``), and, when a Tracer is enabled, records
``admit`` / ``prefill`` / ``decode`` / ``preempt`` / ``swap-out`` /
``swap-in`` / ``retire`` events per slot track plus ``decode-tick`` /
``prefill-chunk`` spans on the scheduler track — a serve run exports
straight to Perfetto (obs.trace.Tracer.export_chrome).
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.obs import metrics as obs_metrics
from repro.obs import sampler as obs_sampler
from repro.obs import trace as obs_trace
from repro.runtime import bucketing
from repro.serve import engine
from repro.serve.slots import SlotManager, _attn_view_len


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    num_slots: int = 8          # pool width B (the fused decode batch)
    max_len: int = 256          # cache slots per request (prompt + gen)
    prefill_chunk: int = 32     # C: full-chunk prefill quantum
    max_new_tokens: int = 32    # default generation budget
    temperature: float = 0.0    # default sampling temperature (0 = greedy)
    top_k: int = 0              # default top-k filter (0 = disabled)
    top_p: float = 1.0          # default nucleus mass (1.0 = disabled)
    # k > 0: speculative decoding — draft k tokens per greedy slot per
    # tick, verify them in ONE fused chunk call, accept the agreeing
    # prefix and roll back the rest. Needs an attention-only pattern
    # (SSM chunk scans are irreversible) and k + 1 <= the smallest
    # attention view length (the rollback scatter needs distinct ring
    # rows). Greedy streams stay bit-identical to speculate=0.
    speculate: int = 0
    eos_token: Optional[int] = None
    cache_requests: bool = True
    request_cache_size: int = 1024
    seed: int = 0
    # 'continuous': admit whenever a slot is free (per-step interleaving).
    # 'static': admit a full batch only when the pool is EMPTY — the
    # pad-to-slowest baseline fig_serve compares against.
    admit: str = "continuous"
    # 'contiguous': every slot reserves max_len cache rows.
    # 'paged': attention KV lives in block pools (serve.paging) —
    # admission gates on free BLOCKS, slots grow block-by-block as they
    # decode, and a growth failure preempts the youngest slot.
    allocator: str = "contiguous"
    block_size: int = 16        # paged: cache positions per block
    # paged: physical blocks in the global-KV pool. None = equal memory
    # with the contiguous layout (num_slots * ceil(max_len / block_size))
    # — with that default no request can ever fail to grow, so scheduling
    # is identical to contiguous; smaller pools trade preemptions for
    # memory.
    num_blocks: Optional[int] = None
    # paged: also page sliding-window rings through ring-mode page-table
    # groups (one per distinct window length) instead of reserving a
    # dense window-row slab per slot. Blocks map lazily while a request
    # ramps up to `window` written positions; Pareto-short requests never
    # pay for the full ring. Off = the PR-3/4 dense-ring layout.
    paged_window_attn: bool = True
    # paged: physical blocks per window-ring pool. None = equal memory
    # with the dense rings (num_slots * ceil(min(window, max_len) /
    # block_size)).
    num_window_blocks: Optional[int] = None
    # preempt='swap': byte budget for the host SwapStore. None =
    # unbounded; when an eviction's bytes would exceed it, that victim
    # falls back to recompute-preemption (stats()['swap_rejected']).
    swap_bytes_budget: Optional[int] = None
    # paged: what preempt-on-OOB discards. 'recompute' restarts the
    # victim from scratch; 'swap' parks its block bytes in a host
    # SwapStore and resumes it at the saved position on re-admission.
    preempt: str = "recompute"
    # paged: 'optimistic' books blocks for the prompt only (growth may
    # hit OOB -> preempt); 'reserved' books blocks_for(prompt + max_new)
    # at admission, so admitted traffic can never be preempted (QoS).
    admission: str = "optimistic"
    # paged: share block-aligned prompt prefixes across requests through
    # a refcounted PrefixIndex (serve.paging) — an admitted prompt whose
    # leading chunks are indexed maps those blocks read-shared and starts
    # prefill past them; copy-on-write keeps sharers isolated. Greedy
    # streams stay bit-identical to unshared (the shared region is
    # chunk-aligned, so the remaining prefill chunks at the same
    # offsets an unshared run would).
    prefix_sharing: bool = False
    # prefix_sharing: LRU entry bound on the prefix index (each entry
    # holds one block per page-table group alive).
    prefix_index_capacity: int = 512
    # Shard the slot pool over a 1-D device mesh: num_slots splits evenly
    # into mesh_shards shards, each owning its OWN block pools / page
    # tables / swap store / prefix index (num_blocks etc. are then PER
    # SHARD — equal per-device memory), and every tick runs ONE fused
    # program spanning all shards (engine.jit_sharded_*_step; pass a
    # Mesh via Scheduler(mesh=...) to shard_map it over devices).
    # Requires allocator='paged'. None = the unsharded pool;
    # mesh_shards=1 runs the sharded control path over the SAME compiled
    # programs, bit-identical to None.
    mesh_shards: Optional[int] = None
    # sharded: which shard an admitted request lands on.
    # 'least_blocks' (default) picks the shard with the most free
    # blocks; 'round_robin' cycles. Scheduler.placement_fn overrides
    # with a callable (sched, slot_state) -> shard.
    placement: str = "least_blocks"
    # sharded: work-stealing rebalance — a queue head blocked on a full
    # shard migrates to an idle shard that can admit it now instead of
    # head-of-line blocking (swapped-out heads move their host SwapEntry
    # between shard stores, keeping all prefill progress).
    steal: bool = True


@dataclasses.dataclass
class _Slot:
    """Host-side per-slot request state (the validity mask's payload)."""
    rid: int
    prompt: np.ndarray          # int32 (L,)
    max_new_tokens: int
    policy: engine.SamplingPolicy
    mode: str = "generate"      # 'generate' | 'score' (prompt logprobs)
    ctx: int = 0                # tokens consumed into the slot's cache
    chunk_tokens: int = 0       # of which via chunk steps (not decode)
    out: List[int] = dataclasses.field(default_factory=list)
    logprobs: List[float] = dataclasses.field(default_factory=list)
    accepted: int = 0           # speculative drafts accepted (this request)
    drafted: int = 0            # speculative drafts proposed (this request)
    admit_seq: int = -1         # admission order: preemption evicts max
    shard: int = 0              # home shard (0 on unsharded pools)

    @property
    def temperature(self) -> float:
        return self.policy.temperature


@dataclasses.dataclass
class _Timeline:
    """Per-request phase stamps (perf_counter), kept while the request
    is in flight and folded into its Completion at finish."""
    submit_t: float
    admit_t: Optional[float] = None     # first slot claim (None = cached)
    first_token_t: Optional[float] = None
    swap_out_t: Optional[float] = None  # open swap interval, if any
    swapped_s: float = 0.0              # total time parked in the SwapStore
    recomputed_steps: int = 0           # decode ticks redone after preempt
    preemptions: int = 0


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: np.ndarray          # int32 (g,)
    reason: str                 # 'eos' | 'length' | 'score' | 'cached'
    prompt_len: int
    submit_t: float             # time.perf_counter() stamp at submit
    finish_t: float             # time.perf_counter() stamp at finish
    # per-phase stamps (defaults match cache-served completions, which
    # never touch the pool)
    admit_t: Optional[float] = None     # first slot claim
    first_token_t: Optional[float] = None
    swapped_s: float = 0.0              # time parked in the SwapStore
    recomputed_steps: int = 0           # decode ticks redone after preempt
    preemptions: int = 0
    # score() requests: log p(prompt[i] | prompt[:i]) for i = 1..L-1,
    # fp32 (L-1,); None for generate requests
    logprobs: Optional[np.ndarray] = None
    # speculative-decoding effort for this request (0 when speculate=0
    # or served from cache): drafts accepted / proposed
    accepted: int = 0
    drafted: int = 0

    @property
    def latency(self) -> float:
        # perf_counter deltas are monotonic: a wall-clock (NTP) step can
        # never make a latency negative and skew fig_serve's p50/p95
        return self.finish_t - self.submit_t

    @property
    def queue_wait(self) -> float:
        """Submit -> first admission. 0 for cache-served requests."""
        return self.admit_t - self.submit_t if self.admit_t is not None \
            else 0.0

    @property
    def ttft(self) -> float:
        """Submit -> first generated token (== latency when the request
        was served from cache or produced its one token at finish)."""
        return self.first_token_t - self.submit_t \
            if self.first_token_t is not None else self.latency

    @property
    def prefill_s(self) -> float:
        """Admission -> first token: prompt consumption time."""
        if self.admit_t is None or self.first_token_t is None:
            return 0.0
        return self.first_token_t - self.admit_t

    @property
    def decode_s(self) -> float:
        """First token -> finish: pure generation time."""
        return self.finish_t - self.first_token_t \
            if self.first_token_t is not None else 0.0

    @property
    def itl(self) -> float:
        """Mean inter-token latency over the decode phase."""
        return self.decode_s / max(len(self.tokens) - 1, 1)


class RequestCache:
    """LRU memo: (prompt, params) -> completed tokens (greedy only).

    Zipfian traffic repeats a few hot prompts; serving them from the memo
    costs zero decode steps (ROADMAP 'runtime caching' item). Sampled
    (temperature > 0) requests bypass the cache — they are not
    deterministic functions of the key. The request *mode* (score vs
    generate) and the sampling-policy fingerprint are part of the key: a
    ``score()`` and a ``generate()`` of the same prompt return different
    payloads and must never alias in the memo.
    """

    def __init__(self, maxsize: int = 1024):
        self.maxsize = maxsize
        self._d: "collections.OrderedDict[Tuple, Tuple[np.ndarray, str, Optional[np.ndarray]]]" \
            = collections.OrderedDict()
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(prompt: np.ndarray, max_new_tokens: int,
            eos_token: Optional[int], mode: str = "generate",
            policy: Tuple = ()) -> Tuple:
        # dtype + shape are part of the key: raw bytes alone collide for
        # e.g. int64([1]) vs int32([1, 0]) (same little-endian bytes) or
        # a (4,) vs (2, 2) view of the same buffer. mode + policy
        # fingerprint (SamplingPolicy.fingerprint()) distinguish
        # score/generate and sampling configurations of one prompt.
        p = np.ascontiguousarray(prompt)
        return (p.tobytes(), p.dtype.str, p.shape,
                max_new_tokens, eos_token, mode, tuple(policy))

    def get(self, key: Tuple) \
            -> Optional[Tuple[np.ndarray, str, Optional[np.ndarray]]]:
        got = self._d.get(key)
        if got is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return got

    def put(self, key: Tuple, tokens: np.ndarray, reason: str,
            logprobs: Optional[np.ndarray] = None):
        # defensive copy, frozen: the caller (and the original
        # requester's Completion) may hold the array we were handed —
        # memoizing it by reference would let `completion.tokens[0] = x`
        # corrupt every future hit. get() consumers copy on the way out.
        tokens = np.asarray(tokens, np.int32).copy()
        tokens.setflags(write=False)
        if logprobs is not None:
            logprobs = np.asarray(logprobs, np.float32).copy()
            logprobs.setflags(write=False)
        self._d[key] = (tokens, reason, logprobs)
        self._d.move_to_end(key)
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


#: scheduler-owned counters, pre-declared at zero so stats() keys are
#: stable from construction (obs.schema.SCHEDULER_STATS pins them)
_COUNTER_KEYS = (
    "submitted", "admitted", "completed", "steps", "decode_steps",
    "chunk_steps", "generated_tokens", "prefill_tokens",
    "live_decode_slots", "preempted", "swapped_in", "swapped_out",
    "recomputed_decode_steps", "prefix_shared_tokens",
    # sharded pools: queue heads migrated off a full shard (0 otherwise)
    "steals",
    # speculative decoding (all 0 when speculate=0; 'real' drafts only —
    # teacher-forced ramp positions are excluded from the denominator)
    "spec.drafted_tokens", "spec.accepted_tokens", "spec.rejected_tokens",
    "spec.rollbacks",
)


def _log_softmax_np(lg: np.ndarray) -> np.ndarray:
    """Row-wise log-softmax over the last axis, fp32 (host-side prompt
    scoring from surfaced chunk/decode logits)."""
    lg = np.asarray(lg, np.float32)
    m = lg.max(axis=-1, keepdims=True)
    e = lg - m
    return (e - np.log(np.exp(e).sum(axis=-1, keepdims=True))).astype(
        np.float32)


class _ShardObs:
    """Registry ``serve.shard`` provider (sharded pools only): per-shard
    occupancy (``shard<i>.live_slots`` / ``free_slots`` / block + swap
    levels from the pool) plus the scheduler's placement/steal view —
    ``shard<i>.placed`` / ``steals`` / ``queued`` and the pool-wide
    ``steals`` total. The scheduler holds the strong reference (the
    registry keeps providers weakly)."""

    def __init__(self, sched: "Scheduler"):
        self._sched = sched

    def metrics(self) -> dict:
        sched = self._sched
        out = dict(sched.slots.shard_metrics())
        for s in range(sched.slots.num_shards):
            out[f"shard{s}.placed"] = sched._shard_placed[s]
            out[f"shard{s}.steals"] = sched._shard_steals[s]
            out[f"shard{s}.queued"] = len(sched._queues[s])
        out["num_shards"] = sched.slots.num_shards
        out["steals"] = int(sched.counters["steals"])
        return out


class Scheduler:
    """submit(prompts) / step() / drain() continuous-batching engine."""

    def __init__(self, cfg: ModelConfig, params,
                 sched: SchedulerConfig = SchedulerConfig(),
                 tracer: Optional[obs_trace.Tracer] = None,
                 draft_fn=None, mesh=None):
        self.cfg = cfg
        self.params = params
        self.sched = sched
        # pluggable draft source for speculate=k: draft_fn(seq, need) ->
        # >= need proposed next tokens given the committed sequence
        # (prompt + generated so far). None = built-in prompt-lookup
        # self-draft. A draft model slots in here; draft quality only
        # affects speed, never correctness (verify rejects disagreement).
        self._draft_fn = draft_fn
        for field, allowed in (("allocator", ("contiguous", "paged")),
                               ("preempt", ("recompute", "swap")),
                               ("admission", ("optimistic", "reserved"))):
            if getattr(sched, field) not in allowed:
                raise ValueError(f"SchedulerConfig.{field}="
                                 f"{getattr(sched, field)!r} not in {allowed}")
        if sched.prefix_sharing and sched.allocator != "paged":
            raise ValueError("prefix_sharing requires allocator='paged' "
                             "(blocks are the sharing granule)")
        if sched.placement not in ("least_blocks", "round_robin"):
            raise ValueError(f"SchedulerConfig.placement="
                             f"{sched.placement!r} not in "
                             "('least_blocks', 'round_robin')")
        if sched.mesh_shards is not None and sched.allocator != "paged":
            raise ValueError("mesh_shards requires allocator='paged' "
                             "(shards own per-shard block pools)")
        if mesh is not None and sched.mesh_shards is None:
            raise ValueError("Scheduler(mesh=...) needs "
                             "SchedulerConfig.mesh_shards set")
        if sched.speculate < 0:
            raise ValueError(f"speculate must be >= 0: {sched.speculate}")
        if sched.speculate:
            bad = [(s.mixer, s.mlp) for s in cfg.pattern
                   if s.mixer != "attn" or s.mlp == "rwkv_ffn"]
            if bad:
                raise ValueError(
                    "speculate requires an attention-only pattern with "
                    f"stateless MLPs (got {bad}): SSM/rwkv_ffn chunk "
                    "scans cannot roll back rejected drafts")
            min_view = min(_attn_view_len(s, sched.max_len)
                           for s in cfg.pattern)
            if sched.speculate + 1 > min_view:
                raise ValueError(
                    f"speculate={sched.speculate}: verify span "
                    f"{sched.speculate + 1} exceeds the smallest "
                    f"attention view length {min_view} (the rollback "
                    "scatter needs distinct ring rows)")
        # validates temperature/top_k/top_p ranges (ValueError on bad)
        engine.SamplingPolicy(sched.temperature, sched.top_k, sched.top_p)
        # shared prefixes must end on a chunk boundary AND a block
        # boundary: the sharer skips whole chunk steps and maps whole
        # blocks, so only lcm-aligned prefixes keep the remaining
        # prefill chunking (and so the greedy stream) bit-identical to
        # an unshared run.
        prefix_align = math.lcm(sched.prefill_chunk, sched.block_size)
        self.slots = SlotManager(cfg, sched.num_slots, sched.max_len,
                                 paged=sched.allocator == "paged",
                                 block_size=sched.block_size,
                                 num_blocks=sched.num_blocks,
                                 paged_window=sched.paged_window_attn,
                                 num_window_blocks=sched.num_window_blocks,
                                 swap_bytes_budget=sched.swap_bytes_budget,
                                 prefix_sharing=sched.prefix_sharing,
                                 prefix_align=prefix_align,
                                 prefix_capacity=sched.prefix_index_capacity,
                                 mesh_shards=sched.mesh_shards,
                                 mesh=mesh)
        # one FCFS queue per shard (exactly one on unsharded pools, so
        # every single-queue invariant — arrival order, head-of-line
        # admission — is the pre-sharding behavior verbatim)
        self._queues: List["collections.deque[_Slot]"] = [
            collections.deque() for _ in range(self.slots.num_shards)]
        self._rr_next = 0               # round_robin placement cursor
        # pluggable placement: fn(scheduler, _Slot) -> shard index;
        # overrides SchedulerConfig.placement when set
        self.placement_fn = None
        self._shard_placed = [0] * self.slots.num_shards
        self._shard_steals = [0] * self.slots.num_shards
        self._by_slot: Dict[int, _Slot] = {}
        self._inflight: Dict[Tuple, List[int]] = {}
        self._fresh: List[int] = []     # finished, not yet handed out
        self._tl: Dict[int, _Timeline] = {}
        self.results: Dict[int, Completion] = {}
        self.request_cache = RequestCache(sched.request_cache_size)
        self._key = jax.random.PRNGKey(sched.seed)
        self._next_rid = 0
        self._next_seq = 0          # admission sequence (preempt youngest)
        self.counters = collections.Counter(dict.fromkeys(_COUNTER_KEYS, 0))
        # per-request latency histograms (lifetime count/sum, windowed
        # p50/p95) — the sampled series SLO rules like ``ttft_p95 < X``
        # monitor; fresh per scheduler so benchmarks don't cross-pollute
        self._lat = {name: obs_metrics.Histogram()
                     for name in ("queue_wait_ms", "ttft_ms", "itl_ms",
                                  "spec.accept_len")}
        # closed-loop actuator knobs (obs.control.BackpressureController):
        # admit_cap caps admissions per tick while an overload alert
        # fires (None = uncapped FCFS), preempt_override flips the
        # preemption policy without touching the frozen config. Both only
        # ever change timing/admission — greedy token streams are
        # bit-identical with or without them (tests/test_obs_loop.py).
        self.admit_cap: Optional[int] = None
        self.preempt_override: Optional[str] = None
        self._tracer = tracer
        # slot -> (phase name, t0, rid): the open per-slot phase span,
        # closed at first-token / preempt / retire (tracer enabled only)
        self._open_phase: Dict[int, Tuple[str, float, int]] = {}
        obs_metrics.REGISTRY.register_provider("serve", self)
        self._shard_obs = None
        if self.slots.sharded:
            self._shard_obs = _ShardObs(self)
            obs_metrics.REGISTRY.register_provider("serve.shard",
                                                   self._shard_obs)

    @property
    def tracer(self) -> obs_trace.Tracer:
        return self._tracer if self._tracer is not None \
            else obs_trace.get_tracer()

    @property
    def preempt_policy(self) -> str:
        """The policy preempt-on-OOB actually uses this tick: the
        controller's override when backpressure is engaged, else the
        configured one."""
        return self.preempt_override or self.sched.preempt

    def _phase_begin(self, slot: int, name: str, rid: int):
        if self.tracer.enabled:
            self._open_phase[slot] = (name, time.perf_counter(), rid)

    def _phase_end(self, slot: int):
        open_ = self._open_phase.pop(slot, None)
        if open_ is not None:
            name, t0, rid = open_
            self.tracer.complete(name, f"slot{slot}", t0,
                                 time.perf_counter(), rid=rid)

    # -- submission ----------------------------------------------------------

    def submit(self, prompts: Sequence, max_new_tokens: Optional[int] = None,
               temperature: Optional[float] = None,
               top_k: Optional[int] = None,
               top_p: Optional[float] = None) -> List[int]:
        """Enqueue prompts (FCFS); returns request ids. Cached greedy
        repeats complete immediately without touching the pool.
        temperature/top_k/top_p default to the SchedulerConfig values and
        form the batch's SamplingPolicy (validated here, ValueError)."""
        mnt = self.sched.max_new_tokens if max_new_tokens is None \
            else max_new_tokens
        policy = engine.SamplingPolicy(
            self.sched.temperature if temperature is None else temperature,
            self.sched.top_k if top_k is None else top_k,
            self.sched.top_p if top_p is None else top_p)
        rids = []
        # user-input feasibility checks raise ValueError (not assert:
        # they must hold under `python -O` too — the pool's progress
        # guarantee depends on them). The WHOLE batch is validated
        # before anything is enqueued: a mid-batch failure must not
        # leave earlier prompts admitted as orphans whose rids the
        # caller never received (they would complete into `results`
        # with nobody to pop them).
        if mnt < 1:
            raise ValueError("max_new_tokens must be >= 1")
        batch = []
        for p in prompts:
            p = np.asarray(p, np.int32).reshape(-1)
            if not 1 <= len(p) <= self.sched.max_len - mnt:
                raise ValueError(
                    f"prompt length {len(p)} + max_new {mnt} exceeds "
                    f"max_len {self.sched.max_len}")
            if self.slots.paged:
                # progress guarantee for preempt-on-OOB: with every other
                # slot evicted the oldest request must fit the whole pool
                # — in EVERY page-table group (global KV and each
                # window-ring group; ring demand clamps at the full ring)
                why = self.slots.fits_pool(len(p) + mnt)
                if why is not None:
                    raise ValueError(why)
            batch.append(p)
        for p in batch:
            rid = self._next_rid
            self._next_rid += 1
            self._tl[rid] = _Timeline(submit_t=time.perf_counter())
            self.counters["submitted"] += 1
            self.tracer.instant("submit", "scheduler", rid=rid)
            if self.sched.cache_requests and policy.greedy:
                key = RequestCache.key(p, mnt, self.sched.eos_token,
                                       policy=policy.fingerprint())
                if key in self._inflight:
                    # coalesce: an identical request is already queued or
                    # decoding — ride its completion (memo-layer hit: a
                    # zipfian burst of one hot prompt decodes ONCE)
                    self._inflight[key].append(rid)
                    self.request_cache.hits += 1
                    rids.append(rid)
                    continue
                got = self.request_cache.get(key)
                if got is not None:
                    toks, _, _ = got
                    self._finish(rid, len(p), toks.copy(), "cached")
                    rids.append(rid)
                    continue
                self._inflight[key] = []
            self._enqueue(_Slot(rid=rid, prompt=p, max_new_tokens=mnt,
                                policy=policy))
            rids.append(rid)
        return rids

    def score(self, prompts: Sequence) -> List[int]:
        """Enqueue prompts for per-token logprob scoring; returns request
        ids. Each completion carries ``logprobs`` — fp32 (L-1,) with
        ``logprobs[i-1] = log p(prompt[i] | prompt[:i])`` — and no
        generated tokens (reason 'score'). Scoring rides the same chunk
        path as prefill (the per-chunk-logits seam), teacher-forcing the
        prompt and reading every position's logits; deterministic, so
        results memoize in the RequestCache under a score-mode key that
        can never alias a generate() of the same prompt."""
        batch = []
        for p in prompts:
            p = np.asarray(p, np.int32).reshape(-1)
            if not 2 <= len(p) <= self.sched.max_len:
                raise ValueError(
                    f"score prompt length {len(p)} must be in "
                    f"[2, max_len={self.sched.max_len}]")
            if self.slots.paged:
                why = self.slots.fits_pool(len(p))
                if why is not None:
                    raise ValueError(why)
            batch.append(p)
        policy = engine.SamplingPolicy()        # scoring is greedy-only
        rids = []
        for p in batch:
            rid = self._next_rid
            self._next_rid += 1
            self._tl[rid] = _Timeline(submit_t=time.perf_counter())
            self.counters["submitted"] += 1
            self.tracer.instant("submit", "scheduler", rid=rid, mode="score")
            if self.sched.cache_requests:
                key = RequestCache.key(p, 0, self.sched.eos_token,
                                       mode="score",
                                       policy=policy.fingerprint())
                if key in self._inflight:
                    self._inflight[key].append(rid)
                    self.request_cache.hits += 1
                    rids.append(rid)
                    continue
                got = self.request_cache.get(key)
                if got is not None:
                    toks, _, lps = got
                    self._finish(rid, len(p), toks.copy(), "cached",
                                 logprobs=None if lps is None
                                 else lps.copy())
                    rids.append(rid)
                    continue
                self._inflight[key] = []
            self._enqueue(_Slot(rid=rid, prompt=p, max_new_tokens=0,
                                policy=policy, mode="score"))
            rids.append(rid)
        return rids

    def _place(self, st: _Slot) -> int:
        """Pick the home shard for a new request (0 on unsharded pools).
        'least_blocks' takes the shard with the most free blocks, ties
        broken by shorter queue then lower index; 'round_robin' cycles.
        ``placement_fn`` (callable (scheduler, _Slot) -> shard) overrides
        both."""
        n = self.slots.num_shards
        if n == 1:
            return 0
        if self.placement_fn is not None:
            shard = int(self.placement_fn(self, st))
            if not 0 <= shard < n:
                raise ValueError(f"placement_fn returned shard {shard} "
                                 f"(pool has {n})")
            return shard
        if self.sched.placement == "round_robin":
            shard = self._rr_next
            self._rr_next = (self._rr_next + 1) % n
            return shard
        return min(range(n),
                   key=lambda s: (-self.slots.shard_free_blocks(s),
                                  len(self._queues[s]), s))

    def _enqueue(self, st: _Slot):
        st.shard = self._place(st)
        self._shard_placed[st.shard] += 1
        self._queues[st.shard].append(st)

    # -- the scheduling loop -------------------------------------------------

    def step(self) -> List[Completion]:
        """One tick: admit, chunk-prefill, one fused decode, retire.
        Returns every completion not yet handed out — including requests
        finished at submit time by the request cache."""
        self._admit()
        self._prefill_chunks()
        self._decode_once()
        self.counters["steps"] += 1
        out = [self.results[rid] for rid in self._fresh]
        self._fresh.clear()
        # tick the installed sampler (if any) AFTER the tick's work, so
        # a sample sees the levels this step produced; one global load +
        # None check when live sampling is off
        obs_sampler.tick("serve.step")
        return out

    def drain(self) -> List[Completion]:
        """Run until queue and pool are empty; returns the completions
        NOT yet handed out (by an earlier step() or drain()), rid order —
        a completion is delivered exactly once across step/drain calls.

        ``results`` still archives every completion until the caller
        removes entries — a long-lived scheduler (KernelService front
        door) should ``results.pop(rid)`` once a completion is consumed,
        or ``results`` grows without bound."""
        fresh: List[int] = []
        while any(self._queues) or self._by_slot:
            fresh.extend(c.rid for c in self.step())
        fresh.extend(self._fresh)   # cache hits finished at submit time
        self._fresh.clear()
        return [self.results[rid] for rid in sorted(fresh)]

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues)

    @property
    def live(self) -> int:
        return len(self._by_slot)

    def metrics(self) -> dict:
        """Scheduler-owned metrics (registry 'serve' provider): every
        counter (pre-declared), queue/pool levels, cache rates, the
        latency histograms (flattened ``<name>.<field>``) and the live
        overload signal + actuator knobs the SLO/control loop reads.
        ``stats()`` = this + the slot pool's keys."""
        decode_steps = self.counters["decode_steps"]
        head_wait = 0.0
        heads = [q[0] for q in self._queues if q]
        if heads:
            # oldest queue head across shards (one queue when unsharded)
            head_wait = time.perf_counter() \
                - min(self._tl[st.rid].submit_t for st in heads)
        out = {**{k: int(v) for k, v in self.counters.items()},
               "pending": self.pending,
               "live": len(self._by_slot),
               "coalesced_waiting": sum(
                   len(v) for v in self._inflight.values()),
               "cache_hits": self.request_cache.hits,
               "cache_misses": self.request_cache.misses,
               "cache_hit_rate": round(self.request_cache.hit_rate, 4),
               "mean_occupancy": round(
                   self.counters["live_decode_slots"] / decode_steps, 4)
               if decode_steps else 0.0,
               "queue_head_wait_s": round(head_wait, 6),
               "admit_cap": -1 if self.admit_cap is None
               else int(self.admit_cap),
               "preempt_policy": self.preempt_policy}
        for name, h in self._lat.items():
            for k, v in h.summary().items():
                out[f"{name}.{k}"] = v
        return out

    def stats(self) -> dict:
        return {**self.metrics(), **self.slots.stats()}

    # -- internals -----------------------------------------------------------

    def _admit(self):
        if self.sched.admit == "static" and self._by_slot:
            return      # static batching: wait for the whole batch
        self._steal_rebalance()
        # Per-shard FCFS with head-of-line blocking: if a queue head's
        # blocks aren't free (paged), nothing behind it on that shard
        # jumps the line — preserves arrival order and starves no
        # request. Unsharded pools run exactly one queue, so this IS the
        # pre-sharding single-queue loop.
        admitted_this_tick = 0
        for shard, q in enumerate(self._queues):
            while q:
                # backpressure: while the overload alert fires the
                # controller caps admissions per tick (order is still
                # FCFS — only timing changes, so greedy streams are
                # unchanged)
                if self.admit_cap is not None \
                        and admitted_this_tick >= self.admit_cap:
                    return
                if not self._admit_head(shard, q):
                    break           # head-of-line blocked: next shard
                admitted_this_tick += 1

    def _head_admissible(self, shard: int, st: _Slot) -> bool:
        """Could ``st`` admit right now? Swapped-out requests check the
        shard whose store holds their entry; fresh ones check ``shard``.
        Mirrors the checks ``_admit_head`` performs before claiming."""
        if self.slots.is_swapped(st.rid):
            return self.slots.can_admit_swapped(st.rid)
        need = len(st.prompt) + (
            st.max_new_tokens
            if self.sched.admission == "reserved" else 0)
        span = len(st.prompt) + st.max_new_tokens
        pr = st.prompt if st.mode == "generate" else None
        return self.slots.can_admit(
            need, prompt=pr, span=span,
            shard=shard if self.slots.sharded else None)

    def _steal_rebalance(self):
        """Work-stealing rebalance (sharded pools): a queue head that
        cannot admit on its home shard migrates to an IDLE shard (empty
        queue) that can admit it right now, instead of head-of-line
        blocking behind a full shard. The busiest-free destination wins.
        Swapped-out heads move their host SwapEntry between shard swap
        stores (budget- and block-checked up front; a refusal means no
        steal), so a stolen request never loses prefill progress."""
        n = self.slots.num_shards
        if not self.sched.steal or n < 2:
            return
        for s, q in enumerate(self._queues):
            if not q:
                continue
            st = q[0]
            if self._head_admissible(s, st):
                continue            # admits normally this tick
            swapped = self.slots.is_swapped(st.rid)
            cands = [d for d in range(n)
                     if d != s and not self._queues[d]
                     and (self.slots.can_steal_swapped(st.rid, d)
                          if swapped else self._head_admissible(d, st))]
            if not cands:
                continue
            d = max(cands, key=self.slots.shard_free_blocks)
            if swapped and not self.slots.migrate_swapped(st.rid, d):
                continue
            q.popleft()
            st.shard = d
            self._queues[d].append(st)
            self.counters["steals"] += 1
            self._shard_steals[d] += 1
            self.tracer.instant("steal", "scheduler", rid=st.rid,
                                src_shard=s, dst_shard=d)

    def _admit_head(self, shard: int, q) -> bool:
        """Try to admit ``q``'s head onto ``shard``; True = admitted (and
        popped), False = head-of-line blocked (pool or blocks full)."""
        st = q[0]
        sh = shard if self.slots.sharded else None
        swapped_in = False
        if self.slots.is_swapped(st.rid):
            # resume a swap-preempted request: remap + upload its
            # saved blocks; it continues at st.ctx with st.out intact
            got = self.slots.swap_in(st.rid)
            if got is None:
                return False
            slot, _ = got
            self.counters["swapped_in"] += 1
            swapped_in = True
        else:
            # reserved admission books the whole generation budget up
            # front: growth can never OOB, so QoS traffic is never
            # preempted (submit checked it fits the pool)
            need = len(st.prompt) + (
                st.max_new_tokens
                if self.sched.admission == "reserved" else 0)
            # prefix sharing needs the prompt (to match the index)
            # and the request's full span (ring groups only share
            # when the span fits the ring, so no wrap can ever
            # write through a shared block). Score rows never share:
            # a shared prefix skips the chunk steps whose logits ARE
            # the scored logprobs.
            span = len(st.prompt) + st.max_new_tokens
            pr = st.prompt if st.mode == "generate" else None
            if not self.slots.can_admit(need, prompt=pr, span=span,
                                        shard=sh):
                return False
            slot = self.slots.alloc(st.rid, prompt_len=need,
                                    prompt=pr, span=span, shard=sh)
            start = self.slots.prefill_start(slot)
            if start:
                # the leading `start` positions were admitted mapped
                # to index-held blocks: their KV already exists, so
                # prefill resumes past them (chunk-aligned, so the
                # remaining chunking is identical to an unshared run)
                st.ctx = start
                st.chunk_tokens = start
                self.counters["prefix_shared_tokens"] += start
        q.popleft()
        st.admit_seq = self._next_seq
        self._next_seq += 1
        self._by_slot[slot] = st
        self.counters["admitted"] += 1
        now = time.perf_counter()
        tl = self._tl[st.rid]
        if tl.admit_t is None:
            tl.admit_t = now        # first admission only (queue-wait)
            self._lat["queue_wait_ms"].observe(
                (now - tl.submit_t) * 1e3)
        if swapped_in:
            if tl.swap_out_t is not None:
                tl.swapped_s += now - tl.swap_out_t
                tl.swap_out_t = None
            self.tracer.instant("swap-in", f"slot{slot}", rid=st.rid)
        else:
            self.tracer.instant("admit", f"slot{slot}", rid=st.rid,
                                prompt_len=len(st.prompt))
        self._phase_begin(slot, "prefill" if st.ctx < len(st.prompt)
                          else "decode", st.rid)
        return True

    def _preempt(self, slot: int):
        """Evict a live slot to free its blocks (paged growth failure);
        the request re-queues at the FRONT. Under preempt='recompute' it
        restarts from scratch — every decode step it had consumed is
        redone (counted in 'recomputed_decode_steps'; greedy completions
        are unchanged by determinism, sampled ones may diverge like any
        restart). Under preempt='swap' its block bytes move to the host
        SwapStore and it later RESUMES at st.ctx — no wasted work,
        unless the SwapStore's byte budget rejects the entry, in which
        case this victim degrades to a recompute restart (the store
        counts the rejection; stats()['swap_rejected'])."""
        st = self._by_slot.pop(slot)
        self._phase_end(slot)
        tl = self._tl[st.rid]
        swapped = False
        if self.preempt_policy == "swap":
            # bytes moved AND budget rejections are tracked once, by the
            # backing's SwapStore (surfaced through stats() —
            # 'swap_rejected' has a single owner); counters only count
            # scheduler events
            swapped = self.slots.swap_out(slot) is not None
            if swapped:
                self.counters["swapped_out"] += 1
                tl.swap_out_t = time.perf_counter()
                self.tracer.instant("swap-out", f"slot{slot}", rid=st.rid)
        if not swapped:
            self.slots.release(slot)
            # decode ticks this victim consumed (ctx minus chunk-step
            # tokens) that the restart will pay for again
            wasted = st.ctx - st.chunk_tokens
            self.counters["recomputed_decode_steps"] += wasted
            tl.recomputed_steps += wasted
            tl.first_token_t = None     # the restart re-earns its TTFT
            self.tracer.instant("preempt", f"slot{slot}", rid=st.rid,
                                wasted_steps=wasted)
            st.ctx = 0
            st.chunk_tokens = 0
            st.out = []
            st.logprobs = []    # a score restart re-collects from scratch
        st.admit_seq = -1
        # re-queue at the FRONT of the home shard's queue (the shard the
        # slot lived on — a swapped entry's bytes are parked there)
        st.shard = self.slots.shard_of_slot(slot)
        self._queues[st.shard].appendleft(st)
        self.counters["preempted"] += 1
        tl.preemptions += 1

    def _ensure_or_preempt(self, slot: int, upto_pos: int,
                           write_from: Optional[int] = None) -> bool:
        """Grow ``slot``'s storage to cover ``upto_pos``; on block
        exhaustion evict the youngest live slot and retry. The oldest
        live request is only ever self-evicted (when nothing younger is
        left), and the submit-time feasibility assert guarantees it fits
        an empty pool — so the pool always makes forward progress.
        ``write_from`` bounds the copy-on-write scan (speculative ticks
        write a span, not one position). Returns False iff ``slot``
        itself was preempted. Victims come from the grower's own shard —
        block pools are shard-local, so evicting elsewhere frees
        nothing it can use (every slot is shard 0 on unsharded pools)."""
        shard = self.slots.shard_of_slot(slot)
        while not self.slots.ensure(slot, upto_pos, write_from=write_from):
            victim = max((s for s in self._by_slot
                          if self.slots.shard_of_slot(s) == shard),
                         key=lambda s: self._by_slot[s].admit_seq)
            self._preempt(victim)
            if victim == slot:
                return False
        return True

    def _prefill_chunks(self):
        """Consume every pending full chunk (first L-1 prompt tokens only;
        the final token always rides the decode step so decode is the one
        sampler). Bucketed pow2 gather keeps compiles O(log pool)."""
        ch = self.sched.prefill_chunk
        while True:
            need = [s for s, st in sorted(self._by_slot.items())
                    if len(st.prompt) - 1 - st.ctx >= ch]
            if not need:
                return
            if self.slots.paged:
                # prompts are fully mapped at admission (alloc_reset
                # covers positions [0, prompt_len)), so a chunk write can
                # never need a new block — block growth, and with it
                # preempt-on-OOB, happens only on the decode path. NOTE:
                # ensure() is side-effecting, so it must be CALLED
                # outside the assert (python -O strips assert statements
                # — the mapping itself must not depend on them).
                for s in need:
                    # write_from bounds the copy-on-write scan to the
                    # chunk's actual write span [ctx, ctx+ch-1] — which
                    # by construction starts at/after the slot's shared
                    # prefix, so admission-path writes never trigger CoW
                    ok = self.slots.ensure(s, self._by_slot[s].ctx + ch - 1,
                                           write_from=self._by_slot[s].ctx)
                    assert ok, "prefill chunk outgrew the admission mapping"
            m = len(need)
            if self.slots.sharded:
                # the sharded backing pads PER SHARD (pad-by-repeat of
                # each shard's first entry, common pow2 width) so every
                # shard sees the same chunk program; pass the live set
                # unpadded and take rows back in input order
                idx = list(need)
            else:
                bsz = bucketing.round_up_pow2(m, 1)
                idx = need + [need[0]] * (bsz - m)  # pad-by-repeat
            toks = np.stack([
                self._by_slot[s].prompt[self._by_slot[s].ctx:
                                        self._by_slot[s].ctx + ch]
                for s in idx])
            pos = np.asarray([self._by_slot[s].ctx for s in idx], np.int32)
            # pad rows duplicate row 0 bit-for-bit -> scatter deterministic
            with self.tracer.span("prefill-chunk", "scheduler",
                                  slots=m, chunk=ch):
                logits = self.slots.run_chunk(self.params, idx, toks, pos)
            score_rows = [j for j, s in enumerate(need)
                          if self._by_slot[s].mode == "score"]
            if score_rows:
                # chunk logits ARE the prompt scores: logits[j, i]
                # predicts position ctx+i+1, all of which are prompt
                # positions <= L-1 here (the chunk condition guarantees
                # ctx+ch <= L-1)
                lp = _log_softmax_np(
                    np.asarray(logits[np.asarray(score_rows)], np.float32))
                for row, j in enumerate(score_rows):
                    st = self._by_slot[need[j]]
                    fed = st.prompt[st.ctx + 1:st.ctx + ch + 1]
                    st.logprobs.extend(
                        float(lp[row, i, t]) for i, t in enumerate(fed))
            for s in need:
                self._by_slot[s].ctx += ch
                self._by_slot[s].chunk_tokens += ch
            self.counters["chunk_steps"] += 1
            self.counters["prefill_tokens"] += m * ch
            # a score row whose last needed position (L-2) was just
            # consumed is complete without ever decoding
            for s in need:
                st = self._by_slot.get(s)
                if st is not None and st.mode == "score" \
                        and st.ctx >= len(st.prompt) - 1:
                    self._retire(s, "score")

    def _max_commit(self, st: _Slot) -> int:
        """Last cache position a speculative tick may commit for ``st``:
        generate rows never feed past the position producing their final
        token (L + max_new - 2); score rows never feed past the position
        producing the last prompt logprob (L - 2)."""
        ln = len(st.prompt)
        return ln - 2 if st.mode == "score" else ln + st.max_new_tokens - 2

    def _first_token(self, slot: int, st: _Slot):
        """First-generated-token bookkeeping: TTFT stamp, phase flip,
        prefix publication (shared by the plain and speculative ticks)."""
        tl = self._tl[st.rid]
        if tl.first_token_t is None:
            tl.first_token_t = time.perf_counter()
            self._lat["ttft_ms"].observe(
                (tl.first_token_t - tl.submit_t) * 1e3)
        # the prefill phase ends at the first sampled token
        self._phase_end(slot)
        self._phase_begin(slot, "decode", st.rid)
        # publish the prompt's chunk-consumed prefix blocks to
        # the prefix index now that their KV is fully written
        # (no-op unless prefix_sharing; idempotent per prompt)
        self.slots.register_prefix(
            slot, st.prompt, len(st.prompt) + st.max_new_tokens,
            st.chunk_tokens)

    def _decode_once(self):
        """One fused decode over the FULL pool: per-slot tokens, positions
        and sampling policies; free slots run on masked junk (never
        read). With ``speculate=k`` the tick is a verify-accept chunk
        instead (``_decode_speculative``)."""
        if not self._by_slot:
            return
        if self.sched.speculate:
            self._decode_speculative(self.sched.speculate)
            return
        if self.slots.paged:
            # every live slot writes its cache at position ctx this tick:
            # map the covering blocks, preempting youngest-first on OOB
            for s in sorted(self._by_slot):
                if s in self._by_slot:
                    self._ensure_or_preempt(s, self._by_slot[s].ctx)
            if not self._by_slot:
                return
        b = self.slots.num_slots
        toks = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        temps = np.zeros((b,), np.float32)
        top_ks = np.zeros((b,), np.int32)
        top_ps = np.ones((b,), np.float32)
        for s, st in self._by_slot.items():
            toks[s, 0] = (st.prompt[st.ctx] if st.ctx < len(st.prompt)
                          else st.out[-1])
            pos[s] = st.ctx
            temps[s] = st.policy.temperature
            top_ks[s] = st.policy.top_k
            top_ps[s] = st.policy.top_p
        self._key, ks = jax.random.split(self._key)
        with self.tracer.span("decode-tick", "scheduler",
                              live=len(self._by_slot)):
            nxt, logits = self.slots.run_decode(
                self.params, jnp.asarray(toks), jnp.asarray(pos),
                jnp.asarray(temps), ks, jnp.asarray(top_ks),
                jnp.asarray(top_ps))
            nxt = np.asarray(nxt)
        self.counters["decode_steps"] += 1
        # admitted-concurrency numerator: mean live slots per decode tick
        # = live_decode_slots / decode_steps (fig_serve's occupancy gate)
        self.counters["live_decode_slots"] += len(self._by_slot)
        score_live = [s for s, st in self._by_slot.items()
                      if st.mode == "score"]
        lp = None
        if score_live:
            # the fed token at ctx predicts position ctx+1 — a prompt
            # position (score rows retire before ctx reaches L-1)
            lp = _log_softmax_np(np.asarray(logits[:, 0], np.float32))

        for s in sorted(self._by_slot):
            st = self._by_slot[s]
            if st.mode == "score":
                st.logprobs.append(float(lp[s, st.prompt[st.ctx + 1]]))
                st.ctx += 1
                if st.ctx >= len(st.prompt) - 1:
                    self._retire(s, "score")
                continue
            st.ctx += 1
            if st.ctx < len(st.prompt):
                continue                            # still teacher-forcing
            tok = int(nxt[s])
            st.out.append(tok)
            self.counters["generated_tokens"] += 1
            if len(st.out) == 1:
                self._first_token(s, st)
            eos = (self.sched.eos_token is not None
                   and tok == self.sched.eos_token)
            if eos or len(st.out) >= st.max_new_tokens:
                self._retire(s, "eos" if eos else "length")

    # -- speculative decoding --------------------------------------------

    @staticmethod
    def _lookup_draft(seq: np.ndarray, need: int) -> List[int]:
        """Prompt-lookup self-draft: find the most recent earlier
        occurrence of the sequence's trailing 2-gram and copy the tokens
        that followed it; repeat the last token when nothing matches.
        Draft quality only affects speed — never correctness (the verify
        step rejects disagreeing drafts)."""
        n = len(seq)
        drafts: List[int] = []
        if n >= 3:
            a, b = int(seq[-2]), int(seq[-1])
            for i in range(n - 3, -1, -1):
                if int(seq[i]) == a and int(seq[i + 1]) == b:
                    j = i + 2
                    while len(drafts) < need and j < n:
                        drafts.append(int(seq[j]))
                        j += 1
                    break
        last = int(seq[-1]) if n else 0
        while len(drafts) < need:
            drafts.append(last)
        return drafts

    def _draft_tokens(self, st: _Slot, k: int) -> List[int]:
        """k draft tokens for positions ctx+1..ctx+k: true prompt tokens
        through the teacher-forced ramp (they MUST be — the accepted span
        is written to the cache), prompt-lookup self-draft past it."""
        ln = len(st.prompt)
        out: List[int] = []
        p = st.ctx + 1
        while len(out) < k and p < ln:
            out.append(int(st.prompt[p]))
            p += 1
        if len(out) < k:
            seq = (st.prompt if not st.out
                   else np.concatenate([st.prompt,
                                        np.asarray(st.out, np.int32)]))
            need = k - len(out)
            if self._draft_fn is not None:
                got = [int(t) for t in self._draft_fn(seq, need)][:need]
                out.extend(got)
                need -= len(got)
                if need:                    # short draft: pad via lookup
                    out.extend(self._lookup_draft(seq, need))
            else:
                out.extend(self._lookup_draft(seq, need))
        return out

    def _decode_speculative(self, k: int):
        """One fused verify-accept tick over the FULL pool: feed k+1
        tokens per slot (true next token + k drafts) through the chunk
        path, accept each row's agreeing draft prefix, emit up to k+1
        tokens. Rejected cache writes were rolled back in-program, so
        host state only ever advances by exactly what was committed —
        greedy streams are bit-identical to speculate=0."""
        if self.slots.paged:
            for s in sorted(self._by_slot):
                if s in self._by_slot:
                    st = self._by_slot[s]
                    # the verify span writes [ctx, ctx+k]; only positions
                    # that may COMMIT need mapped blocks (rolled-back
                    # writes beyond the mapping land in the trash block,
                    # which is never attended)
                    upto = max(min(st.ctx + k, self._max_commit(st)),
                               st.ctx)
                    self._ensure_or_preempt(s, upto, write_from=st.ctx)
            if not self._by_slot:
                return
        b = self.slots.num_slots
        toks = np.zeros((b, k + 1), np.int32)
        pos = np.zeros((b,), np.int32)
        plen = np.ones((b,), np.int32)
        maxp = np.zeros((b,), np.int32)
        score_f = np.zeros((b,), bool)
        active = np.zeros((b,), bool)
        temps = np.zeros((b,), np.float32)
        top_ks = np.zeros((b,), np.int32)
        top_ps = np.ones((b,), np.float32)
        for s, st in self._by_slot.items():
            first = (st.prompt[st.ctx] if st.ctx < len(st.prompt)
                     else st.out[-1])
            toks[s] = [int(first)] + self._draft_tokens(st, k)
            pos[s] = st.ctx
            plen[s] = len(st.prompt)
            maxp[s] = self._max_commit(st)
            score_f[s] = st.mode == "score"
            active[s] = True
            temps[s] = st.policy.temperature
            top_ks[s] = st.policy.top_k
            top_ps[s] = st.policy.top_p
        self._key, ks = jax.random.split(self._key)
        with self.tracer.span("decode-tick", "scheduler",
                              live=len(self._by_slot), speculate=k):
            out_tok, acc_n, lp = self.slots.run_verify(
                self.params, jnp.asarray(toks), jnp.asarray(pos),
                jnp.asarray(plen), jnp.asarray(maxp), jnp.asarray(score_f),
                jnp.asarray(active), jnp.asarray(temps),
                jnp.asarray(top_ks), jnp.asarray(top_ps), ks)
            out_tok = np.asarray(out_tok)
            acc_n = np.asarray(acc_n)
            lp = np.asarray(lp, np.float32)
        self.counters["decode_steps"] += 1
        self.counters["live_decode_slots"] += len(self._by_slot)

        tick_accepts: List[int] = []
        for s in sorted(self._by_slot):
            st = self._by_slot[s]
            n = int(acc_n[s])
            adv = n + 1
            base = st.ctx
            ln = len(st.prompt)
            if st.mode == "score":
                # lp[i] scores the token fed at chunk slot i+1 (position
                # base+i+1) — a prompt token for every i <= n (the accept
                # rule clamps score rows to n <= k-1)
                st.logprobs.extend(float(lp[s, i]) for i in range(adv))
                st.ctx = base + adv
                if st.ctx >= ln - 1:
                    self._retire(s, "score")
                continue
            if st.policy.greedy:
                # spec accounting counts REAL drafts only: ramp positions
                # are teacher-forced prompt tokens, not speculation
                forced = max(0, min(ln - (base + 1), k))
                real_drafted = k - forced
                real_accepted = max(n - forced, 0)
                rejected = real_drafted - real_accepted
                st.drafted += real_drafted
                st.accepted += real_accepted
                self.counters["spec.drafted_tokens"] += real_drafted
                self.counters["spec.accepted_tokens"] += real_accepted
                self.counters["spec.rejected_tokens"] += rejected
                if rejected > 0:
                    self.counters["spec.rollbacks"] += 1
                if real_drafted > 0:
                    self._lat["spec.accept_len"].observe(
                        float(real_accepted))
                    tick_accepts.append(real_accepted)
            retired = False
            for i in range(adv):
                if base + i + 1 < ln:
                    continue                        # still teacher-forcing
                tok = int(out_tok[s, i])
                st.out.append(tok)
                self.counters["generated_tokens"] += 1
                if len(st.out) == 1:
                    self._first_token(s, st)
                eos = (self.sched.eos_token is not None
                       and tok == self.sched.eos_token)
                if eos or len(st.out) >= st.max_new_tokens:
                    # tokens past an EOS were committed to the cache but
                    # the slot retires here — release discards them, so
                    # the stream matches the oracle exactly
                    st.ctx = base + adv
                    self._retire(s, "eos" if eos else "length")
                    retired = True
                    break
            if not retired:
                st.ctx = base + adv
        if tick_accepts and self.tracer.enabled:
            # Perfetto counter track: per-tick accepted draft length
            self.tracer.counter("spec.accept_len", "scheduler",
                                mean=float(np.mean(tick_accepts)),
                                max=float(np.max(tick_accepts)))

    def _retire(self, slot: int, reason: str):
        st = self._by_slot.pop(slot)
        self._phase_end(slot)
        self.tracer.instant("retire", f"slot{slot}", rid=st.rid,
                            reason=reason)
        self.slots.release(slot)
        toks = np.asarray(st.out, np.int32)
        lps = (np.asarray(st.logprobs, np.float32)
               if st.mode == "score" else None)
        if self.sched.cache_requests and st.policy.greedy:
            key = RequestCache.key(st.prompt, st.max_new_tokens,
                                   self.sched.eos_token, mode=st.mode,
                                   policy=st.policy.fingerprint())
            self.request_cache.put(key, toks, reason, lps)
            for rid in self._inflight.pop(key, ()):     # coalesced waiters
                self._finish(rid, len(st.prompt), toks.copy(), "cached",
                             logprobs=None if lps is None else lps.copy())
        self._finish(st.rid, len(st.prompt), toks, reason, logprobs=lps,
                     accepted=st.accepted, drafted=st.drafted)

    def _finish(self, rid: int, prompt_len: int, tokens: np.ndarray,
                reason: str, logprobs: Optional[np.ndarray] = None,
                accepted: int = 0, drafted: int = 0):
        self.counters["completed"] += 1
        self._fresh.append(rid)
        tl = self._tl.pop(rid)
        comp = Completion(
            rid=rid, tokens=tokens, reason=reason, prompt_len=prompt_len,
            submit_t=tl.submit_t, finish_t=time.perf_counter(),
            admit_t=tl.admit_t, first_token_t=tl.first_token_t,
            swapped_s=tl.swapped_s, recomputed_steps=tl.recomputed_steps,
            preemptions=tl.preemptions, logprobs=logprobs,
            accepted=accepted, drafted=drafted)
        self.results[rid] = comp
        # ITL is only meaningful for pool-served requests (cache hits
        # have no decode phase)
        if tl.admit_t is not None and tl.first_token_t is not None:
            self._lat["itl_ms"].observe(comp.itl * 1e3)
