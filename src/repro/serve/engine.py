"""Serving steps: prefill and single-token decode over static-shape caches.

Decode is the dependency-bound 1-D recurrence of serving — each step
consumes the previous step's cache/state (the paper's global-counter
pattern at request scale). Attention layers carry KV ring buffers; RWKV/
Mamba layers carry O(1) recurrent state, making decode cost flat in
context length (the long_500k story).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.obs import trace as obs_trace
from repro.sharding import named_sharding


@dataclasses.dataclass(frozen=True)
class SamplingPolicy:
    """Per-request sampling knobs threaded through the fused decode steps.

    ``temperature <= 0`` is greedy (exact argmax of the raw logits —
    the differential-harness contract). ``top_k = 0`` disables top-k;
    ``top_p = 1.0`` disables nucleus filtering. Both filters are exact
    identities when disabled, so default-policy streams are bitwise
    unchanged.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0: {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 disables): {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1]: {self.top_p}")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0

    def fingerprint(self):
        """Hashable identity for memo keys (RequestCache, coalescing)."""
        return (float(self.temperature), int(self.top_k), float(self.top_p))


def _filter_topk_topp(lg: jnp.ndarray, top_ks: jnp.ndarray,
                      top_ps: jnp.ndarray) -> jnp.ndarray:
    """Mask logits (B, V) outside the per-row top-k / nucleus sets to -inf.

    top_ks (B,) int32 (0 = disabled) and top_ps (B,) fp32 (1.0 =
    disabled) are value thresholds against the descending sort: ties at
    the cut survive together, and a disabled filter keeps every entry,
    making the whole function a bitwise identity for the defaults.
    """
    v = lg.shape[-1]
    srt = jnp.sort(lg, axis=-1)[:, ::-1]                  # descending
    k = jnp.clip(jnp.where(top_ks <= 0, v, top_ks), 1, v)
    kth = jnp.take_along_axis(srt, (k - 1)[:, None], axis=-1)
    keep_k = lg >= kth
    # exclusive cumsum of sorted probs: entry i kept iff the mass strictly
    # before it is < top_p — always keeps the argmax, disabled at p = 1.
    probs = jax.nn.softmax(srt, axis=-1)
    cum = jnp.cumsum(probs, axis=-1) - probs
    nk = jnp.maximum(jnp.sum((cum < top_ps[:, None]).astype(jnp.int32),
                             axis=-1), 1)
    nth = jnp.take_along_axis(srt, (nk - 1)[:, None], axis=-1)
    keep_p = lg >= nth
    return jnp.where(keep_k & keep_p, lg, -jnp.inf)


def sample_token(logits: jnp.ndarray, key=None, temperature=0.0,
                 top_k=0, top_p=1.0) -> jnp.ndarray:
    """logits: (B, 1, V) -> (B,) int32. temperature 0 = greedy.

    ``temperature`` may be a python float (shared) or a (B,) array —
    per-slot temperatures for continuous batching — and ``top_k`` /
    ``top_p`` likewise (python scalars or (B,) vectors). The array path
    uses the Gumbel-max identity (categorical(l/T) == argmax(l/T + g))
    with a per-row where() so greedy rows stay exactly argmax of the RAW
    logits regardless of the filters.
    """
    lg = logits[:, -1].astype(jnp.float32)
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    scalars = (isinstance(temperature, (int, float))
               and isinstance(top_k, int)
               and isinstance(top_p, (int, float)))
    if scalars:
        if temperature <= 0.0 or key is None:
            return greedy
        if top_k > 0 or top_p < 1.0:                # skip the sort when off
            b = lg.shape[0]
            lg = _filter_topk_topp(
                lg, jnp.full((b,), top_k, jnp.int32),
                jnp.full((b,), top_p, jnp.float32))
        return jax.random.categorical(key, lg / temperature).astype(jnp.int32)
    b = lg.shape[0]
    temps = jnp.broadcast_to(jnp.asarray(temperature, jnp.float32), (b,))
    ks = jnp.broadcast_to(jnp.asarray(top_k, jnp.int32), (b,))
    ps = jnp.broadcast_to(jnp.asarray(top_p, jnp.float32), (b,))
    filt = _filter_topk_topp(lg, ks, ps)
    g = jax.random.gumbel(key, lg.shape, jnp.float32)
    scaled = filt / jnp.maximum(temps, 1e-6)[:, None] + g
    sampled = jnp.argmax(scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0.0, sampled, greedy)


def make_prefill_step(cfg: ModelConfig, cache_slots: int):
    """prefill(params, tokens|embeds) -> (last_logits, caches)."""

    def prefill(params, batch: Dict[str, jnp.ndarray]):
        logits, _, caches = T.apply_model(
            params, cfg, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"), mode="prefill",
            cache_slots=cache_slots)
        return logits, caches

    return prefill


def make_decode_step(cfg: ModelConfig, temperature: float = 0.0):
    """decode(params, caches, inp, pos[, key]) -> (next_tok, logits, caches).

    inp: {"tokens": (B,1)} or {"embeds": (B,1,D)}; pos: int32 scalar —
    the absolute position of the incoming token.
    """

    def decode(params, caches, inp: Dict[str, jnp.ndarray],
               pos: jnp.ndarray, key: Optional[jnp.ndarray] = None):
        logits, _, caches = T.apply_model(
            params, cfg, tokens=inp.get("tokens"),
            embeds=inp.get("embeds"), mode="decode", caches=caches,
            pos_scalar=pos)
        nxt = sample_token(logits, key, temperature)
        return nxt, logits, caches

    return decode


# ---------------------------------------------------------------------------
# continuous-batching steps: per-slot position vectors (serve.scheduler)
# ---------------------------------------------------------------------------

def make_slot_decode_step(cfg: ModelConfig):
    """decode(params, caches, tokens, pos, temps, key[, top_ks, top_ps])
    -> (next_tok, logits, caches) with PER-SLOT clocks.

    tokens: (B, 1) int32; pos: (B,) int32 — each row's absolute position;
    temps: (B,) fp32 per-slot temperature (0 = greedy); top_ks (B,) int32
    / top_ps (B,) fp32 optional per-slot filters (None = disabled).
    Caches must use the per-row position layout
    (init_caches(per_slot_pos=True)).
    """

    def decode(params, caches, tokens: jnp.ndarray, pos: jnp.ndarray,
               temps: jnp.ndarray, key: jnp.ndarray,
               top_ks: Optional[jnp.ndarray] = None,
               top_ps: Optional[jnp.ndarray] = None):
        logits, _, caches = T.apply_model(
            params, cfg, tokens=tokens, mode="decode", caches=caches,
            pos_scalar=pos)
        nxt = sample_token(logits, key, temps,
                           0 if top_ks is None else top_ks,
                           1.0 if top_ps is None else top_ps)
        return nxt, logits, caches

    return decode


def make_chunk_step(cfg: ModelConfig):
    """chunk(params, caches, tokens, pos) -> (logits (B, C, V), caches).

    Chunked prefill AND the teacher-forced verify path: tokens (B, C)
    are C consecutive tokens per row, starting at absolute position
    pos[b]. Attention appends the chunk to the cache and masks by
    absolute position (causal within the chunk for free); SSM layers run
    the state-carried chunk-parallel scan. Every row must carry a FULL
    chunk — exactness comes from never padding inside a chunk (remainder
    tokens go through the decode ramp). Logits cover EVERY chunk
    position (bitwise identical to stepping the same tokens one at a
    time through the decode step) — prompt scoring and speculative
    verification consume the non-final positions.
    """

    def chunk(params, caches, tokens: jnp.ndarray, pos: jnp.ndarray):
        logits, _, caches = T.apply_model(
            params, cfg, tokens=tokens, mode="decode", caches=caches,
            pos_scalar=pos)
        return logits, caches

    return chunk


# ---------------------------------------------------------------------------
# speculative verify-accept: teacher-force k drafts through the chunk
# path, accept the agreeing prefix, roll the cache back in-program
# ---------------------------------------------------------------------------

def _ring_gather(leaf: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Gather ring rows idx (B, S) from a cache leaf (P, B, slots, ...)
    along the slot axis (index 2), modulo that leaf's view length."""
    take = jax.vmap(jax.vmap(lambda l, i: l[i], in_axes=(0, 0)),
                    in_axes=(0, None))
    return take(leaf, idx % leaf.shape[2])


def _ring_scatter(leaf: jnp.ndarray, idx: jnp.ndarray,
                  rows: jnp.ndarray) -> jnp.ndarray:
    """Inverse of _ring_gather: write rows (P, B, S, ...) back at ring
    indices idx (B, S). Indices within a row are distinct (the verify
    span never exceeds the smallest view length), so the scatter is
    deterministic."""
    put = jax.vmap(jax.vmap(lambda l, i, r: l.at[i].set(r),
                            in_axes=(0, 0, 0)),
                   in_axes=(0, None, 0))
    return put(leaf, idx % leaf.shape[2], rows)


def _snapshot_span(caches, idx):
    """Pre-step snapshot: the ring rows every attention leaf will
    (re)write for absolute positions idx (B, S)."""
    from repro.models.attention import KVCache  # local: avoid import cycle

    return {key: KVCache(k=_ring_gather(e["attn"].k, idx),
                         v=_ring_gather(e["attn"].v, idx),
                         pos=_ring_gather(e["attn"].pos, idx))
            for key, e in caches.items()}


def _restore_span(caches, idx, saved, limit):
    """Post-step rollback: keep chunk writes at absolute positions
    <= limit[b] (the last accepted position), restore the snapshot
    everywhere else — inactive rows pass limit = -1 and get a full undo,
    so the cache only ever holds committed-correct entries."""
    from repro.models.attention import KVCache

    keep = idx <= limit[:, None]                    # (B, S)

    def mix(new, old):
        k2 = keep.reshape((1,) + keep.shape + (1,) * (new.ndim - 3))
        return jnp.where(k2, new, old)

    out = {}
    for key, e in caches.items():
        kv, sv = e["attn"], saved[key]
        e = dict(e)
        e["attn"] = KVCache(
            k=_ring_scatter(kv.k, idx, mix(_ring_gather(kv.k, idx), sv.k)),
            v=_ring_scatter(kv.v, idx, mix(_ring_gather(kv.v, idx), sv.v)),
            pos=_ring_scatter(kv.pos, idx,
                              mix(_ring_gather(kv.pos, idx), sv.pos)))
        out[key] = e
    return out


def make_verify_step(cfg: ModelConfig):
    """verify(params, caches, tokens, pos, prompt_len, max_pos, score,
    active, temps, top_ks, top_ps, key) ->
    (out_tok (B, S), accept_n (B,), logprobs (B, S), caches).

    One fused speculative tick over the whole pool. tokens (B, S) carry
    [t, d_1..d_k] per row (S = k+1): the true next token t at absolute
    position pos[b] followed by k drafts. The chunk path teacher-forces
    all S positions, then the accept rule takes the longest prefix of
    drafts agreeing with the model's own greedy predictions — under
    greedy sampling this makes the emitted stream bit-identical to
    one-token-at-a-time decode. Rows with temps > 0 accept nothing and
    sample their first token under the full per-slot policy (exactly the
    non-speculative semantics). ``forced`` teacher-forcing positions
    (draft position < prompt_len, i.e. the decode ramp) auto-accept;
    accepts clamp to max_pos[b] (last position allowed to commit) and,
    for score rows, to k-1 so every prompt position's logprob is
    surfaced exactly once. Rejected (and inactive-row) cache writes are
    rolled back in-program via a span snapshot, so the pool cache never
    holds uncommitted state.

    Requires an attention-only pattern (SSM chunk scans are
    irreversible) and S <= the smallest attention view length (distinct
    ring indices for the rollback scatter) — callers gate both.
    """
    for spec in cfg.pattern:
        if spec.mixer != "attn" or spec.mlp == "rwkv_ffn":
            raise ValueError(
                "speculative verify needs an attention-only pattern with "
                f"stateless MLPs; got mixer={spec.mixer!r} mlp={spec.mlp!r} "
                "(SSM/rwkv_ffn chunk scans cannot be rolled back)")

    def verify(params, caches, tokens: jnp.ndarray, pos: jnp.ndarray,
               prompt_len: jnp.ndarray, max_pos: jnp.ndarray,
               score: jnp.ndarray, active: jnp.ndarray,
               temps: jnp.ndarray, top_ks: jnp.ndarray,
               top_ps: jnp.ndarray, key: jnp.ndarray):
        s = tokens.shape[1]
        k = s - 1
        idx = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
        saved = _snapshot_span(caches, idx)
        logits, _, caches = T.apply_model(
            params, cfg, tokens=tokens, mode="decode", caches=caches,
            pos_scalar=pos)
        lg = logits.astype(jnp.float32)             # (B, S, V)
        greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        drafts = tokens[:, 1:]                      # (B, k)
        # a draft at chunk slot i+1 occupies absolute position pos+i+1;
        # ramp positions (< prompt_len) are teacher-forced true tokens
        # and auto-accept — greedy agreement only gates real samples.
        forced = (idx[:, :k] + 1) < prompt_len[:, None]
        match = (greedy[:, :k] == drafts) | forced
        n = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=-1), axis=-1)
        n = jnp.where(temps > 0.0, 0, n)
        n = jnp.where(score, jnp.minimum(n, k - 1), n)
        n = jnp.minimum(n, jnp.maximum(max_pos - pos, 0))
        n = jnp.where(active, n, 0)
        limit = jnp.where(active, pos + n, -1)
        caches = _restore_span(caches, idx, saved, limit)
        # out_tok[:, i] = the model's prediction after consuming chunk
        # slot i; sampled rows replace slot 0 with a policy sample (their
        # only emission this tick — accept_n is 0 for them).
        first = sample_token(lg[:, :1], key, temps, top_ks, top_ps)
        out_tok = greedy.at[:, 0].set(first)
        # logprobs[:, i] = log p(token fed at slot i+1 | prefix); the
        # final slot scores the model's own bonus prediction.
        fed = jnp.concatenate([drafts, out_tok[:, -1:]], axis=-1)
        lp = jnp.take_along_axis(jax.nn.log_softmax(lg, axis=-1),
                                 fed[..., None], axis=-1)[..., 0]
        return out_tok, n.astype(jnp.int32), lp, caches

    return verify


@functools.lru_cache(maxsize=None)
def jit_verify_step(cfg: ModelConfig):
    return obs_trace.instrumented_jit(
        jax.jit(make_verify_step(cfg), donate_argnums=(1,)),
        name=f"verify_step[{cfg.name}]", prefix="serve.engine")


# ModelConfig is a frozen dataclass, so jitted step programs are shared
# process-wide per config (one compile per (cfg, shape) — a new Scheduler
# or generate() call never retraces; same discipline as runtime.dispatch).
# The caches argument is donated: the pool is the scarce resource, and
# without donation every step materializes a second full copy of it.
# Callers must drop their reference (`_, caches = step(params, caches, …)`).

@functools.lru_cache(maxsize=None)
def jit_chunk_step(cfg: ModelConfig):
    return obs_trace.instrumented_jit(
        jax.jit(make_chunk_step(cfg), donate_argnums=(1,)),
        name=f"chunk_step[{cfg.name}]", prefix="serve.engine")


@functools.lru_cache(maxsize=None)
def jit_slot_decode_step(cfg: ModelConfig):
    return obs_trace.instrumented_jit(
        jax.jit(make_slot_decode_step(cfg), donate_argnums=(1,)),
        name=f"slot_decode_step[{cfg.name}]", prefix="serve.engine")


# ---------------------------------------------------------------------------
# paged steps: caches split into dense per-slot leaves + a physical block
# pool read through a page table (serve.paging / serve.slots paged backing)
# ---------------------------------------------------------------------------

def _merge_paged(dense, paged, rows, block_size):
    """Rebuild the full cache tree the model steps expect: dense entries
    pass through; paged attention layers (dense holds None) get a per-slot
    view gathered through the page-table ``rows[key]``. View lengths vary
    per key — cache_slots for global-attention layers, the ring length
    for sliding-window layers — and each key's trash floor is recovered
    from its flat pool's shape (the trash block is the last
    ``block_size`` rows)."""
    from repro.models import attention  # local: avoid import cycle

    caches = {}
    for key, entry in dense.items():
        if key in paged:
            entry = dict(entry)
            entry["attn"] = attention.paged_view(
                paged[key], rows[key],
                attention.paged_live_rows(paged[key], block_size))
        caches[key] = entry
    return caches


def _split_paged(caches, paged, rows):
    """Inverse of _merge_paged: scatter updated views back into the pool
    and strip them from the dense tree (None placeholders restored)."""
    from repro.models import attention

    dense, paged_new = {}, {}
    for key, entry in caches.items():
        if key in paged:
            entry = dict(entry)
            view = entry["attn"]
            entry["attn"] = None
            paged_new[key] = attention.paged_writeback(paged[key], view,
                                                       rows[key])
        dense[key] = entry
    return dense, paged_new


@functools.lru_cache(maxsize=None)
def jit_paged_decode_step(cfg: ModelConfig):
    """Fused page-gather -> decode -> page-scatter over the whole pool.

    dense: cache tree with None at paged attention entries (per-slot SSM
    state, any unpaged leaves); paged: dict pattern-key -> flat KVCache
    block pool; rows: dict pattern-key -> (B, V_key) flat physical row
    per view position (keys in one page-table group share the array);
    block_size (static): every group's block size — each key's trash
    floor is its flat pool's rows minus one block. One jitted program per
    cfg — same one-fused-program-per-tick property as the contiguous
    path, the page tables are just extra gather indices.
    """
    step = make_slot_decode_step(cfg)

    def run(params, dense, paged, rows, tokens, pos, temps, key,
            top_ks, top_ps, block_size: int):
        caches = _merge_paged(dense, paged, rows, block_size)
        nxt, logits, caches = step(params, caches, tokens, pos, temps, key,
                                   top_ks, top_ps)
        dense, paged = _split_paged(caches, paged, rows)
        return nxt, logits, dense, paged

    return obs_trace.instrumented_jit(
        jax.jit(run, donate_argnums=(1, 2), static_argnums=(10,)),
        name=f"paged_decode_step[{cfg.name}]", prefix="serve.engine")


@functools.lru_cache(maxsize=None)
def jit_paged_chunk_step(cfg: ModelConfig):
    """Fused gather -> chunk-prefill -> scatter for the paged layout,
    returning (logits (m, C, V), dense, paged).

    ``idx`` selects the sub-batch of slots (pad-by-repeat contract as the
    contiguous pooled chunk step); ``rows`` values are already
    per-sub-row (len(idx), V_key). Dense leaves gather/scatter on the
    slot axis, paged leaves through their page tables. Logits cover every
    chunk position of every sub-row (prompt scoring reads them; plain
    prefill ignores them).
    """
    step = make_chunk_step(cfg)

    def run(params, dense, paged, idx, rows, tokens, pos, block_size: int):
        sub = jax.tree_util.tree_map(
            lambda l: jnp.take(l, idx, axis=1), dense)
        caches = _merge_paged(sub, paged, rows, block_size)
        logits, caches = step(params, caches, tokens, pos)
        sub, paged = _split_paged(caches, paged, rows)
        dense = jax.tree_util.tree_map(
            lambda l, s: l.at[:, idx].set(s.astype(l.dtype)), dense, sub)
        return logits, dense, paged

    return obs_trace.instrumented_jit(
        jax.jit(run, donate_argnums=(1, 2), static_argnums=(7,)),
        name=f"paged_chunk_step[{cfg.name}]", prefix="serve.engine")


@functools.lru_cache(maxsize=None)
def jit_paged_verify_step(cfg: ModelConfig):
    """Fused page-gather -> verify-accept -> rollback -> page-scatter
    over the whole pool (same full-pool ``rows`` contract as
    jit_paged_decode_step). The span snapshot/restore operates on the
    gathered per-slot views, so the writeback only ever lands committed
    rows in the physical block pool.
    """
    step = make_verify_step(cfg)

    def run(params, dense, paged, rows, tokens, pos, prompt_len, max_pos,
            score, active, temps, top_ks, top_ps, key, block_size: int):
        caches = _merge_paged(dense, paged, rows, block_size)
        out_tok, n, lp, caches = step(
            params, caches, tokens, pos, prompt_len, max_pos, score,
            active, temps, top_ks, top_ps, key)
        dense, paged = _split_paged(caches, paged, rows)
        return out_tok, n, lp, dense, paged

    return obs_trace.instrumented_jit(
        jax.jit(run, donate_argnums=(1, 2), static_argnums=(14,)),
        name=f"paged_verify_step[{cfg.name}]", prefix="serve.engine")


# ---------------------------------------------------------------------------
# sharded steps: the slot pool split over a 1-D device mesh
# ---------------------------------------------------------------------------
#
# Every per-slot cache leaf carries the slot axis at position 1
# ((periods, B, ...)), so sharding the pool is sharding that axis:
# stacked arrays hold all shards' segments back-to-back (dense: B =
# num_shards * slots_per_shard; paged flat pools: num_shards segments of
# (num_blocks + 1) * block_size rows, each segment ending in its OWN
# trash block), and the fused step runs once per tick spanning every
# shard. Three compilation strategies behind one factory signature:
#
#   * num_shards == 1, no mesh — delegate to the unsharded jitted step
#     (the SAME compiled program: the mesh=1 differential is structurally
#     bit-identical).
#   * num_shards > 1, no mesh  — jax.vmap over the shard axis (multi-
#     shard semantics on a single-device CI host).
#   * mesh                     — jax.shard_map over the mesh axis: one
#     fused program, one shard per device, block ids never cross shards.
#
# Row vectors passed to these steps are SHARD-LOCAL physical rows (each
# shard indexes only its own flat-pool segment); host-side block ops
# (reset/gather/upload/copy_block_rows) keep using GLOBAL rows into the
# stacked arrays.

def _check_shard_mesh(num_shards: int, mesh, axis):
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if mesh is not None:
        if axis not in mesh.axis_names:
            raise ValueError(f"axis {axis!r} not in mesh axes "
                             f"{mesh.axis_names}")
        if mesh.shape[axis] != num_shards:
            raise ValueError(
                f"mesh axis {axis!r} has {mesh.shape[axis]} device(s) "
                f"but num_shards={num_shards}: the slot-pool shard count "
                "must match the mesh")


def _split_shard_axis(n: int):
    """(tree fns) stacked (P, n*x, ...) <-> per-shard (P, n, x, ...)."""
    def split(l):
        return l.reshape(l.shape[:1] + (n, l.shape[1] // n) + l.shape[2:])

    def fuse(l):
        return l.reshape(l.shape[:1] + (l.shape[1] * l.shape[2],)
                         + l.shape[3:])

    return split, fuse


def _make_sharded_decode_inner(cfg: ModelConfig, block_size: int):
    """Per-shard decode body shared by the vmap and shard_map paths:
    operates on ONE shard's dense/paged segment with shard-local rows.
    ``key`` arrives as (2,) under vmap and (1, 2) under shard_map."""
    step = make_slot_decode_step(cfg)

    def inner(params, dense, paged, rows, tokens, pos, temps, key,
              top_ks, top_ps):
        key = key.reshape(2)
        caches = _merge_paged(dense, paged, rows, block_size)
        nxt, logits, caches = step(params, caches, tokens, pos, temps,
                                   key, top_ks, top_ps)
        dense, paged = _split_paged(caches, paged, rows)
        return nxt, logits, dense, paged

    return inner


@functools.lru_cache(maxsize=None)
def jit_sharded_decode_step(cfg: ModelConfig, num_shards: int,
                            block_size: int, mesh=None,
                            axis: Optional[str] = None):
    """Fused decode over the sharded pool. Signature of the returned fn:
    run(params, dense, paged, rows, tokens, pos, temps, keys, top_ks,
    top_ps) -> (nxt (B,), logits (B, 1, V), dense, paged) with
    B = num_shards * slots_per_shard, ``rows`` shard-local, and ``keys``
    (num_shards, 2) per-shard PRNG keys. The lru key folds num_shards,
    block_size AND the mesh + axis name, so a resized mesh can never
    reuse a stale compiled program."""
    _check_shard_mesh(num_shards, mesh, axis)
    if num_shards == 1 and mesh is None:
        base = jit_paged_decode_step(cfg)

        def run(params, dense, paged, rows, tokens, pos, temps, keys,
                top_ks, top_ps):
            return base(params, dense, paged, rows, tokens, pos, temps,
                        keys.reshape(2), top_ks, top_ps, block_size)

        return run
    inner = _make_sharded_decode_inner(cfg, block_size)
    n = num_shards
    if mesh is None:
        split, fuse = _split_shard_axis(n)
        tm = jax.tree_util.tree_map

        def run(params, dense, paged, rows, tokens, pos, temps, keys,
                top_ks, top_ps):
            nxt, logits, dense, paged = jax.vmap(
                inner, in_axes=(None, 1, 1, 0, 0, 0, 0, 0, 0, 0),
                out_axes=(0, 0, 1, 1))(
                params, tm(split, dense), tm(split, paged),
                tm(lambda r: r.reshape((n, -1) + r.shape[1:]), rows),
                tokens.reshape((n, -1) + tokens.shape[1:]),
                pos.reshape(n, -1), temps.reshape(n, -1), keys,
                top_ks.reshape(n, -1), top_ps.reshape(n, -1))
            return (nxt.reshape(-1),
                    logits.reshape((-1,) + logits.shape[2:]),
                    tm(fuse, dense), tm(fuse, paged))
    else:
        from jax.sharding import PartitionSpec as P
        from repro.runtime.dispatch import _shard_map
        run = _shard_map(
            inner, mesh=mesh,
            in_specs=(P(), P(None, axis), P(None, axis), P(axis), P(axis),
                      P(axis), P(axis), P(axis), P(axis), P(axis)),
            out_specs=(P(axis), P(axis), P(None, axis), P(None, axis)))
    return obs_trace.instrumented_jit(
        jax.jit(run, donate_argnums=(1, 2)),
        name=f"sharded_decode_step[{cfg.name}x{num_shards}]",
        prefix="serve.engine")


def _make_sharded_chunk_inner(cfg: ModelConfig, block_size: int):
    """Per-shard chunk-prefill body. Each shard gets its own padded
    sub-batch (idx (m,) shard-local slots, pad-by-repeat); ``live`` False
    marks a shard with nothing to prefill this call: its rows point at
    the shard's trash block and its dense writes are reverted, so the
    step is a semantic no-op there. Operands arrive with a leading
    size-1 shard axis under shard_map and without it under vmap — the
    reshapes normalize."""
    step = make_chunk_step(cfg)

    def inner(params, dense, paged, idx, rows, tokens, pos, live):
        idx = idx.reshape(idx.shape[-1])
        rows = {k: r.reshape(r.shape[-2:]) for k, r in rows.items()}
        tokens = tokens.reshape(tokens.shape[-2:])
        pos = pos.reshape(pos.shape[-1])
        live = live.reshape(())
        tm = jax.tree_util.tree_map
        sub = tm(lambda l: jnp.take(l, idx, axis=1), dense)
        caches = _merge_paged(sub, paged, rows, block_size)
        logits, caches = step(params, caches, tokens, pos)
        sub2, paged = _split_paged(caches, paged, rows)
        # idle shard: paged writes landed in the trash block (masked on
        # every read); dense writes are reverted here
        sub2 = tm(lambda a, b: jnp.where(live, a, b.astype(a.dtype)),
                  sub2, sub)
        dense = tm(lambda l, s: l.at[:, idx].set(s.astype(l.dtype)),
                   dense, sub2)
        return logits, dense, paged

    return inner


@functools.lru_cache(maxsize=None)
def jit_sharded_chunk_step(cfg: ModelConfig, num_shards: int,
                           block_size: int, mesh=None,
                           axis: Optional[str] = None):
    """Fused chunk-prefill over the sharded pool. run(params, dense,
    paged, idx, rows, tokens, pos, live) -> (logits (n, m, C, V), dense,
    paged): idx (n, m) shard-LOCAL slot ids (pad-by-repeat within a
    shard), rows shard-local (n, m, V_key), tokens (n, m, C), pos
    (n, m), live (n,) bool (False = idle shard: idx/rows carry trash)."""
    _check_shard_mesh(num_shards, mesh, axis)
    if num_shards == 1 and mesh is None:
        base = jit_paged_chunk_step(cfg)

        def run(params, dense, paged, idx, rows, tokens, pos, live):
            logits, dense, paged = base(
                params, dense, paged, idx[0],
                {k: r[0] for k, r in rows.items()}, tokens[0], pos[0],
                block_size)
            return logits[None], dense, paged

        return run
    inner = _make_sharded_chunk_inner(cfg, block_size)
    n = num_shards
    if mesh is None:
        split, fuse = _split_shard_axis(n)
        tm = jax.tree_util.tree_map

        def run(params, dense, paged, idx, rows, tokens, pos, live):
            logits, dense, paged = jax.vmap(
                inner, in_axes=(None, 1, 1, 0, 0, 0, 0, 0),
                out_axes=(0, 1, 1))(
                params, tm(split, dense), tm(split, paged), idx, rows,
                tokens, pos, live)
            return logits, tm(fuse, dense), tm(fuse, paged)
    else:
        from jax.sharding import PartitionSpec as P
        from repro.runtime.dispatch import _shard_map
        smapped = _shard_map(
            inner, mesh=mesh,
            in_specs=(P(), P(None, axis), P(None, axis), P(axis), P(axis),
                      P(axis), P(axis), P(axis)),
            out_specs=(P(axis), P(None, axis), P(None, axis)))

        def run(params, dense, paged, idx, rows, tokens, pos, live):
            logits, dense, paged = smapped(params, dense, paged, idx,
                                           rows, tokens, pos, live)
            # shard_map concatenates per-shard (m, C, V) on axis 0
            return (logits.reshape((n, -1) + logits.shape[1:]),
                    dense, paged)
    return obs_trace.instrumented_jit(
        jax.jit(run, donate_argnums=(1, 2)),
        name=f"sharded_chunk_step[{cfg.name}x{num_shards}]",
        prefix="serve.engine")


def _make_sharded_verify_inner(cfg: ModelConfig, block_size: int):
    """Per-shard speculative verify-accept body (rows/accept semantics
    are per-slot, so sharding is a pure partition of the pool)."""
    step = make_verify_step(cfg)

    def inner(params, dense, paged, rows, tokens, pos, prompt_len,
              max_pos, score, active, temps, top_ks, top_ps, key):
        key = key.reshape(2)
        caches = _merge_paged(dense, paged, rows, block_size)
        out_tok, n, lp, caches = step(
            params, caches, tokens, pos, prompt_len, max_pos, score,
            active, temps, top_ks, top_ps, key)
        dense, paged = _split_paged(caches, paged, rows)
        return out_tok, n, lp, dense, paged

    return inner


@functools.lru_cache(maxsize=None)
def jit_sharded_verify_step(cfg: ModelConfig, num_shards: int,
                            block_size: int, mesh=None,
                            axis: Optional[str] = None):
    """Fused speculative verify over the sharded pool (full-pool row
    contract of jit_paged_verify_step, shard-local rows, per-shard keys
    (num_shards, 2))."""
    _check_shard_mesh(num_shards, mesh, axis)
    if num_shards == 1 and mesh is None:
        base = jit_paged_verify_step(cfg)

        def run(params, dense, paged, rows, tokens, pos, prompt_len,
                max_pos, score, active, temps, top_ks, top_ps, keys):
            return base(params, dense, paged, rows, tokens, pos,
                        prompt_len, max_pos, score, active, temps,
                        top_ks, top_ps, keys.reshape(2), block_size)

        return run
    inner = _make_sharded_verify_inner(cfg, block_size)
    n = num_shards
    if mesh is None:
        split, fuse = _split_shard_axis(n)
        tm = jax.tree_util.tree_map

        def run(params, dense, paged, rows, tokens, pos, prompt_len,
                max_pos, score, active, temps, top_ks, top_ps, keys):
            shard_rows = lambda x: x.reshape((n, -1) + x.shape[1:])  # noqa: E731
            out_tok, acc, lp, dense, paged = jax.vmap(
                inner,
                in_axes=(None, 1, 1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0),
                out_axes=(0, 0, 0, 1, 1))(
                params, tm(split, dense), tm(split, paged),
                tm(shard_rows, rows), shard_rows(tokens),
                pos.reshape(n, -1), prompt_len.reshape(n, -1),
                max_pos.reshape(n, -1), score.reshape(n, -1),
                active.reshape(n, -1), temps.reshape(n, -1),
                top_ks.reshape(n, -1), top_ps.reshape(n, -1), keys)
            return (out_tok.reshape((-1,) + out_tok.shape[2:]),
                    acc.reshape(-1), lp.reshape((-1,) + lp.shape[2:]),
                    tm(fuse, dense), tm(fuse, paged))
    else:
        from jax.sharding import PartitionSpec as P
        from repro.runtime.dispatch import _shard_map
        run = _shard_map(
            inner, mesh=mesh,
            in_specs=(P(), P(None, axis), P(None, axis), P(axis), P(axis),
                      P(axis), P(axis), P(axis), P(axis), P(axis),
                      P(axis), P(axis), P(axis), P(axis)),
            out_specs=(P(axis), P(axis), P(axis), P(None, axis),
                       P(None, axis)))
    return obs_trace.instrumented_jit(
        jax.jit(run, donate_argnums=(1, 2)),
        name=f"sharded_verify_step[{cfg.name}x{num_shards}]",
        prefix="serve.engine")


@functools.partial(jax.jit, donate_argnums=(0,))
def reset_block_rows(paged, rows):
    """Zero the physical rows of freshly-mapped blocks (k=v=0, pos=-1) —
    the paged counterpart of SlotManager.alloc's slot reset. ``rows`` may
    be padded with trash rows (identical writes: deterministic)."""
    from repro.models.attention import KVCache

    return {key: KVCache(k=c.k.at[:, rows].set(0),
                         v=c.v.at[:, rows].set(0),
                         pos=c.pos.at[:, rows].set(-1))
            for key, c in paged.items()}


@jax.jit
def gather_block_rows(paged, rows):
    """Pull the physical ``rows`` of every paged cache leaf — the
    device half of swap-out preemption (the host then ``device_get``s
    the result into a SwapStore). ``rows`` comes from
    PageTable.block_rows over the victim's mapped blocks, pow2-padded
    with trash rows so compiles stay O(log blocks_per_slot)."""
    from repro.models.attention import KVCache

    return {key: KVCache(k=jnp.take(c.k, rows, axis=1),
                         v=jnp.take(c.v, rows, axis=1),
                         pos=jnp.take(c.pos, rows, axis=1))
            for key, c in paged.items()}


@functools.partial(jax.jit, donate_argnums=(0,))
def upload_block_rows(paged, saved, rows):
    """Write saved block bytes into freshly-mapped physical ``rows`` —
    the resume half of swap preemption (inverse of gather_block_rows,
    same PageTable.block_rows layout). Pad rows land in the trash block
    with identical (zero) payloads, so the scatter is deterministic."""
    from repro.models.attention import KVCache

    return {key: KVCache(
        k=c.k.at[:, rows].set(saved[key].k.astype(c.k.dtype)),
        v=c.v.at[:, rows].set(saved[key].v.astype(c.v.dtype)),
        pos=c.pos.at[:, rows].set(saved[key].pos.astype(jnp.int32)))
            for key, c in paged.items()}


@functools.partial(jax.jit, donate_argnums=(0,))
def copy_block_rows(paged, src_rows, dst_rows):
    """Device-side block copy: duplicate the physical ``src_rows`` into
    ``dst_rows`` on every paged cache leaf — the copy half of
    copy-on-write (PageTable.cow_block picks the blocks; this moves the
    bytes without a host round-trip). Row vectors use the same
    PageTable.block_rows layout as gather/upload and may be pow2-padded
    with trash->trash pairs (the trash block copies onto itself:
    harmless, deterministic)."""
    from repro.models.attention import KVCache

    return {key: KVCache(
        k=c.k.at[:, dst_rows].set(jnp.take(c.k, src_rows, axis=1)),
        v=c.v.at[:, dst_rows].set(jnp.take(c.v, src_rows, axis=1)),
        pos=c.pos.at[:, dst_rows].set(jnp.take(c.pos, src_rows, axis=1)))
            for key, c in paged.items()}


def generate(params, cfg: ModelConfig, prompt, max_new_tokens: int,
             *, temperature: float = 0.0, top_k: int = 0,
             top_p: float = 1.0, eos_token: Optional[int] = None,
             prefill_chunk: int = 32, cache_slots: int = 0,
             key: Optional[jnp.ndarray] = None):
    """Per-request generation — the scheduler's single-request oracle.

    Consumes the prompt with the SAME chunked-prefill + decode-ramp
    policy the continuous scheduler uses (full ``prefill_chunk`` chunks
    over the first L-1 tokens, remainder teacher-forced through decode),
    so a Scheduler run is token-identical to mapping this over requests
    under greedy sampling. Returns (tokens: np-able (g,) int32, reason).
    """
    import numpy as np

    prompt = jnp.asarray(prompt, jnp.int32)
    ln = int(prompt.shape[0])
    assert ln >= 1, "empty prompt"
    slots = cache_slots or (ln + max_new_tokens)
    caches = T.init_caches(cfg, batch=1, slots=slots, per_slot_pos=True)
    chunk_fn = jit_chunk_step(cfg)
    decode_fn = jit_slot_decode_step(cfg)
    if key is None:
        key = jax.random.PRNGKey(0)

    ctx = 0
    while ln - 1 - ctx >= prefill_chunk:
        toks = prompt[None, ctx:ctx + prefill_chunk]
        _, caches = chunk_fn(params, caches, toks,
                             jnp.asarray([ctx], jnp.int32))
        ctx += prefill_chunk

    temps = jnp.asarray([temperature], jnp.float32)
    tks = jnp.asarray([top_k], jnp.int32)
    tps = jnp.asarray([top_p], jnp.float32)
    out, reason, last = [], "length", None
    while len(out) < max_new_tokens:
        tok = prompt[ctx] if ctx < ln else last
        key, ks = jax.random.split(key)
        nxt, _, caches = decode_fn(params, caches, tok.reshape(1, 1),
                                   jnp.asarray([ctx], jnp.int32), temps, ks,
                                   tks, tps)
        ctx += 1
        last = nxt[0]
        if ctx >= ln:                       # prompt consumed: real sample
            out.append(int(last))
            if eos_token is not None and out[-1] == eos_token:
                reason = "eos"
                break
    return np.asarray(out, np.int32), reason


# ---------------------------------------------------------------------------
# cache shardings (mirror transformer.init_caches structure)
# ---------------------------------------------------------------------------

def cache_shardings(cfg: ModelConfig, cache_shapes: Any):
    """NamedShardings for a cache pytree (from its eval_shape shapes).

    Mirrors the structure built by transformer.init_caches / emitted by the
    prefill scan: dict p<i> -> per-mixer state, every leaf stacked over
    periods (leading axis replicated).
    """
    from repro.models.attention import KVCache  # local: avoid import cycle

    def ns(leaf, *names):
        return named_sharding(leaf.shape, (None,) + tuple(names))

    out = {}
    for i, spec in enumerate(cfg.pattern):
        c = cache_shapes[f"p{i}"]
        entry = {}
        if spec.mixer == "attn":
            kv = c["attn"]
            entry["attn"] = KVCache(
                k=ns(kv.k, "cache_batch", "cache_seq", "cache_kv_heads",
                     "cache_head_dim"),
                v=ns(kv.v, "cache_batch", "cache_seq", "cache_kv_heads",
                     "cache_head_dim"),
                # shared pos is (periods, S); per-row pos (periods, B, S)
                pos=(ns(kv.pos, "cache_batch", None)
                     if len(kv.pos.shape) == 3 else ns(kv.pos, None)))
        elif spec.mixer == "rwkv":
            st = c["rwkv"]
            entry["rwkv"] = {
                "s": ns(st["s"], "cache_batch", "ssm_heads", None, None),
                "x_prev": ns(st["x_prev"], "cache_batch", None)}
            if "ffn_x" in c:
                entry["ffn_x"] = ns(c["ffn_x"], "cache_batch", None)
        elif spec.mixer == "mamba":
            st = c["mamba"]
            entry["mamba"] = {
                "conv": ns(st["conv"], "cache_batch", None, "ssm_channels"),
                "h": ns(st["h"], "cache_batch", "ssm_channels", "ssm_state")}
        out[f"p{i}"] = entry
    return out
