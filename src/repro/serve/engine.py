"""Serving steps: prefill and single-token decode over static-shape caches.

Decode is the dependency-bound 1-D recurrence of serving — each step
consumes the previous step's cache/state (the paper's global-counter
pattern at request scale). Attention layers carry KV ring buffers; RWKV/
Mamba layers carry O(1) recurrent state, making decode cost flat in
context length (the long_500k story).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.obs import trace as obs_trace
from repro.sharding import named_sharding


def sample_token(logits: jnp.ndarray, key=None,
                 temperature=0.0) -> jnp.ndarray:
    """logits: (B, 1, V) -> (B,) int32. temperature 0 = greedy.

    ``temperature`` may be a python float (shared) or a (B,) array —
    per-slot temperatures for continuous batching. The array path uses
    the Gumbel-max identity (categorical(l/T) == argmax(l/T + g)) with a
    per-row where() so greedy rows stay exactly argmax.
    """
    lg = logits[:, -1].astype(jnp.float32)
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    if isinstance(temperature, (int, float)):
        if temperature <= 0.0 or key is None:
            return greedy
        return jax.random.categorical(key, lg / temperature).astype(jnp.int32)
    temps = jnp.asarray(temperature, jnp.float32)
    g = jax.random.gumbel(key, lg.shape, jnp.float32)
    scaled = lg / jnp.maximum(temps, 1e-6)[:, None] + g
    sampled = jnp.argmax(scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0.0, sampled, greedy)


def make_prefill_step(cfg: ModelConfig, cache_slots: int):
    """prefill(params, tokens|embeds) -> (last_logits, caches)."""

    def prefill(params, batch: Dict[str, jnp.ndarray]):
        logits, _, caches = T.apply_model(
            params, cfg, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"), mode="prefill",
            cache_slots=cache_slots)
        return logits, caches

    return prefill


def make_decode_step(cfg: ModelConfig, temperature: float = 0.0):
    """decode(params, caches, inp, pos[, key]) -> (next_tok, logits, caches).

    inp: {"tokens": (B,1)} or {"embeds": (B,1,D)}; pos: int32 scalar —
    the absolute position of the incoming token.
    """

    def decode(params, caches, inp: Dict[str, jnp.ndarray],
               pos: jnp.ndarray, key: Optional[jnp.ndarray] = None):
        logits, _, caches = T.apply_model(
            params, cfg, tokens=inp.get("tokens"),
            embeds=inp.get("embeds"), mode="decode", caches=caches,
            pos_scalar=pos)
        nxt = sample_token(logits, key, temperature)
        return nxt, logits, caches

    return decode


# ---------------------------------------------------------------------------
# continuous-batching steps: per-slot position vectors (serve.scheduler)
# ---------------------------------------------------------------------------

def make_slot_decode_step(cfg: ModelConfig):
    """decode(params, caches, tokens, pos, temps, key) ->
    (next_tok, logits, caches) with PER-SLOT clocks.

    tokens: (B, 1) int32; pos: (B,) int32 — each row's absolute position;
    temps: (B,) fp32 per-slot temperature (0 = greedy). Caches must use
    the per-row position layout (init_caches(per_slot_pos=True)).
    """

    def decode(params, caches, tokens: jnp.ndarray, pos: jnp.ndarray,
               temps: jnp.ndarray, key: jnp.ndarray):
        logits, _, caches = T.apply_model(
            params, cfg, tokens=tokens, mode="decode", caches=caches,
            pos_scalar=pos)
        nxt = sample_token(logits, key, temps)
        return nxt, logits, caches

    return decode


def make_chunk_step(cfg: ModelConfig):
    """chunk(params, caches, tokens, pos) -> (last_logits, caches).

    Chunked prefill: tokens (B, C) are C consecutive prompt tokens per
    row, starting at absolute position pos[b]. Attention appends the
    chunk to the cache and masks by absolute position (causal within the
    chunk for free); SSM layers run the state-carried chunk-parallel
    scan. Every row must carry a FULL chunk — exactness comes from never
    padding inside a chunk (remainder tokens go through the decode ramp).
    """

    def chunk(params, caches, tokens: jnp.ndarray, pos: jnp.ndarray):
        logits, _, caches = T.apply_model(
            params, cfg, tokens=tokens, mode="decode", caches=caches,
            pos_scalar=pos)
        return logits, caches

    return chunk


# ModelConfig is a frozen dataclass, so jitted step programs are shared
# process-wide per config (one compile per (cfg, shape) — a new Scheduler
# or generate() call never retraces; same discipline as runtime.dispatch).
# The caches argument is donated: the pool is the scarce resource, and
# without donation every step materializes a second full copy of it.
# Callers must drop their reference (`_, caches = step(params, caches, …)`).

@functools.lru_cache(maxsize=None)
def jit_chunk_step(cfg: ModelConfig):
    return obs_trace.instrumented_jit(
        jax.jit(make_chunk_step(cfg), donate_argnums=(1,)),
        name=f"chunk_step[{cfg.name}]", prefix="serve.engine")


@functools.lru_cache(maxsize=None)
def jit_slot_decode_step(cfg: ModelConfig):
    return obs_trace.instrumented_jit(
        jax.jit(make_slot_decode_step(cfg), donate_argnums=(1,)),
        name=f"slot_decode_step[{cfg.name}]", prefix="serve.engine")


# ---------------------------------------------------------------------------
# paged steps: caches split into dense per-slot leaves + a physical block
# pool read through a page table (serve.paging / serve.slots paged backing)
# ---------------------------------------------------------------------------

def _merge_paged(dense, paged, rows, block_size):
    """Rebuild the full cache tree the model steps expect: dense entries
    pass through; paged attention layers (dense holds None) get a per-slot
    view gathered through the page-table ``rows[key]``. View lengths vary
    per key — cache_slots for global-attention layers, the ring length
    for sliding-window layers — and each key's trash floor is recovered
    from its flat pool's shape (the trash block is the last
    ``block_size`` rows)."""
    from repro.models import attention  # local: avoid import cycle

    caches = {}
    for key, entry in dense.items():
        if key in paged:
            entry = dict(entry)
            entry["attn"] = attention.paged_view(
                paged[key], rows[key],
                attention.paged_live_rows(paged[key], block_size))
        caches[key] = entry
    return caches


def _split_paged(caches, paged, rows):
    """Inverse of _merge_paged: scatter updated views back into the pool
    and strip them from the dense tree (None placeholders restored)."""
    from repro.models import attention

    dense, paged_new = {}, {}
    for key, entry in caches.items():
        if key in paged:
            entry = dict(entry)
            view = entry["attn"]
            entry["attn"] = None
            paged_new[key] = attention.paged_writeback(paged[key], view,
                                                       rows[key])
        dense[key] = entry
    return dense, paged_new


@functools.lru_cache(maxsize=None)
def jit_paged_decode_step(cfg: ModelConfig):
    """Fused page-gather -> decode -> page-scatter over the whole pool.

    dense: cache tree with None at paged attention entries (per-slot SSM
    state, any unpaged leaves); paged: dict pattern-key -> flat KVCache
    block pool; rows: dict pattern-key -> (B, V_key) flat physical row
    per view position (keys in one page-table group share the array);
    block_size (static): every group's block size — each key's trash
    floor is its flat pool's rows minus one block. One jitted program per
    cfg — same one-fused-program-per-tick property as the contiguous
    path, the page tables are just extra gather indices.
    """
    step = make_slot_decode_step(cfg)

    def run(params, dense, paged, rows, tokens, pos, temps, key,
            block_size: int):
        caches = _merge_paged(dense, paged, rows, block_size)
        nxt, logits, caches = step(params, caches, tokens, pos, temps, key)
        dense, paged = _split_paged(caches, paged, rows)
        return nxt, logits, dense, paged

    return obs_trace.instrumented_jit(
        jax.jit(run, donate_argnums=(1, 2), static_argnums=(8,)),
        name=f"paged_decode_step[{cfg.name}]", prefix="serve.engine")


@functools.lru_cache(maxsize=None)
def jit_paged_chunk_step(cfg: ModelConfig):
    """Fused gather -> chunk-prefill -> scatter for the paged layout.

    ``idx`` selects the sub-batch of slots (pad-by-repeat contract as the
    contiguous pooled chunk step); ``rows`` values are already
    per-sub-row (len(idx), V_key). Dense leaves gather/scatter on the
    slot axis, paged leaves through their page tables.
    """
    step = make_chunk_step(cfg)

    def run(params, dense, paged, idx, rows, tokens, pos, block_size: int):
        sub = jax.tree_util.tree_map(
            lambda l: jnp.take(l, idx, axis=1), dense)
        caches = _merge_paged(sub, paged, rows, block_size)
        _, caches = step(params, caches, tokens, pos)
        sub, paged = _split_paged(caches, paged, rows)
        dense = jax.tree_util.tree_map(
            lambda l, s: l.at[:, idx].set(s.astype(l.dtype)), dense, sub)
        return dense, paged

    return obs_trace.instrumented_jit(
        jax.jit(run, donate_argnums=(1, 2), static_argnums=(7,)),
        name=f"paged_chunk_step[{cfg.name}]", prefix="serve.engine")


@functools.partial(jax.jit, donate_argnums=(0,))
def reset_block_rows(paged, rows):
    """Zero the physical rows of freshly-mapped blocks (k=v=0, pos=-1) —
    the paged counterpart of SlotManager.alloc's slot reset. ``rows`` may
    be padded with trash rows (identical writes: deterministic)."""
    from repro.models.attention import KVCache

    return {key: KVCache(k=c.k.at[:, rows].set(0),
                         v=c.v.at[:, rows].set(0),
                         pos=c.pos.at[:, rows].set(-1))
            for key, c in paged.items()}


@jax.jit
def gather_block_rows(paged, rows):
    """Pull the physical ``rows`` of every paged cache leaf — the
    device half of swap-out preemption (the host then ``device_get``s
    the result into a SwapStore). ``rows`` comes from
    PageTable.block_rows over the victim's mapped blocks, pow2-padded
    with trash rows so compiles stay O(log blocks_per_slot)."""
    from repro.models.attention import KVCache

    return {key: KVCache(k=jnp.take(c.k, rows, axis=1),
                         v=jnp.take(c.v, rows, axis=1),
                         pos=jnp.take(c.pos, rows, axis=1))
            for key, c in paged.items()}


@functools.partial(jax.jit, donate_argnums=(0,))
def upload_block_rows(paged, saved, rows):
    """Write saved block bytes into freshly-mapped physical ``rows`` —
    the resume half of swap preemption (inverse of gather_block_rows,
    same PageTable.block_rows layout). Pad rows land in the trash block
    with identical (zero) payloads, so the scatter is deterministic."""
    from repro.models.attention import KVCache

    return {key: KVCache(
        k=c.k.at[:, rows].set(saved[key].k.astype(c.k.dtype)),
        v=c.v.at[:, rows].set(saved[key].v.astype(c.v.dtype)),
        pos=c.pos.at[:, rows].set(saved[key].pos.astype(jnp.int32)))
            for key, c in paged.items()}


@functools.partial(jax.jit, donate_argnums=(0,))
def copy_block_rows(paged, src_rows, dst_rows):
    """Device-side block copy: duplicate the physical ``src_rows`` into
    ``dst_rows`` on every paged cache leaf — the copy half of
    copy-on-write (PageTable.cow_block picks the blocks; this moves the
    bytes without a host round-trip). Row vectors use the same
    PageTable.block_rows layout as gather/upload and may be pow2-padded
    with trash->trash pairs (the trash block copies onto itself:
    harmless, deterministic)."""
    from repro.models.attention import KVCache

    return {key: KVCache(
        k=c.k.at[:, dst_rows].set(jnp.take(c.k, src_rows, axis=1)),
        v=c.v.at[:, dst_rows].set(jnp.take(c.v, src_rows, axis=1)),
        pos=c.pos.at[:, dst_rows].set(jnp.take(c.pos, src_rows, axis=1)))
            for key, c in paged.items()}


def generate(params, cfg: ModelConfig, prompt, max_new_tokens: int,
             *, temperature: float = 0.0, eos_token: Optional[int] = None,
             prefill_chunk: int = 32, cache_slots: int = 0,
             key: Optional[jnp.ndarray] = None):
    """Per-request generation — the scheduler's single-request oracle.

    Consumes the prompt with the SAME chunked-prefill + decode-ramp
    policy the continuous scheduler uses (full ``prefill_chunk`` chunks
    over the first L-1 tokens, remainder teacher-forced through decode),
    so a Scheduler run is token-identical to mapping this over requests
    under greedy sampling. Returns (tokens: np-able (g,) int32, reason).
    """
    import numpy as np

    prompt = jnp.asarray(prompt, jnp.int32)
    ln = int(prompt.shape[0])
    assert ln >= 1, "empty prompt"
    slots = cache_slots or (ln + max_new_tokens)
    caches = T.init_caches(cfg, batch=1, slots=slots, per_slot_pos=True)
    chunk_fn = jit_chunk_step(cfg)
    decode_fn = jit_slot_decode_step(cfg)
    if key is None:
        key = jax.random.PRNGKey(0)

    ctx = 0
    while ln - 1 - ctx >= prefill_chunk:
        toks = prompt[None, ctx:ctx + prefill_chunk]
        _, caches = chunk_fn(params, caches, toks,
                             jnp.asarray([ctx], jnp.int32))
        ctx += prefill_chunk

    temps = jnp.asarray([temperature], jnp.float32)
    out, reason, last = [], "length", None
    while len(out) < max_new_tokens:
        tok = prompt[ctx] if ctx < ln else last
        key, ks = jax.random.split(key)
        nxt, _, caches = decode_fn(params, caches, tok.reshape(1, 1),
                                   jnp.asarray([ctx], jnp.int32), temps, ks)
        ctx += 1
        last = nxt[0]
        if ctx >= ln:                       # prompt consumed: real sample
            out.append(int(last))
            if eos_token is not None and out[-1] == eos_token:
                reason = "eos"
                break
    return np.asarray(out, np.int32), reason


# ---------------------------------------------------------------------------
# cache shardings (mirror transformer.init_caches structure)
# ---------------------------------------------------------------------------

def cache_shardings(cfg: ModelConfig, cache_shapes: Any):
    """NamedShardings for a cache pytree (from its eval_shape shapes).

    Mirrors the structure built by transformer.init_caches / emitted by the
    prefill scan: dict p<i> -> per-mixer state, every leaf stacked over
    periods (leading axis replicated).
    """
    from repro.models.attention import KVCache  # local: avoid import cycle

    def ns(leaf, *names):
        return named_sharding(leaf.shape, (None,) + tuple(names))

    out = {}
    for i, spec in enumerate(cfg.pattern):
        c = cache_shapes[f"p{i}"]
        entry = {}
        if spec.mixer == "attn":
            kv = c["attn"]
            entry["attn"] = KVCache(
                k=ns(kv.k, "cache_batch", "cache_seq", "cache_kv_heads",
                     "cache_head_dim"),
                v=ns(kv.v, "cache_batch", "cache_seq", "cache_kv_heads",
                     "cache_head_dim"),
                # shared pos is (periods, S); per-row pos (periods, B, S)
                pos=(ns(kv.pos, "cache_batch", None)
                     if len(kv.pos.shape) == 3 else ns(kv.pos, None)))
        elif spec.mixer == "rwkv":
            st = c["rwkv"]
            entry["rwkv"] = {
                "s": ns(st["s"], "cache_batch", "ssm_heads", None, None),
                "x_prev": ns(st["x_prev"], "cache_batch", None)}
            if "ffn_x" in c:
                entry["ffn_x"] = ns(c["ffn_x"], "cache_batch", None)
        elif spec.mixer == "mamba":
            st = c["mamba"]
            entry["mamba"] = {
                "conv": ns(st["conv"], "cache_batch", None, "ssm_channels"),
                "h": ns(st["h"], "cache_batch", "ssm_channels", "ssm_state")}
        out[f"p{i}"] = entry
    return out
