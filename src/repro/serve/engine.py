"""Serving steps: prefill and single-token decode over static-shape caches.

Decode is the dependency-bound 1-D recurrence of serving — each step
consumes the previous step's cache/state (the paper's global-counter
pattern at request scale). Attention layers carry KV ring buffers; RWKV/
Mamba layers carry O(1) recurrent state, making decode cost flat in
context length (the long_500k story).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.sharding import named_sharding


def sample_token(logits: jnp.ndarray, key=None,
                 temperature: float = 0.0) -> jnp.ndarray:
    """logits: (B, 1, V) -> (B,) int32. temperature 0 = greedy."""
    lg = logits[:, -1].astype(jnp.float32)
    if temperature <= 0.0 or key is None:
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, lg / temperature).astype(jnp.int32)


def make_prefill_step(cfg: ModelConfig, cache_slots: int):
    """prefill(params, tokens|embeds) -> (last_logits, caches)."""

    def prefill(params, batch: Dict[str, jnp.ndarray]):
        logits, _, caches = T.apply_model(
            params, cfg, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"), mode="prefill",
            cache_slots=cache_slots)
        return logits, caches

    return prefill


def make_decode_step(cfg: ModelConfig, temperature: float = 0.0):
    """decode(params, caches, inp, pos[, key]) -> (next_tok, logits, caches).

    inp: {"tokens": (B,1)} or {"embeds": (B,1,D)}; pos: int32 scalar —
    the absolute position of the incoming token.
    """

    def decode(params, caches, inp: Dict[str, jnp.ndarray],
               pos: jnp.ndarray, key: Optional[jnp.ndarray] = None):
        logits, _, caches = T.apply_model(
            params, cfg, tokens=inp.get("tokens"),
            embeds=inp.get("embeds"), mode="decode", caches=caches,
            pos_scalar=pos)
        nxt = sample_token(logits, key, temperature)
        return nxt, logits, caches

    return decode


# ---------------------------------------------------------------------------
# cache shardings (mirror transformer.init_caches structure)
# ---------------------------------------------------------------------------

def cache_shardings(cfg: ModelConfig, cache_shapes: Any):
    """NamedShardings for a cache pytree (from its eval_shape shapes).

    Mirrors the structure built by transformer.init_caches / emitted by the
    prefill scan: dict p<i> -> per-mixer state, every leaf stacked over
    periods (leading axis replicated).
    """
    from repro.models.attention import KVCache  # local: avoid import cycle

    def ns(leaf, *names):
        return named_sharding(leaf.shape, (None,) + tuple(names))

    out = {}
    for i, spec in enumerate(cfg.pattern):
        c = cache_shapes[f"p{i}"]
        entry = {}
        if spec.mixer == "attn":
            kv = c["attn"]
            entry["attn"] = KVCache(
                k=ns(kv.k, "cache_batch", "cache_seq", "cache_kv_heads",
                     "cache_head_dim"),
                v=ns(kv.v, "cache_batch", "cache_seq", "cache_kv_heads",
                     "cache_head_dim"),
                pos=ns(kv.pos, None))
        elif spec.mixer == "rwkv":
            st = c["rwkv"]
            entry["rwkv"] = {
                "s": ns(st["s"], "cache_batch", "ssm_heads", None, None),
                "x_prev": ns(st["x_prev"], "cache_batch", None)}
            if "ffn_x" in c:
                entry["ffn_x"] = ns(c["ffn_x"], "cache_batch", None)
        elif spec.mixer == "mamba":
            st = c["mamba"]
            entry["mamba"] = {
                "conv": ns(st["conv"], "cache_batch", None, "ssm_channels"),
                "h": ns(st["h"], "cache_batch", "ssm_channels", "ssm_state")}
        out[f"p{i}"] = entry
    return out
