"""Pallas TPU kernel: fused blockwise (flash) attention with GQA/MQA.

Why this kernel exists (EXPERIMENTS.md §Perf, gemma3/olmoe iterations):
the pure-jnp online-softmax path materializes fp32 (Sq, blk) score/prob
tensors per KV block — measured as the dominant memory-term contributor
on every attention train cell (~28 GB/fusion on gemma3 train_4k). The fix
is fusion, not dtype: scores must live and die in VMEM. That is exactly
what this kernel does — one (q-block × kv-block) tile of scores at a time
in VMEM scratch, with the m/l/acc online-softmax carry, so HBM traffic is
q + k + v + out only.

Squire mapping: the KV-block loop is the 1-D dependency chain (running
max/denominator = the global counter); q-blocks × (batch, head) are the
dependency-free fine-grain parallelism (the grid).

GQA/MQA: the kv BlockSpec index_map folds the query-head -> kv-head
mapping (h // group), so grouped heads read the same KV block without
materializing a broadcast.

Causal masking is by absolute position; `window > 0` adds a sliding
window (gemma3 local layers). Fully-masked KV blocks are skipped via the
loop bound (causal ⇒ kv blocks beyond the q block never load).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, seq_kv: int, window: int, scale: float):
    qb = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, hd)

    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qb * bq + jax.lax.iota(jnp.int32, bq)      # absolute q rows

    # causal: kv blocks strictly after this q block are fully masked
    n_kv = jax.lax.min((qb + 1) * bq + bk - 1, seq_kv) // bk

    def body(i, _):
        k_blk = k_ref[0, 0, pl.ds(i * bk, bk), :].astype(jnp.float32)
        v_blk = v_ref[0, 0, pl.ds(i * bk, bk), :].astype(jnp.float32)
        kv_pos = i * bk + jax.lax.iota(jnp.int32, bk)

        s = jax.lax.dot_general(
            q, k_blk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bq, bk)
        ok = kv_pos[None, :] <= q_pos[:, None]
        if window > 0:
            ok &= (q_pos[:, None] - kv_pos[None, :]) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(ok, p, 0.0)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        return 0

    jax.lax.fori_loop(0, n_kv, body, 0)
    out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)[:, None]
    o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, window: int = 0, bq: int = 128,
                           bk: int = 128, interpret: bool = True):
    """Fused causal (optionally sliding-window) attention.

    q: (B, H, Sq, hd); k, v: (B, KV, Skv, hd) with H % KV == 0.
    Sq % bq == 0 and Skv % bk == 0 (ops.py pads). Returns (B, H, Sq, hd)
    in q.dtype.
    """
    b, h, sq, hd = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    assert h % kvh == 0, (h, kvh)
    grp = h // kvh
    if sq % bq or skv % bk:
        raise ValueError(f"Sq={sq} % bq={bq} or Skv={skv} % bk={bk} != 0")
    grid = (b, h, sq // bq)
    scale = hd ** -0.5

    kern = functools.partial(_flash_kernel, bq=bq, bk=bk, seq_kv=skv,
                             window=window, scale=scale)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b_, h_, q_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, skv, hd),
                         lambda b_, h_, q_: (b_, h_ // grp, 0, 0)),
            pl.BlockSpec((1, 1, skv, hd),
                         lambda b_, h_, q_: (b_, h_ // grp, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b_, h_, q_: (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
