"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG = jnp.float32(-1e18)
BIG = jnp.float32(1e18)


def ssm_scan_ref(r, w, k, v, u):
    """Sequential WKV-style scan. Shapes as kernels.ssm_scan."""
    f32 = lambda x: x.astype(jnp.float32)
    r, w, k, v, u = map(f32, (r, w, k, v, u))

    def one(rb, wb, kb, vb):
        def step(s, rwkv):
            rt, wt, kt, vt = rwkv
            kv = kt[:, None] * vt[None, :]
            yt = jnp.sum(rt[:, None] * (s + u[:, None] * kv), axis=0)
            s = wt[:, None] * s + kv
            return s, yt
        s0 = jnp.zeros((r.shape[-1], v.shape[-1]), jnp.float32)
        _, y = jax.lax.scan(step, s0, (rb, wb, kb, vb))
        return y

    return jax.vmap(one)(r, w, k, v)


def chain_scan_ref(scores, w):
    """Sequential banded max-plus recurrence (= core.chain.chain_sequential)."""
    n, t = scores.shape

    def step(ring, si_wi):
        si, wi = si_wi
        cand = si + ring
        best = jnp.max(cand)
        arg = jnp.argmax(cand).astype(jnp.int32) + 1
        fi = jnp.maximum(best, wi)
        off = jnp.where(best >= wi, arg, 0)
        ring = jnp.concatenate([fi[None], ring[:-1]])
        return ring, (fi, off)

    ring0 = jnp.full((t,), NEG)
    _, (f, off) = jax.lax.scan(step, ring0,
                               (scores.astype(jnp.float32),
                                w.astype(jnp.float32)))
    return f, off


def dp_tile_ref(top, left, corner, a, b, *, kind="dtw", match=2.0,
                mismatch=-4.0, gap=4.0):
    """Row-major (tr, tc) tile via sequential double scan."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    tr, tc = a.shape[0], b.shape[0]

    def cell(dg, up, lf, av, bv):
        if kind == "dtw":
            return jnp.abs(av - bv) + jnp.minimum(dg, jnp.minimum(up, lf))
        sub = jnp.where(av == bv, jnp.float32(match), jnp.float32(mismatch))
        return jnp.maximum(
            0.0, jnp.maximum(dg + sub, jnp.maximum(up - gap, lf - gap)))

    def row_step(carry, inp):
        prev_row = carry
        av, lval, dval = inp

        def col_step(c, cinp):
            lft, dgn = c
            up, bv = cinp
            val = cell(dgn, up, lft, av, bv)
            return (val, up), val

        _, row = jax.lax.scan(col_step, (lval, dval), (prev_row, b))
        return row, row

    # diag seed for row i is M[i-1, -1]: corner for row 0, then left[i-1]
    dvals = jnp.concatenate([jnp.atleast_1d(corner).astype(jnp.float32),
                             left[:-1].astype(jnp.float32)])
    _, mat = jax.lax.scan(row_step, top.astype(jnp.float32),
                          (a, left.astype(jnp.float32), dvals))
    return mat
