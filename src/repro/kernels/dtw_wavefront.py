"""Pallas TPU kernel: 2-D DP wavefront tile (DTW min-plus / SW max-plus).

One program computes a (tr x tc) DP tile given its top row, left column and
corner boundary values — the exact unit Squire's workers compute between
local-counter handoffs (Alg. 4 / Fig. 5). Inside the tile, cells are swept
in anti-diagonal order with the whole diagonal updated as one vector op
(the fine-grain parallelism; tr lanes), using two rolling diagonal buffers
in VMEM.

Output is *diagonal-major*: D[k, i] = M[i, k - i]. ops.py converts back to
row-major and extracts boundaries (a production kernel would emit tiles
directly; the conversion is outside the dependency-critical path).

Dependency bookkeeping (i = row lane, j = k - i):
    up   M[i-1, j  ] = top[j]       if i == 0 else  D_{k-1}[i-1]
    left M[i,   j-1] = left[i]      if j == 0 else  D_{k-1}[i]
    diag M[i-1, j-1] = corner       if i == 0 and j == 0
                     = top[j-1]     if i == 0
                     = left[i-1]    if j == 0
                     = D_{k-2}[i-1] otherwise

VMEM: 2 diagonal buffers (tr,) + boundaries + the (K, tr) output block;
tr = tc = 128 -> ~140 KB fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 1e18  # python float: compile-time immediate inside the kernel


def _rev_gather(x_rev_padded, xlen: int, tr: int, k2):
    """val[i] = x[k2 - i] for i in [0, tr); junk where out of range."""
    start = xlen - 1 - k2 + tr
    return jax.lax.dynamic_slice(x_rev_padded, (start,), (tr,))


def _dp_tile_kernel(top_ref, left_ref, corner_ref, a_ref, b_ref, d_ref,
                    d1_ref, d2_ref, *, kind: str, tr: int, tc: int,
                    match: float, mismatch: float, gap: float):
    top = top_ref[...]
    left = left_ref[...]
    corner = corner_ref[0]
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)

    zpad = jnp.zeros((tr,), jnp.float32)
    top_rp = jnp.concatenate([zpad, top[::-1], zpad])
    b_rp = jnp.concatenate([zpad, b[::-1], zpad])

    ii = jnp.arange(tr)
    left_shift = jnp.concatenate([left[:1], left[:-1]])  # left[i-1]

    def step(k, _):
        jj = k - ii
        valid = (jj >= 0) & (jj < tc)
        d1 = d1_ref[...]
        d2 = d2_ref[...]
        d1s = jnp.concatenate([d1[:1], d1[:-1]])          # D_{k-1}[i-1]
        d2s = jnp.concatenate([d2[:1], d2[:-1]])          # D_{k-2}[i-1]
        topj = _rev_gather(top_rp, tc, tr, k)
        topjm1 = _rev_gather(top_rp, tc, tr, k - 1)
        bj = _rev_gather(b_rp, tc, tr, k)

        up = jnp.where(ii == 0, topj, d1s)
        lf = jnp.where(jj == 0, left, d1)
        dg = jnp.where(ii == 0, topjm1,
                       jnp.where(jj == 0, left_shift, d2s))
        dg = jnp.where((ii == 0) & (jj == 0), corner, dg)

        if kind == "dtw":
            new = jnp.abs(a - bj) + jnp.minimum(dg, jnp.minimum(up, lf))
            pad_val = BIG
        elif kind == "sw":
            sub = jnp.where(a == bj, jnp.float32(match),
                            jnp.float32(mismatch))
            new = jnp.maximum(dg + sub,
                              jnp.maximum(up - gap, lf - gap))
            new = jnp.maximum(new, 0.0)
            pad_val = jnp.float32(0.0)
        else:
            raise ValueError(kind)

        new = jnp.where(valid, new, pad_val)
        d_ref[pl.ds(k, 1), :] = new[None, :]
        d2_ref[...] = d1
        d1_ref[...] = new
        return 0

    jax.lax.fori_loop(0, tr + tc - 1, step, 0, unroll=False)


@functools.partial(jax.jit,
                   static_argnames=("kind", "match", "mismatch", "gap",
                                    "interpret"))
def dp_tile_pallas(top, left, corner, a, b, *, kind: str = "dtw",
                   match: float = 2.0, mismatch: float = -4.0,
                   gap: float = 4.0, interpret: bool = True):
    """Run one wavefront tile. Returns diagonal-major D (tr+tc-1, tr)."""
    tr, tc = a.shape[0], b.shape[0]
    kern = functools.partial(_dp_tile_kernel, kind=kind, tr=tr, tc=tc,
                             match=match, mismatch=mismatch, gap=gap)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((tr + tc - 1, tr), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tr,), jnp.float32),
                        pltpu.VMEM((tr,), jnp.float32)],
        interpret=interpret,
    )(top.astype(jnp.float32), left.astype(jnp.float32),
      jnp.atleast_1d(corner).astype(jnp.float32),
      a.astype(jnp.float32), b.astype(jnp.float32))
