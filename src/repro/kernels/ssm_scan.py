"""Pallas TPU kernel: chunked diagonal-linear recurrence (RWKV6 / Mamba).

The LM-scale instance of the paper's 1-D pattern (DESIGN.md §3.1): per head,

    S_t = diag(w_t) S_{t-1} + k_t^T v_t              (state: dk x dv)
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)          (RWKV6 readout)

Squire mapping:
  * worker chunk   -> one grid step owning a C-step time chunk; the chunk's
                      working set (q/k/v/w blocks + the state) lives in VMEM.
  * global counter -> the state scratch carried across sequential grid
                      steps (Pallas TPU grids iterate in order; the scratch
                      is the boundary handoff).
  * loop fission   -> the dk x dv rank-1 update and readout are fully
                      vectorized per step (VPU); only the C-long chunk loop
                      is serial, giving depth C instead of T per (b, h).

VMEM budget per program (fp32): 4 blocks of (C, d) + state (dk, dv)
= 4*C*d + dk*dv floats; with C=64, d=dk=dv=64: ~82 KB — well under 16 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(r_ref, w_ref, k_ref, v_ref, u_ref, y_ref, state_ref,
                *, chunk: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    def step(t, _):
        rt = r_ref[0, t, :]                      # (dk,)
        wt = w_ref[0, t, :]
        kt = k_ref[0, t, :]
        vt = v_ref[0, t, :]                      # (dv,)
        u = u_ref[...]                           # (dk,)
        s = state_ref[...]                       # (dk, dv)
        kv = kt[:, None] * vt[None, :]
        # readout uses S_{t-1} plus the bonus-weighted current kv (RWKV6)
        yt = jnp.sum(rt[:, None] * (s + u[:, None] * kv), axis=0)
        y_ref[0, pl.ds(t, 1), :] = yt[None, :]
        state_ref[...] = wt[:, None] * s + kv
        return 0

    jax.lax.fori_loop(0, chunk, step, 0, unroll=False)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan_pallas(r, w, k, v, u, *, chunk: int = 64,
                    interpret: bool = True):
    """Chunked WKV-style scan.

    Args:
      r, w, k: (B, T, dk)  — receptance / decay (multiplicative, in (0,1])
                             / key. B folds batch*heads.
      v: (B, T, dv) values.
      u: (dk,) bonus for the current token (RWKV6's `u`; zeros for Mamba).
      chunk: time chunk per grid step (the "worker" granularity).

    Returns: y (B, T, dv) in fp32.
    """
    b, t, dk = r.shape
    dv = v.shape[-1]
    if t % chunk:
        raise ValueError(f"T={t} not a multiple of chunk={chunk}")
    nchunks = t // chunk
    f32 = lambda x: x.astype(jnp.float32)

    grid = (b, nchunks)
    blk = lambda d: pl.BlockSpec((1, chunk, d), lambda i, c: (i, c, 0))
    return pl.pallas_call(
        functools.partial(_ssm_kernel, chunk=chunk),
        grid=grid,
        in_specs=[blk(dk), blk(dk), blk(dk), blk(dv),
                  pl.BlockSpec((dk,), lambda i, c: (0,))],
        out_specs=blk(dv),
        out_shape=jax.ShapeDtypeStruct((b, t, dv), jnp.float32),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(f32(r), f32(w), f32(k), f32(v), f32(u))
