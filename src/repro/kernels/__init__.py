"""Pallas TPU kernels for the paper's compute hot-spots.

chain_scan       — banded max-plus chain recurrence (paper Alg. 3 serial part)
dtw_wavefront    — 2-D DP wavefront tile (DTW / Smith-Waterman, Alg. 4)
ssm_scan         — chunked diagonal-linear scan (RWKV6/Mamba; DESIGN.md §3.1)
flash_attention  — fused blockwise attention w/ GQA + sliding window (the
                   production fix for the fp32 score traffic §Perf exposed)
radix_rank       — radix counting-sort rank/histogram pass (Alg. 1 hot-spot)

ops.py: jit'd wrappers (padding, layout, wavefront/sort integration).
ref.py: pure-jnp oracles; tests assert allclose across shape/dtype sweeps.
All kernels run under interpret=True on CPU; compiled mode on real TPUs.
"""

from repro.kernels import ops, ref  # noqa: F401
