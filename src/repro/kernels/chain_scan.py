"""Pallas TPU kernel: banded max-plus chain recurrence (paper Alg. 3).

Consumes the fission-phase score matrix S (N, T) — produced as dense
VPU/MXU work by core.chain.chain_scores — and runs the serial part

    f(i) = max(w_i, max_t S[i, t] + f(i - t)),   t in [1, T]

with the last-T window of f held in a VMEM ring (the paper keeps it in the
workers' L1/L2; the global-counter ordering is the sequential grid).

Squire mapping:
  * worker         -> the T band lanes: every candidate in the band is
                      evaluated in one vector op (the paper's workers split
                      this same band round-robin).
  * global counter -> the ring scratch carried across sequential grid steps.

Band T is padded to the 128-lane register width by ops.py. VMEM per
program: (C, T) scores block + (1, T) ring; C=256, T=128 -> ~132 KB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e18  # python float: becomes a compile-time immediate in the kernel


def _chain_kernel(scores_ref, w_ref, f_ref, off_ref, ring_ref, *,
                  block: int):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        ring_ref[...] = jnp.full_like(ring_ref, NEG)

    def step(t, _):
        row = scores_ref[pl.ds(t, 1), :]          # (1, T)
        ring = ring_ref[...]                      # (1, T); slot j = f(i-1-j)
        cand = row + ring
        best = jnp.max(cand)
        arg = jnp.argmax(cand[0, :]).astype(jnp.int32)
        wi = w_ref[pl.ds(t, 1)][0]
        fi = jnp.maximum(best, wi)
        off = jnp.where(best >= wi, arg + 1, 0)
        f_ref[pl.ds(t, 1)] = fi[None]
        off_ref[pl.ds(t, 1)] = off[None]
        # shift the window: new f enters slot 0
        shifted = jnp.concatenate([fi[None, None], ring[:, :-1]], axis=1)
        ring_ref[...] = shifted
        return 0

    jax.lax.fori_loop(0, block, step, 0, unroll=False)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def chain_scan_pallas(scores, w, *, block: int = 256,
                      interpret: bool = True):
    """scores: (N, T) fp32 band scores (NEG where invalid); w: (N,).

    Returns (f: (N,) fp32, off: (N,) int32 in [0, T]; 0 = chain start).
    """
    n, t = scores.shape
    if n % block:
        raise ValueError(f"N={n} not a multiple of block={block}")
    grid = (n // block,)
    f, off = pl.pallas_call(
        functools.partial(_chain_kernel, block=block),
        grid=grid,
        in_specs=[pl.BlockSpec((block, t), lambda i: (i, 0)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((block,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32),
                   jax.ShapeDtypeStruct((n,), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((1, t), jnp.float32)],
        interpret=interpret,
    )(scores.astype(jnp.float32), w.astype(jnp.float32))
    return f, off
