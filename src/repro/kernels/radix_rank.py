"""Pallas TPU kernel: radix counting-sort rank pass (paper Alg. 1 hot-spot).

One LSD radix pass over a worker chunk = three dependency-bound steps:
per-key bucket histogram, exclusive bucket prefix (the serial part), and
stable rank assignment. The paper's workers run this scalar loop per
chunk; the TPU version keeps the (C, R) one-hot block in VMEM and turns
the histogram + stable rank into MXU/VPU work:

  * grid = chunks ("workers") — dependency-free fine-grain parallelism,
  * per chunk: bucket = (keys >> shift) & (R-1); the running per-bucket
    count is a VMEM carry across C-sized key blocks (the global-counter
    pattern — order inside a chunk preserves stability),
  * rank[i] = running_count[bucket_i] before i, computed blockwise with a
    causal one-hot cumsum (vectorized, C x R in VMEM).

Output is each key's stable rank within (chunk, bucket) plus the chunk's
bucket histogram; ops.py composes ranks + histograms into scatter
positions exactly like core.sort._counting_pass (the jnp oracle).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rank_kernel(keys_ref, rank_ref, hist_ref, count_ref, *,
                 block: int, n_blocks: int, radix: int, shift: int):
    @pl.when(pl.program_id(0) >= 0)      # init per chunk (grid dim 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)

    def body(i, _):
        keys = keys_ref[0, pl.ds(i * block, block)]          # (C,)
        bucket = (keys >> shift) & (radix - 1)
        onehot = (bucket[:, None] ==
                  jax.lax.iota(jnp.uint32, radix)[None, :])  # (C, R) bool
        oh = onehot.astype(jnp.int32)
        # stable rank: keys earlier in the block with the same bucket
        within = jnp.cumsum(oh, axis=0) - oh                 # (C, R)
        prior = count_ref[...]                               # (1, R)
        rank = jnp.sum((within + prior) * oh, axis=1)        # (C,)
        rank_ref[0, pl.ds(i * block, block)] = rank.astype(jnp.int32)
        count_ref[...] = prior + jnp.sum(oh, axis=0)[None, :]
        return 0

    jax.lax.fori_loop(0, n_blocks, body, 0, unroll=False)
    hist_ref[0, :] = count_ref[0, :]


@functools.partial(jax.jit, static_argnames=("radix", "shift", "block",
                                             "interpret"))
def radix_rank_pallas(keys, *, radix: int = 256, shift: int = 0,
                      block: int = 512, interpret: bool = True):
    """keys: (n_chunks, chunk_len) uint32. Returns (ranks, hists):
    ranks (n_chunks, chunk_len) int32 — stable rank within (chunk, bucket);
    hists (n_chunks, radix) int32 — per-chunk bucket histogram.
    chunk_len must be a multiple of `block`.
    """
    n_chunks, clen = keys.shape
    if clen % block:
        raise ValueError(f"chunk_len={clen} not a multiple of {block}")
    kern = functools.partial(_rank_kernel, block=block,
                             n_blocks=clen // block, radix=radix,
                             shift=shift)
    return pl.pallas_call(
        kern,
        grid=(n_chunks,),
        in_specs=[pl.BlockSpec((1, clen), lambda c: (c, 0))],
        out_specs=[pl.BlockSpec((1, clen), lambda c: (c, 0)),
                   pl.BlockSpec((1, radix), lambda c: (c, 0))],
        out_shape=[jax.ShapeDtypeStruct((n_chunks, clen), jnp.int32),
                   jax.ShapeDtypeStruct((n_chunks, radix), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((1, radix), jnp.int32)],
        interpret=interpret,
    )(keys.astype(jnp.uint32))
