"""jit'd public wrappers around the Pallas kernels.

These adapt shapes (lane padding, block alignment), convert kernel-native
layouts back to caller layouts, and plug the tile kernel into the
core.wavefront scheduler so `dtw_tiled(..., tile_fn=ops.dtw_tile_fn)` runs
the Pallas path end to end.

`interpret=True` everywhere in this repo: the container is CPU-only; on a
real TPU these flip to compiled mode unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.chain_scan import chain_scan_pallas
from repro.kernels.dtw_wavefront import dp_tile_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas

NEG = jnp.float32(-1e18)


# --------------------------------------------------------------------------
# ssm_scan
# --------------------------------------------------------------------------

def ssm_scan(r, w, k, v, u=None, chunk: int = 64, interpret: bool = True):
    """Chunked WKV scan with automatic T-padding. Shapes (B, T, d*)."""
    b, t, dk = r.shape
    if u is None:
        u = jnp.zeros((dk,), jnp.float32)
    pad = (-t) % chunk
    if pad:
        padk = jnp.zeros((b, pad, dk), r.dtype)
        padv = jnp.zeros((b, pad, v.shape[-1]), v.dtype)
        r = jnp.concatenate([r, padk], axis=1)
        w = jnp.concatenate([w, jnp.ones((b, pad, dk), w.dtype)], axis=1)
        k = jnp.concatenate([k, padk], axis=1)
        v = jnp.concatenate([v, padv], axis=1)
    y = ssm_scan_pallas(r, w, k, v, u, chunk=chunk, interpret=interpret)
    return y[:, :t]


# --------------------------------------------------------------------------
# chain
# --------------------------------------------------------------------------

def chain_scan(scores, w, block: int = 256, lanes: int = 128,
               interpret: bool = True):
    """Banded chain recurrence with band padded to `lanes` and N to block."""
    n, t = scores.shape
    if t < lanes:
        scores = jnp.concatenate(
            [scores, jnp.full((n, lanes - t), NEG)], axis=1)
    padn = (-n) % block
    if padn:
        scores = jnp.concatenate(
            [scores, jnp.full((padn, scores.shape[1]), NEG)], axis=0)
        w = jnp.concatenate([w, jnp.full((padn,), NEG)], axis=0)
    f, off = chain_scan_pallas(scores, w, block=block, interpret=interpret)
    off = jnp.minimum(off, t)  # padded lanes can never win, but clamp anyway
    return f[:n], off[:n]


def chain_anchors(q, r, T: int = 64, params=None, block: int = 256,
                  interpret: bool = True):
    """Drop-in for core.chain.chain_anchors on the Pallas path."""
    from repro.core import chain as cchain
    params = params or cchain.ChainParams()
    n = q.shape[0]
    w = jnp.full((n,), float(params.kmer), jnp.float32)
    scores = cchain.chain_scores(q, r, T, params)   # fission phase (dense)
    f, off = chain_scan(scores, w, block=block, interpret=interpret)
    pred = jnp.where(off > 0, jnp.arange(n) - off, -1)
    return f, pred


# --------------------------------------------------------------------------
# 2-D DP tiles
# --------------------------------------------------------------------------

def _diag_to_row_major(d, tr: int, tc: int):
    rows = jnp.arange(tr)[:, None]
    cols = jnp.arange(tc)[None, :]
    return d[rows + cols, jnp.broadcast_to(rows, (tr, tc))]


def dp_tile(top, left, corner, a, b, *, kind="dtw", interpret=True, **params):
    """Pallas tile with core.wavefront.TileFn signature."""
    tr, tc = a.shape[0], b.shape[0]
    d = dp_tile_pallas(top, left, corner, a, b, kind=kind,
                       interpret=interpret, **params)
    tile = _diag_to_row_major(d, tr, tc)
    return tile, tile[-1, :], tile[:, -1], tile[-1, -1]


def dtw_tile_fn(top, left, corner, a, b):
    return dp_tile(top, left, corner, a, b, kind="dtw")


def make_sw_tile_fn(match=2.0, mismatch=-4.0, gap=4.0):
    return functools.partial(dp_tile, kind="sw", match=match,
                             mismatch=mismatch, gap=gap)


# --------------------------------------------------------------------------
# radix sort (rank kernel + jnp scatter/merge)
# --------------------------------------------------------------------------

def radix_sort_chunks(keys, vals=None, key_bits: int = 32,
                      block: int = 512, interpret: bool = True):
    """Chunk-parallel LSD radix sort on the Pallas rank kernel.

    keys: (n_chunks, chunk_len) uint32 -> sorted within each chunk; the
    caller merges chunks (core.sort.merge_sorted), mirroring Alg. 1.
    """
    from repro.kernels.radix_rank import radix_rank_pallas

    n_chunks, clen = keys.shape
    if vals is None:
        vals = jnp.broadcast_to(jnp.arange(clen, dtype=jnp.int32)[None],
                                keys.shape)
    blk = min(block, clen)
    for shift in range(0, key_bits, 8):
        ranks, hists = radix_rank_pallas(keys, shift=shift, block=blk,
                                         interpret=interpret)
        starts = jnp.cumsum(hists, axis=1) - hists          # exclusive
        bucket = ((keys >> shift) & 255).astype(jnp.int32)
        pos = jnp.take_along_axis(starts, bucket, axis=1) + ranks
        keys = jnp.zeros_like(keys).at[
            jnp.arange(n_chunks)[:, None], pos].set(keys)
        vals = jnp.zeros_like(vals).at[
            jnp.arange(n_chunks)[:, None], pos].set(vals)
    return keys, vals


def dtw_tiled(s, r, tile_r: int = 128, tile_c: int = 128, **kw):
    """End-to-end Pallas DTW: wavefront scheduler + Pallas tiles."""
    from repro.core import dtw as cdtw
    return cdtw.dtw_tiled(s, r, tile_r, tile_c, tile_fn=dtw_tile_fn, **kw)


def sw_tiled(a, b, params=None, tile_r: int = 128, tile_c: int = 128):
    from repro.core import align as calign
    p = params or calign.SWParams()
    fn = make_sw_tile_fn(p.match, p.mismatch, p.gap)
    return calign.sw_tiled(a, b, p, tile_r, tile_c, tile_fn=fn)
