"""KernelService — one entry point for bulk dependency-bound kernel work.

The paper's pitch is that five very different dependency-bound kernels
(chain, Smith-Waterman, DTW, sort/seeding, 1-D scans) accelerate behind
*one* dispatch interface with minimal software changes. This registry is
that interface at traffic scale: heterogeneous requests go in, the service
groups them by kernel, buckets them by shape (``runtime.bucketing``),
batches each bucket through the worker-pool dispatcher
(``runtime.dispatch``) with host/device overlap (``runtime.pipeline``),
and scatters per-request results back in order.

    svc = KernelService(ServiceConfig(), reference=ref)   # ref: mapper/seed
    results = svc.submit([
        Request("chain", {"q": q, "r": r}),
        Request("dtw",   {"s": s, "r": r2}),
        Request("map",   {"read": read}),
        ...
    ])

Every kernel result is bit-identical to the corresponding direct call into
``repro.core`` / ``repro.apps.read_mapper``: batching is pure vmap over
the same per-request computation, and sentinel padding is appended *after*
the true data, which none of these left-to-right recurrences can see.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.apps import read_mapper as rm
from repro.core import align as align_lib
from repro.core import chain as chain_lib
from repro.core import dtw as dtw_lib
from repro.core import seeding
from repro.core import sort as rsort
from repro.core import wavefront
from repro.core.scan1d import affine_scan
from repro.core.semiring import SEMIRINGS, finite_zero
from repro.obs import metrics as obs_metrics
from repro.obs import sampler as obs_sampler
from repro.runtime import bucketing
from repro.runtime.autotune import Autotuner
from repro.runtime.dispatch import Dispatcher
from repro.runtime.pipeline import run_pipelined


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Static knobs; one compiled program per (kernel, bucket key)."""
    # bucketing
    seq_bucket: int = 64        # sw/dtw sequence quantum (tile-aligned)
    anchor_bucket: int = 256    # chain anchor quantum
    sort_bucket: int = 256
    scan_bucket: int = 64
    bucket_mode: str = "linear"     # 'linear' | 'pow2'
    # chain
    chain_T: int = 64
    chain_mode: str = "fission"     # fission | sequential | blocked
    chain_block: int = 16
    # align / dtw
    sw_params: align_lib.SWParams = align_lib.SWParams()
    sw_tile: int = 32
    dtw_tile: int = 32
    # sort / seed / scan
    sort_chunks: int = 4
    scan_semiring: str = "real"
    scan_mode: str = "sequential"
    # end-to-end mapper
    mapper: rm.MapperConfig = rm.MapperConfig()
    # pipeline
    pipeline_depth: int = 2

    def tuned(self, tuner: Optional[Autotuner] = None) -> "ServiceConfig":
        """Override tile/chunk knobs from the autotune cache (fig9-seeded)."""
        tuner = tuner or Autotuner()
        over = {}
        dtw_tile = tuner.get("dtw.tile")
        if dtw_tile:
            over["dtw_tile"] = int(dtw_tile)
            over["sw_tile"] = int(dtw_tile)     # same engine, same knee
        chunk = tuner.get("ssm.chunk")
        if chunk:
            over["scan_bucket"] = int(chunk)
        return dataclasses.replace(self, **over) if over else self


@dataclasses.dataclass(frozen=True)
class Request:
    kernel: str
    payload: Dict[str, Any]


def _spec(size: int, mode: str) -> bucketing.BucketSpec:
    return bucketing.BucketSpec(size=size, mode=mode)


def _payload_key(payload: Dict) -> Tuple:
    """Content key for a kernel payload (bulk-submit dedup). dtype +
    shape ride along with the bytes for the same reason RequestCache.key
    carries them: equal bytes alone collide across dtypes/shapes."""
    parts: List[Tuple] = []
    for k in sorted(payload):
        v = payload[k]
        if isinstance(v, (np.ndarray, jnp.ndarray, list, tuple)):
            a = np.ascontiguousarray(v)
            parts.append((k, a.tobytes(), a.dtype.str, a.shape))
        else:
            parts.append((k, v))
    return tuple(parts)


def _copy_result(res: Any) -> Any:
    """Fresh arrays for a deduped duplicate: handing every requester the
    SAME array would let one caller's in-place edit corrupt another's
    result (the RequestCache aliasing bug, one layer down)."""
    return jax.tree_util.tree_map(
        lambda x: x.copy() if isinstance(x, np.ndarray) else x, res)


# --------------------------------------------------------------------------
# cached batched building blocks
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _scan_fn(srname: str, mode: str):
    sr = SEMIRINGS[srname]

    def run(a, b, x0):
        return affine_scan(a, b, x0, sr, mode=mode)
    return run


@functools.lru_cache(maxsize=None)
def _sort_fn(num_chunks: int):
    def run(keys, vals):
        return rsort.radix_sort(keys, vals, num_chunks=num_chunks,
                                min_parallel=0)
    return run


@functools.lru_cache(maxsize=None)
def _sw_tile_batched(params: align_lib.SWParams):
    return jax.jit(jax.vmap(functools.partial(align_lib._sw_tile_fn,
                                              params)))


@functools.lru_cache(maxsize=None)
def _dtw_tile_batched():
    return jax.jit(jax.vmap(dtw_lib._dtw_tile_fn))


def _sw_batched(a: np.ndarray, b: np.ndarray,
                params: align_lib.SWParams, tile: int) -> jnp.ndarray:
    """(B, na) x (B, nb) -> H matrices (B, na, nb) via the batched
    wavefront; per-row bit-identical to align.sw_tiled on that row."""
    bsz, na = a.shape
    nb = b.shape[1]
    ap = wavefront.pad_to_multiple(jnp.asarray(a, jnp.int32), tile, 1, 255)
    bp = wavefront.pad_to_multiple(jnp.asarray(b, jnp.int32), tile, 1, 255)
    npad, mpad = ap.shape[1], bp.shape[1]
    mat, _, _, _ = wavefront.run_wavefront_batched(
        _sw_tile_batched(params), ap, bp,
        top0=jnp.zeros((bsz, mpad), jnp.float32),
        left0=jnp.zeros((bsz, npad), jnp.float32),
        corner0=jnp.zeros((bsz,), jnp.float32),
        tile_r=tile, tile_c=tile, assemble=True)
    return mat[:, :na, :nb]


def _dtw_batched(s: np.ndarray, r: np.ndarray, tile: int) -> jnp.ndarray:
    """(B, n) x (B, m) -> DTW matrices (B, n, m), per-row bit-identical to
    dtw.dtw_tiled on that row."""
    bsz, n = s.shape
    m = r.shape[1]
    big = jnp.float32(jnp.finfo(jnp.float32).max / 4)
    sp = wavefront.pad_to_multiple(jnp.asarray(s, jnp.float32), tile, 1, 1e18)
    rp = wavefront.pad_to_multiple(jnp.asarray(r, jnp.float32), tile, 1, 1e18)
    npad, mpad = sp.shape[1], rp.shape[1]
    mat, _, _, _ = wavefront.run_wavefront_batched(
        _dtw_tile_batched(), sp, rp,
        top0=jnp.full((bsz, mpad), big, jnp.float32),
        left0=jnp.full((bsz, npad), big, jnp.float32),
        corner0=jnp.zeros((bsz,), jnp.float32),
        tile_r=tile, tile_c=tile, assemble=True)
    return mat[:, :n, :m]


# --------------------------------------------------------------------------
# kernel adapters
# --------------------------------------------------------------------------

class KernelAdapter:
    """Bucket -> batch -> dispatch -> unpack for one kernel family.

    Subclasses implement ``bucket_key`` / ``prepare`` / ``launch`` /
    ``collect``; the generic ``run`` pipelines the buckets (padding the
    next bucket on the host while the current one computes)."""

    name: str = ""

    def __init__(self, svc: "KernelService"):
        self.svc = svc
        self.cfg = svc.cfg

    # hooks -------------------------------------------------------------
    def bucket_key(self, payload: Dict) -> Tuple:
        raise NotImplementedError

    def prepare(self, key: Tuple, payloads: List[Dict]):
        raise NotImplementedError

    def launch(self, key: Tuple, leaves):
        raise NotImplementedError

    def collect(self, key: Tuple, out, payloads: List[Dict]) -> List[Any]:
        raise NotImplementedError

    # generic pipeline ---------------------------------------------------
    def run(self, payloads: List[Dict]) -> List[Any]:
        """Dedup identical payloads (content hash — cheap next to a
        dispatch), run the unique set through the bucketed pipeline,
        fan results back out. A bulk submit repeating one hot read /
        key array pays for ONE dispatch; duplicates receive fresh
        array copies so no two requesters alias the same buffer."""
        keys = []
        for p in payloads:
            try:
                keys.append(_payload_key(p))
            except TypeError:       # unhashable extra → never deduped
                keys.append(object())
        first: Dict[Any, int] = {}
        uniq: List[int] = []
        for i, k in enumerate(keys):
            if k not in first:
                first[k] = len(uniq)
                uniq.append(i)
        if len(uniq) == len(payloads):
            return self._run_unique(payloads)
        self.svc.deduped_requests += len(payloads) - len(uniq)
        got = self._run_unique([payloads[i] for i in uniq])
        return [got[first[k]] if i == uniq[first[k]]
                else _copy_result(got[first[k]])
                for i, k in enumerate(keys)]

    def _run_unique(self, payloads: List[Dict]) -> List[Any]:
        groups = bucketing.group_by_key(
            [self.bucket_key(p) for p in payloads])
        results: List[Any] = [None] * len(payloads)

        def work():
            for key, rows in groups.items():
                yield key, rows, self.prepare(
                    key, [payloads[r] for r in rows])

        def launch(item):
            key, rows, leaves = item
            return key, rows, self.launch(key, leaves)

        for key, rows, out in run_pipelined(
                work(), launch, depth=self.cfg.pipeline_depth):
            out = jax.tree_util.tree_map(np.asarray, out)
            got = self.collect(key, out, [payloads[r] for r in rows])
            for r, res in zip(rows, got):
                results[r] = res
        return results


class ChainAdapter(KernelAdapter):
    """payload {q, r} -> {"f", "pred"} (minimap2 chain DP, §III-B)."""

    name = "chain"

    def bucket_key(self, p):
        return (_spec(self.cfg.anchor_bucket, self.cfg.bucket_mode)
                .padded(max(len(p["q"]), 1)),)

    def prepare(self, key, payloads):
        nb = key[0]
        qp = bucketing.pad_stack([np.asarray(p["q"], np.int32)
                                  for p in payloads], nb, 0)
        rp = bucketing.pad_stack([np.asarray(p["r"], np.int32)
                                  for p in payloads], nb, 2**30)
        vp = bucketing.valid_mask(
            bucketing.lengths_of([p["q"] for p in payloads]), nb)
        return qp, rp, vp

    def launch(self, key, leaves):
        block = self.cfg.chain_block
        if self.cfg.chain_mode == "blocked":
            # per-bucket autotuned block (fig9 sweep); only the blocked
            # schedule consumes a block size — fission/sequential ignore
            # it, so the lookup would be misleading there
            block = int(self.svc.tuner.get_bucketed("chain.block", key[0],
                                                    block))
        fn = rm._chain_fn(self.cfg.chain_T, self.cfg.chain_mode, block)
        return self.svc.dispatcher.run(fn, leaves)

    def collect(self, key, out, payloads):
        f, pred = out
        return [{"f": f[i, :len(p["q"])], "pred": pred[i, :len(p["q"])]}
                for i, p in enumerate(payloads)]


class SWAdapter(KernelAdapter):
    """payload {a, b} -> {"score", "end"} (Smith-Waterman, §III-B)."""

    name = "sw"

    def _padded(self, n):
        spec = _spec(self.cfg.seq_bucket, self.cfg.bucket_mode)
        return bucketing.round_up(spec.padded(n), self.cfg.sw_tile)

    def bucket_key(self, p):
        return (self._padded(len(p["a"])), self._padded(len(p["b"])))

    def prepare(self, key, payloads):
        na, nb = key
        a = bucketing.pad_stack([np.asarray(p["a"], np.int32)
                                 for p in payloads], na, 254)
        b = bucketing.pad_stack([np.asarray(p["b"], np.int32)
                                 for p in payloads], nb, 255)
        return a, b

    def launch(self, key, leaves):
        a, b = leaves
        return _sw_batched(a, b, self.cfg.sw_params, self.cfg.sw_tile)

    def collect(self, key, mats, payloads):
        out = []
        for i, p in enumerate(payloads):
            mat = mats[i, :len(p["a"]), :len(p["b"])]
            flat = int(np.argmax(mat))
            out.append({"score": mat.flat[flat],
                        "end": (flat // mat.shape[1], flat % mat.shape[1])})
        return out


class DTWAdapter(KernelAdapter):
    """payload {s, r} -> {"distance"} (dynamic time warping, §III-C)."""

    name = "dtw"

    def _padded(self, n):
        spec = _spec(self.cfg.seq_bucket, self.cfg.bucket_mode)
        return bucketing.round_up(spec.padded(n), self.cfg.dtw_tile)

    def bucket_key(self, p):
        return (self._padded(len(p["s"])), self._padded(len(p["r"])))

    def prepare(self, key, payloads):
        n, m = key
        s = bucketing.pad_stack([np.asarray(p["s"], np.float32)
                                 for p in payloads], n, 1e18)
        r = bucketing.pad_stack([np.asarray(p["r"], np.float32)
                                 for p in payloads], m, 1e18)
        return s, r

    def launch(self, key, leaves):
        s, r = leaves
        return _dtw_batched(s, r, self.cfg.dtw_tile)

    def collect(self, key, mats, payloads):
        return [{"distance": mats[i, len(p["s"]) - 1, len(p["r"]) - 1]}
                for i, p in enumerate(payloads)]


class SortAdapter(KernelAdapter):
    """payload {keys[, vals]} -> {"keys", "vals"} (chunked radix, §III-A)."""

    name = "sort"

    def bucket_key(self, p):
        return (_spec(self.cfg.sort_bucket, self.cfg.bucket_mode)
                .padded(max(len(p["keys"]), 1)),)

    def prepare(self, key, payloads):
        nb = key[0]
        keys = bucketing.pad_stack(
            [np.asarray(p["keys"], np.uint32) for p in payloads], nb,
            np.uint32(0xFFFFFFFF))
        vals = bucketing.pad_stack(
            [np.asarray(p["vals"], np.int32) if "vals" in p
             else np.arange(len(p["keys"]), dtype=np.int32)
             for p in payloads], nb, 0)
        return keys, vals

    def launch(self, key, leaves):
        chunks = self.svc.tuner.get_bucketed("sort.chunks", key[0],
                                             self.cfg.sort_chunks)
        return self.svc.dispatcher.run(_sort_fn(int(chunks)), leaves)

    def collect(self, key, out, payloads):
        keys, vals = out
        return [{"keys": keys[i, :len(p["keys"])],
                 "vals": vals[i, :len(p["keys"])]}
                for i, p in enumerate(payloads)]


class SeedAdapter(KernelAdapter):
    """payload {read} -> {"q", "r"} anchors (minimizer seeding, §III-B).

    The reference index is service state (KernelService(reference=...)),
    broadcast to every worker (vmap in_axes None)."""

    name = "seed"

    def bucket_key(self, p):
        cfg = self.cfg.mapper
        return (bucketing.round_up(len(p["read"]), cfg.read_bucket),)

    def prepare(self, key, payloads):
        nb = key[0]
        reads = bucketing.pad_stack(
            [np.asarray(p["read"], np.int32) for p in payloads], nb, 0)
        lens = bucketing.lengths_of([p["read"] for p in payloads])
        index = self.svc.index
        return index.hashes, index.positions, reads, lens

    def launch(self, key, leaves):
        cfg = self.cfg.mapper
        n_chunks = cfg.num_workers if cfg.mode == "squire" else 1
        fn = rm._seed_fn(cfg.k, cfg.w, cfg.max_occ, n_chunks)
        return self.svc.dispatcher.run(fn, leaves,
                                       in_axes=(None, None, 0, 0))

    def collect(self, key, out, payloads):
        q, r, valid = out
        return [{"q": q[i][valid[i]], "r": r[i][valid[i]]}
                for i in range(len(payloads))]


class ScanAdapter(KernelAdapter):
    """payload {a, b, x0} -> {"xs"} (1-D affine recurrence, the global-
    counter pattern; semiring/mode from ServiceConfig)."""

    name = "scan1d"

    def bucket_key(self, p):
        return (_spec(self.cfg.scan_bucket, self.cfg.bucket_mode)
                .padded(max(len(p["a"]), 1)),)

    def prepare(self, key, payloads):
        nb = key[0]
        sr = SEMIRINGS[self.cfg.scan_semiring]
        dtype = np.float32
        one = np.asarray(sr.one, dtype)
        zero = np.asarray(finite_zero(sr, jnp.float32), dtype)
        a = bucketing.pad_stack([np.asarray(p["a"], dtype)
                                 for p in payloads], nb, one)
        b = bucketing.pad_stack([np.asarray(p["b"], dtype)
                                 for p in payloads], nb, zero)
        x0 = np.asarray([np.asarray(p["x0"], dtype) for p in payloads])
        return a, b, x0

    def launch(self, key, leaves):
        fn = _scan_fn(self.cfg.scan_semiring, self.cfg.scan_mode)
        return self.svc.dispatcher.run(fn, leaves)

    def collect(self, key, out, payloads):
        return [{"xs": out[i, :len(p["a"])]}
                for i, p in enumerate(payloads)]


class MapperAdapter(KernelAdapter):
    """payload {read} -> MapResult: the end-to-end mapper with each stage
    batched across the in-flight requests (the paper's Fig. 8 pipeline at
    traffic scale). Stage functions and padding are shared with
    ReadMapper, so results are bit-identical to per-read mapping."""

    name = "map"

    def run(self, payloads: List[Dict]) -> List[Any]:
        cfg = self.cfg.mapper
        svc = self.svc
        reads = [np.asarray(p["read"]) for p in payloads]
        results: List[Optional[rm.MapResult]] = [None] * len(reads)

        live = []
        for i, rd in enumerate(reads):
            if len(rd) < cfg.k + cfg.w:
                results[i] = rm.MapResult(-1, 0.0, 0.0, 0, 0)
            else:
                live.append(i)

        # -- seed: the same adapter the standalone "seed" kernel uses ----
        anchors: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        seeded = svc._adapters["seed"].run(
            [{"read": reads[i]} for i in live])
        for i, got in zip(live, seeded):
            nv = len(got["q"])
            if nv < 2:
                results[i] = rm.MapResult(-1, 0.0, 0.0, nv, 0)
            else:
                anchors[i] = (got["q"], got["r"])

        # -- chain (bucketed by padded anchor count) ---------------------
        windows: Dict[int, Tuple[float, int, int]] = {}
        if cfg.use_pallas:
            chain_fn = rm._chain_fn_pallas(cfg.band_T)
        else:
            mode = "blocked" if cfg.mode == "squire" else "sequential"
            chain_fn = rm._chain_fn(cfg.band_T, mode, 16)
        groups = bucketing.group_by_key(
            [(bucketing.round_up(max(len(anchors[i][0]), 1),
                                 cfg.anchor_bucket),)
             for i in sorted(anchors)])
        order = sorted(anchors)
        for (nb,), rows in groups.items():
            idxs = [order[r] for r in rows]
            parts = [rm.chain_payload(anchors[i][0], anchors[i][1], cfg)
                     for i in idxs]
            qp = np.stack([x[0] for x in parts])
            rp = np.stack([x[1] for x in parts])
            vp = np.stack([x[2] for x in parts])
            f, pred = jax.tree_util.tree_map(
                np.asarray, svc.dispatcher.run(chain_fn, (qp, rp, vp)))
            for row, i in enumerate(idxs):
                qv, rv = anchors[i]
                nv = len(qv)
                chains = chain_lib.backtrack(f[row][:nv], pred[row][:nv],
                                             min_score=cfg.min_chain_score)
                if not chains:
                    results[i] = rm.MapResult(-1, 0.0, 0.0, nv, 0)
                    continue
                score, members = chains[0]
                lo, hi = rm.chain_window(qv, rv, members, len(reads[i]),
                                         len(svc.reference), cfg)
                if hi - lo < cfg.k:
                    results[i] = rm.MapResult(-1, 0.0, score, nv, 0)
                else:
                    windows[i] = (score, lo, hi)

        # -- align (bucketed by padded (read, window) shape) -------------
        pend = sorted(windows)
        pairs = {}
        for i in pend:
            _, lo, hi = windows[i]
            window = svc.reference[lo:hi].astype(np.int32)
            pairs[i] = rm.align_payload(reads[i], window, cfg)
        groups = bucketing.group_by_key(
            [(pairs[i][0].shape[0], pairs[i][1].shape[0]) for i in pend])
        for (na, nb), rows in groups.items():
            idxs = [pend[r] for r in rows]
            a = np.stack([pairs[i][0] for i in idxs])
            b = np.stack([pairs[i][1] for i in idxs])
            mats = np.asarray(self._align_batched(a, b))
            for row, i in enumerate(idxs):
                chain_score, lo, hi = windows[i]
                mat = mats[row]
                sw_score = float(mat.max())
                results[i] = rm.MapResult(
                    pos=lo, sw_score=sw_score, chain_score=chain_score,
                    n_anchors=len(anchors[i][0]),
                    align_cells=len(reads[i]) * (hi - lo))
        return results

    def _align_batched(self, a: np.ndarray, b: np.ndarray):
        cfg = self.cfg.mapper
        if cfg.use_pallas or cfg.mode == "squire":
            if cfg.use_pallas:
                from repro.kernels import ops
                p = cfg.sw_params
                tile_b = jax.vmap(ops.make_sw_tile_fn(p.match, p.mismatch,
                                                      p.gap))
            else:
                tile_b = _sw_tile_batched(cfg.sw_params)
            bsz = a.shape[0]
            ap = wavefront.pad_to_multiple(jnp.asarray(a), cfg.sw_tile,
                                           1, 255)
            bp = wavefront.pad_to_multiple(jnp.asarray(b), cfg.sw_tile,
                                           1, 255)
            mat, _, _, _ = wavefront.run_wavefront_batched(
                tile_b, ap, bp,
                top0=jnp.zeros((bsz, bp.shape[1]), jnp.float32),
                left0=jnp.zeros((bsz, ap.shape[1]), jnp.float32),
                corner0=jnp.zeros((bsz,), jnp.float32),
                tile_r=cfg.sw_tile, tile_c=cfg.sw_tile, assemble=True)
            return mat[:, :a.shape[1], :b.shape[1]]
        fn, _ = rm._sw_fn("baseline", cfg.sw_tile, False, cfg.sw_params)
        mats, _ = self.svc.dispatcher.run(fn, (a, b))
        return mats


class GenerateAdapter(KernelAdapter):
    """payload {prompt[, max_new_tokens, temperature]} -> {"tokens",
    "reason"}: LM decode traffic through the same front door as the
    dependency-bound kernels (ROADMAP serving-integration item).

    Decode is the request-scale 1-D recurrence, so batching happens in
    *time* (continuous batching), not in the request list: the adapter
    forwards the whole bulk to the attached ``serve.Scheduler``, whose
    slot pool interleaves prefill/decode/retire per step. Attach with
    ``KernelService(lm=Scheduler(...))``."""

    name = "generate"

    def run(self, payloads: List[Dict]) -> List[Any]:
        sched = self.svc.lm
        if sched is None:
            raise ValueError(
                "generate kernel needs KernelService(lm=serve.Scheduler)")
        rids = []
        for p in payloads:
            rids.extend(sched.submit(
                [np.asarray(p["prompt"], np.int32)],
                max_new_tokens=p.get("max_new_tokens"),
                temperature=p.get("temperature"),
                top_k=p.get("top_k"), top_p=p.get("top_p")))
        sched.drain()
        # pop: a long-lived service must not accumulate Completions
        done = [sched.results.pop(r) for r in rids]
        return [{"tokens": c.tokens, "reason": c.reason,
                 "accepted": c.accepted, "drafted": c.drafted}
                for c in done]


class ScoreAdapter(KernelAdapter):
    """payload {prompt} -> {"logprobs", "reason"}: per-token prompt
    logprobs (``logprobs[i-1] = log p(prompt[i] | prompt[:i])``) through
    the scheduler's chunk path — same slot pool, cache and admission
    machinery as 'generate', zero sampled tokens. Attach with
    ``KernelService(lm=Scheduler(...))``."""

    name = "score"

    def run(self, payloads: List[Dict]) -> List[Any]:
        sched = self.svc.lm
        if sched is None:
            raise ValueError(
                "score kernel needs KernelService(lm=serve.Scheduler)")
        rids = []
        for p in payloads:
            rids.extend(sched.score([np.asarray(p["prompt"], np.int32)]))
        sched.drain()
        done = [sched.results.pop(r) for r in rids]
        return [{"logprobs": c.logprobs, "reason": c.reason}
                for c in done]


_ADAPTERS = (ChainAdapter, SWAdapter, DTWAdapter, SortAdapter, SeedAdapter,
             ScanAdapter, MapperAdapter, GenerateAdapter, ScoreAdapter)


class KernelService:
    """The software Squire accelerator pool: submit heterogeneous kernel
    requests in bulk, get per-request results back in order."""

    def __init__(self, cfg: ServiceConfig = ServiceConfig(),
                 reference: Optional[np.ndarray] = None,
                 dispatcher: Optional[Dispatcher] = None,
                 lm: Optional[Any] = None,
                 tuner: Optional[Autotuner] = None):
        self.cfg = cfg
        self.dispatcher = dispatcher or Dispatcher()
        self.reference = (None if reference is None
                          else np.asarray(reference, np.int8))
        self.lm = lm            # serve.Scheduler for the 'generate' kernel
        self.tuner = tuner or Autotuner()
        self._index = None
        self._adapters: Dict[str, KernelAdapter] = {
            a.name: a(self) for a in _ADAPTERS}
        # per-kernel traffic: requests routed / bulk submits seen /
        # duplicate payloads served from a sibling's dispatch
        self.request_counts = collections.Counter(
            dict.fromkeys(self.kernels, 0))
        self.submit_count = 0
        self.deduped_requests = 0
        obs_metrics.REGISTRY.register_provider("runtime.service", self)

    @property
    def index(self):
        """Lazily-built reference minimizer index (seed/map kernels)."""
        if self._index is None:
            if self.reference is None:
                raise ValueError(
                    "seed/map kernels need KernelService(reference=...)")
            m = self.cfg.mapper
            self._index = seeding.build_index(self.reference, m.k, m.w)
        return self._index

    @property
    def kernels(self) -> Tuple[str, ...]:
        return tuple(sorted(self._adapters))

    def metrics(self) -> Dict[str, Any]:
        """Registry 'runtime.service' provider: per-kernel request
        traffic (``requests.<kernel>``) + bulk submit count."""
        out: Dict[str, Any] = {"submits": self.submit_count,
                               "deduped_requests": self.deduped_requests}
        out.update({f"requests.{k}": int(v)
                    for k, v in sorted(self.request_counts.items())})
        return out

    def stats(self) -> Dict[str, Any]:
        """Service-level introspection: registered kernels + per-kernel
        traffic counters plus, when an LM scheduler is attached, its
        pool/occupancy counters (incl. the paged allocator's block
        utilization — serve.SlotManager.stats)."""
        out: Dict[str, Any] = {"kernels": list(self.kernels),
                               **self.metrics()}
        if self.lm is not None:
            out["lm"] = self.lm.stats()
        return out

    def submit(self, requests: Sequence[Request]) -> List[Any]:
        """Run a heterogeneous batch; results align with ``requests``."""
        results: List[Any] = [None] * len(requests)
        by_kernel: Dict[str, List[int]] = {}
        for i, req in enumerate(requests):
            if req.kernel not in self._adapters:
                raise KeyError(f"unknown kernel {req.kernel!r}; "
                               f"have {self.kernels}")
            by_kernel.setdefault(req.kernel, []).append(i)
        self.submit_count += 1
        for kernel, idxs in by_kernel.items():
            self.request_counts[kernel] += len(idxs)
            got = self._adapters[kernel].run(
                [requests[i].payload for i in idxs])
            for i, res in zip(idxs, got):
                results[i] = res
        obs_sampler.tick("service.submit")
        return results
