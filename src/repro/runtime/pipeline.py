"""Double-buffered executor: host data movement overlapped with compute.

The paper's workers sit adjacent to L2 so operand delivery overlaps the
host's own progress; the runtime equivalent is pipelining the *host* work
(padding/stacking the next bucket, the data movement) against the *device*
work (the batch in flight). Two mechanisms compose:

  1. a prefetch thread pulls items from the (lazy, host-side) work
     generator so padding for bucket i+1 happens while bucket i computes;
  2. JAX async dispatch keeps up to ``depth`` launched batches in flight;
     ``jax.block_until_ready`` fences only when a result is yielded.

``run_pipelined`` preserves input order, so callers can scatter results
back to request slots positionally.
"""

from __future__ import annotations

import queue
import threading
import time
from collections import deque
from typing import Callable, Iterable, Iterator, TypeVar

import jax

from repro.obs import metrics as obs_metrics

T = TypeVar("T")
R = TypeVar("R")

_STOP = object()


def prefetched(items: Iterable[T], buffer: int = 2) -> Iterator[T]:
    """Iterate ``items`` through a background thread with a bounded queue,
    so producing the next item (host padding) overlaps consumer work.
    Exceptions in the producer re-raise at the consumer; abandoning the
    iterator (consumer raised / stopped early) stops the producer rather
    than leaving it blocked on the full queue."""
    q: "queue.Queue" = queue.Queue(maxsize=max(buffer, 1))
    stop = threading.Event()

    def put(item) -> bool:
        """Bounded put that gives up when the consumer went away."""
        while not stop.is_set():
            try:
                q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def worker():
        try:
            for it in items:
                if not put(it):
                    return
        except BaseException as e:            # propagate to consumer
            put((_STOP, e))
            return
        put((_STOP, None))

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            got = q.get()
            if isinstance(got, tuple) and len(got) == 2 \
                    and got[0] is _STOP:
                if got[1] is not None:
                    raise got[1]
                return
            yield got
    finally:
        stop.set()                            # unblock a mid-put producer


def run_pipelined(items: Iterable[T], launch: Callable[[T], R],
                  depth: int = 2, buffer: int = 2) -> Iterator[R]:
    """Launch ``launch(item)`` for each work item, keeping up to ``depth``
    results in flight; yield completed results in input order.

    ``launch`` should *dispatch* device work and return promptly (JAX's
    async dispatch does this for jitted calls); the fence happens here,
    just before the result is handed to the caller — by which time the
    next batches are already padded (prefetch thread) and launched.
    """
    # fence wall-time histogram: how long results-in-flight keep the host
    # waiting — near-zero fences mean the overlap is doing its job
    h_fence = obs_metrics.REGISTRY.histogram("runtime.pipeline.fence_ms")

    def fence(x):
        t0 = time.perf_counter()
        out = jax.block_until_ready(x)
        h_fence.observe((time.perf_counter() - t0) * 1e3)
        return out

    inflight: deque = deque()
    for item in prefetched(items, buffer=buffer):
        inflight.append(launch(item))
        while len(inflight) > max(depth, 1):
            yield fence(inflight.popleft())
    while inflight:
        yield fence(inflight.popleft())
