"""Shape bucketing — the fixed-capacity discipline behind every runtime batch.

Accelerator pipelines compile one program per input *shape*; serving
variable-length requests therefore means snapping lengths to a small set of
shape buckets, padding with sentinels that cannot perturb the true result,
and masking/unpadding on the way out. `apps/read_mapper.py` grew a private
copy of this logic (read buckets, anchor buckets, SW sentinel padding);
this module is that logic generalized so every kernel the runtime serves
shares one batcher and one compile-cache key scheme.

Two bucket policies:
  * ``linear`` — round up to a multiple of ``size`` (the read-mapper
    scheme; bounded waste ``size-1``, bucket count grows with max length).
  * ``pow2``   — round up to ``size * 2^k`` (geometric; O(log) distinct
    buckets, the usual serving choice under heavy-tailed lengths).

All helpers are host-side numpy: padding happens before dispatch, on the
host thread the pipeline overlaps with device compute (pipeline.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

Array = np.ndarray


def round_up(n: int, mult: int) -> int:
    """Smallest multiple of ``mult`` >= n (and >= mult: shapes never 0)."""
    return max(-(-n // mult), 1) * mult


def round_up_pow2(n: int, base: int) -> int:
    """Smallest ``base * 2^k`` >= n."""
    m = base
    while m < n:
        m *= 2
    return m


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """Length -> padded-length policy for one array family."""
    size: int                 # bucket quantum (the read_bucket of old)
    mode: str = "linear"      # 'linear' | 'pow2'

    def padded(self, n: int) -> int:
        if self.mode == "linear":
            return round_up(n, self.size)
        if self.mode == "pow2":
            return round_up_pow2(n, self.size)
        raise ValueError(f"unknown bucket mode: {self.mode!r}")


def pad_to(x: Array, n: int, fill) -> Array:
    """Pad 1-D ``x`` to length ``n`` with ``fill`` (identity if already n)."""
    x = np.asarray(x)
    if x.shape[0] == n:
        return x
    out = np.full((n,) + x.shape[1:], fill, dtype=x.dtype)
    out[: x.shape[0]] = x
    return out


def pad_stack(arrs: Sequence[Array], n: int, fill, dtype=None) -> Array:
    """Stack variable-length 1-D arrays into a (B, n) batch, sentinel-padded."""
    if dtype is None:
        dtype = np.asarray(arrs[0]).dtype
    out = np.full((len(arrs), n), fill, dtype=dtype)
    for i, a in enumerate(arrs):
        a = np.asarray(a, dtype=dtype)
        out[i, : a.shape[0]] = a
    return out


def lengths_of(arrs: Sequence[Array]) -> Array:
    return np.asarray([np.asarray(a).shape[0] for a in arrs], np.int32)


def valid_mask(lengths: Array, n: int) -> Array:
    """(B, n) bool mask: True on real elements, False on padding."""
    return np.arange(n)[None, :] < np.asarray(lengths)[:, None]


def unpad(stacked: Array, lengths: Array) -> List[Array]:
    """Inverse of pad_stack: slice each row back to its true length."""
    return [np.asarray(stacked[i, : int(l)])
            for i, l in enumerate(np.asarray(lengths))]


def group_by_bucket(lengths: Iterable[int], spec: BucketSpec
                    ) -> Dict[int, List[int]]:
    """Request indices grouped by padded length (one compile per key)."""
    groups: Dict[int, List[int]] = {}
    for i, n in enumerate(lengths):
        groups.setdefault(spec.padded(int(n)), []).append(i)
    return groups


def group_by_key(keys: Sequence[Tuple]) -> Dict[Tuple, List[int]]:
    """Generic grouping: indices by arbitrary hashable bucket key (multi-
    array kernels bucket on a tuple of padded shapes)."""
    groups: Dict[Tuple, List[int]] = {}
    for i, k in enumerate(keys):
        groups.setdefault(k, []).append(i)
    return groups


def shape_key(*arrays) -> Tuple:
    """Hashable compile-cache key for a tuple of arrays: (shape, dtype)*.

    jit caches by abstract value already; this key lets host-side caches
    (dispatch executables, autotune entries) share the same identity.
    """
    return tuple((tuple(np.asarray(a).shape), np.asarray(a).dtype.str)
                 for a in arrays)
