"""Block-size / worker-count autotuner with a persistent JSON cache.

The paper picks its design points (worker count, 1 KB/8 KB caches, T=64)
from design-space sweeps; ``benchmarks/fig9_blocksize.py`` reproduces the
sweep. This module closes the loop: sweep results (or live measurements)
are persisted per knob, and the runtime reads them back so a tuned box
serves with the measured-best tile/chunk/worker settings instead of the
static defaults.

Keys are flat strings, ``"<kernel>.<knob>"`` (e.g. ``"dtw.tile"``,
``"ssm.chunk"``, ``"chain.block"``). The cache file lives at
``$REPRO_AUTOTUNE_CACHE`` (default ``~/.cache/repro/autotune.json``).
"""

from __future__ import annotations

import json
import os
import re
import time
from typing import Callable, Dict, Iterable, Optional

import jax


def default_cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "autotune.json"))


class Autotuner:
    """get/put/tune over a {key: {"value", "us", "when"}} JSON cache."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_cache_path()
        self._cache: Dict[str, dict] = {}
        if os.path.exists(self.path):
            try:
                with open(self.path) as f:
                    self._cache = json.load(f)
            except (OSError, ValueError):
                self._cache = {}

    # -- cache ---------------------------------------------------------------

    def get(self, key: str, default=None):
        entry = self._cache.get(key)
        return entry["value"] if entry else default

    def get_bucketed(self, key: str, bucket: int, default=None):
        """Per-bucket knob lookup: ``<kernel>.<knob>@b<bucket>`` first,
        then the per-kernel ``<kernel>.<knob>`` entry, then ``default``.

        The paper tunes one design point per kernel; serving sees the
        same kernel at many shape buckets, and the best block/chunk moves
        with the bucket (a 64-anchor chain wants a smaller block than a
        4096-anchor one), so sweeps persist per-bucket keys and the
        service resolves through this fallback chain."""
        got = self.get(f"{key}@b{int(bucket)}")
        if got is not None:
            return got
        return self.get(key, default)

    def put(self, key: str, value, us: Optional[float] = None,
            failed: Optional[Dict[str, str]] = None,
            candidates: Optional[Dict[str, dict]] = None):
        entry = {"value": value, "us": us, "when": time.time()}
        if failed:
            entry["failed"] = failed
        if candidates:
            # per-candidate measurement records: steady-state us plus the
            # warm (first-call) us whose excess over steady is the
            # compile cost (runtime.dispatch's compile/execute split at
            # sweep time)
            entry["candidates"] = candidates
        self._cache[key] = entry
        self.save()

    def save(self):
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        # concurrent processes (a sweep fanned out over shapes) write the
        # shared cache too: under an flock (POSIX; best-effort elsewhere),
        # merge the on-disk entries under ours before renaming — another
        # process's keys survive our whole-file replace and the lock
        # closes the read-to-rename window — and use a per-pid tmp so
        # two writers can't clobber each other's half-written file.
        lock = open(f"{self.path}.lock", "w")
        try:
            try:
                import fcntl
                fcntl.flock(lock, fcntl.LOCK_EX)
            except ImportError:         # non-POSIX: merge without lock
                pass
            try:
                with open(self.path) as f:
                    merged = json.load(f)
            except (OSError, ValueError):
                merged = {}
            merged.update(self._cache)
            self._cache = merged
            tmp = f"{self.path}.{os.getpid()}.tmp"
            with open(tmp, "w") as f:
                json.dump(self._cache, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        finally:
            lock.close()                # closing drops the flock

    # -- measurement ---------------------------------------------------------

    @staticmethod
    def _measure(candidates: Dict, make_thunk: Callable, repeats: int):
        """Time every candidate; returns ``(best_v, best_us, failed,
        records)``. Failing candidates are skipped, not fatal; best_us is
        inf when every candidate failed."""
        if not isinstance(candidates, dict):
            candidates = {v: v for v in candidates}
        best_v, best_us = None, float("inf")
        failed: Dict[str, str] = {}
        records: Dict[str, dict] = {}
        for label, cand in candidates.items():
            try:
                thunk = make_thunk(cand)
                t0 = time.perf_counter()
                jax.block_until_ready(thunk())      # warm the compile cache
                warm_us = (time.perf_counter() - t0) * 1e6
                ts = []
                for _ in range(repeats):
                    t0 = time.perf_counter()
                    jax.block_until_ready(thunk())
                    ts.append(time.perf_counter() - t0)
            except Exception as e:                  # bad candidate: skip
                failed[str(label)] = f"{type(e).__name__}: {e}"[:200]
                continue
            us = sorted(ts)[len(ts) // 2] * 1e6
            # warm - steady ~= one-time compile cost: choosing a candidate
            # by steady-state speed alone can pick one whose compile never
            # amortizes at low traffic, so the record keeps both
            records[str(label)] = {"us": round(us, 1),
                                   "warm_us": round(warm_us, 1),
                                   "compile_us": round(
                                       max(warm_us - us, 0.0), 1)}
            if us < best_us:
                best_v, best_us = cand, us
        return best_v, best_us, failed, records

    def tune(self, key: str, candidates: Dict, make_thunk: Callable,
             repeats: int = 3, force: bool = False):
        """Measure ``make_thunk(candidate)()`` per candidate, persist and
        return the fastest candidate value (must be JSON-serializable).
        Cached unless ``force``.

        candidates: a {label: value} dict or an iterable of values.

        A candidate whose thunk raises (e.g. a block size incompatible
        with the bucket shape) is SKIPPED, not fatal — the sweep still
        returns the fastest of the survivors, and the failures are
        recorded in the cache entry under ``"failed"`` for inspection.
        Only when *every* candidate fails does tune raise.
        """
        if not force:
            got = self.get(key)
            if got is not None:
                return got
        best_v, best_us, failed, records = self._measure(
            candidates, make_thunk, repeats)
        if best_us == float("inf"):
            raise RuntimeError(
                f"autotune {key!r}: every candidate failed: {failed}")
        self.put(key, best_v, us=best_us, failed=failed or None,
                 candidates=records)
        return best_v

    def retune(self, key: str, candidates: Dict, make_thunk: Callable,
               repeats: int = 3, min_improvement: float = 0.02):
        """Bounded ONLINE re-sweep (the obs AutotuneController's entry
        point): re-measure the candidates and persist the winner only if
        it beats the incumbent entry's recorded ``us`` by at least
        ``min_improvement`` (relative) — a live system's knob never
        regresses from a noisy re-measurement. Returns ``(value,
        improved)``: the knob to use and whether it changed.

        Unlike :meth:`tune`, a fully-failing re-sweep does NOT raise —
        the serve keeps its incumbent knob and the failure is recorded
        in the cache entry under ``"resweep_failed"``.
        """
        incumbent = self._cache.get(key)
        best_v, best_us, failed, records = self._measure(
            candidates, make_thunk, repeats)
        if best_us == float("inf"):
            if incumbent is not None:
                incumbent = dict(incumbent)
                incumbent["resweep_failed"] = failed
                self._cache[key] = incumbent
                self.save()
                return incumbent["value"], False
            return None, False
        inc_us = incumbent.get("us") if incumbent else None
        if incumbent is not None and inc_us is not None and \
                best_us >= inc_us * (1.0 - min_improvement):
            return incumbent["value"], False        # keep the incumbent
        self.put(key, best_v, us=best_us, failed=failed or None,
                 candidates=records)
        return best_v, True


# --------------------------------------------------------------------------
# fig9 bridge: seed the cache from the design-space sweep's CSV rows
# --------------------------------------------------------------------------

_FIG9_ROW = re.compile(r"^fig9\.(?P<kernel>\w+)\.(?P<knob>[a-z]+)"
                       r"(?P<value>\d+)(?P<bucket>@b\d+)?,(?P<us>[0-9.]+),")


def seed_from_fig9(rows: Iterable[str],
                   path: Optional[str] = None) -> Dict[str, int]:
    """Parse ``fig9.<kernel>.<knob><value>[@b<bucket>],<us>,...`` rows and
    persist the fastest value per ``<kernel>.<knob>[@b<bucket>]`` knob.

    Called by benchmarks/fig9_blocksize.py after its sweep, so running the
    paper's design-space exploration tunes the serving runtime for free.
    Bucketed rows (``@b<n>`` suffix — chain block / sort chunks swept per
    shape bucket) land on per-bucket keys that
    ``Autotuner.get_bucketed`` resolves ahead of the per-kernel entry.
    """
    best: Dict[str, tuple] = {}
    for row in rows:
        m = _FIG9_ROW.match(row)
        if not m:
            continue
        key = f"{m['kernel']}.{m['knob']}{m['bucket'] or ''}"
        us = float(m["us"])
        if key not in best or us < best[key][1]:
            best[key] = (int(m["value"]), us)
    tuner = Autotuner(path)
    for key, (value, us) in best.items():
        tuner.put(key, value, us=us)
    return {k: v for k, (v, _) in best.items()}
