"""Worker-pool dispatcher — "one Squire accelerator pool per core", in JAX.

The paper attaches a pool of low-overhead workers to each host core; kernel
calls are farmed to the pool instead of running on the core. Here the pool
is the device mesh: a bucket's batch of same-shape requests is ``vmap``-ed
(the fine-grain parallel workers) and, when a mesh is installed, the batch
axis is mapped over devices with ``jax.shard_map`` (one pool per device,
mirroring ``repro.sharding``'s data axis). On the single-CPU container the
shard_map path is degenerate but identical in results, so tests exercise it
and production meshes (``repro.launch.mesh``) drop in unchanged.

Two entry points:
  * ``run(fn, leaves)``     — batched dispatch: jit(vmap(fn)) [+ shard_map],
    compiled once per (fn, in_axes, shapes) — the per-bucket compile cache.
  * ``run_one(fn, leaves)`` — single-request dispatch with the same cache
    discipline (used by ReadMapper's per-read path).

Every dispatch is timed and classified (did this call grow the compile
cache?) into the metrics registry: ``runtime.dispatch.cache_hits`` /
``cache_misses`` counters and ``compile_ms`` / ``execute_ms`` histograms
process-wide, plus a per-bucket split under
``runtime.dispatch.bucket.<fn>[b<batch>].*`` — the numbers the Autotuner
stamps into its candidate records and fig_runtime reports. With a Tracer
enabled each ``run`` also records a ``bucket-dispatch`` span on the
dispatcher track.
"""

from __future__ import annotations

import functools
import time
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.obs import metrics as obs_metrics
from repro.obs import sampler as obs_sampler
from repro.obs import trace as obs_trace

try:                                    # jax >= 0.6 re-exports at top level
    _shard_map = jax.shard_map
except AttributeError:                  # 0.4.x experimental home
    from jax.experimental.shard_map import shard_map as _shard_map


# Canonical constructor lives in repro.launch.mesh (validates worker count
# against available devices up front); re-exported here for callers that
# only know the runtime layer.
from repro.launch.mesh import make_worker_mesh  # noqa: E402,F401


# Process-wide compile caches: the stage fns are already identity-stable
# (module-level / lru_cache factories), so every Dispatcher instance —
# each ReadMapper, each KernelService — shares one compiled program per
# (fn, in_axes, mesh) instead of retracing per instance.

@functools.lru_cache(maxsize=None)
def _jit_single(fn):
    return obs_trace.instrumented_jit(
        jax.jit(fn), name=getattr(fn, "__name__", "fn"),
        prefix="runtime.dispatch")


@functools.lru_cache(maxsize=None)
def _jit_batched(fn, in_axes: Tuple, mesh: Optional[Mesh], axis):
    vfn = jax.vmap(fn, in_axes=in_axes)
    if mesh is not None:
        specs = tuple(P(axis) if ax == 0 else P() for ax in in_axes)
        vfn = _shard_map(vfn, mesh=mesh, in_specs=specs,
                         out_specs=P(axis))
    return jax.jit(vfn)


class _BucketStats:
    """Per-bucket dispatch accounting: ``<fn>[b<batch>]`` -> hit/miss
    counts and compile/execute wall-ms totals. Registry provider
    ``runtime.dispatch.bucket`` — what the Autotuner's candidate records
    and fig_runtime's dispatch table read."""

    def __init__(self):
        self.buckets: Dict[str, Dict[str, Any]] = {}

    def record(self, key: str, compiled: bool, ms: float):
        b = self.buckets.setdefault(
            key, {"hits": 0, "misses": 0,
                  "compile_ms": 0.0, "execute_ms": 0.0})
        if compiled:
            b["misses"] += 1
            b["compile_ms"] += ms
        else:
            b["hits"] += 1
            b["execute_ms"] += ms

    def metrics(self) -> Dict[str, Any]:
        return {f"{key}.{k}": (round(v, 3) if isinstance(v, float) else v)
                for key, b in sorted(self.buckets.items())
                for k, v in b.items()}

    def clear(self):
        self.buckets.clear()


#: process-wide per-bucket dispatch stats (cleared by benchmarks that
#: want a per-run table)
BUCKET_STATS = _BucketStats()
obs_metrics.REGISTRY.register_provider("runtime.dispatch.bucket",
                                       BUCKET_STATS)


class Dispatcher:
    """Batched kernel dispatch over an optional device mesh.

    ``mesh=None`` (the default) runs jit(vmap(fn)) on the default device;
    with a 1-D mesh the vmapped program is shard_mapped over ``axis`` and
    the batch is padded to a multiple of the worker count (padding rows
    repeat the last request and are sliced off — results are positionally
    identical to the vmap path).
    """

    def __init__(self, mesh: Optional[Mesh] = None, axis: Optional[str] = None):
        self.mesh = mesh
        self.axis = axis or (mesh.axis_names[0] if mesh is not None else None)

    @property
    def num_workers(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.devices.shape[0]

    # -- dispatch ------------------------------------------------------------

    def run(self, fn, leaves: Sequence, in_axes: Optional[Sequence] = None):
        """Dispatch one bucket batch. ``leaves`` are positional args of the
        single-request ``fn``; batched leaves carry the batch on axis 0,
        shared leaves (in_axes entry None) are broadcast to every worker.

        Returns fn's outputs with a leading batch axis (device arrays —
        dispatch is async; the pipeline fences with block_until_ready).
        """
        leaves = tuple(leaves)
        axes = tuple(0 for _ in leaves) if in_axes is None else tuple(in_axes)
        bsz = next(np.asarray(l).shape[0]
                   for l, ax in zip(leaves, axes) if ax == 0)
        w = self.num_workers
        pad = (-bsz) % w
        if pad:
            leaves = tuple(
                np.concatenate([np.asarray(l),
                                np.repeat(np.asarray(l)[-1:], pad, axis=0)])
                if ax == 0 else l
                for l, ax in zip(leaves, axes))
        jfn = _jit_batched(fn, axes, self.mesh, self.axis)
        # qualname keeps factory closures apart ('_sort_fn.run' vs
        # '_scan_fn.run' — plain __name__ is 'run' for both)
        name = getattr(fn, "__qualname__",
                       getattr(fn, "__name__", "fn")).replace(
                           ".<locals>", "")
        cache_size = getattr(jfn, "_cache_size", None)
        n0 = cache_size() if cache_size is not None else -1
        t0 = time.perf_counter()
        out = jfn(*leaves)
        t1 = time.perf_counter()
        compiled = cache_size is not None and cache_size() > n0
        reg = obs_metrics.REGISTRY
        ms = (t1 - t0) * 1e3
        if compiled:
            reg.counter("runtime.dispatch.cache_misses").inc()
            reg.histogram("runtime.dispatch.compile_ms").observe(ms)
        else:
            reg.counter("runtime.dispatch.cache_hits").inc()
            reg.histogram("runtime.dispatch.execute_ms").observe(ms)
        BUCKET_STATS.record(f"{name}[b{bsz + pad}]", compiled, ms)
        obs_trace.get_tracer().complete(
            "bucket-dispatch", "dispatcher", t0, t1, fn=name,
            batch=bsz + pad, workers=w, compiled=compiled)
        obs_sampler.tick("dispatch.run")
        if pad:
            out = jax.tree_util.tree_map(lambda x: x[:bsz], out)
        return out

    def run_one(self, fn, leaves: Sequence, jit: bool = True):
        """Single-request dispatch; jit-compiled and cached per fn unless
        ``jit=False`` (tile-jitted eager schedules manage their own cache)."""
        if not jit:
            return fn(*leaves)
        return _jit_single(fn)(*leaves)
