"""Worker-pool dispatcher — "one Squire accelerator pool per core", in JAX.

The paper attaches a pool of low-overhead workers to each host core; kernel
calls are farmed to the pool instead of running on the core. Here the pool
is the device mesh: a bucket's batch of same-shape requests is ``vmap``-ed
(the fine-grain parallel workers) and, when a mesh is installed, the batch
axis is mapped over devices with ``jax.shard_map`` (one pool per device,
mirroring ``repro.sharding``'s data axis). On the single-CPU container the
shard_map path is degenerate but identical in results, so tests exercise it
and production meshes (``repro.launch.mesh``) drop in unchanged.

Two entry points:
  * ``run(fn, leaves)``     — batched dispatch: jit(vmap(fn)) [+ shard_map],
    compiled once per (fn, in_axes, shapes) — the per-bucket compile cache.
  * ``run_one(fn, leaves)`` — single-request dispatch with the same cache
    discipline (used by ReadMapper's per-read path).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import numpy as np

import jax
from jax.sharding import Mesh, PartitionSpec as P

try:                                    # jax >= 0.6 re-exports at top level
    _shard_map = jax.shard_map
except AttributeError:                  # 0.4.x experimental home
    from jax.experimental.shard_map import shard_map as _shard_map


def make_worker_mesh(num_workers: Optional[int] = None,
                     axis: str = "workers") -> Mesh:
    """1-D mesh over the first ``num_workers`` local devices (default all)."""
    devs = jax.devices()
    n = len(devs) if num_workers is None else min(num_workers, len(devs))
    return Mesh(np.asarray(devs[:n]), (axis,))


# Process-wide compile caches: the stage fns are already identity-stable
# (module-level / lru_cache factories), so every Dispatcher instance —
# each ReadMapper, each KernelService — shares one compiled program per
# (fn, in_axes, mesh) instead of retracing per instance.

@functools.lru_cache(maxsize=None)
def _jit_single(fn):
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _jit_batched(fn, in_axes: Tuple, mesh: Optional[Mesh], axis):
    vfn = jax.vmap(fn, in_axes=in_axes)
    if mesh is not None:
        specs = tuple(P(axis) if ax == 0 else P() for ax in in_axes)
        vfn = _shard_map(vfn, mesh=mesh, in_specs=specs,
                         out_specs=P(axis))
    return jax.jit(vfn)


class Dispatcher:
    """Batched kernel dispatch over an optional device mesh.

    ``mesh=None`` (the default) runs jit(vmap(fn)) on the default device;
    with a 1-D mesh the vmapped program is shard_mapped over ``axis`` and
    the batch is padded to a multiple of the worker count (padding rows
    repeat the last request and are sliced off — results are positionally
    identical to the vmap path).
    """

    def __init__(self, mesh: Optional[Mesh] = None, axis: Optional[str] = None):
        self.mesh = mesh
        self.axis = axis or (mesh.axis_names[0] if mesh is not None else None)

    @property
    def num_workers(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.devices.shape[0]

    # -- dispatch ------------------------------------------------------------

    def run(self, fn, leaves: Sequence, in_axes: Optional[Sequence] = None):
        """Dispatch one bucket batch. ``leaves`` are positional args of the
        single-request ``fn``; batched leaves carry the batch on axis 0,
        shared leaves (in_axes entry None) are broadcast to every worker.

        Returns fn's outputs with a leading batch axis (device arrays —
        dispatch is async; the pipeline fences with block_until_ready).
        """
        leaves = tuple(leaves)
        axes = tuple(0 for _ in leaves) if in_axes is None else tuple(in_axes)
        bsz = next(np.asarray(l).shape[0]
                   for l, ax in zip(leaves, axes) if ax == 0)
        w = self.num_workers
        pad = (-bsz) % w
        if pad:
            leaves = tuple(
                np.concatenate([np.asarray(l),
                                np.repeat(np.asarray(l)[-1:], pad, axis=0)])
                if ax == 0 else l
                for l, ax in zip(leaves, axes))
        out = _jit_batched(fn, axes, self.mesh, self.axis)(*leaves)
        if pad:
            out = jax.tree_util.tree_map(lambda x: x[:bsz], out)
        return out

    def run_one(self, fn, leaves: Sequence, jit: bool = True):
        """Single-request dispatch; jit-compiled and cached per fn unless
        ``jit=False`` (tile-jitted eager schedules manage their own cache)."""
        if not jit:
            return fn(*leaves)
        return _jit_single(fn)(*leaves)
