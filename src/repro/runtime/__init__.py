"""repro.runtime — batched kernel-dispatch runtime (the software Squire
accelerator pool).

The paper attaches low-overhead worker pools to host cores so dependency-
bound kernels accelerate behind one dispatch interface; this package is
that layer for the JAX reproduction, and the entry point for running
kernel work at traffic scale:

  * bucketing  — shape buckets, sentinel padding, pad/mask/unpad
  * dispatch   — vmap worker pools + shard_map over the device mesh
  * service    — KernelService: heterogeneous submit(requests) -> results
  * pipeline   — double-buffered host/device overlap
  * autotune   — persistent block-size/worker tuner (fig9-seeded)
"""

from repro.runtime.autotune import Autotuner, seed_from_fig9
from repro.runtime.bucketing import (BucketSpec, group_by_bucket,
                                     group_by_key, lengths_of, pad_stack,
                                     pad_to, round_up, round_up_pow2,
                                     shape_key, unpad, valid_mask)
from repro.runtime.dispatch import Dispatcher, make_worker_mesh
from repro.runtime.pipeline import prefetched, run_pipelined

_SERVICE_NAMES = ("KernelService", "Request", "ServiceConfig")


def __getattr__(name):
    # service imports apps.read_mapper, which imports runtime.bucketing;
    # loading it lazily keeps `import repro.apps` acyclic.
    if name in _SERVICE_NAMES:
        from repro.runtime import service
        return getattr(service, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "Autotuner", "seed_from_fig9",
    "BucketSpec", "group_by_bucket", "group_by_key", "lengths_of",
    "pad_stack", "pad_to", "round_up", "round_up_pow2", "shape_key",
    "unpad", "valid_mask",
    "Dispatcher", "make_worker_mesh",
    "prefetched", "run_pipelined",
    "KernelService", "Request", "ServiceConfig",
]
