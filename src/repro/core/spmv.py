"""Chunk-parallel sparse matrix-vector product (paper Fig. 1c).

SpMV is the paper's third motivating kernel: "sorting and SpMV coarse-
grain tasks could be further parallelized by processing chunks of the
array or independent rows of the matrix in parallel. However, this is not
efficient [on SIMD] due to data-dependent irregular patterns and the fact
that SIMD gather/scatter memory operations are not efficient."

The Squire mapping: rows are the dependency-free fine-grain units; the
irregularity (variable nonzeros per row) is what defeats lockstep SIMD.
The TPU adaptation replaces dynamic row loops with the standard fixed-
shape decomposition:

  * **ELL-style worker chunks** (`spmv_chunked`) — rows are padded to the
    chunk's max nonzeros (the capacity-mask discipline used everywhere
    else in this repo) and each worker-chunk computes a dense
    gather+reduce; load imbalance is contained per chunk, exactly like
    Squire assigning row blocks to workers.
  * **segment-sum form** (`spmv_segsum`) — a flat COO gather + masked
    segment reduction; the segment boundaries are the 1-D handoff
    (monotone row ids make the reduction a scan over the global counter).

Both are exact vs the dense oracle for any chunking (property-tested).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import numpy as np

import jax
import jax.numpy as jnp

Array = jnp.ndarray


class CSR(NamedTuple):
    """Fixed-shape CSR: indptr (n+1,), indices (nnz,), data (nnz,)."""
    indptr: Array
    indices: Array
    data: Array
    n_cols: int


def random_csr(n_rows: int, n_cols: int, density: float, seed: int = 0,
               skew: float = 0.0) -> CSR:
    """Synthetic sparse matrix; ``skew`` > 0 gives power-law row lengths
    (the load imbalance the paper calls out)."""
    rng = np.random.default_rng(seed)
    base = max(1, int(n_cols * density))
    if skew > 0:
        lens = np.minimum(
            (base * rng.pareto(1.0 + 1.0 / max(skew, 1e-6), n_rows) +
             1).astype(np.int64), n_cols)
    else:
        lens = np.full(n_rows, base)
    indptr = np.zeros(n_rows + 1, np.int32)
    indptr[1:] = np.cumsum(lens)
    nnz = int(indptr[-1])
    indices = np.concatenate(
        [np.sort(rng.choice(n_cols, size=l, replace=False)) for l in lens])
    data = rng.normal(size=nnz).astype(np.float32)
    return CSR(jnp.asarray(indptr), jnp.asarray(indices.astype(np.int32)),
               jnp.asarray(data), n_cols)


def to_dense(m: CSR, n_rows: int) -> np.ndarray:
    out = np.zeros((n_rows, m.n_cols), np.float32)
    indptr = np.asarray(m.indptr)
    idx, dat = np.asarray(m.indices), np.asarray(m.data)
    for r in range(n_rows):
        for j in range(indptr[r], indptr[r + 1]):
            out[r, idx[j]] += dat[j]
    return out


# --------------------------------------------------------------------------
# ELL-style chunked execution (the worker partitioning)
# --------------------------------------------------------------------------

def _ell_pack(m: CSR, n_rows: int, num_chunks: int
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Host-side: rows -> (chunk, row, slot) fixed-capacity gather plan."""
    indptr = np.asarray(m.indptr)
    lens = np.diff(indptr)
    rows_per = -(-n_rows // num_chunks)
    width = 0
    for c in range(num_chunks):
        lo, hi = c * rows_per, min((c + 1) * rows_per, n_rows)
        if lo < hi:
            width = max(width, int(lens[lo:hi].max()))
    width = max(width, 1)
    cols = np.zeros((num_chunks, rows_per, width), np.int32)
    vals = np.zeros((num_chunks, rows_per, width), np.float32)
    idx, dat = np.asarray(m.indices), np.asarray(m.data)
    for c in range(num_chunks):
        for r in range(rows_per):
            row = c * rows_per + r
            if row >= n_rows:
                continue
            lo, hi = indptr[row], indptr[row + 1]
            cols[c, r, :hi - lo] = idx[lo:hi]
            vals[c, r, :hi - lo] = dat[lo:hi]
    return cols, vals, lens, rows_per


def spmv_chunked(m: CSR, x: Array, n_rows: int, num_chunks: int = 8
                 ) -> Array:
    """Worker-chunked SpMV: each chunk is a dense (rows_per, width)
    gather-multiply-reduce; zero padding makes irregularity exact."""
    cols, vals, _, rows_per = _ell_pack(m, n_rows, num_chunks)

    def chunk_fn(cc, vv):
        return jnp.sum(vv * x[cc], axis=-1)           # (rows_per,)

    y = jax.vmap(chunk_fn)(jnp.asarray(cols), jnp.asarray(vals))
    return y.reshape(-1)[:n_rows]


# --------------------------------------------------------------------------
# segment-sum form (flat COO; the 1-D handoff formulation)
# --------------------------------------------------------------------------

def spmv_segsum(m: CSR, x: Array, n_rows: int) -> Array:
    """products = data * x[indices]; y = segment_sum by row id."""
    nnz = m.data.shape[0]
    row_ids = jnp.searchsorted(m.indptr, jnp.arange(nnz, dtype=jnp.int32),
                               side="right") - 1
    prod = m.data * x[m.indices]
    return jax.ops.segment_sum(prod, row_ids, num_segments=n_rows)
