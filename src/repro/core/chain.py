"""Minimap2 chain kernel (paper §III-B, Algs. 2-3) — 1-D banded max-plus DP.

    f(i) = max( w_i,  max_{i-T <= j < i} [ f(j) + alpha(i,j) - beta(i,j) ] )

The paper's two software transformations are reproduced exactly:

  1. *Loop fission* (Alg. 3): the match-up scores S[i, t] = alpha - beta for
     t = i - j in [1, T] are dependency-free -> computed as one dense
     (N, T) pass (`chain_scores`). Only the tiny max-plus recurrence over
     f remains serial.
  2. *Band truncation*: T = 5000 -> 64 (validated in benchmarks/fig_band).

Execution modes for the serial part:
  * 'sequential'  — lax.scan with a (T,) ring carry (single-worker).
  * 'fission'     — the Squire version: scores precomputed in parallel,
                    scan consumes a row per step (vectorized max).
                    [identical schedule; kept for benchmark clarity]
  * 'blocked'     — beyond-paper: band-to-band tropical transfer matrices
                    per block composed with an associative scan; depth
                    O(B + log(N/B)) instead of O(N). Exact, but each block
                    composition is a (T x T) max-plus matmul, so it pays off
                    for small T — measured in benchmarks/fig7_sync.py.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.semiring import MAXPLUS

Array = jnp.ndarray

NEG = jnp.float32(-1e18)


class ChainParams(NamedTuple):
    kmer: int = 15          # anchor width (w_i and alpha cap)
    max_dist: int = 5000    # max reference/query span of a match-up
    bandwidth: int = 500    # max |dq - dr| (gap)
    gap_scale: float = 0.01


def chain_scores(q: Array, r: Array, T: int,
                 params: ChainParams = ChainParams(),
                 anchor_valid: Array | None = None) -> Array:
    """Fission phase (Alg. 3 lines 4-5): dense (N, T) match-up scores.

    q, r: (N,) anchor query/reference positions, sorted by r.
    S[i, t] is the score of chaining anchor i after anchor j = i - t;
    -inf where invalid (out of range / over band / negative advance).
    ``anchor_valid``: optional (N,) bool — padding anchors (fixed-capacity
    pipelines) score -inf in both roles.
    Fully dependency-free: this is the work Squire farms to its workers and
    the MXU/VPU consumes as one dense pass.
    """
    n = q.shape[0]
    idx = jnp.arange(n)[:, None]                  # (N, 1)
    t = jnp.arange(1, T + 1)[None, :]             # (1, T)
    j = idx - t                                   # predecessor index
    valid = j >= 0
    jc = jnp.clip(j, 0, n - 1)

    dq = q[:, None] - q[jc]
    dr = r[:, None] - r[jc]
    gap = jnp.abs(dq - dr).astype(jnp.float32)

    alpha = jnp.minimum(jnp.minimum(dq, dr),
                        params.kmer).astype(jnp.float32)
    beta = (params.gap_scale * params.kmer * gap
            + 0.5 * jnp.log2(gap + 1.0))

    ok = (valid & (dq > 0) & (dr >= 0)
          & (dq <= params.max_dist) & (dr <= params.max_dist)
          & (gap <= params.bandwidth))
    if anchor_valid is not None:
        ok &= anchor_valid[:, None] & anchor_valid[jc]
    return jnp.where(ok, alpha - beta, NEG)


def _ring_to_f(scores_row: Array, ring: Array) -> Array:
    """candidates for f(i): S[i, t] + f(i - t); ring[t-1] = f(i-t)."""
    return scores_row + ring


def chain_sequential(scores: Array, w: Array) -> Tuple[Array, Array]:
    """Serial consumption phase. scores: (N, T); w: (N,) anchor self-scores.

    Returns (f: (N,), pred_offset: (N,) int32 in [0, T]; 0 = chain start).
    """
    n, T = scores.shape

    def step(ring, si_wi):
        si, wi = si_wi
        cand = _ring_to_f(si, ring)
        best = jnp.max(cand)
        t_best = jnp.argmax(cand).astype(jnp.int32) + 1
        fi = jnp.maximum(best, wi)
        off = jnp.where(best >= wi, t_best, 0)
        ring = jnp.concatenate([fi[None], ring[:-1]])  # f(i-1) at slot 0
        return ring, (fi, off)

    ring0 = jnp.full((T,), NEG)
    _, (f, off) = jax.lax.scan(step, ring0, (scores, w))
    return f, off


def chain_blocked(scores: Array, w: Array, block: int = 16
                  ) -> Tuple[Array, Array]:
    """Beyond-paper mode: tropical block-transfer associative scan.

    State v_i = [f(i-1), ..., f(i-T)]. One step is the tropical affine map
      v' = M_i (x) v (+) c_i,
    with M_i row 0 = scores[i] (new f via max-plus dot), rows 1.. = shift,
    and c_i = [w_i, -inf, ...]. Blocks of `block` steps are composed
    sequentially into (T x T) transfer matrices — *in parallel across
    blocks* — then an associative scan stitches block boundary states.
    Exact; preds recovered by a final parallel re-evaluation.
    """
    n, T = scores.shape
    pad = (-n) % block
    if pad:
        scores = jnp.concatenate(
            [scores, jnp.full((pad, T), NEG)], axis=0)
        w = jnp.concatenate([w, jnp.full((pad,), NEG)], axis=0)
    nb = scores.shape[0] // block

    eye = jnp.where(jnp.eye(T, dtype=bool), 0.0, NEG)          # tropical I
    shift = jnp.where(jnp.eye(T, k=-1, dtype=bool), 0.0, NEG)  # v'[k]=v[k-1]

    def step_matrix(si, wi):
        m = shift.at[0, :].set(si)           # row 0: new f from band
        c = jnp.full((T,), NEG).at[0].set(wi)
        return m, c

    def compose(mc1, mc2):
        """apply mc1 then mc2 (tropical affine composition)."""
        m1, c1 = mc1
        m2, c2 = mc2
        m = MAXPLUS.matmul(m2, m1)
        c = jnp.maximum(MAXPLUS.matmul(m2, c1[:, None])[:, 0], c2)
        return m, c

    sc_b = scores.reshape(nb, block, T)
    w_b = w.reshape(nb, block)

    def block_transfer(sb, wb):
        def body(mc, sw):
            return compose(mc, step_matrix(*sw)), None
        (m, c), _ = jax.lax.scan(body, (eye, jnp.full((T,), NEG)), (sb, wb))
        return m, c

    bm, bc = jax.vmap(block_transfer)(sc_b, w_b)      # parallel across blocks

    pm, pc = jax.lax.associative_scan(
        lambda x, y: jax.vmap(compose)(x, y), (bm, bc), axis=0)
    v0 = jnp.full((T,), NEG)
    v_in = jnp.concatenate(
        [v0[None],
         jnp.maximum(MAXPLUS.matmul(pm[:-1], v0[None, :, None])[..., 0],
                     pc[:-1])], axis=0)               # state entering block b

    def replay(vin, sb, wb):
        def body(v, sw):
            si, wi = sw
            cand = si + v
            best = jnp.max(cand)
            t_best = jnp.argmax(cand).astype(jnp.int32) + 1
            fi = jnp.maximum(best, wi)
            off = jnp.where(best >= wi, t_best, 0)
            v = jnp.concatenate([fi[None], v[:-1]])
            return v, (fi, off)
        _, (f, off) = jax.lax.scan(body, vin, (sb, wb))
        return f, off

    f, off = jax.vmap(replay)(v_in, sc_b, w_b)        # parallel re-evaluation
    f = f.reshape(-1)[:n]
    off = off.reshape(-1)[:n]
    return f, off


def chain_anchors(q: Array, r: Array, T: int = 64,
                  params: ChainParams = ChainParams(),
                  mode: str = "fission", block: int = 16,
                  anchor_valid: Array | None = None):
    """Full chain kernel. Returns (f, pred) with pred[i] in [-1, i)."""
    n = q.shape[0]
    w = jnp.full((n,), float(params.kmer), jnp.float32)
    if anchor_valid is not None:
        w = jnp.where(anchor_valid, w, NEG)
    scores = chain_scores(q, r, T, params, anchor_valid=anchor_valid)
    if mode in ("sequential", "fission"):
        f, off = chain_sequential(scores, w)
    elif mode == "blocked":
        f, off = chain_blocked(scores, w, block=block)
    else:
        raise ValueError(f"unknown chain mode: {mode!r}")
    pred = jnp.where(off > 0, jnp.arange(n) - off, -1)
    return f, pred


def chain_ref_unbanded(q: np.ndarray, r: np.ndarray,
                       params: ChainParams = ChainParams(),
                       T: int = 5000):
    """Pure-numpy oracle with arbitrary T (used to validate T=64)."""
    n = len(q)
    f = np.zeros(n, np.float64)
    pred = np.full(n, -1, np.int64)
    for i in range(n):
        best, bj = float(params.kmer), -1
        lo = max(0, i - T)
        for j in range(i - 1, lo - 1, -1):
            dq, dr = q[i] - q[j], r[i] - r[j]
            if dq <= 0 or dr < 0 or dq > params.max_dist \
                    or dr > params.max_dist:
                continue
            g = abs(int(dq) - int(dr))
            if g > params.bandwidth:
                continue
            alpha = min(dq, dr, params.kmer)
            beta = params.gap_scale * params.kmer * g + 0.5 * np.log2(g + 1.0)
            sc = f[j] + alpha - beta
            if sc > best:
                best, bj = sc, j
        f[i] = best
        pred[i] = bj
    return f, pred


def backtrack(f: np.ndarray, pred: np.ndarray, min_score: float = 40.0):
    """Host-side chain extraction (paper's backtracking pass)."""
    order = np.argsort(-f)
    used = np.zeros(len(f), bool)
    chains = []
    for i in order:
        if f[i] < min_score:
            break
        if used[i]:
            continue
        node, members = int(i), []
        while node >= 0 and not used[node]:
            used[node] = True
            members.append(node)
            node = int(pred[node])
        if len(members) >= 2:
            chains.append((float(f[i]), members[::-1]))
    return chains
